"""Warm-state handoff + SLO-driven autoscaler: a handed-off instance is
byte-identical to the source's warm state, a drained node's ledger returns
to pre-restore residency, in-flight work always completes before handoff,
and the control loop grows/shrinks the fleet on sustained signal only."""
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import lm
from repro.serve.autoscale import AutoScaler, ServiceSLO, SLOMonitor
from repro.serve.cluster import ClusterRouter, FunctionCatalog
from repro.serve.handoff import handoff_warm, wait_idle_warm
from repro.serve.instance import InstanceState
from repro.serve.invocation import QosClass
from repro.serve.node import FixedTTLPolicy, InvokeResult, NodeScheduler

ARCH = "qwen1.5-0.5b"
PROMPT = np.array([[3, 1, 4, 1, 5, 9]], dtype=np.int32)


@pytest.fixture(scope="module")
def catalog_with_zoo(tmp_path_factory):
    d = tmp_path_factory.mktemp("hzoo")
    cfg = get_config(ARCH).reduced()
    catalog = FunctionCatalog()
    for i, fname in enumerate(["hf-a", "hf-b", "hf-c"]):
        params = lm.init_params(cfg, jax.random.PRNGKey(60 + i), jnp.float32)
        catalog.publish(fname, cfg, params, str(d), warm_ttl_s=3600.0,
                        formats=("jif",))
    # compile-cache warmup through a throwaway node
    node = NodeScheduler(registry=catalog.registry)
    node.invoke("hf-a", PROMPT, max_new_tokens=2, mode="spice_sync", cfg=cfg)
    return catalog, cfg, str(d)


def _router(catalog, n=2, **kwargs):
    nodes = [
        NodeScheduler(registry=catalog.registry, keepalive=FixedTTLPolicy(3600.0))
        for _ in range(n)
    ]
    return ClusterRouter(catalog, nodes, **kwargs)


def _leaves(state):
    flat, _ = jax.tree.flatten(state)
    return [np.asarray(a) for a in flat]


def _other(router, name):
    return next(n.name for n in router.nodes if n.name != name)


# ------------------------------------------------------------ the handoff
def test_handoff_byte_identical_and_reroutes(catalog_with_zoo, tmp_path):
    catalog, cfg, _ = catalog_with_zoo
    router = _router(catalog)
    ref = router.invoke("hf-a", PROMPT, max_new_tokens=3, mode="spice", cfg=cfg)
    assert ref.cold
    src_name, dst_name = ref.node, _other(router, ref.node)
    src, dst = router.node(src_name), router.node(dst_name)
    src_leaves = _leaves(src.warm_state("hf-a"))

    hs = handoff_warm(router, "hf-a", src_name, dst_name,
                      handoff_dir=str(tmp_path), cfg=cfg)
    assert hs.ok, hs.reason

    # byte-identity: every leaf of the successor's warm tree equals the
    # source's pre-handoff tree
    dst_leaves = _leaves(dst.warm_state("hf-a"))
    assert len(dst_leaves) == len(src_leaves) > 0
    for a, b in zip(src_leaves, dst_leaves):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(a, b)

    # the move is an infrastructure transfer, not a demand cold start
    assert dst.stats["cold_starts"] == 0
    assert dst.stats["speculative_restores"] == 1
    assert src.instance("hf-a").state is InstanceState.EVICTED
    assert router.replicas("hf-a") == [dst_name]

    # the next request is warm ON THE SUCCESSOR, with identical tokens
    r = router.invoke("hf-a", PROMPT, max_new_tokens=3, mode="spice", cfg=cfg)
    assert not r.cold and r.node == dst_name
    np.testing.assert_array_equal(r.tokens, ref.tokens)
    router.audit()
    router.close()


def test_handoff_delta_is_dirty_state_only(catalog_with_zoo, tmp_path):
    """Warm generation is read-only over the restored tree, so the handoff
    image's private payload is a sliver of the full state."""
    catalog, cfg, _ = catalog_with_zoo
    router = _router(catalog)
    r = router.invoke("hf-b", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
    hs = handoff_warm(router, "hf-b", r.node, _other(router, r.node),
                      handoff_dir=str(tmp_path), cfg=cfg)
    assert hs.ok, hs.reason
    assert hs.total_bytes > 0
    assert hs.delta_bytes < 0.1 * hs.total_bytes
    router.audit()
    router.close()


def test_inflight_invocation_completes_before_handoff(catalog_with_zoo, tmp_path):
    """A handoff issued while the source instance is busy (here: mid
    restore + generation, throttled by simulate_read_bw) must wait the
    work out — the caller gets a full result, then the handoff lands."""
    catalog, cfg, _ = catalog_with_zoo
    router = _router(catalog)
    seed = router.invoke("hf-c", PROMPT, max_new_tokens=3, mode="spice", cfg=cfg)
    src_name, dst_name = seed.node, _other(router, seed.node)
    src = router.node(src_name)
    src.evict("hf-c")
    fut = src.submit("hf-c", PROMPT, max_new_tokens=3, mode="spice", cfg=cfg,
                     simulate_read_bw=5e7)  # slow restore: instance is busy
    deadline = time.time() + 10
    while time.time() < deadline:  # wait until the restore is in flight
        inst = src.instance("hf-c")
        if inst is not None and inst.state is InstanceState.RESTORING:
            break
        time.sleep(0.001)
    assert src.instance("hf-c").state is InstanceState.RESTORING
    hs = handoff_warm(router, "hf-c", src_name, dst_name,
                      handoff_dir=str(tmp_path), cfg=cfg)
    r = fut.result(timeout=60)
    assert r.cold  # the in-flight request completed normally...
    np.testing.assert_array_equal(r.tokens, seed.tokens)
    assert hs.ok, hs.reason  # ...and only then did the handoff proceed
    assert router.node(dst_name).instance("hf-c").state is InstanceState.WARM
    router.audit()
    router.close()


def test_handoff_of_missing_instance_fails_gracefully(catalog_with_zoo, tmp_path):
    catalog, cfg, _ = catalog_with_zoo
    router = _router(catalog)
    hs = handoff_warm(router, "hf-a", router.nodes[0].name,
                      router.nodes[1].name, handoff_dir=str(tmp_path),
                      cfg=cfg, timeout=0.2)
    assert not hs.ok and hs.reason
    assert not wait_idle_warm(router.nodes[0], "hf-a", timeout=0.05)
    router.close()


# ------------------------------------------------------------ the drain
def test_drain_returns_ledger_to_prerestore_residency(catalog_with_zoo, tmp_path):
    catalog, cfg, _ = catalog_with_zoo
    router = _router(catalog)
    baseline = {n.name: n.memory.held_bytes() for n in router.nodes}
    r = router.invoke("hf-a", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
    src = router.node(r.node)
    assert src.memory.held_bytes() > baseline[r.node]  # warm state resident

    scaler = AutoScaler(router, [], handoff_dir=str(tmp_path), min_nodes=1)
    drained = scaler.drain_node(r.node)
    assert drained is src and src.name not in [n.name for n in router.nodes]
    # every function-state reservation the restore took was returned (the
    # audit ran inside drain_node); what remains charged is only the buffer
    # pool's cached staging — ladder inventory, fully reclaimable to zero
    kinds = src.memory.kind_bytes()
    for kind in ("working_set", "residual", "scratch", "image_cache",
                 "device_image", "chunk_cas"):
        assert kinds.get(kind, 0) == 0, (kind, kinds)
    src.memory.reclaim(1 << 40)
    assert src.memory.held_bytes() == baseline[r.node]
    src.memory.audit()
    # ...and the warm state survived on the successor: next request warm
    r2 = router.invoke("hf-a", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
    assert not r2.cold and r2.node != r.node
    np.testing.assert_array_equal(r2.tokens, r.tokens)
    router.audit()
    router.close()


def test_drain_without_handoff_forces_future_cold_start(catalog_with_zoo, tmp_path):
    """The ablation: drain-and-evict throws the warm state away, so the
    next request pays a cold restore — exactly what handoff eliminates."""
    catalog, cfg, _ = catalog_with_zoo
    router = _router(catalog)
    r = router.invoke("hf-b", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
    scaler = AutoScaler(router, [], handoff_dir=str(tmp_path), min_nodes=1,
                        handoff=False)
    scaler.drain_node(r.node)
    assert scaler.stats["drain_evictions"] == 1
    assert scaler.stats["handoffs_ok"] == 0
    r2 = router.invoke("hf-b", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
    assert r2.cold
    router.audit()
    router.close()


# ----------------------------------------------------------- the monitor
def _result(qos="latency", ttft=0.01, wait=0.0, mode="spice"):
    return InvokeResult(tokens=np.zeros((1, 1), np.int32), cold=False,
                        mode=mode, ttft_s=ttft, queue_wait_s=wait, qos=qos)


def test_slo_monitor_needs_min_samples_to_violate():
    mon = SLOMonitor(window_s=60.0, min_samples=4)
    slos = [ServiceSLO(QosClass.LATENCY, ttft_p99_s=0.1)]
    for _ in range(3):
        mon.observe(_result(ttft=5.0))
    violations, slack = mon.assess(slos)
    assert not violations  # three slow requests are noise, not a trend
    assert not slack       # ...but they do cancel the scale-in signal
    mon.observe(_result(ttft=5.0))
    violations, _ = mon.assess(slos)
    assert violations and "latency:ttft" in violations[0]


def test_slo_monitor_excludes_prewarms_and_reports_slack():
    mon = SLOMonitor(window_s=60.0, min_samples=2)
    slos = [ServiceSLO(QosClass.LATENCY, ttft_p99_s=0.1,
                       queue_wait_p95_s=0.1)]
    for _ in range(8):
        mon.observe(_result(ttft=0.01, wait=0.01))
        mon.observe(_result(ttft=99.0, mode="prewarm"))  # infrastructure
    violations, slack = mon.assess(slos)
    assert not violations and slack
    assert mon.percentile(QosClass.LATENCY, "ttft", 0.99) == \
        pytest.approx(0.01)
    # an idle class (no samples) counts as slack, not as a violation
    violations, slack = mon.assess([ServiceSLO(QosClass.BATCH, ttft_p99_s=0.1)])
    assert not violations and slack


# ------------------------------------------------------- the control loop
def test_autoscaler_scales_out_on_sustained_violation(catalog_with_zoo, tmp_path):
    catalog, cfg, _ = catalog_with_zoo
    router = _router(catalog, n=1)
    mon = SLOMonitor(window_s=60.0, min_samples=2)
    scaler = AutoScaler(
        router, [ServiceSLO(QosClass.LATENCY, ttft_p99_s=0.05)],
        handoff_dir=str(tmp_path), monitor=mon, scale_out_after=2,
        max_nodes=2,
        node_factory=lambda name: NodeScheduler(
            registry=catalog.registry, keepalive=FixedTTLPolicy(3600.0),
            name=name),
    )
    for _ in range(4):
        mon.observe(_result(ttft=1.0))
    assert scaler.tick() is None  # hysteresis: one violating tick buys nothing
    assert scaler.tick() == "scale_out"
    assert len(router.nodes) == 2 and scaler.stats["scale_outs"] == 1
    assert scaler.tick() is None  # max_nodes caps further growth
    # the grown node serves traffic (registry adopted, monitor wired)
    grown = router.nodes[-1]
    r = grown.invoke("hf-c", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
    assert r.cold and grown.on_result == mon.observe
    router.audit()
    router.close()


def test_autoscaler_scales_in_on_sustained_slack(catalog_with_zoo, tmp_path):
    catalog, cfg, _ = catalog_with_zoo
    router = _router(catalog, n=3)
    r = router.invoke("hf-a", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
    scaler = AutoScaler(
        router, [ServiceSLO(QosClass.LATENCY, ttft_p99_s=0.5)],
        handoff_dir=str(tmp_path), min_nodes=2, scale_in_after=2,
    )
    assert scaler.tick() is None  # idle window = slack, but hysteresis holds
    assert scaler.tick() == "scale_in"
    # least-loaded victim: an EMPTY node went first — the warm instance
    # was never touched, no handoff was needed
    assert len(router.nodes) == 2 and scaler.stats["handoffs_ok"] == 0
    assert any(n.name == r.node for n in router.nodes)
    for _ in range(4):
        assert scaler.tick() != "scale_in"  # min_nodes floors the fleet
    assert len(router.nodes) == 2
    assert scaler.node_seconds() > 0
    router.audit()
    router.close()


# ------------------------------------------------------ load-probe cache
def test_load_probe_cache_invalidated_by_lifecycle_edge(catalog_with_zoo):
    catalog, cfg, _ = catalog_with_zoo
    node = NodeScheduler(registry=catalog.registry,
                         keepalive=FixedTTLPolicy(3600.0), load_ttl_s=30.0)
    l1 = node.load()
    assert node.load() is l1  # within TTL, no transitions: cached snapshot
    node.invoke("hf-a", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
    l2 = node.load()  # lifecycle edges bumped the epoch despite the TTL
    assert l2 is not l1 and "hf-a" in l2.warm
    node.evict("hf-a")
    assert "hf-a" not in node.load().warm
    node.memory.audit()
    node.close()
