"""Multi-tenant node runtime: concurrent restores through the shared
prefetch I/O scheduler, instance lifecycle (TTL + LRU eviction), and
joined in-flight restores."""
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import ServerlessNode
from repro.serve.instance import InstanceState
from repro.serve.node import FixedTTLPolicy, NodeScheduler

ARCH = "qwen1.5-0.5b"
PROMPT = np.array([[3, 1, 4, 1, 5, 9]], dtype=np.int32)
FNAMES = ["fn-a", "fn-b", "fn-c", "fn-d"]


@pytest.fixture(scope="module")
def node_with_zoo(tmp_path_factory):
    """Four functions of one arch (distinct weights) on one node."""
    d = tmp_path_factory.mktemp("zoo")
    cfg = get_config(ARCH).reduced()
    node = ServerlessNode()
    for i, fname in enumerate(FNAMES):
        params = lm.init_params(cfg, jax.random.PRNGKey(i), jnp.float32)
        node.publish(fname, cfg, params, str(d), warm_ttl_s=0.0,
                     formats=("jif", "monolith"))
    # compile-cache warmup (shared across functions of one arch)
    node.invoke(FNAMES[0], PROMPT, max_new_tokens=3, mode="spice_sync", cfg=cfg)
    return node, cfg


def test_concurrent_cold_invokes_match_warm_reference(node_with_zoo):
    node, cfg = node_with_zoo
    # warm reference tokens, one function at a time
    ref = {}
    for fname in FNAMES:
        node.evict()
        r = node.invoke(fname, PROMPT, max_new_tokens=4, mode="spice_sync", cfg=cfg)
        ref[fname] = r.tokens
    node.evict()

    before = node.iosched.snapshot_stats()
    futures = [
        node.submit(fname, PROMPT, max_new_tokens=4, mode="spice", cfg=cfg)
        for fname in FNAMES
    ]
    results = {f.result().function: f.result() for f in futures}
    after = node.iosched.snapshot_stats()

    assert set(results) == set(FNAMES)
    for fname in FNAMES:
        assert results[fname].cold
        np.testing.assert_array_equal(results[fname].tokens, ref[fname],
                                      err_msg=fname)
    # every restore went through the SHARED scheduler
    assert after["streams_opened"] - before["streams_opened"] >= len(FNAMES)
    assert after["bytes_read"] > before["bytes_read"]


def test_concurrent_same_function_joins_inflight_restore(node_with_zoo):
    node, cfg = node_with_zoo
    node.evict()
    futures = [
        node.submit(FNAMES[0], PROMPT, max_new_tokens=3, mode="spice", cfg=cfg)
        for _ in range(4)
    ]
    results = [f.result() for f in futures]
    toks = results[0].tokens
    for r in results[1:]:
        np.testing.assert_array_equal(r.tokens, toks)
    assert all(r.cold for r in results)
    # exactly one owner restored; the rest joined its handle tree
    assert sum(1 for r in results if r.joined) == len(results) - 1


def test_contended_restores_issue_demand_boosts(node_with_zoo):
    """With several functions restoring through one arbiter at simulated
    NVMe bandwidth, execution demand must overtake background prefetch."""
    node, cfg = node_with_zoo
    node.evict()
    before = node.iosched.snapshot_stats()["demand_boosts"]
    futures = [
        node.submit(fname, PROMPT, max_new_tokens=3, mode="spice", cfg=cfg,
                    simulate_read_bw=1e9)
        for fname in FNAMES[:3]
    ]
    for f in futures:
        assert f.result().cold
    assert node.iosched.snapshot_stats()["demand_boosts"] > before


def test_warm_ttl_expiry_takes_cold_path(tmp_path):
    """Regression: warm instances past their TTL must be evicted and the
    next invocation must take the cold path (the seed stored the expiry
    but never checked it)."""
    cfg = get_config(ARCH).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(9), jnp.float32)
    node = ServerlessNode()
    node.publish("ttl-fn", cfg, params, str(tmp_path), warm_ttl_s=0.4,
                 formats=("jif",))
    r1 = node.invoke("ttl-fn", PROMPT, max_new_tokens=3, mode="spice", cfg=cfg)
    r2 = node.invoke("ttl-fn", PROMPT, max_new_tokens=3, mode="spice", cfg=cfg)
    assert r1.cold and not r2.cold  # within TTL: warm
    inst = node.scheduler.instance("ttl-fn")
    assert inst.state is InstanceState.WARM
    time.sleep(0.5)
    r3 = node.invoke("ttl-fn", PROMPT, max_new_tokens=3, mode="spice", cfg=cfg)
    assert r3.cold  # expired: evicted, cold path again
    assert node.scheduler.stats["ttl_evictions"] >= 1
    assert inst.counters["ttl_evictions"] >= 1
    np.testing.assert_array_equal(r1.tokens, r3.tokens)


def test_lru_eviction_under_memory_budget(tmp_path):
    """A tight node budget keeps only the most recently used instances
    warm; older ones are LRU-evicted."""
    cfg = get_config(ARCH).reduced()
    node = ServerlessNode(
        pool=None,
        keepalive=FixedTTLPolicy(3600.0),  # everyone WANTS to stay warm
    )
    for i, fname in enumerate(["lru-a", "lru-b", "lru-c"]):
        params = lm.init_params(cfg, jax.random.PRNGKey(20 + i), jnp.float32)
        node.publish(fname, cfg, params, str(tmp_path), formats=("jif",))

    r = node.invoke("lru-a", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
    assert r.cold
    inst_a = node.scheduler.instance("lru-a")
    assert inst_a.state is InstanceState.WARM and inst_a.memory_bytes > 0
    # budget: room for ~1.5 instances on top of pool staging memory
    node.scheduler.memory_budget = (
        node.pool.held_bytes + int(1.5 * inst_a.memory_bytes)
    )
    node.invoke("lru-b", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
    assert node.scheduler.instance("lru-a").state is InstanceState.EVICTED
    assert node.scheduler.instance("lru-b").state is InstanceState.WARM
    node.invoke("lru-c", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
    assert node.scheduler.instance("lru-b").state is InstanceState.EVICTED
    assert node.scheduler.instance("lru-c").state is InstanceState.WARM
    assert node.scheduler.stats["lru_evictions"] >= 2


def test_instance_state_machine_transitions():
    from repro.core import FunctionSpec
    from repro.serve.instance import FunctionInstance

    spec = FunctionSpec(name="f", arch=ARCH, jif_path="/dev/null")
    inst = FunctionInstance(spec, cfg=None)
    assert inst.state is InstanceState.COLD
    with inst.cond:
        gen = inst.begin_restore("spice")
        assert inst.state is InstanceState.RESTORING and gen == 1
        inst.publish_restore({"x": 1}, None, None)
        inst.promote_warm({"x": np.zeros(64)}, ttl_s=10.0, now=time.time())
        assert inst.state is InstanceState.WARM
        assert inst.memory_bytes == 64 * 8
        assert inst.evict("manual")
        assert inst.state is InstanceState.EVICTED
        # next restore bumps the generation
        assert inst.begin_restore("spice") == 2
        inst.publish_restore({"x": 1}, None, None)
        inst.promote_warm({"x": 1}, ttl_s=0.0, now=time.time())  # no keep-alive
        assert inst.state is InstanceState.COLD and inst.tree is None
