"""Multi-tenant node runtime: concurrent restores through the shared
prefetch I/O scheduler, instance lifecycle (TTL + LRU eviction), and
joined in-flight restores."""
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import ServerlessNode
from repro.serve.instance import InstanceState
from repro.serve.node import FixedTTLPolicy, NodeScheduler

ARCH = "qwen1.5-0.5b"
PROMPT = np.array([[3, 1, 4, 1, 5, 9]], dtype=np.int32)
FNAMES = ["fn-a", "fn-b", "fn-c", "fn-d"]


@pytest.fixture(scope="module")
def node_with_zoo(tmp_path_factory):
    """Four functions of one arch (distinct weights) on one node."""
    d = tmp_path_factory.mktemp("zoo")
    cfg = get_config(ARCH).reduced()
    node = ServerlessNode()
    for i, fname in enumerate(FNAMES):
        params = lm.init_params(cfg, jax.random.PRNGKey(i), jnp.float32)
        node.publish(fname, cfg, params, str(d), warm_ttl_s=0.0,
                     formats=("jif", "monolith"))
    # compile-cache warmup (shared across functions of one arch)
    node.invoke(FNAMES[0], PROMPT, max_new_tokens=3, mode="spice_sync", cfg=cfg)
    return node, cfg


def test_concurrent_cold_invokes_match_warm_reference(node_with_zoo):
    node, cfg = node_with_zoo
    # warm reference tokens, one function at a time
    ref = {}
    for fname in FNAMES:
        node.evict()
        r = node.invoke(fname, PROMPT, max_new_tokens=4, mode="spice_sync", cfg=cfg)
        ref[fname] = r.tokens
    node.evict()

    before = node.iosched.snapshot_stats()
    futures = [
        node.submit(fname, PROMPT, max_new_tokens=4, mode="spice", cfg=cfg)
        for fname in FNAMES
    ]
    results = {f.result().function: f.result() for f in futures}
    after = node.iosched.snapshot_stats()

    assert set(results) == set(FNAMES)
    for fname in FNAMES:
        assert results[fname].cold
        np.testing.assert_array_equal(results[fname].tokens, ref[fname],
                                      err_msg=fname)
    # every restore went through the SHARED scheduler
    assert after["streams_opened"] - before["streams_opened"] >= len(FNAMES)
    assert after["bytes_read"] > before["bytes_read"]


def test_concurrent_same_function_joins_inflight_restore(node_with_zoo):
    node, cfg = node_with_zoo
    node.evict()
    futures = [
        node.submit(FNAMES[0], PROMPT, max_new_tokens=3, mode="spice", cfg=cfg)
        for _ in range(4)
    ]
    results = [f.result() for f in futures]
    toks = results[0].tokens
    for r in results[1:]:
        np.testing.assert_array_equal(r.tokens, toks)
    assert all(r.cold for r in results)
    # exactly one owner restored; the rest joined its handle tree
    assert sum(1 for r in results if r.joined) == len(results) - 1


def test_contended_restores_issue_demand_boosts(node_with_zoo):
    """With several functions restoring through one arbiter at simulated
    NVMe bandwidth, execution demand must overtake background prefetch."""
    node, cfg = node_with_zoo
    node.evict()
    before = node.iosched.snapshot_stats()["demand_boosts"]
    futures = [
        node.submit(fname, PROMPT, max_new_tokens=3, mode="spice", cfg=cfg,
                    simulate_read_bw=1e9)
        for fname in FNAMES[:3]
    ]
    for f in futures:
        assert f.result().cold
    assert node.iosched.snapshot_stats()["demand_boosts"] > before


def test_warm_ttl_expiry_takes_cold_path(tmp_path):
    """Regression: warm instances past their TTL must be evicted and the
    next invocation must take the cold path (the seed stored the expiry
    but never checked it)."""
    cfg = get_config(ARCH).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(9), jnp.float32)
    node = ServerlessNode()
    node.publish("ttl-fn", cfg, params, str(tmp_path), warm_ttl_s=0.4,
                 formats=("jif",))
    r1 = node.invoke("ttl-fn", PROMPT, max_new_tokens=3, mode="spice", cfg=cfg)
    r2 = node.invoke("ttl-fn", PROMPT, max_new_tokens=3, mode="spice", cfg=cfg)
    assert r1.cold and not r2.cold  # within TTL: warm
    inst = node.scheduler.instance("ttl-fn")
    assert inst.state is InstanceState.WARM
    time.sleep(0.5)
    r3 = node.invoke("ttl-fn", PROMPT, max_new_tokens=3, mode="spice", cfg=cfg)
    assert r3.cold  # expired: evicted, cold path again
    assert node.scheduler.stats["ttl_evictions"] >= 1
    assert inst.counters["ttl_evictions"] >= 1
    np.testing.assert_array_equal(r1.tokens, r3.tokens)


def test_background_reaper_evicts_idle_expired_instance(tmp_path):
    """Regression: reap_expired only ran inside _enforce_budget, so an
    expired warm instance on an IDLE node held its ledger bytes forever.
    The background reaper must evict it — and release its ledger regions —
    without any further invocation arriving."""
    cfg = get_config(ARCH).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(13), jnp.float32)
    node = ServerlessNode(reap_interval_s=0.05)
    try:
        node.publish("reap-fn", cfg, params, str(tmp_path), warm_ttl_s=0.3,
                     formats=("jif",))
        r = node.invoke("reap-fn", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
        assert r.cold
        node.scheduler.drain_residual()
        inst = node.scheduler.instance("reap-fn")
        assert inst.state is InstanceState.WARM
        assert node.memory.kind_bytes()["working_set"] > 0
        # NO further invocations: only the reaper thread can evict it
        deadline = time.time() + 5
        while time.time() < deadline and inst.state is not InstanceState.EVICTED:
            time.sleep(0.02)
        assert inst.state is InstanceState.EVICTED
        assert node.scheduler.stats["ttl_evictions"] >= 1
        kinds = node.memory.kind_bytes()
        assert kinds["working_set"] == 0 and kinds["residual"] == 0
        node.memory.audit()
    finally:
        node.scheduler.stop_reaper()


def test_lru_eviction_under_memory_budget(tmp_path):
    """A tight node budget keeps only the most recently used instances
    warm; older ones are LRU-evicted."""
    cfg = get_config(ARCH).reduced()
    node = ServerlessNode(
        pool=None,
        keepalive=FixedTTLPolicy(3600.0),  # everyone WANTS to stay warm
    )
    for i, fname in enumerate(["lru-a", "lru-b", "lru-c"]):
        params = lm.init_params(cfg, jax.random.PRNGKey(20 + i), jnp.float32)
        node.publish(fname, cfg, params, str(tmp_path), formats=("jif",))

    r = node.invoke("lru-a", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
    assert r.cold
    inst_a = node.scheduler.instance("lru-a")
    assert inst_a.state is InstanceState.WARM and inst_a.memory_bytes > 0
    # budget: room for ~1.5 instances and NO slack for pool staging — the
    # ladder trims the (expendable) free list first, so only a budget this
    # tight forces the warm-LRU rung
    node.scheduler.memory_budget = int(1.5 * inst_a.memory_bytes)
    node.invoke("lru-b", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
    assert node.scheduler.instance("lru-a").state is InstanceState.EVICTED
    assert node.scheduler.instance("lru-b").state is InstanceState.WARM
    node.invoke("lru-c", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
    assert node.scheduler.instance("lru-b").state is InstanceState.EVICTED
    assert node.scheduler.instance("lru-c").state is InstanceState.WARM
    assert node.scheduler.stats["lru_evictions"] >= 2


def test_warm_at_working_set_promotion(tmp_path):
    """With residual state behind the ws boundary, the owner promotes at
    working-set completion (WARMING) instead of waiting for the full image;
    the residual finalizes WARM in the background."""
    cfg = get_config(ARCH).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(31), jnp.float32)
    node = ServerlessNode()
    extra = {"opt": np.ones((1 << 20,), np.float32)}  # 4 MB residual tail
    node.publish("ws-fn", cfg, params, str(tmp_path), warm_ttl_s=60,
                 formats=("jif",), extra_state=extra)
    r1 = node.invoke("ws-fn", PROMPT, max_new_tokens=3, mode="spice", cfg=cfg,
                     simulate_read_bw=5e8)
    assert r1.cold
    assert r1.stats["ws_ready"]
    assert r1.stats["residual_tensors"] > 0
    assert node.scheduler.stats["ws_promotions"] == 1
    inst = node.scheduler.instance("ws-fn")
    assert inst.state in (InstanceState.WARMING, InstanceState.WARM)
    assert inst.ws_ready and inst.memory_bytes > 0
    # invocations during/after WARMING route warm (no second restore)
    r2 = node.invoke("ws-fn", PROMPT, max_new_tokens=3, cfg=cfg)
    assert not r2.cold
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    # the background residual stream drains and finalizes WARM
    deadline = time.time() + 30
    while time.time() < deadline and inst.state is not InstanceState.WARM:
        time.sleep(0.05)
    assert inst.state is InstanceState.WARM
    assert inst.getter is None  # resolved device tree swapped in
    assert node.scheduler.residual_streams() == 0
    r3 = node.invoke("ws-fn", PROMPT, max_new_tokens=3, cfg=cfg)
    assert not r3.cold
    np.testing.assert_array_equal(r1.tokens, r3.tokens)


def test_record_access_then_relayout(tmp_path):
    """The §5 feedback loop: a warm generation is traced, relayout rewrites
    the JIF with the observed order, and the next cold start still produces
    identical tokens."""
    from repro.core.jif import JifReader

    cfg = get_config(ARCH).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(33), jnp.float32)
    node = ServerlessNode()
    node.publish("rl-fn", cfg, params, str(tmp_path), warm_ttl_s=60,
                 formats=("jif",))
    r1 = node.invoke("rl-fn", PROMPT, max_new_tokens=3, mode="spice", cfg=cfg)
    assert r1.cold

    order = node.record_access("rl-fn", PROMPT, max_new_tokens=2, cfg=cfg)
    assert order
    assert node.catalog.recorded_order("rl-fn") == order

    stats = node.relayout("rl-fn")
    assert stats.ws_boundary > 0
    assert stats.ws_tensors == len(order)
    assert node.catalog.stats["relayouts"] == 1
    with JifReader(node.registry.get("rl-fn").jif_path) as r:
        assert r.version == 2
        assert r.meta["access_order"][: len(order)] == order
        assert r.meta.get("relayout") is True

    node.evict()
    r2 = node.invoke("rl-fn", PROMPT, max_new_tokens=3, mode="spice", cfg=cfg)
    assert r2.cold
    np.testing.assert_array_equal(r1.tokens, r2.tokens)


def test_residual_evict_then_cheap_rerestore(tmp_path):
    """The EVICTED → RESTORING re-restore path: dropping only residual
    pages keeps the pinned working set, so the next restore reads strictly
    fewer bytes (exactly the residual) and still generates identically."""
    cfg = get_config(ARCH).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(51), jnp.float32)
    node = ServerlessNode()
    extra = {"opt": np.ones((1 << 20,), np.float32)}  # 4 MB residual tail
    node.publish("rr-fn", cfg, params, str(tmp_path), warm_ttl_s=60,
                 formats=("jif",), extra_state=extra)
    r1 = node.invoke("rr-fn", PROMPT, max_new_tokens=3, mode="spice", cfg=cfg)
    assert r1.cold
    assert node.scheduler.drain_residual()
    inst = node.scheduler.instance("rr-fn")
    cold_read = inst.restore_stats.as_dict()["bytes_read"]
    ws_bytes = inst.ws_region.nbytes
    residual_bytes = inst.residual_region.nbytes

    freed = node.scheduler.evict_residual("rr-fn")
    assert freed == residual_bytes
    assert inst.state is InstanceState.EVICTED
    assert inst.ws_pinned and inst.ws_region is not None
    assert inst.residual_region is None
    assert node.scheduler.stats["residual_evictions"] == 1
    node.memory.audit()  # pinned ws still charged, residual uncharged

    r2 = node.invoke("rr-fn", PROMPT, max_new_tokens=3, mode="spice", cfg=cfg)
    assert r2.cold  # a restore, but a cheap one
    assert node.scheduler.drain_residual()
    d2 = inst.restore_stats.as_dict()
    assert d2["reused_bytes"] == ws_bytes      # whole ws served from memory
    assert d2["bytes_read"] < cold_read        # strictly fewer bytes read
    # ... and only the dropped tail (chunk-padded per residual tensor)
    assert d2["bytes_read"] <= residual_bytes + 4096 * d2["residual_tensors"]
    assert node.scheduler.stats["ws_rerestores"] == 1
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    node.memory.audit()


def test_manual_evict_waits_for_warming(tmp_path):
    """Regression: evict() during the WARMING window used to no-op (the
    residual stream is unevictable mid-flight), so the next invocation
    silently routed warm instead of cold."""
    cfg = get_config(ARCH).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(71), jnp.float32)
    node = ServerlessNode()
    extra = {"opt": np.ones((1 << 20,), np.float32)}
    node.publish("ev-fn", cfg, params, str(tmp_path), warm_ttl_s=60,
                 formats=("jif",), extra_state=extra)
    # warm the compile cache so the invoke returns DURING the residual
    # stream (the race window)
    node.invoke("ev-fn", PROMPT, max_new_tokens=2, mode="spice_sync", cfg=cfg)
    node.evict()
    r1 = node.invoke("ev-fn", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg,
                     simulate_read_bw=5e8)
    assert r1.cold
    node.evict()  # must wait out WARMING, then actually evict
    inst = node.scheduler.instance("ev-fn")
    assert inst.state is InstanceState.EVICTED
    r2 = node.invoke("ev-fn", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
    assert r2.cold
    np.testing.assert_array_equal(r1.tokens, r2.tokens)


def test_reclaim_ladder_order(tmp_path):
    """Pressure reclaim drops residual tails before cached base images
    before warm LRU state (the paper's cheap-state-first ladder)."""
    from repro.core import BaseImage

    cfg = get_config(ARCH).reduced()
    node = ServerlessNode()
    extra = {"opt": np.ones((1 << 20,), np.float32)}  # 4 MB residual
    for i, fname in enumerate(["lad-a", "lad-b"]):
        params = lm.init_params(cfg, jax.random.PRNGKey(60 + i), jnp.float32)
        node.publish(fname, cfg, params, str(tmp_path), warm_ttl_s=3600,
                     formats=("jif",), extra_state=extra)
    node.invoke("lad-a", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
    node.invoke("lad-b", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
    assert node.scheduler.drain_residual()
    img = BaseImage.from_state("lad-img", {"x": np.ones((1 << 18,), np.float32)})
    node.node_cache.put(img)  # 1 MB cached image
    inst_a = node.scheduler.instance("lad-a")
    inst_b = node.scheduler.instance("lad-b")
    residual = inst_a.residual_region.nbytes

    # rung 0: both residual tails cover the request; images and warm
    # instances are untouched
    freed = node.memory.reclaim(2 * residual)
    assert freed >= 2 * residual
    assert inst_a.state is InstanceState.EVICTED and inst_a.ws_pinned
    assert inst_b.state is InstanceState.EVICTED and inst_b.ws_pinned
    assert node.node_cache.get("lad-img") is not None

    # rung 1: residual exhausted — the cached image goes next; pinned
    # working sets survive
    freed = node.memory.reclaim(img.nbytes)
    assert freed >= img.nbytes
    assert node.node_cache.get("lad-img") is None
    assert inst_a.ws_pinned and inst_b.ws_pinned

    # rung 2 trims idle pool staging before any warm state is touched;
    # rung 3 then sacrifices pinned working sets LRU-first.  Request
    # enough that the pool alone cannot cover it.
    pool_free = sum(sc * len(lst) for sc, lst in node.pool._free.items())
    freed = node.memory.reclaim(pool_free + inst_a.ws_region.nbytes)
    assert freed > 0
    assert inst_a.ws_pinned is None  # oldest pin dropped first
    assert inst_b.ws_pinned          # newer pin survives the request
    node.memory.audit()


def test_instance_state_machine_transitions():
    from repro.core import FunctionSpec
    from repro.serve.instance import FunctionInstance

    spec = FunctionSpec(name="f", arch=ARCH, jif_path="/dev/null")
    inst = FunctionInstance(spec, cfg=None)
    assert inst.state is InstanceState.COLD
    with inst.cond:
        gen = inst.begin_restore("spice")
        assert inst.state is InstanceState.RESTORING and gen == 1
        inst.publish_restore({"x": 1}, None, None)
        inst.promote_warm({"x": np.zeros(64)}, ttl_s=10.0, now=time.time())
        assert inst.state is InstanceState.WARM
        assert inst.memory_bytes == 64 * 8
        assert inst.evict("manual")
        assert inst.state is InstanceState.EVICTED
        # next restore bumps the generation
        assert inst.begin_restore("spice") == 2
        inst.publish_restore({"x": 1}, None, None)
        inst.promote_warm({"x": 1}, ttl_s=0.0, now=time.time())  # no keep-alive
        assert inst.state is InstanceState.COLD and inst.tree is None
