"""Unified node memory subsystem: ledger invariant, region primitives,
reclaim ladder, pool capacity accounting, and budget-bounded concurrent
restores (the paper's "memory budget is an invariant" property).

The interleaving tests are deterministic (seeded RandomState) like
test_core.py; a hypothesis-powered variant is not needed — the seeds cover
the same op-sequence space reproducibly."""
import gc
import threading
import time

import numpy as np
import pytest

from repro.core import (
    BufferPool,
    KIND_IMAGE_CACHE,
    KIND_POOL,
    KIND_RESIDUAL,
    KIND_SCRATCH,
    KIND_WORKING_SET,
    MEMORY_KINDS,
    MemoryPressureError,
    NodeMemoryManager,
)


# ------------------------------------------------------------ region basics
def test_reserve_commit_release_accounting():
    mm = NodeMemoryManager(1000)
    a = mm.reserve(400, KIND_WORKING_SET, owner="a")
    b = mm.reserve(300, KIND_RESIDUAL, owner="b")
    assert mm.held_bytes() == 700
    assert mm.kind_bytes()[KIND_WORKING_SET] == 400
    assert mm.kind_bytes()[KIND_RESIDUAL] == 300
    a.populate(250)
    a.commit(pinned="working_set")
    assert a.state == "committed" and a.pinned == "working_set"
    snap = mm.audit()
    assert snap["total"] == 700
    assert b.release() == 300
    assert b.release() == 0  # idempotent
    assert mm.held_bytes() == 400
    a.release()
    assert mm.held_bytes() == 0
    assert mm.audit()["total"] == 0


def test_reserve_fails_fast_over_budget():
    mm = NodeMemoryManager(100)
    mm.reserve(80, KIND_WORKING_SET)
    with pytest.raises(MemoryPressureError):
        mm.reserve(40, KIND_WORKING_SET, block=False)
    # accounting unchanged by the failed admission
    assert mm.held_bytes() == 80
    mm.audit()


def test_unlimited_budget_accounting_only():
    mm = NodeMemoryManager(None)
    r = mm.reserve(10 << 30, KIND_SCRATCH)  # admits anything
    assert mm.over_budget() == 0 and mm.pressure() == 0.0
    r.release()


def test_blocking_reserve_waits_for_release():
    mm = NodeMemoryManager(100)
    a = mm.reserve(90, KIND_WORKING_SET)
    got = []

    def reserver():
        got.append(mm.reserve(50, KIND_WORKING_SET, timeout=10))

    t = threading.Thread(target=reserver)
    t.start()
    time.sleep(0.1)
    assert not got  # blocked: 90 + 50 > 100
    a.release()
    t.join(timeout=10)
    assert got and mm.held_bytes() == 50
    got[0].release()


def test_region_resize_respects_budget():
    mm = NodeMemoryManager(100)
    r = mm.reserve(40, KIND_POOL)
    assert r.resize(90)
    assert not r.resize(110)  # would exceed the budget: charge unchanged
    assert mm.held_bytes() == 90
    assert r.resize(10)  # shrink always succeeds
    assert mm.held_bytes() == 10
    mm.audit()
    r.release()


def test_high_water_marks_per_kind():
    mm = NodeMemoryManager(1000)
    a = mm.reserve(400, KIND_WORKING_SET)
    b = mm.reserve(200, KIND_IMAGE_CACHE)
    a.release()
    c = mm.reserve(100, KIND_WORKING_SET)
    hw = mm.high_water()
    assert hw[KIND_WORKING_SET] == 400
    assert hw[KIND_IMAGE_CACHE] == 200
    assert hw["total"] == 600
    b.release(); c.release()


# ------------------------------------------------------------ reclaim ladder
def test_reclaim_ladder_runs_in_order():
    mm = NodeMemoryManager(100)
    calls = []
    regions = {}
    for kind, name, order in [
        (KIND_RESIDUAL, "residual", 0),
        (KIND_IMAGE_CACHE, "image-cache", 1),
        (KIND_WORKING_SET, "warm-lru", 2),
    ]:
        regions[name] = mm.reserve(30, kind)

        def rung(nbytes, protect, _n=name):
            calls.append(_n)
            return regions[_n].release()

        mm.register_reclaimer(name, rung, order)
    # 90 held; a 40-byte reserve needs 30 freed: rung 0 suffices
    r = mm.reserve(40, KIND_WORKING_SET)
    assert calls == ["residual"]
    # next 40 needs 40 freed: residual is empty now, so the ladder walks
    # down through image-cache and warm-lru in order
    r2 = mm.reserve(40, KIND_WORKING_SET)
    assert calls == ["residual", "residual", "image-cache", "warm-lru"]
    r.release(); r2.release()
    mm.audit()


def test_reclaim_returns_freed_bytes_and_stops_early():
    mm = NodeMemoryManager(None)
    freed_log = []
    r1 = mm.reserve(60, KIND_RESIDUAL)
    r2 = mm.reserve(60, KIND_IMAGE_CACHE)

    mm.register_reclaimer("a", lambda n, p: freed_log.append(n) or r1.release(), 0)
    mm.register_reclaimer("b", lambda n, p: freed_log.append(n) or r2.release(), 1)
    assert mm.reclaim(50) == 60  # rung 0 covered it
    assert freed_log == [50]    # rung 1 never ran
    assert mm.reclaim(100) == 60  # rung 0 empty now; rung 1 runs
    assert freed_log == [50, 100, 100]


# ------------------------------------------------- pool capacity (satellite)
def test_pool_miss_allocations_are_charged():
    """Regression: the seed's acquire() miss path allocated np.zeros without
    charging capacity, so N concurrent restores staged unbounded untracked
    memory.  Misses now charge; held_bytes covers outstanding buffers."""
    pool = BufferPool(capacity_bytes=64 << 10)
    bufs = [pool.acquire(16 << 10) for _ in range(4)]  # 4 x 16K = capacity
    assert pool.held_bytes == 64 << 10
    extra = pool.acquire(16 << 10)  # over capacity: unmanaged transient
    assert pool.held_bytes == 64 << 10
    assert pool.snapshot_stats()["unmanaged_allocs"] == 1
    # the overshoot is a live gauge, not a silent count
    assert pool.snapshot_stats()["unmanaged_bytes"] == 16 << 10
    assert pool.snapshot_stats()["unmanaged_bytes_hw"] == 16 << 10
    pool.release(extra)  # dropped, not pooled; gauge settles back
    assert pool.held_bytes == 64 << 10
    assert pool.snapshot_stats()["dropped_releases"] == 1
    assert pool.snapshot_stats()["unmanaged_bytes"] == 0
    for b in bufs:
        pool.release(b)
    assert pool.held_bytes == 64 << 10  # all charged bytes now in free lists


def test_pool_foreign_release_is_dropped():
    pool = BufferPool(capacity_bytes=1 << 20)
    pool.release(np.zeros(4096, np.uint8))  # never acquired from this pool
    assert pool.held_bytes == 0
    assert pool.snapshot_stats()["dropped_releases"] == 1


def test_pool_gc_sweep_reclaims_leaked_charges():
    """A caller that drops an acquired buffer without releasing it (e.g. a
    non-pipelined restore whose state tree dies) must not pin the charge."""
    pool = BufferPool(capacity_bytes=64 << 10)
    buf = pool.acquire(32 << 10)
    assert pool.held_bytes == 32 << 10
    del buf
    gc.collect()
    assert pool.held_bytes == 0
    assert pool.snapshot_stats()["gc_reclaimed_bytes"] == 32 << 10


def test_pool_region_mirrors_held_bytes():
    mm = NodeMemoryManager(1 << 20)
    pool = BufferPool(capacity_bytes=1 << 20)
    pool.attach(mm)
    b = pool.acquire(10_000)
    assert mm.kind_bytes()[KIND_POOL] == pool.held_bytes > 0
    pool.release(b)
    assert mm.kind_bytes()[KIND_POOL] == pool.held_bytes
    mm.audit()
    pool.detach()
    assert mm.kind_bytes()[KIND_POOL] == 0


def test_pool_respects_node_budget_not_just_capacity():
    """With a ledger attached, a pool miss that fits capacity but not the
    node budget becomes an unmanaged transient instead of over-committing."""
    mm = NodeMemoryManager(8 << 10)
    other = mm.reserve(6 << 10, KIND_WORKING_SET)
    pool = BufferPool(capacity_bytes=1 << 20)
    pool.attach(mm)
    buf = pool.acquire(4 << 10)  # 4K + 6K > 8K budget
    assert pool.held_bytes == 0
    assert pool.snapshot_stats()["unmanaged_allocs"] == 1
    assert mm.held_bytes() == 6 << 10
    pool.release(buf)
    assert pool.snapshot_stats()["dropped_releases"] == 1
    other.release()
    mm.audit()


def test_image_cache_capacity_evict_honors_pin():
    """An unrecoverable (pinned) base must survive both the pressure
    reclaimer AND the capacity LRU — evicting it would crash every restore
    deduplicated against it."""
    from repro.core import BaseImage, NodeImageCache

    img_nbytes = 4096 * 4
    cache = NodeImageCache(capacity_bytes=int(2.5 * img_nbytes))
    cache.put(BaseImage.from_state("pinned", {"x": np.ones(4096, np.float32)}),
              evictable=False)
    cache.put(BaseImage.from_state("lru-1", {"x": np.ones(4096, np.float32)}))
    cache.put(BaseImage.from_state("lru-2", {"x": np.ones(4096, np.float32)}))
    assert cache.get("pinned") is not None   # pin survived capacity churn
    assert cache.get("lru-1") is None        # recoverable LRU went first
    assert cache.get("lru-2") is not None
    # the pressure reclaimer also skips the pin
    mm = NodeMemoryManager(None)
    cache.attach(mm)
    freed = cache.reclaim(1 << 30)
    assert freed > 0
    assert cache.get("pinned") is not None
    assert cache.get("lru-2") is None
    mm.audit()


# --------------------------------------- ledger invariant (property, seeded)
def _interleave(seed: int, mm: NodeMemoryManager, budget, victims, steps=400):
    """Random reserve/populate/commit/release/reclaim interleaving; the
    audit invariant must hold after EVERY op.  ``victims`` feeds the
    registered reclaimer (regions it may sacrifice under pressure)."""
    r = np.random.RandomState(seed)
    live = []
    for _ in range(steps):
        op = r.randint(7)
        if op <= 1:  # reserve
            kind = MEMORY_KINDS[r.randint(len(MEMORY_KINDS))]
            nb = int(r.randint(1, budget // 2))
            try:
                live.append(mm.reserve(nb, kind, block=False))
            except MemoryPressureError:
                pass
        elif op == 2 and live:  # populate
            reg = live[r.randint(len(live))]
            reg.populate(int(r.randint(1, 1 + reg.nbytes)))
        elif op == 3 and live:  # commit
            reg = live[r.randint(len(live))]
            reg.commit(pinned="working_set" if r.randint(2) else None)
        elif op == 4 and live:  # release
            live.pop(r.randint(len(live))).release()
        elif op == 5 and live:  # mark reclaimable (an idle warm instance)
            victims.append(live.pop(r.randint(len(live))))
        else:  # reclaim under pressure
            mm.reclaim(int(r.randint(1, budget)))
        snap = mm.audit()  # asserts sum(regions) == held <= budget
        assert snap["total"] <= budget
    for reg in live + victims:
        reg.release()
    assert mm.held_bytes() == 0


@pytest.mark.parametrize("seed", range(6))
def test_ledger_invariant_random_interleavings(seed):
    budget = 10_000
    mm = NodeMemoryManager(budget)
    # a reclaimer that sacrifices marked regions oldest-first, like the
    # node's ladder rungs do
    victims = []

    def rung(nbytes, protect):
        freed = 0
        while victims and freed < nbytes:
            freed += victims.pop(0).release()
        return freed

    mm.register_reclaimer("drop-oldest", rung, order=0)
    _interleave(seed, mm, budget, victims)


@pytest.mark.parametrize("seed", [0, 1])
def test_ledger_invariant_threaded(seed):
    """Concurrent reserve/release from several threads: the audit must stay
    coherent at every observation point (taken from a sampler thread)."""
    budget = 100_000
    mm = NodeMemoryManager(budget)
    errors = []
    stop = threading.Event()

    def worker(wseed):
        r = np.random.RandomState(wseed)
        held = []
        try:
            for _ in range(300):
                if held and r.randint(2):
                    held.pop(r.randint(len(held))).release()
                else:
                    try:
                        held.append(mm.reserve(
                            int(r.randint(1, 5000)),
                            MEMORY_KINDS[r.randint(len(MEMORY_KINDS))],
                            block=False,
                        ))
                    except MemoryPressureError:
                        pass
            for reg in held:
                reg.release()
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    def sampler():
        while not stop.is_set():
            try:
                assert mm.audit()["total"] <= budget
            except BaseException as e:  # noqa: BLE001
                errors.append(e)
                return

    threads = [threading.Thread(target=worker, args=(seed * 31 + i,)) for i in range(6)]
    s = threading.Thread(target=sampler)
    s.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop.set()
    s.join()
    assert not errors
    assert mm.held_bytes() == 0
    mm.audit()


# ---------------------------------- budget-bounded concurrent cold restores
ARCH = "qwen1.5-0.5b"
PROMPT = np.array([[3, 1, 4, 1, 5, 9]], dtype=np.int32)


def test_concurrent_restores_over_budget_complete_via_reclaim(tmp_path):
    """Acceptance: a node with budget B runs 4 concurrent cold restores
    whose images sum to > B; every invocation completes via the reclaim
    ladder, and at no observation point does held_bytes exceed B or
    disagree with the sum of live region charges."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.jif import JifReader
    from repro.models import lm
    from repro.serve.engine import ServerlessNode
    from repro.serve.node import FixedTTLPolicy

    cfg = get_config(ARCH).reduced()
    node = ServerlessNode(keepalive=FixedTTLPolicy(3600.0))
    fnames = [f"mp-{i}" for i in range(4)]
    extra = {"opt": np.ones((1 << 20,), np.float32)}  # 4 MB residual tail
    for i, fname in enumerate(fnames):
        params = lm.init_params(cfg, jax.random.PRNGKey(40 + i), jnp.float32)
        node.publish(fname, cfg, params, str(tmp_path), formats=("jif",),
                     extra_state=extra)
    # compile-cache warmup, then a clean slate
    node.invoke(fnames[0], PROMPT, max_new_tokens=2, mode="spice_sync", cfg=cfg)
    node.evict()
    node.scheduler.drain_residual()

    img_bytes = []
    for fname in fnames:
        with JifReader(node.registry.get(fname).jif_path) as r:
            img_bytes.append(sum(t.nbytes for t in r.tensors))
    budget = node.pool.held_bytes + int(2.2 * max(img_bytes))
    assert sum(img_bytes) > budget  # the burst genuinely over-subscribes
    node.scheduler.memory_budget = budget

    futures = [
        node.submit(f, PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
        for f in fnames
    ]
    peak = 0
    while not all(f.done() for f in futures):
        snap = node.memory.audit()  # asserts ledger equality + budget
        peak = max(peak, snap["total"])
        time.sleep(0.002)
    results = [f.result() for f in futures]
    assert all(r.cold for r in results)
    assert peak <= budget
    # completing the burst REQUIRED the ladder
    mstats = node.memory.snapshot_stats()
    assert mstats["reclaims"] > 0 and mstats["reclaimed_bytes"] > 0
    assert mstats["pressure_failures"] == 0
    node.scheduler.drain_residual()
    node.memory.audit()
