"""Content-addressed chunk store: CAS refcounting, the node chunk cache and
its ``chunk_cas`` ledger rung, digest plumbing edge cases (v1 backfill
sidecars, non-page-multiple tails, concurrent digest reads), dedup-aware
restore planning, and the catalog/router peer-fetch wiring."""
import os
import shutil
import threading
from pathlib import Path

import numpy as np
import pytest

from repro.core import (
    ChunkStore,
    NodeChunkCache,
    NodeImageCache,
    NodeMemoryManager,
    SpiceRestorer,
    digest_key,
    snapshot,
)
from repro.core.digest import chunk_digest, chunk_digests, zero_chunk_digest
from repro.core.jif import JifReader, digest_sidecar_path
from repro.core.memory import KIND_CHUNK_CAS
from repro.core.treeutil import flatten_state

PAGE = 4096
GOLDEN = Path(__file__).parent / "golden" / "jif_v1_small.jif"


def rng_state(seed=0, tail=False):
    r = np.random.RandomState(seed)
    st = {
        "embed": {"tok": r.randn(64, 32).astype(np.float32)},
        "layers": [
            {"w": r.randn(32, 64).astype(np.float32),
             "b": np.zeros((2048,), np.float32)}
            for _ in range(3)
        ],
        "step": np.int64(7),
    }
    if tail:
        # 1000 float32 = 4000 bytes: a single non-page-multiple chunk
        st["odd"] = r.randn(1000).astype(np.float32)
    return st


def assert_state_equal(a, b):
    la, _ = flatten_state(a)
    lb, _ = flatten_state(b)
    assert [n for n, _ in la] == [n for n, _ in lb]
    for (n, x), (_, y) in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), err_msg=n)


# ----------------------------------------------------------- shared identity
def test_digest_single_definition_shared_everywhere():
    """jif, overlay, and the chunk store must agree on chunk identity."""
    from repro.core import digest, jif, overlay

    assert overlay._DIGEST_BYTES is digest.DIGEST_BYTES
    assert jif._DIGEST_BYTES is digest.DIGEST_BYTES
    assert overlay.chunk_digests is digest.chunk_digests
    buf = np.arange(10000, dtype=np.uint8)
    dg = chunk_digests(memoryview(buf), PAGE)
    assert dg.shape == (3, 16)
    # tail chunk hashed over UNPADDED bytes
    assert bytes(dg[2]) == chunk_digest(buf[2 * PAGE :].tobytes())
    assert zero_chunk_digest(100) == chunk_digest(bytes(100))


# ------------------------------------------------------------------ disk CAS
def test_chunkstore_put_dedup_refcount_unlink(tmp_path):
    store = ChunkStore(str(tmp_path / "cas"))
    data = os.urandom(PAGE)
    dk = chunk_digest(data)
    assert store.put(dk, data) is True
    assert store.put(dk, data) is False  # dedup: refcount bump, no write
    assert store.refcount(dk) == 2
    assert store.stats["bytes_deduped"] == PAGE
    assert store.get(dk) == data
    assert store.decref(dk) is False
    assert store.decref(dk) is True  # last ref: file unlinked
    assert not store.contains(dk)
    assert store.get(dk) is None
    with pytest.raises(KeyError):
        store.decref(dk)
    store.audit()


def test_chunkstore_ingest_jif_dedups_occurrences(tmp_path):
    """Two identical sibling images ingest to ONE physical copy; the second
    manifest is pure dedup."""
    state = rng_state(1)
    pa, pb = str(tmp_path / "a.jif"), str(tmp_path / "b.jif")
    snapshot(state, pa, page_size=PAGE)
    snapshot(state, pb, page_size=PAGE)
    store = ChunkStore(str(tmp_path / "cas"))
    ma, ua, da = store.ingest_jif(pa)
    mb, ub, db = store.ingest_jif(pb)
    assert ma == mb  # identical content -> identical manifests
    assert ua > 0 and ub == 0 and db == ua + da
    store.audit()
    store.release_many(ma)
    store.release_many(mb)
    assert store.audit()["chunks"] == 0


# ------------------------------------------------- digest plumbing edge cases
def test_v1_golden_has_no_digests_without_sidecar(tmp_path):
    p = str(tmp_path / "g.jif")
    shutil.copy(GOLDEN, p)
    with JifReader(p) as r:
        assert not r.has_digests
        assert r.digests("embed/tok") is None


def test_v1_backfill_persists_sidecar_and_matches_content(tmp_path):
    p = str(tmp_path / "g.jif")
    shutil.copy(GOLDEN, p)
    with JifReader(p) as r:
        assert r.ensure_digests()
        assert r.has_digests
        dg = r.digests("embed/tok")
    assert os.path.exists(digest_sidecar_path(p))
    # a FRESH reader loads the sidecar (backfill paid once per image)
    with JifReader(p) as r2:
        assert r2.has_digests
        np.testing.assert_array_equal(r2.digests("embed/tok"), dg)
        # backfilled digests equal digests of the restored bytes
        state, _, _, _ = SpiceRestorer().restore(p)
        raw = np.ascontiguousarray(state["embed"]["tok"]).view(np.uint8).reshape(-1)
        np.testing.assert_array_equal(
            dg, chunk_digests(memoryview(raw), r2.page_size)
        )


def test_stale_sidecar_invalidated_on_identity_change(tmp_path):
    p = str(tmp_path / "g.jif")
    shutil.copy(GOLDEN, p)
    with JifReader(p) as r:
        r.ensure_digests()
    os.utime(p, ns=(1, 1))  # simulate an in-place rewrite (mtime changes)
    with JifReader(p) as r:
        assert not r.has_digests  # stale sidecar must NOT serve


def test_backfill_zero_and_tail_chunks(tmp_path):
    """ZERO runs and a non-page-multiple tail backfill to the same digests
    the writer would have stored."""
    state = rng_state(2, tail=True)
    p = str(tmp_path / "t.jif")
    snapshot(state, p, page_size=PAGE)
    with JifReader(p) as r:
        stored = {t.name: r.digests(t.name) for t in r.tensors}
        assert stored["layers/1/b"] is not None  # all-zero tensor
    # hand-build a digestless (v1-style) image with the same content and
    # verify the backfill reproduces exactly what the v2 writer stored —
    # ZERO runs and the unpadded tail included
    from repro.core import jif as jif_mod
    from repro.core import overlay

    leaves, _ = flatten_state(state)
    # hand-build a digestless (v1-style) image with the same tail layout
    tensors, itables, chunks = [], {}, []
    cursor = 0
    for name, arr in leaves:
        raw = np.ascontiguousarray(arr).view(np.uint8).reshape(-1)
        kinds = overlay.classify(memoryview(raw), PAGE)
        table = overlay.intervals_from_kinds(kinds)
        for row in table:
            if row[2] == overlay.KIND_PRIVATE:
                row[3] = cursor
                cursor += int(row[1])
        itables[name] = table
        t = jif_mod.TensorEntry(
            name=name, dtype=str(arr.dtype),
            shape=tuple(np.asarray(arr).shape), nbytes=raw.nbytes,
        )
        tensors.append(t)
        for start, n, _src in overlay.IntervalTable(table).private_runs():
            chunk = raw[start * PAGE : (start + n) * PAGE]
            pad = (-len(chunk)) % PAGE
            chunks.append(chunk.tobytes() + b"\0" * pad)
    v1 = str(tmp_path / "v1.jif")
    jif_mod.write_jif(
        v1, {"tree": None}, tensors, itables, chunks, PAGE, digests=None
    )
    with JifReader(v1) as r:
        assert not r.has_digests
        r.ensure_digests()
        for name, arr in leaves:
            raw = np.ascontiguousarray(arr).view(np.uint8).reshape(-1)
            np.testing.assert_array_equal(
                r.digests(name), chunk_digests(memoryview(raw), PAGE),
                err_msg=name,
            )


def test_concurrent_digest_reads(tmp_path):
    """JifReader.digests is pread-based: many threads reading digest rows
    concurrently must all see identical data."""
    state = rng_state(3)
    p = str(tmp_path / "c.jif")
    snapshot(state, p, page_size=PAGE)
    with JifReader(p) as r:
        names = [t.name for t in r.tensors]
        expect = {n: r.digests(n).copy() for n in names}
        errors = []

        def hammer():
            try:
                for _ in range(20):
                    for n in names:
                        np.testing.assert_array_equal(r.digests(n), expect[n])
            except Exception as e:  # pragma: no cover - failure path
                errors.append(e)

        threads = [threading.Thread(target=hammer) for _ in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors


# ------------------------------------------------- node cache + ledger rung
def test_chunk_cache_charges_ledger_and_demotes_under_pressure(tmp_path):
    store = ChunkStore(str(tmp_path / "cas"))
    mem = NodeMemoryManager(64 * PAGE)
    cache = NodeChunkCache(store, node="n0")
    cache.attach(mem)
    payloads = {chunk_digest(bytes([i]) * PAGE): bytes([i]) * PAGE for i in range(8)}
    for dk, data in payloads.items():
        cache.ingest(dk, data)
    assert mem.kind_bytes()[KIND_CHUNK_CAS] == 8 * PAGE
    assert mem.high_water()[KIND_CHUNK_CAS] == 8 * PAGE
    mem.audit()
    # pressure: demote to the disk tier; chunks stay one CAS read away
    freed = mem.reclaim(3 * PAGE)
    assert freed >= 3 * PAGE
    assert mem.kind_bytes()[KIND_CHUNK_CAS] <= 5 * PAGE
    for dk, data in payloads.items():
        assert cache.probe(dk) in ("ram", "cas")
        got = cache.get(dk) or cache.get_cas(dk)
        assert got == data
    mem.audit()
    cache.release_all()
    assert mem.kind_bytes()[KIND_CHUNK_CAS] == 0
    assert store.audit()["chunks"] == 0
    mem.audit()


def test_chunk_cache_ram_reject_keeps_disk_tier(tmp_path):
    """A ledger that cannot admit RAM bytes must not lose the chunk — it
    stays served from the disk tier."""
    store = ChunkStore(str(tmp_path / "cas"))
    mem = NodeMemoryManager(2 * PAGE)
    cache = NodeChunkCache(store, node="n0")
    cache.attach(mem)
    datas = [bytes([i]) * PAGE for i in range(6)]
    for d in datas:
        cache.ingest(chunk_digest(d), d)
    assert cache.snapshot_stats()["ram_rejects"] > 0
    for d in datas:
        assert cache.get_cas(chunk_digest(d)) == d
    mem.audit()


# ------------------------------------------------------ dedup-aware restore
def _dedup_restorer(tmp_path, cache):
    return SpiceRestorer(
        node_cache=NodeImageCache(), chunks=cache, pipelined=False
    )


def test_dedup_restore_is_byte_identical_and_skips_shared_reads(tmp_path):
    base = rng_state(5, tail=True)
    parent = str(tmp_path / "p.jif")
    snapshot(base, parent, page_size=PAGE)
    # two sibling fine-tunes with the SAME modification: their private
    # chunks are content-identical, so the second restore should pull ~0
    ca, cb = dict(base), dict(base)
    bump = base["layers"][0]["w"] + 1.5
    ca = {**base, "layers": [dict(l) for l in base["layers"]]}
    cb = {**base, "layers": [dict(l) for l in base["layers"]]}
    ca["layers"][0]["w"] = bump
    cb["layers"][0]["w"] = bump.copy()
    pa, pb = str(tmp_path / "a.jif"), str(tmp_path / "b.jif")
    snapshot(ca, pa, parent=parent, page_size=PAGE)
    snapshot(cb, pb, parent=parent, page_size=PAGE)

    plain_a, _, _, _ = SpiceRestorer(node_cache=NodeImageCache()).restore(pa)
    plain_b, _, _, _ = SpiceRestorer(node_cache=NodeImageCache()).restore(pb)

    store = ChunkStore(str(tmp_path / "cas"))
    cache = NodeChunkCache(store, node="n0")
    shared_images = NodeImageCache()
    r1 = SpiceRestorer(node_cache=shared_images, chunks=cache, pipelined=False)
    got_a, _, _, st_a = r1.restore(pa)
    r2 = SpiceRestorer(node_cache=shared_images, chunks=cache, pipelined=False)
    got_b, _, _, st_b = r2.restore(pb)

    # dedup must never change restored bytes
    assert_state_equal(plain_a, got_a)
    assert_state_equal(plain_b, got_b)
    # second sibling: every private chunk already in the node cache
    assert st_b.bytes_read == 0
    assert st_b.chunk_resident_bytes + st_b.chunk_cas_bytes > 0
    assert st_b.chunk_plan_miss == 0
    assert st_b.chunk_plan_resident + st_b.chunk_plan_cas > 0
    assert st_a.bytes_read > 0  # first occurrence genuinely pulled
    store.audit()


def test_dedup_restore_of_v1_image_via_backfill(tmp_path):
    """A pre-v2 image participates in dedup through the backfill sidecar."""
    p1, p2 = str(tmp_path / "g1.jif"), str(tmp_path / "g2.jif")
    shutil.copy(GOLDEN, p1)
    shutil.copy(GOLDEN, p2)
    plain, _, _, _ = SpiceRestorer().restore(p1)
    store = ChunkStore(str(tmp_path / "cas"))
    cache = NodeChunkCache(store, node="n0")
    _, _, _, st1 = SpiceRestorer(chunks=cache, pipelined=False).restore(p1)
    got, _, _, st2 = SpiceRestorer(chunks=cache, pipelined=False).restore(p2)
    assert_state_equal(plain, got)
    assert st1.bytes_read > 0
    assert st2.bytes_read == 0  # content-identical copy: all cache hits
    assert os.path.exists(digest_sidecar_path(p1))


# ----------------------------------------------------------- peer fetch path
def test_router_wires_peer_fetch_between_node_caches(tmp_path):
    from repro.serve.cluster import ClusterRouter, FunctionCatalog
    from repro.serve.node import NodeScheduler

    store = ChunkStore(str(tmp_path / "cas"))
    catalog = FunctionCatalog(chunk_store=store)
    nodes = [
        NodeScheduler(registry=catalog.registry, name=f"node{i}",
                      chunks=NodeChunkCache(store, node=f"node{i}"))
        for i in range(2)
    ]
    router = ClusterRouter(catalog, nodes, interconnect_bw=1e9)
    data = os.urandom(PAGE)
    dk = chunk_digest(data)
    nodes[0].chunks.ingest(dk, data)  # announces into the catalog index
    assert catalog.chunk_holders(dk) == ("node0",)
    assert not nodes[1].chunks.holds(dk)
    got = nodes[1].chunks.fetch_peer(dk)
    assert got == data
    assert router.stats["peer_fetches"] == 1
    assert router.stats["peer_fetch_bytes"] == PAGE
    # the fetch installed the chunk locally: second lookup is a local hit
    assert nodes[1].chunks.probe(dk) == "ram"
    assert set(catalog.chunk_holders(dk)) == {"node0", "node1"}
    router.audit()
    router.close()
    assert store.refcount(dk) == 0
    store.audit()


def test_publish_ingests_and_republish_releases_old_manifest(tmp_path):
    from repro.serve.cluster import FunctionCatalog

    store = ChunkStore(str(tmp_path / "cas"))
    catalog = FunctionCatalog(chunk_store=store)
    state = rng_state(8)
    p = str(tmp_path / "f.jif")
    snapshot(state, p, page_size=PAGE)
    catalog._ingest_chunks("f", p)
    n1 = store.audit()["chunks"]
    assert n1 > 0
    # republishing identical content must not grow the store or leak refs
    refs_before = store.audit()["refs"]
    catalog._ingest_chunks("f", p)
    assert store.audit()["chunks"] == n1
    assert store.audit()["refs"] == refs_before


# --------------------------------------------------- refcount property test
@pytest.mark.parametrize("seed", [7, 1234])
def test_refcount_property_random_interleavings(tmp_path, seed):
    """Random publish/evict/restore-style interleavings never orphan or
    double-free a CAS chunk; audit stays clean throughout."""
    rng = np.random.RandomState(seed)
    store = ChunkStore(str(tmp_path / "cas"))
    mem = NodeMemoryManager(32 * PAGE)
    caches = [NodeChunkCache(store, node=f"n{i}") for i in range(2)]
    for c in caches:
        c.attach(mem)

    # a small universe of images sharing chunks (sibling fine-tunes)
    images = []
    base = rng_state(20)
    for i in range(3):
        st = {**base, "layers": [dict(l) for l in base["layers"]]}
        st["layers"][i % 3]["w"] = st["layers"][i % 3]["w"] + float(i % 2)
        p = str(tmp_path / f"img{i}.jif")
        snapshot(st, p, page_size=PAGE)
        images.append(p)

    manifests = {}  # path -> live manifest ("published")
    pool = [chunk_digest(bytes([i]) * PAGE) for i in range(10)]

    for step in range(120):
        op = rng.randint(5)
        if op == 0:  # publish (or republish) an image
            p = images[rng.randint(len(images))]
            old = manifests.pop(p, None)
            manifests[p] = store.ingest_jif(p)[0]
            if old:
                store.release_many(old)
        elif op == 1 and manifests:  # unpublish
            p = list(manifests)[rng.randint(len(manifests))]
            store.release_many(manifests.pop(p))
        elif op == 2:  # a restore ingests chunks into a node cache
            c = caches[rng.randint(2)]
            i = rng.randint(len(pool))
            c.ingest(pool[i], bytes([i]) * PAGE)
        elif op == 3:  # node-local eviction of one chunk
            c = caches[rng.randint(2)]
            i = rng.randint(len(pool))
            if c.holds(pool[i]):
                c.drop(pool[i])
        else:  # memory pressure demotes RAM chunks
            mem.reclaim(rng.randint(1, 8) * PAGE)
        if step % 10 == 0:
            store.audit()
            mem.audit()

    store.audit()
    for p in list(manifests):
        store.release_many(manifests.pop(p))
    for c in caches:
        c.release_all()
    assert store.audit() == {"chunks": 0, "refs": 0}
    assert mem.kind_bytes()[KIND_CHUNK_CAS] == 0
    mem.audit()
