"""Device-restore fast path: UploadStream, DeviceImageCache, the fused
restore's equality with the eager path, install-policy selection on the
node, and the device-resident re-restore economics."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (
    BaseImage,
    NodeImageCache,
    NodeMemoryManager,
    SpiceRestorer,
    snapshot,
)
from repro.core.restore import TensorHandle
from repro.core.treeutil import flatten_state
from repro.core.upload import DeviceImageCache, DevicePath, UploadStream
from repro.models import lm
from repro.serve.engine import ServerlessNode, layerwise_state
from repro.serve.instance import InstanceState

ARCH = "qwen1.5-0.5b"
PROMPT = np.array([[3, 1, 4, 1, 5, 9]], dtype=np.int32)


# ------------------------------------------------------------ UploadStream
def test_upload_stream_full_upload_and_flush():
    up = UploadStream(depth=2, name="t-up")
    try:
        handles = []
        keep = []  # buffers must outlive the async jobs
        for i in range(5):
            h = TensorHandle(f"t{i}", (256,), "float32")
            buf = np.zeros(2048, np.uint8)
            buf[:1024] = np.frombuffer(
                np.full(256, float(i), np.float32).tobytes(), np.uint8
            )
            up.upload_full(h, buf, shape=(256,), dtype="float32", nbytes=1024)
            handles.append(h)
            keep.append(buf)
        assert up.flush(timeout=30)
        for i, h in enumerate(handles):
            arr = h.wait(timeout=5)
            assert np.all(np.asarray(arr) == float(i))
        st = up.snapshot_stats()
        assert st["uploads"] == 5
        assert st["uploaded_bytes"] == 5 * 1024
        assert st["failures"] == 0
    finally:
        up.close()
    up.close()  # idempotent


def test_upload_stream_release_called_after_upload_lands():
    """Staging buffers return to their release hook only once the device
    copy finished — the pool re-zeroes them, so an early release would
    corrupt the transfer."""
    released = []
    done = threading.Event()

    def release(buf):
        released.append(buf)
        done.set()

    up = UploadStream(depth=1)
    try:
        h = TensorHandle("t", (16,), "float32")
        buf = np.frombuffer(
            np.arange(16, dtype=np.float32).tobytes(), np.uint8
        ).copy()
        up.upload_full(h, buf, shape=(16,), dtype="float32", nbytes=64,
                       release=release)
        arr = h.wait(timeout=10)
        np.testing.assert_array_equal(
            np.asarray(arr), np.arange(16, dtype=np.float32)
        )
        assert done.wait(10)
        assert released and released[0] is buf
    finally:
        up.close()


def test_upload_stream_failure_fails_handle():
    def broken_install(arr):
        raise RuntimeError("device OOM")

    up = UploadStream(install=broken_install)
    try:
        h = TensorHandle("t", (4,), "float32")
        up.upload_full(h, np.zeros(16, np.uint8), shape=(4,),
                       dtype="float32", nbytes=16)
        with pytest.raises(RuntimeError, match="restore of t failed"):
            h.wait(timeout=10)
        assert up.flush(timeout=10)
        assert up.snapshot_stats()["failures"] == 1
    finally:
        up.close()
    with pytest.raises(RuntimeError, match="closed"):
        up.upload_full(TensorHandle("x", (1,), "float32"),
                       np.zeros(4, np.uint8), shape=(1,),
                       dtype="float32", nbytes=4)


# -------------------------------------------------------- DeviceImageCache
def _base_image(name="b", n_pages=4, page_bytes=512, seed=0):
    page_elems = page_bytes // 4
    raw = np.random.RandomState(seed).randn(
        n_pages * page_elems
    ).astype(np.float32)
    return BaseImage.from_state(name, {"w": raw}, page_size=page_bytes), raw


def test_device_image_cache_ledger_charge_and_reclaim_rung():
    base, raw = _base_image()
    mem = NodeMemoryManager(64 << 20)
    cache = DeviceImageCache()
    cache.attach(mem)
    pages = cache.get_pages(base, "w", 4, 128, np.float32)
    assert pages is not None
    np.testing.assert_array_equal(
        np.asarray(pages).reshape(-1), raw
    )
    assert mem.kind_bytes()["device_image"] == cache.resident_bytes() > 0
    mem.audit()
    # second lookup hits without rebuilding
    again = cache.get_pages(base, "w", 4, 128, np.float32)
    assert again is pages
    st = cache.snapshot_stats()
    assert st["hits"] == 1 and st["misses"] == 1
    # the reclaim rung drains the cache and uncharges the ledger
    freed = cache.reclaim(1 << 30)
    assert freed == st["built_bytes"]
    assert cache.resident_entries() == 0
    assert mem.kind_bytes()["device_image"] == 0
    mem.audit()


def test_device_image_cache_mismatch_returns_none():
    base, _ = _base_image(page_bytes=512)
    cache = DeviceImageCache()
    # page geometry disagrees with the base's page size -> host fallback
    assert cache.get_pages(base, "w", 4, 64, np.float32) is None
    # tensor absent from the base -> host fallback
    assert cache.get_pages(base, "nope", 4, 128, np.float32) is None


def test_device_image_cache_pressure_falls_back():
    base, _ = _base_image()
    mem = NodeMemoryManager(1024)  # far too small for the 8 KB of pages
    cache = DeviceImageCache()
    cache.attach(mem)
    assert cache.get_pages(base, "w", 4, 128, np.float32) is None
    assert mem.kind_bytes()["device_image"] == 0
    mem.audit()


# ------------------------------------------------- fused restore equality
def test_fused_delta_restore_matches_eager(tmp_path):
    ps = 512
    rng = np.random.RandomState(5)
    base_st = {
        "w0": rng.randn(4 * (ps // 4)).astype(np.float32),
        "w1": rng.randn(3 * (ps // 4) + 7).astype(np.float32),  # tail page
    }
    ft = {k: v.copy() for k, v in base_st.items()}
    ft["w0"][: ps // 4] += 1.0  # one dirty page each
    ft["w1"][: ps // 4] += 1.0
    parent = str(tmp_path / "p.jif")
    delta = str(tmp_path / "d.jif")
    snapshot(base_st, parent, page_size=ps)
    snapshot(ft, delta, parent=parent, page_size=ps)

    cache = NodeImageCache()
    r_ref = SpiceRestorer(
        node_cache=cache, transform=lambda a: jnp.array(a, copy=True)
    )
    ref_state, _, _, ref_stats = r_ref.restore(delta)
    r_ref.iosched.shutdown()

    up = UploadStream()
    dpath = DevicePath(upload=up, images=DeviceImageCache())
    r = SpiceRestorer(node_cache=cache, device_path=dpath)
    state, _, handles, st = r.restore(delta, wait=True)
    r.iosched.shutdown()
    up.close()

    l_ref, _ = flatten_state(ref_state)
    l_fused, _ = flatten_state(state)
    for (n1, a), (n2, b) in zip(l_ref, l_fused):
        assert n1 == n2
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=n1)
    # the fused tensors are real device arrays, not host staging views
    for h in handles.values():
        assert isinstance(h._arr, jax.Array)
    # only the private pages crossed to device; the patch covered the rest
    assert st.uploaded_bytes == 2 * ps
    assert st.uploaded_bytes < ref_stats.bytes_read + ref_stats.base_bytes
    assert st.patched_on_device_bytes == sum(a.nbytes for a in ft.values())
    assert st.bytes_read == 2 * ps  # reads also shrank to the private runs


# --------------------------------------------------- node install policies
@pytest.fixture(scope="module")
def policy_zoo(tmp_path_factory):
    d = tmp_path_factory.mktemp("policy-zoo")
    cfg = get_config(ARCH).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(2), jnp.float32)
    return d, cfg, params


def _publish(node, d, cfg, params, extra=None):
    base_key = "pol-base"
    node.node_cache.put(
        BaseImage.from_state(base_key, layerwise_state(cfg, params)),
        evictable=False,
    )
    tuned = dict(params)
    tuned["final_norm"] = tuned["final_norm"] + 0.01
    node.publish("pol-fn", cfg, tuned, str(d), base_name=base_key,
                 formats=("jif",), warm_ttl_s=60, extra_state=extra)


@pytest.mark.parametrize("install", ["host", "eager", "fused"])
def test_install_policy_end_to_end(policy_zoo, install, tmp_path):
    d, cfg, params = policy_zoo
    node = ServerlessNode(install=install)
    try:
        _publish(node, tmp_path, cfg, params)
        r = node.invoke("pol-fn", PROMPT, max_new_tokens=3, mode="spice",
                        cfg=cfg)
        assert r.cold
        assert node.scheduler.drain_residual()
        node.memory.audit()
        # every policy generates the same tokens
        node.evict()
        r2 = node.invoke("pol-fn", PROMPT, max_new_tokens=3,
                         mode="spice_sync", cfg=cfg)
        np.testing.assert_array_equal(r.tokens, r2.tokens)
    finally:
        node.close()


def test_install_policy_callable_and_invalid(policy_zoo):
    _d, cfg, _params = policy_zoo
    calls = []

    def spy(a):
        calls.append(a.nbytes)
        return jnp.array(a, copy=True)

    node = ServerlessNode(install=spy)
    try:
        transform, dpath = node.scheduler._install_policy()
        assert transform is spy and dpath is None
    finally:
        node.close()
    node = ServerlessNode(install="host")
    try:
        transform, dpath = node.scheduler._install_policy()
        assert transform is None and dpath is None
        assert node.scheduler.upload_stream is None
    finally:
        node.close()
    node = ServerlessNode(install="fused")
    try:
        transform, dpath = node.scheduler._install_policy()
        assert transform is None
        assert dpath.upload is node.scheduler.upload_stream
        assert dpath.images is node.scheduler.device_images
        node.scheduler.install = "bogus"
        with pytest.raises(ValueError, match="bogus"):
            node.scheduler._install_policy()
    finally:
        node.close()


# ------------------------------------ device-resident re-restore economics
def test_residual_evict_rerestore_keeps_device_base(policy_zoo, tmp_path):
    """Regression: a residual-evicted instance re-restored under the fused
    policy must read exactly the dropped residual bytes, serve its working
    set from the pinned memory (zero re-uploads for it), and reuse the
    HBM-resident device base without rebuilding a single entry."""
    _d, cfg, params = policy_zoo
    node = ServerlessNode(install="fused")
    try:
        extra = {"opt": np.ones((1 << 20,), np.float32)}  # 4 MB residual
        _publish(node, tmp_path, cfg, params, extra=extra)
        r1 = node.invoke("pol-fn", PROMPT, max_new_tokens=3, mode="spice",
                         cfg=cfg)
        assert r1.cold
        assert node.scheduler.drain_residual()
        inst = node.scheduler.instance("pol-fn")
        residual_bytes = inst.residual_region.nbytes
        images = node.scheduler.device_images
        mid = images.snapshot_stats()
        assert images.resident_bytes() > 0  # base pages live in HBM

        freed = node.scheduler.evict_residual("pol-fn")
        assert freed == residual_bytes
        assert inst.state is InstanceState.EVICTED
        node.memory.audit()
        up_before = node.scheduler.upload_stream.snapshot_stats()

        r2 = node.invoke("pol-fn", PROMPT, max_new_tokens=3, mode="spice",
                         cfg=cfg)
        assert r2.cold
        assert node.scheduler.drain_residual()
        d2 = inst.restore_stats.as_dict()
        # reads: exactly the dropped residual (chunk-padded per tensor)
        assert d2["reused_bytes"] > 0
        assert d2["bytes_read"] <= residual_bytes + 4096 * d2["residual_tensors"]
        # uploads: only the residual tensors crossed again — bounded by the
        # bytes re-read plus zero-page patches, nowhere near the image size
        up_after = node.scheduler.upload_stream.snapshot_stats()
        uploaded = up_after["uploaded_bytes"] - up_before["uploaded_bytes"]
        assert uploaded <= residual_bytes + 4096 * d2["residual_tensors"]
        # the device base was NOT rebuilt: no new cache builds (misses)
        after = images.snapshot_stats()
        assert after["misses"] == mid["misses"]
        np.testing.assert_array_equal(r1.tokens, r2.tokens)
        node.memory.audit()
    finally:
        node.close()
