"""Warmth policy engine: arrival histograms, adaptive keep-alive TTLs,
cost-aware eviction ranking, and speculative BATCH-class pre-warms that
join cleanly with real traffic and yield to the reclaim ladder."""
import time
import types

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import lm
from repro.serve.cluster import ClusterRouter, FunctionCatalog
from repro.serve.instance import InstanceState
from repro.serve.invocation import EVT_RESTORING, Invocation, QosClass
from repro.serve.node import KeepAlivePolicy, NodeScheduler
from repro.serve.prewarm import ArrivalTracker, PrewarmEngine, PrewarmPolicy

ARCH = "qwen1.5-0.5b"
PROMPT = np.array([[2, 7, 1, 8, 2, 8]], dtype=np.int32)


@pytest.fixture(scope="module")
def catalog_with_fns(tmp_path_factory):
    d = tmp_path_factory.mktemp("pwzoo")
    cfg = get_config(ARCH).reduced()
    catalog = FunctionCatalog()
    for i, fname in enumerate(["pw-a", "pw-b", "pw-c"]):
        params = lm.init_params(cfg, jax.random.PRNGKey(80 + i), jnp.float32)
        catalog.publish(fname, cfg, params, str(d), warm_ttl_s=0.0,
                        formats=("jif",))
    node = NodeScheduler(registry=catalog.registry)  # compile-cache warmup
    node.invoke("pw-a", PROMPT, max_new_tokens=2, mode="spice_sync", cfg=cfg)
    return catalog, cfg


# ------------------------------------------------------------ ArrivalTracker
def test_tracker_needs_two_arrivals_for_a_gap():
    tr = ArrivalTracker()
    tr.record("f", now=100.0)
    assert tr.observations("f") == 0
    assert tr.gap_quantile("f", 0.5) is None
    assert tr.predict_eta("f", now=101.0) is None
    assert tr.observations("missing") == 0


def test_tracker_quantiles_and_eta_for_periodic_traffic():
    tr = ArrivalTracker()
    for t in (0.0, 0.4, 0.8, 1.2):
        tr.record("f", now=t)
    assert tr.observations("f") == 3
    # all gaps land in one bucket whose max is the exact period
    assert tr.gap_quantile("f", 0.5) == pytest.approx(0.4)
    assert tr.gap_quantile("f", 0.9) == pytest.approx(0.4)
    # predicted next arrival = last + median gap
    assert tr.predict_eta("f", now=1.3) == pytest.approx(0.3)
    assert tr.predict_eta("f", now=2.0) == pytest.approx(-0.4)  # overdue


def test_tracker_quantiles_are_monotonic_with_mixed_gaps():
    tr = ArrivalTracker()
    t = 0.0
    tr.record("f", now=t)
    for gap in (0.1, 0.1, 0.1, 0.1, 0.1, 0.1, 0.1, 3.0):
        t += gap
        tr.record("f", now=t)
    q50, q95 = tr.gap_quantile("f", 0.5), tr.gap_quantile("f", 0.95)
    assert q50 <= q95
    assert q50 == pytest.approx(0.1)
    assert q95 == pytest.approx(3.0)
    assert tr.observations("f") == 8
    assert "f" in tr.snapshot()


def test_tracker_min_observations_gate():
    tr = ArrivalTracker()
    for t in (0.0, 0.5):
        tr.record("f", now=t)
    assert tr.gap_quantile("f", 0.5, min_observations=2) is None
    assert tr.predict_eta("f", now=0.6, min_observations=2) is None
    tr.record("f", now=1.0)
    assert tr.gap_quantile("f", 0.5, min_observations=2) == pytest.approx(0.5)


# ----------------------------------------------------------- adaptive TTLs
def _spec(name, warm_ttl_s=7.0):
    return types.SimpleNamespace(name=name, warm_ttl_s=warm_ttl_s)


def test_ttl_for_head_tail_and_fallback():
    tr = ArrivalTracker()
    for t in (0.0, 0.2, 0.4, 0.6):  # head: periodic, short gaps
        tr.record("head", now=t)
    for t in (0.0, 100.0, 200.0, 300.0):  # long tail: huge gaps
        tr.record("tail", now=t)
    pol = PrewarmPolicy(tr, max_ttl_s=30.0, tail_ttl_s=0.5, ttl_margin=1.25,
                        min_observations=2)
    # head window = p90 gap x margin, above the floor
    assert pol.ttl_for(_spec("head")) == pytest.approx(0.25)
    # tail would need a 125 s window: rely on restore instead
    assert pol.ttl_for(_spec("tail")) == 0.5
    # no history: the spec's static TTL ...
    assert pol.ttl_for(_spec("unknown")) == 7.0
    # ... unless an explicit default overrides it
    pol2 = PrewarmPolicy(tr, default_ttl_s=1.5, min_observations=2)
    assert pol2.ttl_for(_spec("unknown")) == 1.5


# ------------------------------------------------------- eviction contracts
class _Inst:
    def __init__(self, name, last_used, nbytes):
        self.spec = types.SimpleNamespace(name=name)
        self.last_used = last_used
        self.restore_stats = None
        self.memory_bytes = nbytes


def test_default_victims_honors_need_evict_lru_first():
    """Regression: the default policy used to return the whole warm list
    regardless of ``need_evict``."""
    pol = KeepAlivePolicy()
    warm = [_Inst("a", 3.0, 1), _Inst("b", 1.0, 1), _Inst("c", 2.0, 1)]
    got = pol.victims(warm, need_evict=2)
    assert [i.spec.name for i in got] == ["b", "c"]  # LRU-first, at most 2
    assert pol.victims(warm, need_evict=0) == []
    assert len(pol.victims(warm, need_evict=99)) == 3


def test_cost_aware_victims_rank_cheap_and_far_first():
    now = time.monotonic()
    tr = ArrivalTracker()
    # "soon": period 1.0, next arrival ~now -> tiny eta -> penalty spike
    tr.record("soon", now=now - 2.0)
    tr.record("soon", now=now - 1.0)
    # "later": period 30, next arrival ~now+15
    tr.record("later", now=now - 45.0)
    tr.record("later", now=now - 15.0)
    pol = PrewarmPolicy(tr, min_observations=1, unknown_eta_s=60.0,
                        cost_fn=lambda i: i.memory_bytes)
    soon = _Inst("soon", 5.0, 1 << 20)
    later = _Inst("later", 1.0, 1 << 20)
    pricey = _Inst("pricey-later", 2.0, 64 << 20)  # no history: eta=60 s
    got = pol.victims([soon, later, pricey], need_evict=2)
    # cheapest-to-re-restore x farthest-from-needed go first; the
    # imminent instance survives even though it is equally cheap
    assert [i.spec.name for i in got] == ["later", "pricey-later"]
    assert pol.victims([soon, later, pricey], need_evict=0) == []


# ------------------------------------------------- speculation end-to-end
def _warm_history(engine, fname, period=0.2, n=3):
    """Feed ``n`` arrivals ending now, so the predicted next arrival is
    ``period`` seconds out (inside any reasonable horizon)."""
    now = time.monotonic()
    for k in range(n, 0, -1):
        engine.on_arrival(fname, now=now - period * (k - 1))


def test_speculative_restore_promotes_warm_without_generation(catalog_with_fns):
    catalog, cfg = catalog_with_fns
    tracker = ArrivalTracker()
    engine = PrewarmEngine(tracker, horizon_s=5.0, interval_s=None,
                           min_observations=2)
    node = NodeScheduler(
        registry=catalog.registry,
        keepalive=PrewarmPolicy(tracker, default_ttl_s=30.0,
                                min_observations=2),
    )
    router = ClusterRouter(catalog, [node], prewarm=engine)
    try:
        # one real invocation: sticky placement + the instance's cfg
        r0 = router.invoke("pw-a", PROMPT, max_new_tokens=2, mode="spice",
                           cfg=cfg)
        assert r0.cold
        node.evict("pw-a")
        _warm_history(engine, "pw-a")
        assert engine.tick() == 1
        assert engine.drain(30.0)
        inst = node.instance("pw-a")
        assert inst.state is InstanceState.WARM
        assert node.stats["speculative_restores"] == 1
        assert node.stats["cold_starts"] == 1  # only the priming call
        assert engine.stats["speculative_ok"] == 1
        # the real arrival the engine predicted: a plain warm hit
        r1 = router.invoke("pw-a", PROMPT, max_new_tokens=2, mode="spice",
                           cfg=cfg)
        assert not r1.cold
        np.testing.assert_array_equal(r0.tokens, r1.tokens)
    finally:
        router.close()


def test_engine_suppresses_resident_and_unknown_functions(catalog_with_fns):
    catalog, cfg = catalog_with_fns
    engine = PrewarmEngine(horizon_s=5.0, interval_s=None, min_observations=2)
    node = NodeScheduler(
        registry=catalog.registry,
        keepalive=PrewarmPolicy(engine.tracker, default_ttl_s=30.0,
                                min_observations=2),
    )
    router = ClusterRouter(catalog, [node], prewarm=engine)
    try:
        router.invoke("pw-a", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
        _warm_history(engine, "pw-a")      # warm: must not re-restore
        _warm_history(engine, "ghost-fn")  # tracked but never published
        assert engine.tick() == 0
        assert engine.stats["suppressed_resident"] == 1
        assert node.stats["speculative_restores"] == 0
    finally:
        router.close()


def test_real_invocation_joins_inflight_speculative_restore(catalog_with_fns):
    """A real arrival mid-speculation rides the SAME restore: exactly one
    restore owner (the speculation), the real result marked joined, its
    timeline showing the RESTORING ride."""
    catalog, cfg = catalog_with_fns
    engine = PrewarmEngine(horizon_s=5.0, interval_s=None, min_observations=2,
                           simulate_read_bw=4e6)  # slow restore: ~1 s window
    node = NodeScheduler(
        registry=catalog.registry,
        keepalive=PrewarmPolicy(engine.tracker, default_ttl_s=30.0,
                                min_observations=2),
    )
    router = ClusterRouter(catalog, [node], prewarm=engine)
    try:
        router.invoke("pw-b", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
        node.evict("pw-b")
        _warm_history(engine, "pw-b")
        assert engine.tick() == 1
        inst = node.instance("pw-b")
        deadline = time.monotonic() + 10.0
        while (inst.state is not InstanceState.RESTORING
               and time.monotonic() < deadline):
            time.sleep(0.002)
        assert inst.state is InstanceState.RESTORING
        h = router.submit_invocation(Invocation(
            function="pw-b", prompt=PROMPT, max_new_tokens=2, mode="spice",
            cfg=cfg, qos=QosClass.LATENCY,
        ))
        r = h.result(60.0)
        assert r.joined and r.cold
        assert h.event_ts(EVT_RESTORING) is not None
        assert engine.drain(30.0)
        # one restore total for this round: the speculation owned it
        assert node.stats["speculative_restores"] == 1
        assert node.stats["cold_starts"] == 1  # only the priming call
    finally:
        router.close()


def test_redundant_speculation_against_warm_instance_is_a_noop(catalog_with_fns):
    catalog, cfg = catalog_with_fns
    node = NodeScheduler(
        registry=catalog.registry,
        keepalive=PrewarmPolicy(ArrivalTracker(), default_ttl_s=30.0),
    )
    router = ClusterRouter(catalog, [node])
    try:
        router.invoke("pw-a", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
        h = router.submit_invocation(Invocation(
            function="pw-a", prompt=None, max_new_tokens=0, mode="spice",
            qos=QosClass.BATCH, prewarm=True,
        ))
        r = h.result(30.0)
        assert r.mode == "prewarm" and not r.cold
        assert node.stats["prewarm_redundant"] == 1
        assert node.stats["speculative_restores"] == 0
    finally:
        router.close()


# ------------------------------------------------------- reaper + reclaim
def test_reaper_honors_adaptive_ttls(catalog_with_fns):
    catalog, cfg = catalog_with_fns
    tracker = ArrivalTracker()
    node = NodeScheduler(
        registry=catalog.registry,
        keepalive=PrewarmPolicy(tracker, min_observations=1, max_ttl_s=30.0),
    )
    router = ClusterRouter(catalog, [node])
    try:
        now = time.monotonic()
        for t in (now - 0.3, now - 0.15, now):   # pw-b: ~0.19 s window
            tracker.record("pw-b", now=t)
        for t in (now - 20.0, now - 10.0, now):  # pw-c: ~12.5 s window
            tracker.record("pw-c", now=t)
        router.invoke("pw-b", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
        router.invoke("pw-c", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
        assert node.instance("pw-b").state is InstanceState.WARM
        time.sleep(0.4)  # past pw-b's adaptive TTL, well inside pw-c's
        assert node.reap_expired() == 1
        assert node.instance("pw-b").state is InstanceState.EVICTED
        assert node.instance("pw-c").state is InstanceState.WARM
    finally:
        router.close()


def test_mispredicted_speculation_yields_to_reclaim_ladder(catalog_with_fns):
    """A speculative instance whose predicted arrival never comes is just
    idle warm memory: the reclaim ladder takes it back and the ledger
    stays audit-clean."""
    catalog, cfg = catalog_with_fns
    engine = PrewarmEngine(horizon_s=5.0, interval_s=None, min_observations=2)
    node = NodeScheduler(
        registry=catalog.registry,
        keepalive=PrewarmPolicy(engine.tracker, default_ttl_s=300.0,
                                min_observations=2),
    )
    router = ClusterRouter(catalog, [node], prewarm=engine)
    try:
        router.invoke("pw-c", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
        node.evict("pw-c")
        _warm_history(engine, "pw-c")
        assert engine.tick() == 1
        assert engine.drain(30.0)
        inst = node.instance("pw-c")
        assert inst.state is InstanceState.WARM
        freed = node.memory.reclaim(node.memory.held_bytes() + 1)
        assert freed > 0
        assert inst.state is InstanceState.EVICTED
        assert node.stats["lru_evictions"] >= 1
        node.memory.audit()  # raises if the ledger disagrees
    finally:
        router.close()
