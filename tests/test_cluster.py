"""Cluster serving layer: control-plane/data-plane split (FunctionCatalog
vs NodeScheduler), snapshot-locality-aware placement across N nodes, sticky
join routing, the scale-out knob, and registry persistence under the split."""
import threading
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import BaseImage, FunctionRegistry
from repro.models import lm
from repro.serve.cluster import (
    ClusterRouter,
    FunctionCatalog,
    LeastLoaded,
    LocalityFirst,
    RoundRobin,
)
from repro.serve.engine import ServerlessNode
from repro.serve.instance import InstanceState
from repro.serve.node import FixedTTLPolicy, KeepAlivePolicy, NodeScheduler

ARCH = "qwen1.5-0.5b"
PROMPT = np.array([[2, 7, 1, 8, 2, 8]], dtype=np.int32)


@pytest.fixture(scope="module")
def catalog_with_zoo(tmp_path_factory):
    """A catalog owning three published functions (plain JIFs), plus the
    config — nodes are built fresh per test (they are cheap; the zoo and
    the jit compile cache are not)."""
    d = tmp_path_factory.mktemp("czoo")
    cfg = get_config(ARCH).reduced()
    catalog = FunctionCatalog()
    for i, fname in enumerate(["cl-a", "cl-b", "cl-c"]):
        params = lm.init_params(cfg, jax.random.PRNGKey(40 + i), jnp.float32)
        catalog.publish(fname, cfg, params, str(d), warm_ttl_s=3600.0,
                        formats=("jif",))
    # compile-cache warmup through a throwaway single node
    node = NodeScheduler(registry=catalog.registry)
    node.invoke("cl-a", PROMPT, max_new_tokens=2, mode="spice_sync", cfg=cfg)
    return catalog, cfg, str(d)


def _cluster(catalog, n=3, placement=None, **kwargs):
    nodes = [
        NodeScheduler(registry=catalog.registry, keepalive=FixedTTLPolicy(3600.0))
        for _ in range(n)
    ]
    return ClusterRouter(catalog, nodes, placement=placement, **kwargs)


# ------------------------------------------------------------- control plane
def test_catalog_owns_registry_and_nodes_reference_it(catalog_with_zoo):
    catalog, cfg, _ = catalog_with_zoo
    router = _cluster(catalog)
    for node in router.nodes:
        assert node.registry is catalog.registry
    assert set(catalog.registry.names()) >= {"cl-a", "cl-b", "cl-c"}


def test_registry_roundtrip_under_catalog_split(catalog_with_zoo, tmp_path):
    """Registry save/load survives the split: a catalog rebuilt from disk
    serves invocations on a brand-new node with identical tokens."""
    catalog, cfg, _ = catalog_with_zoo
    ref = _cluster(catalog, n=1).invoke(
        "cl-b", PROMPT, max_new_tokens=3, mode="spice", cfg=cfg
    )

    path = str(tmp_path / "registry.json")
    catalog.save(path)
    loaded = FunctionCatalog.load(path)
    assert loaded.registry.names() == catalog.registry.names()
    spec0, spec1 = catalog.registry.get("cl-b"), loaded.registry.get("cl-b")
    assert (spec0.jif_path, spec0.base_image, spec0.warm_ttl_s) == (
        spec1.jif_path, spec1.base_image, spec1.warm_ttl_s
    )

    node = ServerlessNode(catalog=loaded)
    r = node.invoke("cl-b", PROMPT, max_new_tokens=3, mode="spice", cfg=cfg)
    assert r.cold and r.node == ""  # single-node path: empty node name
    np.testing.assert_array_equal(r.tokens, ref.tokens)


def test_single_node_facade_keeps_surface(catalog_with_zoo, tmp_path):
    """publish/invoke/record_access/relayout still work through the facade
    (catalog behind it), and the data plane carries no publish path."""
    catalog, cfg, _ = catalog_with_zoo
    node = ServerlessNode()
    params = lm.init_params(cfg, jax.random.PRNGKey(77), jnp.float32)
    node.publish("fac-fn", cfg, params, str(tmp_path), warm_ttl_s=60,
                 formats=("jif",))
    assert node.catalog.stats["publishes"] == 1
    r = node.invoke("fac-fn", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
    assert r.cold
    order = node.record_access("fac-fn", PROMPT, max_new_tokens=2, cfg=cfg)
    assert order and node.catalog.recorded_order("fac-fn") == order
    stats = node.relayout("fac-fn")
    assert stats.ws_tensors == len(order)
    assert not hasattr(node.scheduler, "publish")  # pure data plane


# ------------------------------------------------------------ sticky routing
def test_locality_first_sticks_and_second_invoke_is_warm(catalog_with_zoo):
    catalog, cfg, _ = catalog_with_zoo
    router = _cluster(catalog)
    r1 = router.invoke("cl-a", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
    r2 = router.invoke("cl-a", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
    assert r1.cold and not r2.cold
    assert r1.node == r2.node and r1.node.startswith("node")
    assert router.replicas("cl-a") == [r1.node]
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    router.audit()


def test_concurrent_burst_joins_on_one_node_zero_duplicate_colds(catalog_with_zoo):
    """Single population per cluster: a burst of one function's invocations
    rides ONE restore on ONE node — no duplicate concurrent cold restores
    anywhere in the fleet."""
    catalog, cfg, _ = catalog_with_zoo
    router = _cluster(catalog)
    futs = [
        router.submit("cl-b", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg,
                      simulate_read_bw=5e8)
        for _ in range(5)
    ]
    results = [f.result() for f in futs]
    assert len({r.node for r in results}) == 1
    real_colds = sum(1 for r in results if r.cold and not r.joined)
    joined = sum(1 for r in results if r.joined)
    assert real_colds == 1 and joined == len(results) - 1
    toks = results[0].tokens
    for r in results[1:]:
        np.testing.assert_array_equal(r.tokens, toks)
    # cluster-wide: only one node ever cold-started this function
    assert sum(n.stats["cold_starts"] for n in router.nodes) == 1
    router.audit()


def test_round_robin_spreads_while_locality_does_not(catalog_with_zoo):
    catalog, cfg, _ = catalog_with_zoo
    router = _cluster(catalog, placement=RoundRobin())
    nodes_hit = []
    for _ in range(3):
        r = router.invoke("cl-c", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
        nodes_hit.append(r.node)
        assert r.cold  # every placement is a fresh node: always cold
    assert len(set(nodes_hit)) == 3
    router.audit()


def test_least_loaded_avoids_busy_node(catalog_with_zoo):
    catalog, cfg, _ = catalog_with_zoo
    router = _cluster(catalog, n=2, placement=LeastLoaded())
    # jam node0 with a slow restore, then place a different function
    f0 = router.nodes[0].submit("cl-a", PROMPT, max_new_tokens=2, mode="spice",
                                cfg=cfg, simulate_read_bw=2e7)
    deadline = time.time() + 5
    while router.nodes[0].load().queue_depth == 0 and time.time() < deadline:
        time.sleep(0.005)
    r = router.invoke("cl-b", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
    assert r.node == "node1"
    f0.result()
    router.audit()


# ------------------------------------------------------- locality tiers
def test_locality_first_prefers_cached_base_image(catalog_with_zoo, tmp_path):
    """Tier 3 (base-image-cached): the node already holding the function's
    base image wins placement over emptier nodes."""
    catalog, cfg, _ = catalog_with_zoo
    base_params = lm.init_params(cfg, jax.random.PRNGKey(90), jnp.float32)
    from repro.serve.instance import layerwise_state

    img = BaseImage.from_state("tier-base", layerwise_state(cfg, base_params))
    catalog.install_base(img)  # authoring-side: publish dedups against it
    # fine-tune ONE projection so most chunks stay BASE (dedup-able)
    ft = jax.tree.map(np.asarray, base_params)
    ft["pattern"] = list(ft["pattern"])
    ft["pattern"][0] = dict(ft["pattern"][0])
    ft["pattern"][0]["attn"] = dict(ft["pattern"][0]["attn"])
    ft["pattern"][0]["attn"]["wq"] = ft["pattern"][0]["attn"]["wq"] * 1.01
    catalog.publish("tier-fn", cfg, ft, str(tmp_path), base_name="tier-base",
                    warm_ttl_s=3600.0, formats=("jif",))

    router = _cluster(catalog)
    router.nodes[2].node_cache.put(img, evictable=False)  # only node2 has it
    r = router.invoke("tier-fn", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
    assert r.node == "node2"
    assert router.nodes[2].node_cache.stats["base_bytes_served"] > 0
    router.audit()


def test_locality_first_prefers_delta_parent_cached_node(catalog_with_zoo, tmp_path):
    """Tier 4 (delta-parent-cached): after one node bootstraps a delta's
    parent from disk, an unrelated fresh placement of a sibling delta goes
    to that node — its resident parent makes the restore private-only."""
    catalog, cfg, _ = catalog_with_zoo
    from repro.core import snapshot
    from repro.serve.instance import layerwise_state

    base_params = lm.init_params(cfg, jax.random.PRNGKey(91), jnp.float32)
    parent_path = str(tmp_path / "parent.jif")
    snapshot(layerwise_state(cfg, base_params), parent_path)
    for i, fname in enumerate(["delta-x", "delta-y"]):
        ft = jax.tree.map(lambda a: np.asarray(a) * (1.01 + 0.01 * i), base_params)
        catalog.publish(fname, cfg, ft, str(tmp_path), parent=parent_path,
                        warm_ttl_s=3600.0, formats=("jif",))

    router = _cluster(catalog)
    r1 = router.invoke("delta-x", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
    serving = router.node(r1.node)
    key = catalog.locality_key("delta-x")
    assert key is not None and serving.node_cache.contains(key)
    assert catalog.locality_key("delta-y") == key  # same parent chain

    # sibling delta: the parent-cached node must win placement
    r2 = router.invoke("delta-y", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
    assert r2.node == r1.node
    # ...and the parent was bootstrapped exactly once cluster-wide
    assert sum(1 for n in router.nodes if n.node_cache.contains(key)) == 1
    router.audit()

    # relayout must preserve the delta chain: same parent ref, still
    # delta-sized, locality key intact (regression: a chain-dropping
    # rewrite would balloon the file and erase the placement tier)
    import os

    spec = catalog.registry.get("delta-x")
    size_before = os.path.getsize(spec.jif_path)
    order = router.record_access("delta-x", prompt=PROMPT, max_new_tokens=2,
                                 cfg=cfg)
    stats = router.relayout("delta-x")
    assert stats.parent == os.path.abspath(parent_path)
    assert os.path.getsize(spec.jif_path) < 0.6 * os.path.getsize(parent_path) \
        or os.path.getsize(spec.jif_path) <= 1.2 * size_before
    assert catalog.locality_key("delta-x") is not None
    r3 = ClusterRouter(catalog, [NodeScheduler(registry=catalog.registry)]) \
        .invoke("delta-x", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
    np.testing.assert_array_equal(r3.tokens, r1.tokens)


def test_scale_out_knob_spawns_second_replica(catalog_with_zoo):
    catalog, cfg, _ = catalog_with_zoo
    router = _cluster(catalog, scale_out_queue_depth=1)
    futs = [
        router.submit("cl-a", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg,
                      simulate_read_bw=5e7)
        for _ in range(6)
    ]
    for f in futs:
        f.result()
    assert len(router.replicas("cl-a")) >= 2
    assert router.stats["scale_outs"] >= 1
    router.audit()


def test_node_load_probe_surface(catalog_with_zoo):
    catalog, cfg, _ = catalog_with_zoo
    router = _cluster(catalog, n=2)
    r = router.invoke("cl-a", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
    router.drain_residual()
    loads = {l.node: l for l in router.loads()}
    assert set(loads) == {"node0", "node1"}
    serving = loads[r.node]
    assert "cl-a" in serving.warm and serving.warm_bytes > 0
    assert serving.queue_depth == 0 and serving.pressure >= 0.0
    other = loads[{"node0", "node1"}.difference({r.node}).pop()]
    assert "cl-a" not in other.warm


# --------------------------------------------------------- keep-alive policy
def test_custom_keepalive_victims_ordering(catalog_with_zoo, tmp_path):
    """The pluggable victims() contract: eviction under pressure follows
    the policy's order, not the built-in LRU."""

    class EvictNamedFirst(KeepAlivePolicy):
        def __init__(self, first: str):
            self.first = first

        def ttl_for(self, spec):
            return 3600.0

        def victims(self, warm, need_evict):
            return sorted(
                warm, key=lambda i: (i.spec.name != self.first, i.last_used)
            )

    catalog, cfg, _ = catalog_with_zoo
    # "cl-b" is MRU — default LRU would sacrifice cl-a first; the custom
    # policy must pick cl-b regardless
    node = NodeScheduler(registry=catalog.registry,
                         keepalive=EvictNamedFirst("cl-b"))
    node.invoke("cl-b", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
    node.invoke("cl-a", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
    node.drain_residual()
    inst_b = node.instance("cl-b")
    inst_b.last_used = time.time() + 100  # force MRU: LRU would never pick it
    freed = node._reclaim_warm_lru(1, protect=frozenset())
    assert freed > 0
    assert node.instance("cl-b").state is InstanceState.EVICTED
    assert node.instance("cl-a").state is InstanceState.WARM
    node.memory.audit()
