"""Invocation API v2: typed requests, QoS dispatch order, deadlines,
admission control, cancellation races (queued / mid-RESTORING / post-
WS_READY), and a seeded property test that random cancel/deadline
interleavings never leak ledger bytes."""
import threading
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import lm
from repro.serve.cluster import ClusterRouter, FunctionCatalog, LocalityFirst
from repro.serve.instance import InstanceState
from repro.serve.invocation import (
    EVT_ADMITTED,
    EVT_CANCELLED,
    EVT_DONE,
    EVT_PLACED,
    EVT_REJECTED,
    EVT_RESTORING,
    EVT_RUNNING,
    EVT_WS_READY,
    AdmissionController,
    DeadlineExceeded,
    Invocation,
    InvocationCancelled,
    Overloaded,
    QosClass,
    deadline_in,
)
from repro.serve.node import FixedTTLPolicy, NodeScheduler

ARCH = "qwen1.5-0.5b"
PROMPT = np.array([[5, 3, 1, 7, 2, 6]], dtype=np.int32)
SLOW_BW = 2e7  # simulated read bandwidth that keeps a restore in flight


@pytest.fixture(scope="module")
def qzoo(tmp_path_factory):
    """Two functions (with a residual tail behind the ws boundary) plus a
    reference token sequence; nodes are built fresh per test."""
    d = tmp_path_factory.mktemp("qzoo")
    cfg = get_config(ARCH).reduced()
    catalog = FunctionCatalog()
    extra = {"opt": np.ones((1 << 20,), np.float32)}  # 4 MB residual
    for i, fname in enumerate(["q-a", "q-b"]):
        params = lm.init_params(cfg, jax.random.PRNGKey(100 + i), jnp.float32)
        catalog.publish(fname, cfg, params, str(d), warm_ttl_s=3600.0,
                        formats=("jif",), extra_state=extra)
    node = NodeScheduler(registry=catalog.registry)
    ref = {
        f: node.invoke(f, PROMPT, max_new_tokens=3, mode="spice_sync", cfg=cfg).tokens
        for f in ["q-a", "q-b"]
    }
    return catalog, cfg, ref


def _node(catalog, **kwargs):
    kwargs.setdefault("keepalive", FixedTTLPolicy(3600.0))
    return NodeScheduler(registry=catalog.registry, **kwargs)


def _evts(handle):
    return [e for e, _ in handle.events()]


# ------------------------------------------------------------ typed surface
def test_typed_invocation_timeline_and_result(qzoo):
    catalog, cfg, ref = qzoo
    node = _node(catalog)
    h = node.submit_invocation(Invocation(
        function="q-a", prompt=PROMPT, max_new_tokens=3, cfg=cfg,
        qos=QosClass.LATENCY,
    ))
    r = h.result(60)
    np.testing.assert_array_equal(r.tokens, ref["q-a"])
    assert r.cold and r.qos == "latency"
    evts = _evts(h)
    # cold owner: ADMITTED -> PLACED -> RESTORING -> ... -> DONE, with
    # WS_READY and RUNNING both present (RUNNING may precede WS_READY:
    # layer-gated generation overlaps the residual stream)
    assert evts[:3] == [EVT_ADMITTED, EVT_PLACED, EVT_RESTORING]
    assert evts[-1] == EVT_DONE
    assert EVT_WS_READY in evts and EVT_RUNNING in evts
    assert r.timeline == h.events()[:-1]  # result snapshot precedes DONE
    assert r.queue_wait_s >= 0.0 and r.admitted_ts > 0.0
    # warm repeat: WS_READY precedes RUNNING, queue split still derived
    h2 = node.submit_invocation(Invocation("q-a", PROMPT, 3, cfg=cfg))
    r2 = h2.result(60)
    assert not r2.cold
    evts2 = _evts(h2)
    assert evts2.index(EVT_WS_READY) < evts2.index(EVT_RUNNING)
    np.testing.assert_array_equal(r2.tokens, ref["q-a"])
    node.memory.audit()


def test_legacy_submit_handle_ducktypes_future(qzoo):
    catalog, cfg, ref = qzoo
    node = _node(catalog)
    f = node.submit("q-b", PROMPT, max_new_tokens=3, cfg=cfg)
    r = f.result()
    assert f.done() and not f.cancelled() and f.exception() is None
    assert r.qos == "standard"  # legacy wrapper is STANDARD class
    np.testing.assert_array_equal(r.tokens, ref["q-b"])


# ------------------------------------------------------------- cancellation
def test_cancel_while_queued_never_runs(qzoo):
    catalog, cfg, ref = qzoo
    node = _node(catalog, max_workers=1)
    jam = node.submit_invocation(Invocation(
        "q-a", PROMPT, 2, cfg=cfg, simulate_read_bw=SLOW_BW))
    queued = node.submit_invocation(Invocation("q-b", PROMPT, 2, cfg=cfg))
    assert queued.cancel()
    assert queued.cancel()  # idempotent
    with pytest.raises(InvocationCancelled):
        queued.result(60)
    assert queued.cancelled()
    assert EVT_RESTORING not in _evts(queued)  # it never ran
    assert _evts(queued)[-1] == EVT_CANCELLED
    assert node.instance("q-b") is None  # no instance was ever created
    jam.result(60)
    assert node.stats["cancellations"] == 1
    node.memory.audit()


def test_cancel_mid_restoring_aborts_stream_and_releases_ledger(qzoo):
    catalog, cfg, ref = qzoo
    node = _node(catalog)
    h = node.submit_invocation(Invocation(
        "q-a", PROMPT, 2, cfg=cfg, qos=QosClass.BATCH,
        simulate_read_bw=SLOW_BW))
    # wait until the restore owns a stream (RESTORING recorded), then a
    # beat more so reads are genuinely in flight
    deadline = time.time() + 10
    while EVT_RESTORING not in _evts(h) and time.time() < deadline:
        time.sleep(0.002)
    time.sleep(0.02)
    assert h.cancel()
    with pytest.raises(InvocationCancelled):
        h.result(60)
    assert h.cancelled() and _evts(h)[-1] == EVT_CANCELLED
    inst = node.instance("q-a")
    assert inst.state in (InstanceState.EVICTED, InstanceState.COLD)
    # every ledger reservation returned through the failure paths
    kinds = node.memory.kind_bytes()
    assert kinds["working_set"] == 0 and kinds["residual"] == 0
    node.memory.audit()
    # the function is not poisoned: the next invocation restores cleanly
    r = node.invoke("q-a", PROMPT, max_new_tokens=3, cfg=cfg)
    assert r.cold
    np.testing.assert_array_equal(r.tokens, ref["q-a"])
    node.memory.audit()


def test_cancel_after_ws_ready_is_noop_result_delivered(qzoo):
    catalog, cfg, ref = qzoo
    node = _node(catalog)
    h = node.submit_invocation(Invocation(
        "q-b", PROMPT, 3, cfg=cfg, simulate_read_bw=5e8))
    deadline = time.time() + 30
    while EVT_WS_READY not in _evts(h) and time.time() < deadline:
        time.sleep(0.002)
    assert EVT_WS_READY in _evts(h)
    assert not h.cancel()  # past the point of no return
    r = h.result(60)  # result still delivered
    assert not h.cancelled()
    np.testing.assert_array_equal(r.tokens, ref["q-b"])
    node.drain_residual()
    node.memory.audit()


def test_cancel_with_joiner_declines_and_joiner_survives(qzoo):
    """Cancelling the restore owner while a joiner rides the same stream
    must NOT abort it: the cancel is refused, both results deliver."""
    catalog, cfg, ref = qzoo
    node = _node(catalog)
    owner = node.submit_invocation(Invocation(
        "q-a", PROMPT, 2, cfg=cfg, simulate_read_bw=SLOW_BW))
    deadline = time.time() + 10
    while EVT_RESTORING not in _evts(owner) and time.time() < deadline:
        time.sleep(0.002)
    joiner = node.submit_invocation(Invocation("q-a", PROMPT, 2, cfg=cfg))
    # wait until the joiner actually joined (RUNNING over the shared tree)
    while EVT_RUNNING not in _evts(joiner) and time.time() < deadline:
        time.sleep(0.002)
    cancelled = owner.cancel()
    r_j = joiner.result(60)
    if cancelled:
        # raced: the joiner bumped inflight after the abort check — the
        # joiner must still END UP with a correct result via its retry
        assert r_j.function == "q-a"
    else:
        r_o = owner.result(60)
        np.testing.assert_array_equal(r_o.tokens, ref["q-a"][:, :2])
    np.testing.assert_array_equal(r_j.tokens, ref["q-a"][:, :2])
    node.drain_residual()
    node.memory.audit()


# ------------------------------------------------------ deadlines/admission
def test_deadline_already_passed_rejects_at_submit(qzoo):
    catalog, cfg, _ = qzoo
    node = _node(catalog)
    with pytest.raises(DeadlineExceeded):
        node.submit_invocation(Invocation(
            "q-a", PROMPT, 2, cfg=cfg, deadline_s=deadline_in(-0.1)))
    assert node.stats["rejected_deadline"] == 1


def test_deadline_expires_in_queue_typed_rejection(qzoo):
    catalog, cfg, _ = qzoo
    node = _node(catalog, max_workers=1)
    jam = node.submit_invocation(Invocation(
        "q-a", PROMPT, 2, cfg=cfg, simulate_read_bw=SLOW_BW))
    doomed = node.submit_invocation(Invocation(
        "q-b", PROMPT, 2, cfg=cfg, deadline_s=deadline_in(0.02)))
    with pytest.raises(DeadlineExceeded):
        doomed.result(60)
    assert _evts(doomed)[-1] == EVT_REJECTED
    jam.result(60)
    assert node.stats["rejected_deadline"] >= 1
    node.memory.audit()


def test_admission_bounded_queue_overloaded(qzoo):
    catalog, cfg, _ = qzoo
    node = _node(catalog, max_workers=1,
                 admission=AdmissionController(max_queue_depth=1))
    jam = node.submit_invocation(Invocation(
        "q-a", PROMPT, 2, cfg=cfg, simulate_read_bw=SLOW_BW))
    # worker busy; one queue slot. Fill it, then the next must be refused.
    deadline = time.time() + 10
    while EVT_RESTORING not in _evts(jam) and time.time() < deadline:
        time.sleep(0.002)
    ok = node.submit_invocation(Invocation("q-b", PROMPT, 2, cfg=cfg))
    with pytest.raises(Overloaded):
        node.submit_invocation(Invocation("q-b", PROMPT, 2, cfg=cfg))
    assert node.stats["rejected_overloaded"] == 1
    jam.result(60)
    ok.result(60)


def test_admission_per_function_cap(qzoo):
    catalog, cfg, _ = qzoo
    node = _node(catalog, max_workers=4,
                 admission=AdmissionController(default_function_cap=2))
    h1 = node.submit_invocation(Invocation(
        "q-a", PROMPT, 2, cfg=cfg, simulate_read_bw=SLOW_BW))
    h2 = node.submit_invocation(Invocation("q-a", PROMPT, 2, cfg=cfg))
    with pytest.raises(Overloaded):
        node.submit_invocation(Invocation("q-a", PROMPT, 2, cfg=cfg))
    # a DIFFERENT function is not capped by q-a's lane
    h3 = node.submit_invocation(Invocation("q-b", PROMPT, 2, cfg=cfg))
    for h in (h1, h2, h3):
        h.result(60)
    # caps release with completions
    node.submit_invocation(Invocation("q-a", PROMPT, 2, cfg=cfg)).result(60)


def test_qos_dispatch_order_latency_overtakes_batch(qzoo):
    """With one worker jammed, a LATENCY invocation submitted AFTER a
    BATCH one must run first (QoS-ordered run queue, not FIFO)."""
    catalog, cfg, _ = qzoo
    node = _node(catalog, max_workers=1)
    jam = node.submit_invocation(Invocation(
        "q-a", PROMPT, 2, cfg=cfg, simulate_read_bw=SLOW_BW))
    batch = node.submit_invocation(Invocation(
        "q-b", PROMPT, 2, cfg=cfg, qos=QosClass.BATCH))
    lat = node.submit_invocation(Invocation(
        "q-b", PROMPT, 2, cfg=cfg, qos=QosClass.LATENCY))
    jam.result(60)
    r_lat, r_batch = lat.result(60), batch.result(60)
    assert 0 < r_lat.running_ts <= r_batch.running_ts
    node.memory.audit()


# ---------------------------------------------------------------- iosched
def test_iosched_boost_priority_is_qos_weighted():
    """Demand boosts from a higher-priority (LATENCY) stream are served
    before an EARLIER boost from a lower-priority (BATCH) stream."""
    from repro.core import PrefetchIOScheduler

    sched = PrefetchIOScheduler("t")
    gate = threading.Event()
    order = []

    def op(n=1000):
        return lambda: n

    batch = sched.open_stream("batch", priority=-1)
    lat = sched.open_stream("lat", priority=2)
    batch.submit("gate", [lambda: (gate.wait(5), 0)[1]],
                 lambda: order.append("b-gate"))
    for i in range(3):
        batch.submit(f"b{i}", [op()], (lambda n=f"b{i}": order.append(n)))
    for i in range(3):
        lat.submit(f"l{i}", [op()], (lambda n=f"l{i}": order.append(n)))
    batch.seal()
    lat.seal()
    assert batch.boost("b2")   # batch demand arrives FIRST
    assert lat.boost("l2")     # latency demand arrives second
    gate.set()
    assert batch.wait(5) and lat.wait(5)
    assert order.index("l2") < order.index("b2")  # QoS-weighted demand


# ------------------------------------------------------------------ router
def test_router_latency_steal_from_backed_up_node(qzoo):
    catalog, cfg, ref = qzoo
    # one worker per node so STANDARD jams actually QUEUE (urgent_depth
    # counts queued non-batch work, not running occupancy)
    nodes = [NodeScheduler(registry=catalog.registry, name=f"node{i}",
                           max_workers=1, keepalive=FixedTTLPolicy(3600.0))
             for i in range(2)]
    router = ClusterRouter(catalog, nodes, placement=LocalityFirst(),
                           latency_spill_depth=2)
    # pin q-a sticky to node0, then jam node0's queue directly
    r0 = router.invoke("q-a", PROMPT, max_new_tokens=2, cfg=cfg)
    assert r0.node == "node0" or r0.node == "node1"
    sticky = router.node(r0.node)
    other = [n for n in nodes if n is not sticky][0]
    # STANDARD jams count as urgent backlog (parked BATCH work would not:
    # the QoS queue dispatches a LATENCY invocation straight past it)
    jams = [sticky.submit_invocation(Invocation(
        "q-b", PROMPT, 2, cfg=cfg, simulate_read_bw=SLOW_BW))
        for _ in range(3)]
    deadline = time.time() + 10
    while sticky.load().urgent_depth < 2 and time.time() < deadline:
        time.sleep(0.002)
    # a BATCH invoke stays on the sticky (backed-up) replica...
    rb = router.submit_invocation(Invocation(
        "q-a", PROMPT, 2, cfg=cfg, qos=QosClass.BATCH))
    # ...while a LATENCY invoke steals the least-loaded node
    rl = router.submit_invocation(Invocation(
        "q-a", PROMPT, 2, cfg=cfg, qos=QosClass.LATENCY))
    res_l = rl.result(60)
    assert res_l.node == other.name
    assert router.stats["latency_steals"] >= 1
    assert set(router.replicas("q-a")) == {sticky.name, other.name}
    rb.result(60)
    for j in jams:
        j.result(60)
    np.testing.assert_array_equal(res_l.tokens, ref["q-a"][:, :2])
    router.drain_residual()
    router.audit()
    router.close()


def test_router_close_idempotent_and_drains_queue(qzoo):
    catalog, cfg, _ = qzoo
    nodes = [NodeScheduler(registry=catalog.registry, name="n0",
                           max_workers=1, keepalive=FixedTTLPolicy(3600.0))]
    router = ClusterRouter(catalog, nodes)
    jam = router.submit_invocation(Invocation(
        "q-a", PROMPT, 2, cfg=cfg, simulate_read_bw=SLOW_BW))
    queued = [router.submit_invocation(Invocation(
        "q-b", PROMPT, 2, cfg=cfg, qos=QosClass.BATCH)) for _ in range(3)]
    router.close()
    router.close()  # idempotent
    # queued BATCH work resolved with typed rejections — teardown cannot hang
    for h in queued:
        with pytest.raises(Overloaded):
            h.result(10)
        assert _evts(h)[-1] == EVT_REJECTED
    jam.result(60)  # in-flight work still finishes
    with pytest.raises(Overloaded):
        router.submit_invocation(Invocation("q-a", PROMPT, 2, cfg=cfg))
    router.audit()


# ------------------------------------------------------------ property test
def test_random_cancel_deadline_interleavings_never_leak_ledger(qzoo):
    """Seeded chaos: random QoS classes, deadlines, and cancel delays over
    both functions.  Every handle must settle with a typed outcome, the
    ledger invariant must hold throughout, and once everything is evicted
    the working-set/residual columns must return to zero bytes."""
    catalog, cfg, ref = qzoo
    rng = np.random.default_rng(1234)
    node = _node(catalog, max_workers=4,
                 admission=AdmissionController(max_queue_depth=16))
    handles = []
    cancels = []
    for i in range(28):
        fname = ["q-a", "q-b"][int(rng.integers(2))]
        qos = [QosClass.LATENCY, QosClass.STANDARD, QosClass.BATCH][
            int(rng.integers(3))]
        dl = deadline_in(float(rng.uniform(0.005, 3.0))) \
            if rng.random() < 0.3 else None
        bw = SLOW_BW if rng.random() < 0.5 else 5e8
        try:
            h = node.submit_invocation(Invocation(
                fname, PROMPT, 2, cfg=cfg, qos=qos, deadline_s=dl,
                simulate_read_bw=bw))
        except (Overloaded, DeadlineExceeded):
            continue
        handles.append(h)
        if rng.random() < 0.5:
            delay = float(rng.uniform(0.0, 0.05))
            t = threading.Timer(delay, h.cancel)
            t.start()
            cancels.append(t)
        if rng.random() < 0.3:
            time.sleep(float(rng.uniform(0.0, 0.02)))
        if i % 7 == 0:
            node.memory.audit()  # invariant holds mid-flight
    outcomes = {"ok": 0, "cancelled": 0, "deadline": 0}
    for h in handles:
        try:
            r = h.result(120)
            outcomes["ok"] += 1
            np.testing.assert_array_equal(r.tokens, ref[r.function][:, :2])
        except InvocationCancelled:
            outcomes["cancelled"] += 1
        except DeadlineExceeded:
            outcomes["deadline"] += 1
    for t in cancels:
        t.join()
    assert outcomes["ok"] > 0  # the chaos did not starve everything
    assert node.drain_residual()
    node.memory.audit()
    node.evict()  # full eviction: every surviving instance drops its state
    node.memory.audit()
    kinds = node.memory.kind_bytes()
    assert kinds["working_set"] == 0, f"leaked ws bytes: {kinds}"
    assert kinds["residual"] == 0, f"leaked residual bytes: {kinds}"
