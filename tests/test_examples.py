"""The examples are part of the public API surface: run each end-to-end in
a subprocess and assert it exits cleanly with the expected narrative."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
SRC = str(ROOT / "src")

CASES = [
    ("quickstart.py", "COLD start"),
    ("overlay_finetunes.py", "base-image cache"),
    ("train_ft.py", ("resuming from step", "canary", "instant rollback")),
    ("serve_coldstart.py", "node cache"),
]


@pytest.mark.parametrize("script,needle", CASES)
def test_example_runs(script, needle):
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, str(ROOT / "examples" / script)],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    for n in (needle,) if isinstance(needle, str) else needle:
        assert n in out.stdout, f"missing narrative {n!r}"
