"""Fault tolerance: checkpoint/restart equivalence, incremental delta
checkpoints, keep-k GC with chain safety, health/straggler logic, elastic
mesh planning."""
import dataclasses
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.ft.elastic import plan_mesh
from repro.ft.health import HealthMonitor, rebalance_shards
from repro.ft.manager import CheckpointManager
from repro.train.loop import LoopConfig, SimulatedFailure, train_loop
from repro.train.steps import TrainStepConfig


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen1.5-0.5b").reduced()
    tcfg = TrainStepConfig(remat="dots", num_microbatches=2)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4))
    return cfg, tcfg, data


def test_restart_equivalence(tmp_path, setup):
    """train 12 steps straight == train 12 steps with a crash at 7 + resume."""
    cfg, tcfg, data = setup
    ref = train_loop(cfg, tcfg, LoopConfig(steps=12, ckpt_every=4), data)

    mgr = CheckpointManager(str(tmp_path / "ckpt"), async_save=False)
    with pytest.raises(SimulatedFailure):
        train_loop(cfg, tcfg, LoopConfig(steps=12, ckpt_every=4, fail_at_step=7), data, mgr)
    out = train_loop(cfg, tcfg, LoopConfig(steps=12, ckpt_every=4), data, mgr)

    for a, b in zip(jax.tree.leaves(ref["params"]), jax.tree.leaves(out["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


def test_incremental_checkpoints_dedup(tmp_path):
    """Delta checkpoints store only changed chunks (partial-update case:
    fine-tuning a head / frozen layers / sparse optimizer states)."""
    r = np.random.RandomState(0)
    state = {
        "frozen": r.randn(256, 1024).astype(np.float32),
        "head": r.randn(64, 64).astype(np.float32),
        "zeros": np.zeros((64, 1024), np.float32),
    }
    mgr = CheckpointManager(str(tmp_path / "ckpt"), anchor_every=10, async_save=False)
    mgr.save(0, state, blocking=True)
    state2 = dict(state, head=state["head"] + 1.0)  # only the head trains
    mgr.save(1, state2, blocking=True)

    anchor, delta = mgr.history
    assert anchor["anchor"] and not delta["anchor"]
    head_bytes = state["head"].nbytes
    assert delta["bytes_written"] <= head_bytes + 2 * 65536  # page rounding
    assert delta["bytes_written"] < 0.2 * delta["total_bytes"]

    restored, step = mgr.restore(step=1)
    assert step == 1
    np.testing.assert_array_equal(restored["head"], state2["head"])
    np.testing.assert_array_equal(restored["frozen"], state["frozen"])
    np.testing.assert_array_equal(restored["zeros"], state["zeros"])


def test_gc_preserves_chain(tmp_path, setup):
    cfg, tcfg, data = setup
    mgr = CheckpointManager(str(tmp_path / "ckpt"), keep=2, anchor_every=3, async_save=False)
    train_loop(cfg, tcfg, LoopConfig(steps=30, ckpt_every=3), data, mgr)
    # survivors must start at an anchor
    assert mgr.history[0]["anchor"]
    state, step = mgr.restore()  # the latest must be restorable post-GC
    assert step == mgr.history[-1]["step"]
    for p in (Path(str(tmp_path / "ckpt"))).glob("ckpt_*.jif"):
        assert any(h["path"].endswith(p.name) for h in mgr.history)


def test_async_save(tmp_path, setup):
    cfg, tcfg, data = setup
    mgr = CheckpointManager(str(tmp_path / "ckpt"), async_save=True)
    train_loop(cfg, tcfg, LoopConfig(steps=8, ckpt_every=2), data, mgr)
    state, step = mgr.restore()
    assert step == 7


def test_async_save_failure_surfaces(tmp_path):
    """Regression: a save that fails on the background thread must NOT be
    silent — the error re-raises on the training thread at the next
    save()/wait(), and is consumed exactly once."""
    mgr = CheckpointManager(str(tmp_path / "ckpt"), async_save=True)
    state = {"w": np.ones((8, 8), np.float32)}

    def failing(step, state_np):
        raise RuntimeError("disk full")

    mgr._save_sync = failing
    mgr.save(0, state)  # spawns the doomed background save
    with pytest.raises(RuntimeError, match="disk full"):
        mgr.save(1, state)  # the next save surfaces the pending failure
    mgr.wait()  # consumed exactly once: wait() is clean again

    with pytest.raises(RuntimeError, match="disk full"):
        mgr.save(2, state)
        mgr.wait()  # ... and wait() alone surfaces it too


def test_checkpoint_callback_failure_fails_the_save(tmp_path):
    """A publish callback raising on the save thread fails the save like a
    checkpoint write error would — but the checkpoint itself (written
    before callbacks fire) stays restorable."""

    class BadCb:
        def on_checkpoint(self, manager, step, state, entry):
            raise ValueError("gate exploded")

    mgr = CheckpointManager(
        str(tmp_path / "ckpt"), async_save=True, callbacks=[BadCb()]
    )
    mgr.save(0, {"w": np.arange(16, dtype=np.float32)})
    with pytest.raises(ValueError, match="gate exploded"):
        mgr.wait()
    restored, step = mgr.restore()
    assert step == 0
    np.testing.assert_array_equal(
        restored["w"], np.arange(16, dtype=np.float32)
    )


def test_health_monitor():
    t = [0.0]
    mon = HealthMonitor(["h0", "h1", "h2"], heartbeat_timeout_s=5, clock=lambda: t[0])
    for _ in range(8):
        mon.heartbeat("h0", 1.0)
        mon.heartbeat("h1", 1.1)
        mon.heartbeat("h2", 3.0)  # straggler
    assert mon.stragglers() == {"h2"}
    t[0] = 10.0
    mon.heartbeat("h0", 1.0)
    assert mon.dead_hosts() == {"h1", "h2"}
    assert mon.live_hosts() == ["h0"]


def test_rebalance_shards():
    out = rebalance_shards(["a", "b", "c"], {"c"}, 10)
    assert sorted(sum(out.values(), [])) == list(range(10))
    assert len(out["c"]) < len(out["a"])


def test_plan_mesh_elastic():
    p = plan_mesh(256, model_parallel=16)
    assert p.shape == (16, 16)
    p = plan_mesh(240, model_parallel=16)  # lost a host of 16 chips
    assert p.shape == (15, 16)
    p = plan_mesh(512, model_parallel=16, pods=2)
    assert p.shape == (2, 16, 16)
    p = plan_mesh(8, model_parallel=16)  # tiny: TP shrinks to fit
    assert p.shape[-1] <= 8
