"""Snapshot lifecycle subsystem: JIF v2 format compatibility (golden v1
bytes), delta chains, two-phase working-set restore, concurrent itable
loads, and the serving-side WARM-at-working-set promotion + record →
relayout feedback loop."""
import os
import threading
import time
from pathlib import Path

import numpy as np
import pytest

from repro.core import (
    BaseImage,
    NodeImageCache,
    SnapshotPipeline,
    SpiceRestorer,
    snapshot,
)
from repro.core.jif import JifReader
from repro.core.lifecycle import parent_cache_key
from repro.core.treeutil import flatten_state

PAGE = 4096
GOLDEN = Path(__file__).parent / "golden" / "jif_v1_small.jif"


def golden_state():
    """Deterministic state matching the checked-in v1 golden image (written
    by the pre-pipeline writer)."""
    r = np.random.RandomState(42)
    return {
        "embed": {"tok": r.randn(64, 32).astype(np.float32)},
        "layers": [
            {"w": r.randn(32, 48).astype(np.float32),
             "b": np.zeros((2048,), np.float32)}
            for _ in range(3)
        ],
        "step": np.int64(11),
    }


def rng_state(seed=0, scale=1):
    r = np.random.RandomState(seed)
    return {
        "embed": {"tok": r.randn(64 * scale, 32).astype(np.float32)},
        "layers": [
            {"w": r.randn(32, 64).astype(np.float32),
             "b": np.zeros((2048,), np.float32)}
            for _ in range(3)
        ],
        "step": np.int64(7),
    }


def assert_state_equal(a, b):
    la, _ = flatten_state(a)
    lb, _ = flatten_state(b)
    assert [n for n, _ in la] == [n for n, _ in lb]
    for (n, x), (_, y) in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), err_msg=n)


# ------------------------------------------------------- format compatibility
def test_golden_v1_restores_byte_identically():
    """A v1 JIF written by the pre-pipeline writer still restores, byte for
    byte, through the v2 reader."""
    got, meta, _, _ = SpiceRestorer().restore(str(GOLDEN))
    assert_state_equal(golden_state(), got)
    assert meta["golden"] == "v1"


def test_golden_v1_header_defaults():
    with JifReader(str(GOLDEN)) as r:
        assert r.version == 1
        assert not r.has_digests
        assert r.digests("embed/tok") is None
        # no boundary recorded: the whole data segment is the working set
        assert r.ws_boundary == r.n_data_chunks
        assert r.parent is None


def test_v2_header_carries_boundary_and_digests(tmp_path):
    state = rng_state()
    names = [n for n, _ in flatten_state(state)[0]]
    path = str(tmp_path / "f.jif")
    stats = snapshot(state, path, access_order=names, working_set=names[:2],
                     page_size=PAGE)
    with JifReader(path) as r:
        assert r.version == 2
        assert r.has_digests
        assert 0 < r.ws_boundary < r.n_data_chunks
        assert r.ws_boundary == stats.ws_boundary
        assert r.meta["working_set"] == names[:2]
        # stored digests match a fresh hash of the source bytes
        from repro.core import overlay

        raw = np.ascontiguousarray(state["embed"]["tok"]).view(np.uint8).reshape(-1)
        np.testing.assert_array_equal(
            r.digests("embed/tok"),
            overlay.chunk_digests(memoryview(raw), PAGE),
        )


def test_concurrent_itable_loads_one_reader(tmp_path):
    """Regression: itable loads used seek+read on the shared fd; many
    scheduler threads hitting one reader must still see correct tables."""
    state = {f"t{i:02d}": np.full((97 + 13 * i,), i, np.float32) for i in range(40)}
    path = str(tmp_path / "many.jif")
    snapshot(state, path, page_size=256)

    expect = {}
    with JifReader(path) as ref:
        for t in ref.tensors:
            expect[t.name] = ref.itable(t.name).table.copy()

    shared = JifReader(path)
    errors = []

    def worker(seed):
        r = np.random.RandomState(seed)
        names = list(expect)
        r.shuffle(names)
        for name in names:
            got = shared.itable(name).table
            if not np.array_equal(got, expect[name]):
                errors.append(name)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    shared.close()
    assert not errors


# ----------------------------------------------------------------- delta chain
def test_delta_chain_roundtrip(tmp_path):
    """parent → child → grandchild, restored through the chain from a COLD
    cache (parents bootstrapped from disk)."""
    parent = rng_state(5)
    parent_path = str(tmp_path / "parent.jif")
    full = snapshot(parent, parent_path, page_size=PAGE)

    child = rng_state(5)
    child["layers"][0]["w"] = child["layers"][0]["w"] + 1.0
    child_path = str(tmp_path / "child.jif")
    cs = snapshot(child, child_path, parent=parent_path, page_size=PAGE)
    assert cs.private_bytes < 0.4 * full.private_bytes  # only dirty pages
    assert cs.base_bytes > 0
    assert cs.parent == os.path.abspath(parent_path)

    grand = dict(child)
    grand["embed"] = {"tok": child["embed"]["tok"] * 1.5}
    grand_path = str(tmp_path / "grand.jif")
    snapshot(grand, grand_path, parent=child_path, page_size=PAGE)

    cache = NodeImageCache()
    got, _, _, rstats = SpiceRestorer(node_cache=cache).restore(grand_path)
    assert_state_equal(grand, got)
    # both ancestors were bootstrapped into the node cache from disk
    assert cache.get(parent_cache_key(parent_path)) is not None
    assert cache.get(parent_cache_key(child_path)) is not None


def test_delta_against_v1_parent(tmp_path):
    """A v1 parent (no stored digests) is materialized once and still
    serves as a delta base."""
    child = golden_state()
    child["layers"][2]["w"] = child["layers"][2]["w"] + 2.0
    child_path = str(tmp_path / "child.jif")
    stats = snapshot(child, child_path, parent=str(GOLDEN), page_size=PAGE)
    assert stats.base_bytes > 0
    got, _, _, _ = SpiceRestorer(node_cache=NodeImageCache()).restore(child_path)
    assert_state_equal(child, got)


def test_rewritten_parent_fails_loudly(tmp_path):
    """A parent rewritten in place after the delta was written must fail the
    restore (key mismatch), never serve stale/new parent bytes silently."""
    parent_path = str(tmp_path / "p.jif")
    snapshot(rng_state(5), parent_path, page_size=PAGE)
    child = rng_state(5)
    child["layers"][0]["w"] = child["layers"][0]["w"] + 1.0
    child_path = str(tmp_path / "c.jif")
    snapshot(child, child_path, parent=parent_path, page_size=PAGE)

    time.sleep(0.01)  # distinct mtime_ns for the rewrite
    snapshot(rng_state(6), parent_path, page_size=PAGE)  # in-place rewrite
    with pytest.raises(FileNotFoundError, match="changed on disk"):
        SpiceRestorer(node_cache=NodeImageCache()).restore(child_path)


def test_base_image_from_jif_matches_from_state(tmp_path):
    state = rng_state(9)
    path = str(tmp_path / "f.jif")
    snapshot(state, path, page_size=PAGE)
    img = BaseImage.from_jif(path, name="img")
    ref = BaseImage.from_state("img", state, PAGE)
    for name, _ in flatten_state(state)[0]:
        np.testing.assert_array_equal(img.digests(name), ref.digests(name))
        np.testing.assert_array_equal(
            img.chunk_bytes(name, 0, 4), ref.chunk_bytes(name, 0, 4)
        )


# ------------------------------------------------------- two-phase completion
def test_working_set_event_fires_before_residual(tmp_path):
    state = rng_state(3, scale=8)
    names = [n for n, _ in flatten_state(state)[0]]
    ws = names[:3]
    path = str(tmp_path / "f.jif")
    snapshot(state, path, access_order=names, working_set=ws, page_size=PAGE)

    at_ws = {}
    restorer = SpiceRestorer(simulate_read_bw=5e7)
    _, meta, handles, stats = restorer.restore(
        path, wait=False,
        on_working_set=lambda: at_ws.update(complete=stats.complete),
    )
    assert stats.wait_working_set(20)
    assert stats.ws_tensors == 3 and stats.residual_tensors == len(names) - 3
    # at the ws event every ws tensor is resident...
    for n in ws:
        assert handles[n].ready
    # ...and the residual was still streaming when the event fired
    assert at_ws == {"complete": False}
    assert stats.wait_complete(30)
    assert 0 < stats.working_set_s < stats.total_s
    for n in names:
        np.testing.assert_array_equal(
            handles[n].wait(10), np.asarray(dict(flatten_state(state)[0])[n])
        )


def test_residual_demand_boost_still_works(tmp_path):
    """Waiting on a residual tensor after ws completion demand-boosts it
    ahead of the background stream."""
    state = rng_state(4, scale=8)
    names = [n for n, _ in flatten_state(state)[0]]
    path = str(tmp_path / "f.jif")
    snapshot(state, path, access_order=names, working_set=names[:2], page_size=PAGE)
    restorer = SpiceRestorer(simulate_read_bw=3e7)
    _, _, handles, stats = restorer.restore(path, wait=False)
    assert stats.wait_working_set(20)
    tail = names[-1]
    got = handles[tail].wait(20)
    np.testing.assert_array_equal(got, np.asarray(dict(flatten_state(state)[0])[tail]))
    assert stats.wait_complete(30)


# ------------------------------------------------------------ pipeline stages
def test_pipeline_stages_compose(tmp_path):
    pipe = SnapshotPipeline(page_size=PAGE)
    state = rng_state(1)
    c, stats = pipe.classify(state)
    order, ws, boundary = pipe.relocate(c, access_order=None)
    assert boundary > 0 and set(order) == set(c.names) and ws == order
    path = str(tmp_path / "staged.jif")
    pipe.write(path, c, order, {"tree": c.treedesc, "access_order": order,
                                "working_set": ws}, None, boundary)
    got, _, _, _ = SpiceRestorer().restore(path)
    assert_state_equal(state, got)


def test_trim_stage_still_applies(tmp_path):
    state = {"params": rng_state(2)["embed"], "opt": {"m": np.ones((4096,), np.float32)}}
    path = str(tmp_path / "f.jif")
    snapshot(state, path, page_size=PAGE, trim_fn=lambda s: {"params": s["params"]})
    got, _, _, _ = SpiceRestorer().restore(path)
    assert "opt" not in got


# ------------------------------------------------------------------ cache O(n)
def test_node_cache_total_bytes_accounting():
    cache = NodeImageCache(capacity_bytes=1 << 30)
    a = BaseImage.from_state("a", {"x": np.ones(4096, np.float32)})
    b = BaseImage.from_state("b", {"x": np.ones(8192, np.float32)})
    cache.put(a)
    assert cache.total_bytes == a.nbytes
    cache.put(b)
    assert cache.total_bytes == a.nbytes + b.nbytes
    # replacing an image must not double-count
    cache.put(BaseImage.from_state("a", {"x": np.ones(2048, np.float32)}))
    assert cache.total_bytes == 2048 * 4 + b.nbytes
    misses = cache.stats["misses"]
    assert cache.get(None) is None
    assert cache.stats["misses"] == misses  # "no base" is not a miss
    # eviction keeps the running total consistent
    cache.capacity = b.nbytes
    cache.put(BaseImage.from_state("c", {"x": np.ones(1024, np.float32)}))
    assert cache.total_bytes == sum(
        img.nbytes for img in cache._images.values()
    )
