"""Per-kernel validation: interpret=True Pallas execution vs pure-jnp
oracles, sweeping shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import overlay
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.overlay_patch.ops import overlay_patch, plan_from_itable
from repro.kernels.overlay_patch.ref import overlay_patch_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.models.attention import quantize_kv


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------- overlay_patch
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
@pytest.mark.parametrize("n_pages,page", [(4, 128), (16, 256), (33, 512)])
def test_overlay_patch(dtype, n_pages, page):
    key = jax.random.PRNGKey(n_pages)
    k1, k2, k3 = jax.random.split(key, 3)
    base = (jax.random.normal(k1, (n_pages, page)) * 10).astype(dtype)
    kinds = jax.random.randint(k2, (n_pages,), 0, 3)
    n_priv = int(jnp.sum(kinds == overlay.KIND_PRIVATE))
    priv = (jax.random.normal(k3, (max(n_priv, 1), page)) * 10).astype(dtype)
    src = jnp.cumsum(kinds == overlay.KIND_PRIVATE) - 1
    got = overlay_patch(base, priv, kinds, src, interpret=True)
    want = overlay_patch_ref(base, priv, kinds, src)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_overlay_patch_from_itable():
    """End-to-end: JIF interval table -> kernel plan -> patched tensor."""
    page_bytes = 512
    base_arr = np.random.RandomState(0).randn(page_bytes).astype(np.float32)
    priv_arr = base_arr.copy()
    priv_arr[128:256] = 7.0  # dirty page 1 (f32: 128 elems per 512B page)
    priv_arr[384:] = 0.0  # zero page 3
    dg = overlay.chunk_digests(memoryview(base_arr.tobytes()), page_bytes)
    kinds_np = overlay.classify(memoryview(priv_arr.tobytes()), page_bytes, dg)
    table = overlay.intervals_from_kinds(kinds_np)
    cur = 0
    for row in table:
        if row[2] == overlay.KIND_PRIVATE:
            row[3] = cur
            cur += row[1]
    it = overlay.IntervalTable(table)
    kinds, src = plan_from_itable(it)

    page_elems = page_bytes // 4
    base2d = base_arr.reshape(-1, page_elems)
    priv_pages = priv_arr.reshape(-1, page_elems)[kinds_np == overlay.KIND_PRIVATE]
    got = overlay_patch(
        jnp.asarray(base2d), jnp.asarray(priv_pages), jnp.asarray(kinds),
        jnp.asarray(src), interpret=True,
    )
    np.testing.assert_array_equal(
        np.asarray(got).reshape(-1), priv_arr
    )


@pytest.mark.parametrize("kind", [overlay.KIND_BASE, overlay.KIND_ZERO])
def test_overlay_patch_no_private_pages(kind):
    """n_priv == 0: the dummy (1, page) private array must never be
    gathered out of bounds (src clamps), for all-BASE and all-ZERO."""
    n_pages, page = 6, 128
    base = jnp.asarray(
        np.random.RandomState(3).randn(n_pages, page).astype(np.float32)
    )
    kinds = jnp.full((n_pages,), kind, jnp.int32)
    src = jnp.zeros((n_pages,), jnp.int32)
    priv = jnp.zeros((1, page), jnp.float32)  # dummy slot, never selected
    got = overlay_patch(base, priv, kinds, src, interpret=True)
    want = overlay_patch_ref(base, priv, kinds, src)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    expect = base if kind == overlay.KIND_BASE else jnp.zeros_like(base)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))


@pytest.mark.parametrize(
    "kind", [overlay.KIND_BASE, overlay.KIND_ZERO, overlay.KIND_PRIVATE]
)
def test_overlay_patch_single_page(kind):
    """A one-page tensor exercises the degenerate grid for every kind."""
    page = 256
    base = jnp.asarray(np.full((1, page), 2.0, np.float32))
    priv = jnp.asarray(np.full((1, page), 7.0, np.float32))
    kinds = jnp.asarray([kind], jnp.int32)
    src = jnp.zeros((1,), jnp.int32)
    got = overlay_patch(base, priv, kinds, src, interpret=True)
    want = overlay_patch_ref(base, priv, kinds, src)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    expect = {overlay.KIND_BASE: 2.0, overlay.KIND_ZERO: 0.0,
              overlay.KIND_PRIVATE: 7.0}[kind]
    assert np.all(np.asarray(got) == expect)


def test_compact_plan_round_trip_real_itable(tmp_path):
    """plan_from_itable vs compact_plan_from_itable against a REAL JIF
    delta itable, including a non-page-multiple tail tensor: the compact
    read plan + kernel must reproduce the exact snapshotted bytes."""
    from repro.core import snapshot
    from repro.core.jif import JifReader
    from repro.kernels.overlay_patch.ops import compact_plan_from_itable

    ps = 512
    page_elems = ps // 4
    rng = np.random.RandomState(11)
    # w_tail: 3.5 pages (non-page-multiple tail); w_even: page-aligned
    base_st = {
        "w_tail": rng.randn(3 * page_elems + page_elems // 2).astype(np.float32),
        "w_even": rng.randn(4 * page_elems).astype(np.float32),
    }
    ft = {k: v.copy() for k, v in base_st.items()}
    ft["w_tail"][:page_elems] += 1.0       # dirty page 0
    ft["w_tail"][-page_elems // 2:] = 0.0  # zero the partial tail page
    ft["w_even"][page_elems: 2 * page_elems] += 1.0  # dirty page 1
    parent = str(tmp_path / "parent.jif")
    delta = str(tmp_path / "delta.jif")
    snapshot(base_st, parent, page_size=ps)
    snapshot(ft, delta, parent=parent, page_size=ps)

    with JifReader(delta) as r:
        for t in r.tensors:
            it = r.itable(t.name)
            kinds_abs, src_abs = plan_from_itable(it)
            kinds, src, runs, n_priv = compact_plan_from_itable(it)
            # both flavors agree on the page classification
            np.testing.assert_array_equal(kinds, kinds_abs)
            assert n_priv == int((kinds == overlay.KIND_PRIVATE).sum())
            assert 0 < n_priv < it.n_pages
            # execute the compact read plan exactly as the restorer does
            compact = np.zeros(n_priv * ps, np.uint8)
            for slot, src_chunk, count in runs:
                raw = r.pread_chunks(src_chunk, count)
                compact[slot * ps: slot * ps + len(raw)] = np.frombuffer(
                    raw, np.uint8
                )
            base2d = np.zeros((it.n_pages * ps,), np.uint8)
            raw_base = base_st[t.name].view(np.uint8)
            base2d[: raw_base.size] = raw_base
            base2d = base2d.view(np.float32).reshape(it.n_pages, page_elems)
            priv2d = compact.view(np.float32).reshape(max(n_priv, 1), page_elems)
            got = overlay_patch(
                jnp.asarray(base2d), jnp.asarray(priv2d),
                jnp.asarray(kinds), jnp.asarray(src), interpret=True,
            )
            want = overlay_patch_ref(
                jnp.asarray(base2d), jnp.asarray(priv2d),
                jnp.asarray(kinds), jnp.asarray(src),
            )
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
            # tail slice (the restorer's final reshape) matches the source
            n_elems = t.nbytes // 4
            np.testing.assert_array_equal(
                np.asarray(got).reshape(-1)[:n_elems], ft[t.name]
            )


# --------------------------------------------------------- flash_attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,kvH,S,hd,window",
    [
        (1, 4, 4, 256, 64, None),
        (2, 4, 2, 256, 128, None),
        (1, 8, 2, 512, 64, 128),  # GQA + sliding window
        (2, 2, 1, 128, 32, None),
    ],
)
def test_flash_attention(dtype, B, H, kvH, S, hd, window):
    keys = jax.random.split(jax.random.PRNGKey(hash((B, H, S)) % 2**31), 3)
    q = jax.random.normal(keys[0], (B, H, S, hd)).astype(dtype)
    k = jax.random.normal(keys[1], (B, kvH, S, hd)).astype(dtype)
    v = jax.random.normal(keys[2], (B, kvH, S, hd)).astype(dtype)
    got = flash_attention(q, k, v, window=window, block_q=64, block_k=64, interpret=True)
    want = flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


# -------------------------------------------------------- decode_attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,kvH,Sc,hd,pos",
    [(2, 8, 2, 512, 64, 311), (1, 4, 4, 256, 128, 255), (2, 16, 2, 1024, 64, 7)],
)
def test_decode_attention(dtype, B, H, kvH, Sc, hd, pos):
    keys = jax.random.split(jax.random.PRNGKey(pos), 3)
    q = jax.random.normal(keys[0], (B, H, hd)).astype(dtype)
    k = jax.random.normal(keys[1], (B, kvH, Sc, hd)).astype(dtype)
    v = jax.random.normal(keys[2], (B, kvH, Sc, hd)).astype(dtype)
    got = decode_attention(q, k, v, jnp.int32(pos), block_k=128, interpret=True)
    want = decode_attention_ref(q, k, v, jnp.int32(pos))
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_decode_attention_int8_kv():
    B, H, kvH, Sc, hd, pos = 2, 8, 2, 512, 64, 400
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(keys[0], (B, H, hd)).astype(jnp.float32)
    k = jax.random.normal(keys[1], (B, kvH, Sc, hd)).astype(jnp.float32)
    v = jax.random.normal(keys[2], (B, kvH, Sc, hd)).astype(jnp.float32)
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    got = decode_attention(q, kq, vq, jnp.int32(pos), ks, vs, block_k=128, interpret=True)
    want = decode_attention_ref(q, kq, vq, jnp.int32(pos), ks, vs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)
    # and close to the unquantized answer (int8 error bound)
    exact = decode_attention_ref(q, k, v, jnp.int32(pos))
    np.testing.assert_allclose(np.asarray(got), np.asarray(exact), rtol=0.1, atol=0.05)


# --------------------------------------------------------------- ssd_scan
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,G,P,N,chunk",
    [(1, 256, 4, 1, 64, 32, 64), (2, 128, 8, 2, 32, 16, 32), (1, 512, 2, 1, 64, 64, 128)],
)
def test_ssd_scan(dtype, B, S, H, G, P, N, chunk):
    keys = jax.random.split(jax.random.PRNGKey(S + H), 4)
    x = (jax.random.normal(keys[0], (B, S, H, P)) * 0.5).astype(dtype)
    # negative decay logs, moderate magnitude for numerical comparability
    a = -jax.nn.softplus(jax.random.normal(keys[1], (B, H, S))).astype(jnp.float32) * 0.3
    Bm = (jax.random.normal(keys[2], (B, S, G, N)) * 0.5).astype(dtype)
    Cm = (jax.random.normal(keys[3], (B, S, G, N)) * 0.5).astype(dtype)
    y, st = ssd_scan(x, a, Bm, Cm, chunk=chunk, interpret=True)
    y_ref, st_ref = ssd_scan_ref(x, a, Bm, Cm, chunk=chunk)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32), **tol
    )
    np.testing.assert_allclose(
        np.asarray(st, np.float32), np.asarray(st_ref, np.float32), **tol
    )
