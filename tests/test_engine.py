"""Serving engine: publish -> cold start under every restore mode -> warm;
all modes must produce identical tokens; spice overlap must be observable."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import BaseImage
from repro.models import lm
from repro.serve.engine import ServerlessNode, layer_sequence, layerwise_state

ARCH = "qwen1.5-0.5b"


@pytest.fixture(scope="module")
def node_with_fn(tmp_path_factory):
    d = tmp_path_factory.mktemp("fns")
    cfg = get_config(ARCH).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    node = ServerlessNode()
    node.publish("f1", cfg, params, str(d), warm_ttl_s=60.0,
                 extra_state={"opt_m": np.ones((1 << 16,), np.float32)})
    return node, cfg


PROMPT = np.array([[5, 6, 7, 8, 9, 10]], dtype=np.int32)


def test_all_modes_agree(node_with_fn):
    node, cfg = node_with_fn
    outs = {}
    for mode in ["spice", "spice_sync", "criu_star", "reap_star", "faasnap_star"]:
        node.evict()
        r = node.invoke("f1", PROMPT, max_new_tokens=6, mode=mode, cfg=cfg)
        assert r.cold
        outs[mode] = r.tokens
    base = outs["spice"]
    for mode, toks in outs.items():
        np.testing.assert_array_equal(toks, base, err_msg=mode)
    assert base.shape == (1, 6)


def test_warm_path_matches_cold(node_with_fn):
    node, cfg = node_with_fn
    node.evict()
    cold = node.invoke("f1", PROMPT, max_new_tokens=4, mode="spice", cfg=cfg)
    warm = node.invoke("f1", PROMPT, max_new_tokens=4, cfg=cfg)
    assert cold.cold and not warm.cold
    np.testing.assert_array_equal(cold.tokens, warm.tokens)
    assert warm.total_s <= cold.total_s + 1.0


def test_generation_matches_lm_forward(node_with_fn):
    """Engine layerwise generation == monolithic lm.prefill/decode path."""
    node, cfg = node_with_fn
    node.evict()
    r = node.invoke("f1", PROMPT, max_new_tokens=3, mode="spice_sync", cfg=cfg)

    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    logits, caches, _ = lm.prefill(
        cfg, params, {"tokens": jnp.asarray(PROMPT)}, compute_dtype=jnp.float32
    )
    toks = [int(jnp.argmax(logits[0, -1]))]
    pos = PROMPT.shape[1]
    for _ in range(2):
        logits, caches, _ = lm.decode_step(
            cfg, params, {"tokens": jnp.asarray([[toks[-1]]], jnp.int32)},
            caches, jnp.int32(pos), compute_dtype=jnp.float32,
        )
        toks.append(int(jnp.argmax(logits[0, -1])))
        pos += 1
    np.testing.assert_array_equal(r.tokens[0], np.asarray(toks))


def test_layerwise_state_roundtrip(node_with_fn):
    node, cfg = node_with_fn
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    state = layerwise_state(cfg, params)
    assert len(state["layers"]) == cfg.n_layers
    np.testing.assert_array_equal(
        state["layers"][0]["attn"]["wq"], np.asarray(params["pattern"][0]["attn"]["wq"][0])
    )


def test_base_image_dedup_across_finetunes(tmp_path):
    """Two functions sharing a base: the second one's JIF is mostly BASE."""
    cfg = get_config(ARCH).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    node = ServerlessNode()
    base_state = layerwise_state(cfg, params)
    node.node_cache.put(BaseImage.from_state("base-lm", base_state))

    # fine-tune: perturb only the first layer
    ft = jax.tree.map(lambda a: a, params)
    ft["pattern"][0]["attn"]["wq"] = ft["pattern"][0]["attn"]["wq"] + 0.5
    from repro.core.snapshot import snapshot as jif_snapshot

    stats = jif_snapshot(
        layerwise_state(cfg, ft), str(tmp_path / "ft.jif"),
        base=node.node_cache.get("base-lm"),
    )
    assert stats.base_bytes > 0.5 * stats.total_bytes
    assert stats.private_bytes < 0.5 * stats.total_bytes
