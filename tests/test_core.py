"""Core snapshot/restore tests: JIF round-trips, overlay dedup invariants,
pipelined restore correctness, baselines, pool/cache behaviour."""
import os
import threading

import numpy as np
import pytest

from repro.core import (
    BaseImage,
    BufferPool,
    NodeImageCache,
    SpiceRestorer,
    snapshot,
)
from repro.core import baselines, overlay
from repro.core.treeutil import flatten_state, unflatten_state

PAGE = 4096  # small pages keep tests fast


def rng_state(seed=0, scale=1):
    r = np.random.RandomState(seed)
    return {
        "embed": {"tok": r.randn(64 * scale, 32).astype(np.float32)},
        "layers": [
            {
                "w": r.randn(32, 64).astype(np.float32),
                "b": np.zeros((2048,), np.float32),  # zero chunks
            }
            for _ in range(3)
        ],
        "step": np.int64(7),
    }


def assert_state_equal(a, b):
    la, _ = flatten_state(a)
    lb, _ = flatten_state(b)
    assert [n for n, _ in la] == [n for n, _ in lb]
    for (n, x), (_, y) in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), err_msg=n)


# ------------------------------------------------------------------ treeutil
def test_tree_roundtrip():
    state = rng_state()
    leaves, desc = flatten_state(state)
    rebuilt = unflatten_state(desc, dict(leaves))
    assert_state_equal(state, rebuilt)


# ------------------------------------------------------------------- overlay
# (deterministic variants; the hypothesis-powered versions live in
# test_properties.py, which importorskips hypothesis)
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("page", [256, 1024, PAGE])
def test_interval_table_covers_everything(seed, page):
    r = np.random.RandomState(seed)
    data = r.bytes(r.randint(1, PAGE * 7))
    buf = np.frombuffer(data, np.uint8)
    kinds = overlay.classify(memoryview(buf), page)
    table = overlay.IntervalTable(overlay.intervals_from_kinds(kinds))
    assert table.n_pages == overlay.n_chunks(len(data), page)
    for pg in range(table.n_pages):
        kind, _ = table.lookup(pg)
        assert kind == kinds[pg]


@pytest.mark.parametrize("seed", range(8))
def test_zero_detection(seed):
    r = np.random.RandomState(seed)
    n = r.randint(1, 6)
    buf = np.zeros(n * PAGE, np.uint8)
    dirty = set()
    for _ in range(r.randint(0, n)):
        i = r.randint(0, n)
        buf[i * PAGE + r.randint(PAGE)] = 1 + r.randint(255)
        dirty.add(i)
    zm = overlay.zero_mask(memoryview(buf), PAGE)
    assert set(np.flatnonzero(~zm)) == dirty


def test_base_dedup_classification():
    base_arr = np.arange(PAGE * 4, dtype=np.uint8)
    priv = base_arr.copy()
    priv[PAGE : PAGE + 1] += 1  # dirty page 1
    dg = overlay.chunk_digests(memoryview(base_arr), PAGE)
    kinds = overlay.classify(memoryview(priv), PAGE, dg)
    assert kinds[0] == overlay.KIND_BASE
    assert kinds[1] == overlay.KIND_PRIVATE
    assert list(kinds[2:]) == [overlay.KIND_BASE, overlay.KIND_BASE]


# ---------------------------------------------------------------- jif/spice
def test_jif_roundtrip_no_base(tmp_path):
    state = rng_state()
    path = str(tmp_path / "f.jif")
    stats = snapshot(state, path, page_size=PAGE)
    assert stats.zero_bytes >= 3 * 2048 * 4 - PAGE  # the zero biases
    restorer = SpiceRestorer()
    got, meta, handles, rstats = restorer.restore(path)
    assert_state_equal(state, got)
    assert rstats.major_faults == 0
    assert rstats.restore_ops == 1


def test_jif_roundtrip_with_base(tmp_path):
    base_state = rng_state(0)
    state = rng_state(0)
    # perturb one tensor slightly: most chunks should dedup to BASE
    state["layers"][1]["w"] = state["layers"][1]["w"].copy()
    state["layers"][1]["w"][0, 0] += 1.0

    cache = NodeImageCache()
    cache.put(BaseImage.from_state("base-v1", base_state, PAGE))

    path = str(tmp_path / "f.jif")
    stats = snapshot(state, path, base=cache.get("base-v1"), page_size=PAGE)
    assert stats.base_bytes > 0
    assert stats.private_bytes < stats.total_bytes - stats.zero_bytes

    restorer = SpiceRestorer(node_cache=cache)
    got, _, _, rstats = restorer.restore(path)
    assert_state_equal(state, got)
    assert rstats.base_bytes == stats.base_bytes
    # dedup means we read less than the full image from "disk"
    assert rstats.bytes_read <= stats.private_bytes + PAGE * stats.n_tensors


def test_restore_missing_base_fails(tmp_path):
    base_state = rng_state(0)
    cache = NodeImageCache()
    cache.put(BaseImage.from_state("base-v1", base_state, PAGE))
    path = str(tmp_path / "f.jif")
    snapshot(rng_state(0), path, base=cache.get("base-v1"), page_size=PAGE)
    with pytest.raises(FileNotFoundError):
        SpiceRestorer(node_cache=NodeImageCache()).restore(path)


def test_access_order_layout(tmp_path):
    state = rng_state()
    names = [n for n, _ in flatten_state(state)[0]]
    order = list(reversed(names))
    path = str(tmp_path / "f.jif")
    snapshot(state, path, access_order=order, page_size=PAGE)
    got, meta, _, _ = SpiceRestorer().restore(path)
    assert meta["access_order"] == order
    assert_state_equal(state, got)


def test_streaming_restore_overlap(tmp_path):
    """wait=False returns handles immediately; tensors become ready in
    access order and waiting per-tensor yields correct bytes."""
    state = rng_state(3, scale=8)
    path = str(tmp_path / "f.jif")
    snapshot(state, path, page_size=PAGE)
    ready_order = []
    restorer = SpiceRestorer()
    tree, meta, handles, _ = restorer.restore(
        path, on_ready=lambda n, a: ready_order.append(n), wait=False
    )
    leaves, _ = flatten_state(state)
    for name, arr in leaves:
        got = handles[name].wait(10)
        np.testing.assert_array_equal(got, np.asarray(arr))
    assert ready_order == meta["access_order"]


def test_trim_fn(tmp_path):
    state = {"params": rng_state()["embed"], "opt": {"m": np.ones((4096,), np.float32)}}
    path = str(tmp_path / "f.jif")
    snapshot(state, path, page_size=PAGE, trim_fn=lambda s: {"params": s["params"]})
    got, _, _, _ = SpiceRestorer().restore(path)
    assert "opt" not in got


# ------------------------------------------------------------------ baselines
def test_criu_star_roundtrip(tmp_path):
    state = rng_state()
    d = str(tmp_path / "criu")
    baselines.criu_star_snapshot(state, d)
    got, stats = baselines.criu_star_restore(d)
    assert_state_equal(state, got)
    n = len(flatten_state(state)[0])
    assert stats.restore_ops >= 3 * n  # per-resource replay

def test_reap_star_roundtrip(tmp_path):
    state = rng_state()
    extra = {"opt": np.ones((4096,), np.float32)}
    path = str(tmp_path / "mono.img")
    baselines.monolith_snapshot(state, path, extra_state=extra)
    got, stats = baselines.reap_star_restore(path)
    assert_state_equal(state, got)
    total = sum(np.asarray(a).nbytes for _, a in flatten_state(state)[0])
    assert stats.bytes_read > total  # fetched the unused extra state too


def test_faasnap_star_faults(tmp_path):
    state = rng_state()
    path = str(tmp_path / "mono.img")
    baselines.monolith_snapshot(state, path)
    r = baselines.FaasnapAsyncRestorer(path, lag_s=0.05)
    # demand an out-of-order tensor immediately: must fault, still correct
    arr = r.ensure("layers/2/w")
    np.testing.assert_array_equal(arr, state["layers"][2]["w"])
    assert r.stats.major_faults > 0
    assert_state_equal(state, r.state())


# ----------------------------------------------------------------- pool/cache
def test_pool_zero_reuse():
    pool = BufferPool(capacity_bytes=1 << 20)
    b = pool.acquire(5000)
    assert b.nbytes >= 5000 and not b.any()
    b[:] = 7
    pool.release(b)
    b2 = pool.acquire(5000)
    assert not b2.any()  # re-zeroed
    assert pool.stats["hits"] == 1


def test_pool_concurrent_acquire_release():
    """Stress the pool from many threads: stats must balance and every
    acquired buffer must come back zeroed (thread-safety pass)."""
    pool = BufferPool(capacity_bytes=8 << 20)
    errors = []

    def worker(seed):
        r = np.random.RandomState(seed)
        for _ in range(200):
            nb = int(r.randint(1, 64 << 10))
            buf = pool.acquire(nb)
            if buf.any():
                errors.append("dirty buffer from acquire")
                return
            buf[: min(64, buf.nbytes)] = 1
            pool.note_zero_chunks(nb)
            pool.release(buf, dirty=True)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    stats = pool.snapshot_stats()
    assert stats["hits"] + stats["misses"] == 8 * 200
    assert stats["zero_bytes_avoided"] > 0
    assert pool.held_bytes <= pool.capacity


def test_restore_stats_snapshot_consistent(tmp_path):
    """wait=False stats must expose completion; totals are only final (and
    the JifReader only closed) once the stream has drained."""
    state = rng_state(1, scale=8)
    path = str(tmp_path / "f.jif")
    snapshot(state, path, page_size=PAGE)
    restorer = SpiceRestorer(simulate_read_bw=5e8)
    _, _, handles, stats = restorer.restore(path, wait=False)
    d = stats.as_dict()
    assert "complete" in d  # snapshot carries its own consistency marker
    assert stats.wait_complete(timeout=30)
    done = stats.as_dict()
    assert done["complete"]
    total = sum(np.asarray(a).nbytes for _, a in flatten_state(state)[0])
    # all private bytes were read and accounted once the stream completed
    assert done["bytes_read"] + done["zero_bytes"] >= total - PAGE * len(handles)
    for h in handles.values():
        assert h.ready


def test_failed_restore_releases_waiters(tmp_path):
    """A failure on the prefetch path (here: device install) must fail the
    stream, release every TensorHandle waiter with the error, and still
    mark stats complete (reader closed) instead of hanging."""
    state = rng_state()
    path = str(tmp_path / "f.jif")
    snapshot(state, path, page_size=PAGE)

    def bad_install(arr):
        raise RuntimeError("device install failed")

    restorer = SpiceRestorer(transform=bad_install)
    _, _, handles, stats = restorer.restore(path, wait=False)
    with pytest.raises(RuntimeError):
        next(iter(handles.values())).wait(5)
    assert stats.wait_complete(5)


def test_node_cache_lru():
    cache = NodeImageCache(capacity_bytes=1)  # force eviction
    cache.put(BaseImage.from_state("a", {"x": np.ones(4096, np.float32)}))
    cache.put(BaseImage.from_state("b", {"x": np.ones(4096, np.float32)}))
    assert cache.get("a") is None
    assert cache.get("b") is not None
