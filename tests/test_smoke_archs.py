"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step + prefill/decode on CPU; asserts output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import lm
from repro.models.frontends import make_frame_embeds, make_patch_embeds, mrope_positions

B, S = 2, 32


def _batch(cfg, key, batch=B, seq=S, decode=False):
    s = 1 if decode else seq
    out = {}
    if cfg.frontend == "audio":
        out["frame_embeds"] = make_frame_embeds(key, batch, s, cfg.d_model)
    else:
        out["tokens"] = jax.random.randint(key, (batch, s), 0, cfg.vocab_size)
        if cfg.frontend == "vision" and not decode:
            out["patch_embeds"] = make_patch_embeds(key, batch, cfg.frontend_tokens, cfg.d_model)
            out["positions"] = jnp.asarray(mrope_positions(batch, s, cfg.frontend_tokens, grid=2))
    return out


@pytest.fixture(scope="module", params=sorted(ARCHS))
def arch_setup(request):
    cfg = get_config(request.param).reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key, jnp.float32)
    return cfg, params


def test_train_forward(arch_setup):
    cfg, params = arch_setup
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, caches, aux = jax.jit(
        lambda p, b: lm.forward(cfg, p, b, mode="train")
    )(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert caches is None
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


def test_train_step_no_nan(arch_setup):
    cfg, params = arch_setup
    batch = _batch(cfg, jax.random.PRNGKey(2))
    targets = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab_size)

    def loss_fn(p):
        logits, _, aux = lm.forward(cfg, p, batch, mode="train", remat="dots")
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        tgt = jnp.take_along_axis(
            logits.astype(jnp.float32), targets[..., None], axis=-1
        )[..., 0]
        return jnp.mean(lse - tgt) + 0.01 * aux

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert flat, "no grads"
    for g in flat:
        assert np.isfinite(np.asarray(g, np.float32)).all()


def test_prefill_then_decode(arch_setup):
    cfg, params = arch_setup
    batch = _batch(cfg, jax.random.PRNGKey(4))
    logits, caches, _ = jax.jit(lambda p, b: lm.prefill(cfg, p, b))(params, batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    dec = _batch(cfg, jax.random.PRNGKey(5), decode=True)
    pos = jnp.int32(S)
    logits2, caches2, _ = jax.jit(
        lambda p, b, c, t: lm.decode_step(cfg, p, b, c, t)
    )(params, dec, caches, pos)
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    # cache trees must be structurally stable across steps
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


def test_decode_matches_full_forward():
    """Incremental decode must agree with teacher-forced full forward."""
    cfg = get_config("qwen1.5-0.5b").reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key, jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab_size)

    full_logits, _, _ = lm.forward(
        cfg, params, {"tokens": toks}, mode="train", compute_dtype=jnp.float32
    )

    caches = lm.init_cache(cfg, 1, 8, kv_dtype=jnp.float32, compute_dtype=jnp.float32)
    outs = []
    for t in range(8):
        logits, caches, _ = lm.decode_step(
            cfg,
            params,
            {"tokens": toks[:, t : t + 1]},
            caches,
            jnp.int32(t),
            compute_dtype=jnp.float32,
        )
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32),
        np.asarray(full_logits, np.float32),
        rtol=2e-4,
        atol=2e-4,
    )


def test_decode_matches_full_forward_ssm():
    """Same equivalence for the attention-free SSD arch (state recurrence)."""
    cfg = get_config("mamba2-780m").reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab_size)
    full_logits, _, _ = lm.forward(
        cfg, params, {"tokens": toks}, mode="train", compute_dtype=jnp.float32
    )
    caches = lm.init_cache(cfg, 1, 8, kv_dtype=jnp.float32, compute_dtype=jnp.float32)
    outs = []
    for t in range(8):
        logits, caches, _ = lm.decode_step(
            cfg, params, {"tokens": toks[:, t : t + 1]}, caches, jnp.int32(t),
            compute_dtype=jnp.float32,
        )
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full_logits, np.float32),
        rtol=5e-4, atol=5e-4,
    )


def test_param_counts_sane():
    for name, cfg in ARCHS.items():
        n = cfg.param_count()
        na = cfg.active_param_count()
        assert na <= n
        assert n > 1e8, f"{name}: {n}"
