"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import SpiceRestorer, snapshot
from repro.core import overlay
from repro.core.treeutil import flatten_state, leaf_names, unflatten_state
from repro.models.attention import dequantize_kv, quantize_kv
from repro.train.steps import softmax_xent

PAGE = 1024

# ---------------------------------------------------------- state strategies
dtypes = st.sampled_from([np.float32, np.int32, np.uint8, np.float16])


@st.composite
def arrays(draw):
    dt = draw(dtypes)
    shape = tuple(draw(st.lists(st.integers(1, 5), min_size=0, max_size=3)))
    seed = draw(st.integers(0, 2**31 - 1))
    r = np.random.RandomState(seed)
    a = (np.asarray(r.randn(*shape)) * 100).astype(dt)  # 0-d safe
    return a


@st.composite
def state_trees(draw, depth=2):
    if depth == 0:
        return draw(arrays())
    kind = draw(st.sampled_from(["leaf", "dict", "list"]))
    if kind == "leaf":
        return draw(arrays())
    n = draw(st.integers(1, 3))
    if kind == "dict":
        keys = draw(
            st.lists(st.text("abcdef", min_size=1, max_size=4), min_size=n,
                     max_size=n, unique=True)
        )
        return {k: draw(state_trees(depth=depth - 1)) for k in keys}
    return [draw(state_trees(depth=depth - 1)) for _ in range(n)]


@given(state_trees())
@settings(max_examples=25, deadline=None)
def test_jif_roundtrip_any_tree(tmp_path_factory, tree):
    d = tmp_path_factory.mktemp("prop")
    path = str(d / "t.jif")
    snapshot(tree, path, page_size=PAGE)
    got, _, _, _ = SpiceRestorer().restore(path)
    la, _ = flatten_state(tree)
    lb, _ = flatten_state(got)
    assert [n for n, _ in la] == [n for n, _ in lb]
    for (n, x), (_, y) in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), err_msg=n)


@given(state_trees())
@settings(max_examples=25, deadline=None)
def test_tree_flatten_names_stable(tree):
    leaves, desc = flatten_state(tree)
    assert [n for n, _ in leaves] == leaf_names(desc)
    rebuilt = unflatten_state(desc, dict(leaves))
    leaves2, desc2 = flatten_state(rebuilt)
    assert [n for n, _ in leaves] == [n for n, _ in leaves2]


@given(state_trees(), st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_delta_chain_roundtrip_any_tree(tmp_path_factory, tree, seed):
    """Parent → child delta → restore through the chain is byte-identical,
    for arbitrary trees and arbitrary leaf perturbations."""
    from repro.core import NodeImageCache

    d = tmp_path_factory.mktemp("delta")
    parent_path = str(d / "parent.jif")
    snapshot(tree, parent_path, page_size=PAGE)

    r = np.random.RandomState(seed)
    leaves, desc = flatten_state(tree)
    child_leaves = {}
    for n, a in leaves:
        a = np.asarray(a)
        if a.size and r.rand() < 0.5:  # dirty a subset of leaves
            b = a.copy().reshape(-1)
            b[r.randint(0, b.size)] = b[r.randint(0, b.size)] + 1
            a = b.reshape(a.shape)
        child_leaves[n] = a
    child = unflatten_state(desc, child_leaves)

    child_path = str(d / "child.jif")
    stats = snapshot(child, child_path, parent=parent_path, page_size=PAGE)
    assert stats.private_bytes <= stats.total_bytes
    # fresh cache: the parent is bootstrapped from disk during restore
    got, _, _, _ = SpiceRestorer(node_cache=NodeImageCache()).restore(child_path)
    for (n, x), (_, y) in zip(flatten_state(child)[0], flatten_state(got)[0]):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), err_msg=n)


# --------------------------------------------------------- overlay invariants
@given(st.binary(min_size=1, max_size=PAGE * 9), st.booleans())
@settings(max_examples=40, deadline=None)
def test_classification_accounting(data, with_base):
    buf = np.frombuffer(data, np.uint8)
    base = None
    if with_base:
        b = buf.copy()
        if len(b) > PAGE:
            b[:PAGE] = ~b[:PAGE]  # first page always differs
        base = overlay.chunk_digests(memoryview(b.tobytes()), PAGE)
    kinds = overlay.classify(memoryview(buf), PAGE, base)
    table = overlay.IntervalTable(overlay.intervals_from_kinds(kinds))
    counts = table.counts()
    assert sum(counts.values()) == overlay.n_chunks(len(buf), PAGE)
    # intervals are sorted, non-overlapping, alternating kinds
    t = table.table
    for i in range(1, len(t)):
        assert t[i, 0] == t[i - 1, 0] + t[i - 1, 1]
        assert t[i, 2] != t[i - 1, 2]


@given(st.integers(0, 2**31 - 1), st.integers(1, 64))
@settings(max_examples=30, deadline=None)
def test_kv_quantization_error_bound(seed, sc):
    r = np.random.RandomState(seed)
    x = jnp.asarray(r.randn(2, 3, sc, 16).astype(np.float32) * r.uniform(0.01, 10))
    q, scale = quantize_kv(x)
    deq = dequantize_kv(q, scale, jnp.float32)
    # max per-vector error <= scale/2 + eps (symmetric rounding)
    err = np.abs(np.asarray(deq - x))
    bound = np.asarray(scale)[..., None] * 0.51 + 1e-6
    assert (err <= bound).all()


# ------------------------------------------------------------- loss identity
@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_masked_xent_equals_gather_xent(seed):
    r = np.random.RandomState(seed)
    logits = jnp.asarray(r.randn(2, 5, 17).astype(np.float32))
    targets = jnp.asarray(r.randint(0, 17, size=(2, 5)))
    got = softmax_xent(logits, targets)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    want = jnp.mean(lse - tgt)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-6)


# -------------------------------------------------------------- ssd property
@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_ssd_chunking_invariance(seed):
    """SSD output must not depend on the chunk size."""
    from repro.models.mamba2 import ssd

    r = np.random.RandomState(seed)
    B, S, H, P, N = 1, 32, 2, 8, 4
    x = jnp.asarray(r.randn(B, S, H, P).astype(np.float32) * 0.5)
    a = -jnp.asarray(np.abs(r.randn(B, S, H)).astype(np.float32) * 0.3)
    Bm = jnp.asarray(r.randn(B, S, 1, N).astype(np.float32) * 0.5)
    Cm = jnp.asarray(r.randn(B, S, 1, N).astype(np.float32) * 0.5)
    y8, st8 = ssd(x, a, Bm, Cm, 8)
    y16, st16 = ssd(x, a, Bm, Cm, 16)
    y32, st32 = ssd(x, a, Bm, Cm, 32)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y16), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y32), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st8), np.asarray(st32), rtol=1e-4, atol=1e-4)
