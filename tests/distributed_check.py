"""Subprocess worker: numerical equivalence of the sharded paths vs the
single-device oracle, on 8 fake host devices. Invoked by test_distributed.py
(device count must be fixed before jax initializes)."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.specs import make_rules
from repro.configs.base import InputShape
from repro.models import lm
from repro.models.moe import moe_ffn
from repro.models.layers import embed
from repro.sharding.partition import axis_rules
from repro.train.steps import TrainStepConfig, init_train_state, make_train_step


def mesh_2d():
    from repro.launch.mesh import make_mesh_compat

    return make_mesh_compat((2, 4), ("data", "model"))


def check_moe_and_embed():
    # capacity big enough that no token drops: per-shard capacity enforcement
    # (sharded EP) must then agree exactly with the global-capacity oracle
    cfg = dataclasses.replace(get_config("olmoe-1b-7b").reduced(), capacity_factor=8.0)
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)

    ref_logits, _, ref_aux = jax.jit(
        lambda p, t: lm.forward(cfg, p, {"tokens": t}, mode="train",
                                compute_dtype=jnp.float32)
    )(params, toks)

    mesh = mesh_2d()
    rules = make_rules(cfg, InputShape("t", "train", 16, 4), False)
    with mesh, axis_rules(mesh, rules):
        sh_logits, _, sh_aux = jax.jit(
            lambda p, t: lm.forward(cfg, p, {"tokens": t}, mode="train",
                                    compute_dtype=jnp.float32)
        )(params, toks)
    np.testing.assert_allclose(
        np.asarray(ref_logits), np.asarray(sh_logits), rtol=2e-4, atol=2e-4
    )
    # sharded aux is the standard per-device LBL (mean of per-shard products
    # != product of global means): approximate agreement only
    np.testing.assert_allclose(float(ref_aux), float(sh_aux), rtol=0.25)
    print("moe+embed sharded == local: OK")


def check_moe_decode_path():
    """replicated-token EP mode (S=1) against the local path."""
    cfg = dataclasses.replace(
        get_config("phi3.5-moe-42b-a6.6b").reduced(), capacity_factor=8.0
    )
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    caches = lm.init_cache(cfg, 4, 32, kv_dtype=jnp.float32, compute_dtype=jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 1), 0, cfg.vocab_size)

    ref, _, _ = jax.jit(
        lambda p, t, c: lm.decode_step(cfg, p, {"tokens": t}, c, jnp.int32(3),
                                       compute_dtype=jnp.float32)
    )(params, toks, caches)
    mesh = mesh_2d()
    rules = make_rules(cfg, InputShape("d", "decode", 32, 4), False)
    with mesh, axis_rules(mesh, rules):
        got, _, _ = jax.jit(
            lambda p, t, c: lm.decode_step(cfg, p, {"tokens": t}, c, jnp.int32(3),
                                           compute_dtype=jnp.float32)
        )(params, toks, caches)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got), rtol=2e-4, atol=2e-4)
    print("moe decode (replicated EP) sharded == local: OK")


def check_train_step():
    cfg = get_config("qwen1.5-0.5b").reduced()
    tcfg = TrainStepConfig(remat="dots", compute_dtype="float32",
                           num_microbatches=2, kv_repeat=2)
    params, opt = init_train_state(cfg, jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size),
        "targets": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0, cfg.vocab_size),
    }
    step = make_train_step(cfg, tcfg)
    p_ref, _, m_ref = jax.jit(step)(params, opt, batch)

    mesh = mesh_2d()
    rules = make_rules(cfg, InputShape("t", "train", 32, 4), False)
    with mesh, axis_rules(mesh, rules):
        p_sh, _, m_sh = jax.jit(step)(params, opt, batch)
    np.testing.assert_allclose(float(m_ref["loss"]), float(m_sh["loss"]), rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_sh)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-4, atol=3e-4)
    print("train_step sharded == local: OK")


def check_elastic_reshard():
    from repro.ft.elastic import make_mesh_from_plan, plan_mesh, reshard_state
    from repro.models.lm import param_specs

    cfg = get_config("qwen1.5-0.5b").reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    state_np = jax.tree.map(np.asarray, params)
    plan = plan_mesh(8, model_parallel=4)
    assert plan.shape == (2, 4)
    mesh = make_mesh_from_plan(plan)
    rules = make_rules(cfg, InputShape("t", "train", 32, 4), False)
    placed = reshard_state(state_np, param_specs(cfg), mesh, rules)
    for a, b in zip(jax.tree.leaves(placed), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # scale-down: 6 devices -> (1, 4) mesh w/ 2 idle, state still placeable
    plan2 = plan_mesh(6, model_parallel=4)
    mesh2 = make_mesh_from_plan(plan2)
    placed2 = reshard_state(state_np, param_specs(cfg), mesh2, rules)
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(placed2)[0]), np.asarray(jax.tree.leaves(params)[0])
    )
    print("elastic reshard: OK")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    fns = {
        "moe": check_moe_and_embed,
        "moe_decode": check_moe_decode_path,
        "train": check_train_step,
        "elastic": check_elastic_reshard,
    }
    if which == "all":
        for f in fns.values():
            f()
    else:
        fns[which]()
    print("DISTRIBUTED_CHECKS_PASSED")
