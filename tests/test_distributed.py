"""Run the 8-fake-device equivalence checks in a subprocess (jax locks the
device count at first init, so the main pytest process can't host them)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

SCRIPT = Path(__file__).parent / "distributed_check.py"
SRC = str(Path(__file__).resolve().parents[1] / "src")


@pytest.mark.parametrize("which", ["moe", "moe_decode", "train", "elastic"])
def test_distributed(which):
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, str(SCRIPT), which],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"
    assert "DISTRIBUTED_CHECKS_PASSED" in out.stdout
