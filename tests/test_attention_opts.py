"""Optimization levers must be output-invariant: staged causal/window-aware
K-slicing and zero-padded heads change only the lowering, never the math."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.models.attention import attn_full


def _layer0_attn(cfg, key=0):
    params = lm.init_params(cfg, jax.random.PRNGKey(key), jnp.float32)
    return jax.tree.map(lambda a: a[0], params["pattern"][0]["attn"])


@pytest.mark.parametrize("arch,pidx", [("starcoder2-7b", 0), ("gemma3-27b", 0), ("gemma3-27b", 5)])
@pytest.mark.parametrize("stages", [2, 4, 8])
def test_staged_attention_invariant(arch, pidx, stages):
    cfg = get_config(arch).reduced()
    spec = cfg.pattern[pidx]
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    p0 = jax.tree.map(lambda a: a[0], params["pattern"][pidx]["attn"])
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(64), (2, 64))
    y1, _ = attn_full(cfg, spec, p0, x, pos, jnp.float32, q_chunk=8, attn_stages=1)
    ys, cs = attn_full(cfg, spec, p0, x, pos, jnp.float32, q_chunk=8,
                       attn_stages=stages, return_cache=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(ys), rtol=1e-5, atol=1e-5)
    assert cs["k"].shape[2] == min(spec.window or 64, 64)


def test_padded_heads_zero_weights_are_identity():
    """Extending n_heads with zero wq/wo columns must not change outputs."""
    cfg = get_config("starcoder2-7b").reduced()  # 4 heads reduced
    cfg = dataclasses.replace(cfg, n_kv_heads=1)
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    p0 = jax.tree.map(lambda a: a[0], params["pattern"][0]["attn"])
    spec = cfg.pattern[0]
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(16), (1, 16))
    y_base, _ = attn_full(cfg, spec, p0, x, pos, jnp.float32)

    cfg_pad = dataclasses.replace(cfg, n_heads=8, head_dim=cfg.hd)
    hd = cfg.hd
    extra = (cfg_pad.n_heads - cfg.n_heads) * hd
    p_pad = dict(p0)
    p_pad["wq"] = jnp.concatenate(
        [p0["wq"], jnp.zeros((cfg.d_model, extra), jnp.float32)], axis=1
    )
    p_pad["wo"] = jnp.concatenate(
        [p0["wo"], jnp.zeros((extra, cfg.d_model), jnp.float32)], axis=0
    )
    y_pad, _ = attn_full(cfg_pad, spec, p_pad, x, pos, jnp.float32)
    np.testing.assert_allclose(np.asarray(y_base), np.asarray(y_pad), rtol=1e-5, atol=1e-5)
