"""Train→serve deployment pipeline: versioned publishes share base chunks
through the CAS, the canary A/B split is deterministic under seed,
promote/rollback serve byte-identical state, retired-version GC leaves the
CAS audit clean, and colocated BATCH training never starves LATENCY work."""
import threading
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import ChunkStore, SpiceRestorer
from repro.ft.manager import CheckpointManager
from repro.ft.publish import DeltaPublishCallback
from repro.serve.cluster import ClusterRouter, FunctionCatalog
from repro.serve.deploy import (
    ColocatedTrainer,
    RolloutController,
    TokenHealthGate,
)
from repro.serve.instance import layerwise_state
from repro.serve.invocation import AdmissionController, Invocation, Overloaded, QosClass
from repro.serve.node import FixedTTLPolicy, NodeScheduler
from repro.models import lm

ARCH = "qwen1.5-0.5b"
PROMPT = np.array([[3, 1, 4, 1, 5, 9]], dtype=np.int32)


def _finetune(cfg, params, scale: float):
    """The repo's standard partial-fine-tune perturbation (benchmarks
    idiom): dirty the top ~40% of the stacked layers + final_norm, leaving
    the rest byte-identical to the parent — the delta publish should pay
    for roughly that fraction only."""
    params = dict(params)
    params["pattern"] = list(params["pattern"])
    params["final_norm"] = params["final_norm"] + scale

    def bump(a):
        a = np.asarray(a)
        if a.ndim >= 1 and a.shape[0] == cfg.pattern_reps:
            cut = int(cfg.pattern_reps * 0.6)
            a = a.copy()
            a[cut:] = a[cut:] * (1.0 + scale)
        return a

    for pi in range(len(cfg.pattern)):
        params["pattern"][pi] = jax.tree.map(bump, params["pattern"][pi])
    return params


@pytest.fixture(scope="module")
def deployed(tmp_path_factory):
    """One catalog + CAS with three published base functions and their
    init params; a throwaway node warms the compile cache."""
    d = tmp_path_factory.mktemp("deploy")
    cfg = get_config(ARCH).reduced()
    store = ChunkStore(str(d / "cas"))
    catalog = FunctionCatalog(chunk_store=store)
    zoo = {}
    # one base per test that grows a lineage: versions register under
    # "<fname>@vN", so lineages sharing a name would collide across tests
    for i, fname in enumerate(["dp-a", "dp-b", "dp-c", "dp-d", "dp-e", "dp-f"]):
        params = lm.init_params(cfg, jax.random.PRNGKey(80 + i), jnp.float32)
        catalog.publish(fname, cfg, params, str(d), warm_ttl_s=3600.0,
                        formats=("jif",))
        zoo[fname] = params
    node = NodeScheduler(registry=catalog.registry)
    node.invoke("dp-a", PROMPT, max_new_tokens=2, mode="spice_sync", cfg=cfg)
    return catalog, cfg, str(d), zoo, store


def _router(catalog, n=2):
    nodes = [
        NodeScheduler(registry=catalog.registry, keepalive=FixedTTLPolicy(3600.0))
        for _ in range(n)
    ]
    return ClusterRouter(catalog, nodes)


def _leaves(state):
    flat, _ = jax.tree.flatten(state)
    return [np.asarray(a) for a in flat]


# -------------------------------------------------- CAS chunk sharing
def test_versioned_publish_shares_base_chunks(deployed, tmp_path):
    catalog, cfg, d, zoo, store = deployed
    deploy = RolloutController(catalog, seed=7, dirpath=str(tmp_path))
    deploy.track("dp-a")

    before = store.audit()  # also asserts the invariant pre-publish
    rec = deploy.publish_version(
        "dp-a", cfg, _finetune(cfg, zoo["dp-a"], 0.01), step=1
    )
    after = store.audit()

    # the delta pays only for the dirtied fraction, not a second full image
    assert 0 < rec.private_bytes < 0.6 * rec.total_bytes
    v1 = deploy.current("dp-a")
    assert rec.private_bytes < 0.6 * v1.total_bytes
    # CAS growth is the delta's chunks only: far fewer than a full image's
    new_chunks = after["chunks"] - before["chunks"]
    assert 0 < new_chunks
    # the version is a real registered function restorable on any node
    assert catalog.registry.get(rec.name).jif_path == rec.jif_path
    state, _, _, _ = SpiceRestorer().restore(rec.jif_path)
    ref = layerwise_state(cfg, _finetune(cfg, zoo["dp-a"], 0.01))
    for a, b in zip(_leaves(ref), _leaves(state)):
        np.testing.assert_array_equal(a, b)


# -------------------------------------------- deterministic canary split
def test_canary_fraction_deterministic_under_seed(deployed, tmp_path):
    catalog, cfg, d, zoo, store = deployed
    deploy = RolloutController(catalog, seed=123, dirpath=str(tmp_path))
    deploy.track("dp-b")
    rec = deploy.publish_version("dp-b", cfg, _finetune(cfg, zoo["dp-b"], 0.02))

    deploy.begin_canary("dp-b", rec.version, fraction=0.3)
    seq1 = [deploy.resolve("dp-b") for _ in range(400)]
    # re-arming the same (seed, version, name) canary replays the exact
    # same routing decisions — the split is a pure function of the seed
    deploy.begin_canary("dp-b", rec.version, fraction=0.3)
    seq2 = [deploy.resolve("dp-b") for _ in range(400)]
    assert seq1 == seq2

    frac = sum(s == rec.name for s in seq1) / len(seq1)
    assert 0.2 < frac < 0.4  # the requested fraction, not all-or-nothing
    assert {s for s in seq1} == {"dp-b", rec.name}

    # a different controller seed routes differently
    other = RolloutController(catalog, seed=124, dirpath=str(tmp_path))
    other.track("dp-b")
    other.lineage("dp-b").records[rec.version] = rec
    other.begin_canary("dp-b", rec.version, fraction=0.3)
    assert [other.resolve("dp-b") for _ in range(400)] != seq1

    # names that are not logical lineages pass through untouched
    assert deploy.resolve(rec.name) == rec.name
    assert deploy.resolve("unknown-fn") == "unknown-fn"
    deploy.rollback("dp-b")  # reject the canary; dp-b lineage back to v1


# ------------------------------------- promote / rollback byte-identity
def test_promote_rollback_byte_identity(deployed, tmp_path):
    catalog, cfg, d, zoo, store = deployed
    deploy = RolloutController(catalog, seed=5, dirpath=str(tmp_path))
    deploy.track("dp-c")
    tuned = _finetune(cfg, zoo["dp-c"], 0.03)
    rec = deploy.publish_version("dp-c", cfg, tuned, step=2)
    deploy.begin_canary("dp-c", rec.version, fraction=0.5)

    publishes_before = catalog.stats["publishes"]
    deploy.promote("dp-c")
    assert deploy.current("dp-c").version == rec.version
    assert deploy.canary("dp-c") is None
    assert deploy.resolve("dp-c") == rec.name  # all traffic on v2 now
    state, _, _, _ = SpiceRestorer().restore(deploy.current("dp-c").jif_path)
    for a, b in zip(_leaves(layerwise_state(cfg, tuned)), _leaves(state)):
        np.testing.assert_array_equal(a, b)

    # instant rollback: pointer repoint to the parent, zero new publishes,
    # and a fresh restore of what now serves is leaf-by-leaf identical to
    # the original base state
    back = deploy.rollback("dp-c")
    assert back.version == 1 and deploy.resolve("dp-c") == "dp-c"
    assert catalog.stats["publishes"] == publishes_before
    state, _, _, _ = SpiceRestorer().restore(back.jif_path)
    ref = layerwise_state(cfg, zoo["dp-c"])
    for a, b in zip(_leaves(ref), _leaves(state)):
        np.testing.assert_array_equal(a, b)
    store.audit()


# --------------------------------------------------- retired-version GC
def test_retired_version_gc_leaves_cas_clean(deployed, tmp_path):
    catalog, cfg, d, zoo, store = deployed
    deploy = RolloutController(catalog, seed=9, dirpath=str(tmp_path))
    deploy.track("dp-d")
    before = store.audit()
    rec = deploy.publish_version("dp-d", cfg, _finetune(cfg, zoo["dp-d"], 0.04))
    deploy.begin_canary("dp-d", rec.version, fraction=0.25)
    deploy.rollback("dp-d")  # gate failed: reject the canary

    # still registered until GC actually retires it
    assert rec.name in catalog.registry
    retired = deploy.gc_retired("dp-d")
    assert retired == [rec.name]
    assert rec.name not in catalog.registry
    import os
    assert not os.path.exists(rec.jif_path)
    # every chunk the dead version uniquely owned is unlinked; the store
    # invariant (disk == refs) holds and the base's chunks survive
    after = store.audit()
    assert after["chunks"] == before["chunks"]
    assert after["refs"] == before["refs"]

    # the stable ancestor of the live head is NOT collectable
    with pytest.raises(ValueError):
        deploy.retire("dp-d", 1)


# ---------------------------------------- quality gate end-to-end rollout
def test_canary_gate_promotes_over_router(deployed, tmp_path):
    catalog, cfg, d, zoo, store = deployed
    router = _router(catalog)
    deploy = RolloutController(catalog, seed=11, dirpath=str(tmp_path)).attach(router)
    deploy.track("dp-e")
    rec = deploy.publish_version("dp-e", cfg, _finetune(cfg, zoo["dp-e"], 0.05))
    deploy.begin_canary("dp-e", rec.version, fraction=0.5)

    # the router resolves the logical name through the controller
    results = [
        router.invoke("dp-e", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
        for _ in range(8)
    ]
    served = {r.function for r in results}
    assert served == {"dp-e", rec.name}  # both versions took traffic

    ok = deploy.evaluate_canary(
        "dp-e", PROMPT, gate=TokenHealthGate(vocab_size=cfg.vocab_size),
        n_probes=2, max_new_tokens=2, cfg=cfg,
    )
    assert ok and deploy.current("dp-e").version == rec.version

    # a failing gate rejects and keeps the lineage where it was
    rec3 = deploy.publish_version("dp-e", cfg, _finetune(cfg, zoo["dp-e"], 0.06))
    deploy.begin_canary("dp-e", rec3.version, fraction=0.5)

    class AlwaysBad:
        def evaluate(self, results):
            return False

    ok = deploy.evaluate_canary("dp-e", PROMPT, gate=AlwaysBad(),
                                n_probes=1, max_new_tokens=2, cfg=cfg)
    assert not ok
    assert deploy.current("dp-e").version == rec.version
    assert deploy.canary("dp-e") is None
    router.audit()
    router.close()


# ------------------------------------------- serve/train colocation QoS
def test_colocated_batch_training_never_starves_latency(deployed):
    catalog, cfg, d, zoo, store = deployed
    node = NodeScheduler(
        registry=catalog.registry,
        keepalive=FixedTTLPolicy(3600.0),
        max_workers=2,
        admission=AdmissionController(max_batch_inflight=1),
    )
    # warm the serving function first
    r = node.invoke("dp-c", PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
    assert r.cold

    # a second concurrent BATCH payload is REFUSED: the in-flight cap keeps
    # background compute from occupying every worker
    blocker = node.submit_invocation(Invocation(
        function="train:ft", qos=QosClass.BATCH,
        payload=lambda: time.sleep(0.3),
    ))
    with pytest.raises(Overloaded):
        node.submit_invocation(Invocation(
            function="train:ft", qos=QosClass.BATCH,
            payload=lambda: time.sleep(0.3),
        ))

    # a training loop grinding BATCH steps leaves LATENCY service intact
    trainer = ColocatedTrainer(node, job_name="ft")
    stop = threading.Event()

    def grind():
        while not stop.is_set():
            trainer.step(time.sleep, 0.05)

    t = threading.Thread(target=grind, daemon=True)
    t.start()
    try:
        for _ in range(5):
            lr = node.submit_invocation(Invocation(
                function="dp-c", prompt=PROMPT, max_new_tokens=2,
                mode="spice", cfg=cfg, qos=QosClass.LATENCY,
            )).result(10.0)
            assert not lr.cold          # stayed warm throughout
            assert lr.queue_wait_s < 0.25  # never parked behind training
    finally:
        stop.set()
        t.join(5.0)
    blocker.result(10.0)
    assert node.stats["payload_runs"] >= 2
    assert trainer.stats["steps"] >= 1
    node.memory.audit()
    node.close()


# ----------------------------------- checkpoint callback -> new versions
def test_checkpoint_callback_publishes_versions(deployed, tmp_path):
    catalog, cfg, d, zoo, store = deployed
    deploy = RolloutController(catalog, seed=3, dirpath=str(tmp_path / "pub"))
    cb = DeltaPublishCallback(
        deploy, "dp-f", cfg, every=2, canary_fraction=0.5,
        extract=lambda s: s["params"],
    )
    mgr = CheckpointManager(str(tmp_path / "ckpt"), async_save=False,
                            callbacks=[cb])
    for step in range(4):  # 4 saves, every=2 -> 2 published versions
        state = {"params": _finetune(cfg, zoo["dp-f"], 0.001 * (step + 1)),
                 "opt": {"count": np.int32(step)}}
        mgr.save(step, state, blocking=True)
    assert [r.step for r in cb.published] == [0, 2]
    assert len(deploy.versions("dp-f")) == 3  # v1 + the two publishes
    # latest publish is the canary (auto_canary), superseding the first
    assert deploy.canary("dp-f").version == cb.published[-1].version
    assert cb.published[0].status == "rejected"
    deploy.rollback("dp-f")
    assert deploy.gc_retired("dp-f") != []
    store.audit()
