"""Prefetch I/O scheduler: stream completion, round-robin fairness across
concurrent streams, and demand-boost reordering ahead of background
prefetch."""
import threading
import time

import pytest

from repro.core import PrefetchIOScheduler


def _op(nbytes=1000, sleep=0.0):
    def op():
        if sleep:
            time.sleep(sleep)
        return nbytes
    return op


def test_stream_runs_in_order_and_completes():
    sched = PrefetchIOScheduler("t")
    done = []
    completed = []
    stream = sched.open_stream("s", on_complete=lambda: completed.append(True))
    for i in range(5):
        stream.submit(f"t{i}", [_op(), _op()], (lambda n=i: done.append(n)))
    stream.seal()
    assert stream.wait(5)
    assert done == list(range(5))  # FIFO without boosts
    assert completed == [True]
    s = sched.snapshot_stats()
    assert s["io_ops"] == 10 and s["bytes_read"] == 10_000 and s["tensors"] == 5
    assert s["streams_completed"] == 1


def test_demand_boost_reorders_ahead_of_background_prefetch():
    sched = PrefetchIOScheduler("t")
    gate = threading.Event()
    done = []
    stream = sched.open_stream("s")

    def gated():
        gate.wait(5)
        return 10
    stream.submit("t0", [gated], lambda: done.append("t0"))
    for i in range(1, 6):
        stream.submit(f"t{i}", [_op()], (lambda n=f"t{i}": done.append(n)))
    stream.seal()
    # while t0's read is in flight, execution demands t4
    assert stream.boost("t4")
    gate.set()
    assert stream.wait(5)
    assert done.index("t4") < done.index("t1")  # overtook background order
    assert sched.snapshot_stats()["demand_boosts"] == 1
    # boosting an already-finalized tensor is a no-op
    assert not stream.boost("t1")


def test_round_robin_shares_bandwidth_across_streams():
    sched = PrefetchIOScheduler("t")
    gate = threading.Event()
    order = []
    streams = []
    for s in ("a", "b"):
        stream = sched.open_stream(s)
        stream.submit(f"{s}-gate", [lambda: (gate.wait(5), 0)[1]],
                      (lambda n=f"{s}0": order.append(n)))
        for i in range(1, 4):
            stream.submit(f"{s}-t{i}", [_op()],
                          (lambda n=f"{s}{i}": order.append(n)))
        stream.seal()
        streams.append(stream)
    gate.set()
    for stream in streams:
        assert stream.wait(5)
    # neither stream ran to completion before the other started: the first
    # tensors of both finish before the last tensor of either
    a_first, b_first = order.index("a0"), order.index("b0")
    a_last, b_last = order.index("a3"), order.index("b3")
    assert a_first < b_last and b_first < a_last
    assert sched.snapshot_stats()["streams_completed"] == 2


def test_priority_preempts_round_robin():
    sched = PrefetchIOScheduler("t")
    gate = threading.Event()
    order = []
    lo = sched.open_stream("lo", priority=0)
    hi = sched.open_stream("hi", priority=1)
    lo.submit("l-gate", [lambda: (gate.wait(5), 0)[1]], lambda: order.append("l0"))
    for i in range(1, 4):
        lo.submit(f"l{i}", [_op()], (lambda n=f"l{i}": order.append(n)))
    for i in range(3):
        hi.submit(f"h{i}", [_op()], (lambda n=f"h{i}": order.append(n)))
    lo.seal()
    hi.seal()
    gate.set()
    assert hi.wait(5) and lo.wait(5)
    # all high-priority tensors complete before the low stream's tail
    assert max(order.index(f"h{i}") for i in range(3)) < order.index("l3")


def test_failing_op_fails_only_its_stream():
    """One tenant's I/O error must not kill the shared reader thread."""
    sched = PrefetchIOScheduler("t")
    bad = sched.open_stream("bad")
    good = sched.open_stream("good")

    def boom():
        raise IOError("disk gone")

    bad.submit("t0", [boom], lambda: None)
    bad.seal()
    done = []
    good.submit("t0", [_op()], lambda: done.append(1))
    good.seal()
    assert bad.wait(5) and good.wait(5)
    assert isinstance(bad.error, IOError)
    assert done == [1]  # the other stream completed
    # and the scheduler still serves streams opened afterwards
    later = sched.open_stream("later")
    later.submit("x", [_op()], lambda: done.append(2))
    later.seal()
    assert later.wait(5) and done[-1] == 2


def test_boost_entry_expires_with_its_job():
    """A boost stops privileging its stream once the demanded tensor's
    I/O is done — it must not monopolize the reader for the whole queue."""
    sched = PrefetchIOScheduler("t")
    gate = threading.Event()
    order = []
    a = sched.open_stream("a")
    b = sched.open_stream("b")
    a.submit("a-gate", [lambda: (gate.wait(5), 0)[1]], lambda: order.append("a0"))
    for i in range(1, 4):
        a.submit(f"a{i}", [_op()], (lambda n=f"a{i}": order.append(n)))
    for i in range(3):
        b.submit(f"b{i}", [_op()], (lambda n=f"b{i}": order.append(n)))
    a.seal()
    b.seal()
    a.boost("a1")  # demand ONE tensor of stream a
    gate.set()
    assert a.wait(5) and b.wait(5)
    # a1 was served first after the in-flight op, but a's remaining
    # background tensors did not starve b's queue: b got service before
    # a's tail finished
    assert order.index("a1") < order.index("b1")
    assert order.index("b0") < order.index("a3")


def test_inline_stream_drains_on_caller_thread():
    sched = PrefetchIOScheduler("t")
    done = []
    stream = sched.open_stream("sync", inline=True)
    for i in range(3):
        stream.submit(f"t{i}", [_op(500)], (lambda n=i: done.append(n)))
    stream.seal()
    sched.drain_inline(stream)
    assert stream.done and done == [0, 1, 2]
    assert sched.snapshot_stats()["bytes_read"] == 1500
