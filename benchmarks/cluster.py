"""Cluster scenario: M zipf-weighted functions over N nodes, comparing
placement policies end to end.

Each policy serves the same deterministic zipf request schedule against a
fresh 3-node cluster (per-node iosched / pool / image cache / memory
ledger), after a seeding pass that cold-starts every function once through
the router.  Functions are published as DELTAS against one parent JIF on
disk, so a node that cold-restores any function first bootstraps the parent
through its own image cache (``BaseImage.from_jif``) — exactly the
snapshot-locality trade-off the policies differ on:

* ``locality_first`` (sticky) routes repeats to the warm node and joins
  concurrent invocations of one function onto the in-flight restore;
* ``round_robin`` / ``least_loaded`` re-place every request, so popular
  functions cold-start (and re-pull the parent) on every node.

Reported per policy: TTFT p50/p99, cold/warm/join counts, image-pull bytes
(sum of every node's arbiter reads), and per-node ledger high-water marks;
plus a concurrency check that a single-replica function incurs ZERO
duplicate concurrent cold restores across the cluster.  The summary merges
into ``BENCH_coldstart.json`` under the ``"cluster"`` key.
"""
from __future__ import annotations

import dataclasses
import tempfile

import numpy as np

from benchmarks.common import PROMPT, smoke

# merged into BENCH_coldstart.json (written by benchmarks/run.py)
BENCH_TARGET = "coldstart"
SUMMARY_KEY = "cluster"
SUMMARY: dict = {}

N_NODES = 3
N_FUNCTIONS = 5
ZIPF_S = 1.2
SIM_READ_BW = 2e8  # mid-tier NVMe: cold restores are visibly slower than warm


def _smoke() -> bool:
    return smoke()


def _cfg():
    from repro.configs import get_config

    cfg = get_config("qwen1.5-0.5b").reduced()
    if not _smoke():
        cfg = dataclasses.replace(
            cfg, pattern_reps=10, n_layers=10, d_model=256, d_ff=512, head_dim=32
        )
    return cfg


def _publish_zoo(catalog, cfg, dirpath: str):
    """One parent JIF + N_FUNCTIONS delta-published fine-tunes of it."""
    import jax

    from repro.core import snapshot
    from repro.models import lm
    from repro.serve.engine import layerwise_state

    base_params = lm.init_params(cfg, jax.random.PRNGKey(7))
    parent_path = f"{dirpath}/cluster-parent.jif"
    snapshot(layerwise_state(cfg, base_params), parent_path)

    fnames = []
    for i in range(N_FUNCTIONS):
        ft = dict(base_params)
        ft["pattern"] = list(base_params["pattern"])
        ft["final_norm"] = base_params["final_norm"] + 0.01 * (i + 1)
        for pi in range(len(cfg.pattern)):
            def bump(a, _i=i):
                a = np.asarray(a)
                if a.ndim >= 1 and a.shape[0] == cfg.pattern_reps:
                    cut = int(cfg.pattern_reps * 0.7)
                    a = a.copy()
                    a[cut:] = a[cut:] * (1.0 + 0.02 * (_i + 1))
                return a
            ft["pattern"][pi] = jax.tree.map(bump, base_params["pattern"][pi])
        fname = f"zfn-{i}"
        catalog.publish(fname, cfg, ft, dirpath, parent=parent_path,
                        warm_ttl_s=3600.0, formats=("jif",))
        fnames.append(fname)
    return fnames


def _build_cluster(catalog, policy, scale_out=None):
    from repro.serve.cluster import ClusterRouter
    from repro.serve.node import FixedTTLPolicy, NodeScheduler

    nodes = [
        NodeScheduler(
            registry=catalog.registry,
            keepalive=FixedTTLPolicy(3600.0),
            name=f"node{i}",
        )
        for i in range(N_NODES)
    ]
    return ClusterRouter(catalog, nodes, placement=policy,
                         scale_out_queue_depth=scale_out)


def _schedule(fnames, n_requests):
    """Deterministic zipf-weighted request order (func 0 most popular)."""
    w = 1.0 / np.arange(1, len(fnames) + 1) ** ZIPF_S
    p = w / w.sum()
    rng = np.random.default_rng(42)
    return [fnames[i] for i in rng.choice(len(fnames), size=n_requests, p=p)]


def _run_policy(catalog, cfg, policy, fnames, schedule, rows):
    router = _build_cluster(catalog, policy)
    tag = policy.name
    # seeding pass (unmeasured): one cold start per function through the
    # router — establishes the sticky replica for sticky policies and
    # warms the shared jit compile cache
    for f in fnames:
        r = router.invoke(f, PROMPT, max_new_tokens=2, mode="spice", cfg=cfg,
                          simulate_read_bw=SIM_READ_BW)
        assert r.cold, f"seed of {f} expected cold"
    router.drain_residual()

    ttfts, results = [], []
    for f in schedule:
        r = router.invoke(f, PROMPT, max_new_tokens=2, mode="spice", cfg=cfg,
                          simulate_read_bw=SIM_READ_BW)
        ttfts.append(r.ttft_s)
        results.append(r)
    router.drain_residual()

    # concurrency: evict one function cluster-wide, then a burst of joint
    # invocations — sticky routing must yield exactly ONE real cold restore
    # (the rest join it on the same node): zero duplicates cluster-wide
    burst_fn = fnames[0]
    router.evict(burst_fn)
    futs = [
        router.submit(burst_fn, PROMPT, max_new_tokens=2, mode="spice",
                      cfg=cfg, simulate_read_bw=SIM_READ_BW)
        for _ in range(4)
    ]
    burst = [f.result() for f in futs]
    burst_nodes = {r.node for r in burst}
    real_colds = sum(1 for r in burst if r.cold and not r.joined)
    # computed for EVERY policy: non-sticky placement spreads the burst
    # across nodes, and each extra node that cold-restores is a duplicate
    # concurrent cold — exactly the waste sticky join routing eliminates
    # (this used to be None for non-sticky policies, hiding their cost)
    duplicate_concurrent_colds = max(0, real_colds - 1)
    router.drain_residual()

    audits = router.audit()  # raises if any node's ledger invariant broke

    pull_bytes = sum(
        n.iosched.snapshot_stats()["bytes_read"] for n in router.nodes
    )
    node_hw = {n.name: n.memory.high_water() for n in router.nodes}
    per_node_colds = {
        n.name: n.stats["cold_starts"] for n in router.nodes
    }

    router.close()  # idempotent teardown: drains queues, stops reapers
    p50 = float(np.percentile(ttfts, 50))
    p99 = float(np.percentile(ttfts, 99))
    rows.append((f"cluster/{tag}/ttft_p50", p50 * 1e6, ""))
    rows.append((f"cluster/{tag}/ttft_p99", p99 * 1e6, ""))
    rows.append((f"cluster/{tag}/image_pull_mb", pull_bytes / 1e6, ""))
    SUMMARY["policies"][tag] = {
        "ttft_p50_s": p50,
        "ttft_p99_s": p99,
        "requests": len(schedule),
        "cold": sum(1 for r in results if r.cold and not r.joined),
        "joined": sum(1 for r in results if r.joined),
        "warm": sum(1 for r in results if not r.cold),
        "image_pull_bytes": int(pull_bytes),
        "per_node_cold_starts": per_node_colds,
        "per_node_high_water_bytes": node_hw,
        "burst_nodes": sorted(burst_nodes),
        "burst_real_colds": real_colds,
        "duplicate_concurrent_colds": duplicate_concurrent_colds,
        "audit_ok": bool(audits),
        "sticky": policy.sticky,
        "scale_outs": router.stats["scale_outs"],
    }
    return p99


def _scale_out_probe(catalog, cfg, fnames, rows):
    """Opt-in scale-out: with the knob set, a backed-up sticky function
    grows a second replica on another node."""
    from repro.serve.cluster import LocalityFirst

    router = _build_cluster(catalog, LocalityFirst(), scale_out=2)
    f = fnames[0]
    futs = [
        router.submit(f, PROMPT, max_new_tokens=2, mode="spice", cfg=cfg,
                      simulate_read_bw=SIM_READ_BW / 4)
        for _ in range(8)
    ]
    for fut in futs:
        fut.result()
    router.drain_residual()
    router.audit()
    router.close()
    replicas = router.replicas(f)
    rows.append(("cluster/scale_out/replicas", float(len(replicas)), ""))
    SUMMARY["scale_out"] = {
        "queue_depth_knob": 2,
        "replicas": replicas,
        "scale_outs": router.stats["scale_outs"],
    }


def run() -> list:
    from repro.serve.cluster import (
        FunctionCatalog,
        LeastLoaded,
        LocalityFirst,
        RoundRobin,
    )

    cfg = _cfg()
    rows: list = []
    n_requests = 30 if _smoke() else 120
    SUMMARY.clear()
    SUMMARY.update({
        "nodes": N_NODES,
        "functions": N_FUNCTIONS,
        "zipf_s": ZIPF_S,
        "requests": n_requests,
        "policies": {},
    })

    with tempfile.TemporaryDirectory() as d:
        catalog = FunctionCatalog()
        fnames = _publish_zoo(catalog, cfg, d)
        schedule = _schedule(fnames, n_requests)
        p99 = {}
        for policy in (LocalityFirst(), RoundRobin(), LeastLoaded()):
            p99[policy.name] = _run_policy(
                catalog, cfg, policy, fnames, schedule, rows
            )
        _scale_out_probe(catalog, cfg, fnames, rows)

    ratio = p99["locality_first"] / max(p99["round_robin"], 1e-9)
    SUMMARY["locality_vs_roundrobin_p99"] = ratio
    rows.append(("cluster/locality_vs_roundrobin_p99", ratio, "x (must be <1)"))
    return rows
