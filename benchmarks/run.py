"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Module map:
  e2e_latency       -> Fig 1 / Fig 9   (cold start vs systems vs warm)
  metadata_restore  -> Fig 2 / Fig 10  (metadata restore + replay ops)
  prefetch          -> Fig 4           (sync / advisory-async / guaranteed)
  working_set       -> Fig 5 / Table 1 (shared/private/zero composition)
  ablation          -> Fig 11          (restore optimizations, incremental)
  concurrency       -> Fig 12 (+Fig 3 interference) (burst max latency)
  cluster           -> N-node placement policies (locality vs baselines)
  dedup             -> content-addressed chunk store: 1 base + K deltas
                       over 3 nodes, CAS on vs off; merged into
                       BENCH_coldstart.json under "dedup"
  qos               -> Invocation API v2: LATENCY vs BATCH open-loop mix
  rollout           -> train->serve continuous-delta pipeline: mid-flight
                       versioned publishes, canary/promote/rollback,
                       serve/train colocation; merged into
                       BENCH_coldstart.json under "rollout"
  restore_bandwidth -> device-restore fast path (upload stream + overlay
                       patch) vs the storage roofline; merged into
                       BENCH_coldstart.json under "device_restore"
  roofline          -> EXPERIMENTS.md §Roofline (from dry-run artifacts)

``e2e_latency`` additionally drops ``BENCH_coldstart.json`` at the repo
root (per-mode TTFT / working-set time / total restore time, the
delta-chain economics, and the ``memory_pressure`` scenario — budget <
sum of images, N concurrent cold starts completing via the reclaim
ladder, with the ledger's per-kind memory high-water marks) so CI can
track the cold-start trajectory.  Set ``BENCH_SMOKE=1`` for the CI-sized
run (one function, one repetition).
"""
import argparse
import json
import sys
import time
import traceback
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]

MODULES = [
    "e2e_latency",
    "metadata_restore",
    "prefetch",
    "working_set",
    "ablation",
    "concurrency",
    "cluster",
    "dedup",
    "qos",
    "prewarm",
    "scale",
    "rollout",
    "restore_bandwidth",
    "roofline",
]


def _write_summary(name: str, mod, summary: dict) -> Path:
    """One BENCH_<target>.json per module by default; a module that sets
    ``BENCH_TARGET``/``SUMMARY_KEY`` merges under a key of a shared file
    (the cluster scenario rides in BENCH_coldstart.json)."""
    target = getattr(mod, "BENCH_TARGET", name.replace("e2e_latency", "coldstart"))
    out = REPO_ROOT / f"BENCH_{target}.json"
    key = getattr(mod, "SUMMARY_KEY", None)
    try:
        data = json.loads(out.read_text()) if out.exists() else {}
    except json.JSONDecodeError:
        data = {}
    if key:
        data[key] = summary
    else:
        # keyless modules own the top level but must not clobber sibling
        # modules' merged keys (e.g. --only e2e_latency after --only cluster)
        data.update(summary)
    out.write_text(json.dumps(data, indent=2, sort_keys=True))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated module list")
    args = ap.parse_args()
    mods = args.only.split(",") if args.only else MODULES

    print("name,us_per_call,derived")
    failures = 0
    for name in mods:
        t0 = time.time()
        mod = error = None
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            for row in mod.run():
                n, us, derived = row
                print(f"{n},{us:.1f},{derived}")
        except Exception as e:
            failures += 1
            error = f"{type(e).__name__}: {e}"
            print(f"{name},nan,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
        # a failed scenario must be VISIBLY failed, not silently absent:
        # whatever partial SUMMARY it accumulated is written, stamped with
        # the error, and the harness exits non-zero below
        summary = getattr(mod, "SUMMARY", None) if mod is not None else None
        if error is not None:
            summary = dict(summary or {})
            summary["error"] = error
        if summary:
            out = _write_summary(name, mod, summary)
            print(f"# wrote {out}", flush=True)
        # merge regression guard: a module that declares a SUMMARY_KEY
        # must actually land it (or its error stamp) in the shared file —
        # an empty SUMMARY silently skips _write_summary, and that is
        # exactly the failure mode that left qos absent from
        # BENCH_coldstart.json for two releases
        if mod is not None and getattr(mod, "SUMMARY_KEY", None):
            target = getattr(mod, "BENCH_TARGET", name)
            out = REPO_ROOT / f"BENCH_{target}.json"
            landed = False
            try:
                landed = mod.SUMMARY_KEY in json.loads(out.read_text())
            except (OSError, json.JSONDecodeError):
                pass
            if not landed:
                failures += 1
                print(
                    f"{name},nan,ERROR:summary key "
                    f"{mod.SUMMARY_KEY!r} never landed in {out.name}",
                    flush=True,
                )
        print(f"# {name} finished in {time.time()-t0:.1f}s", flush=True)
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
