"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Module map:
  e2e_latency       -> Fig 1 / Fig 9   (cold start vs systems vs warm)
  metadata_restore  -> Fig 2 / Fig 10  (metadata restore + replay ops)
  prefetch          -> Fig 4           (sync / advisory-async / guaranteed)
  working_set       -> Fig 5 / Table 1 (shared/private/zero composition)
  ablation          -> Fig 11          (restore optimizations, incremental)
  concurrency       -> Fig 12 (+Fig 3 interference) (burst max latency)
  roofline          -> EXPERIMENTS.md §Roofline (from dry-run artifacts)
"""
import argparse
import sys
import time
import traceback

MODULES = [
    "e2e_latency",
    "metadata_restore",
    "prefetch",
    "working_set",
    "ablation",
    "concurrency",
    "roofline",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated module list")
    args = ap.parse_args()
    mods = args.only.split(",") if args.only else MODULES

    print("name,us_per_call,derived")
    failures = 0
    for name in mods:
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            for row in mod.run():
                n, us, derived = row
                print(f"{n},{us:.1f},{derived}")
        except Exception as e:
            failures += 1
            print(f"{name},nan,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
        print(f"# {name} finished in {time.time()-t0:.1f}s", flush=True)
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
