"""Fig 4: prefetching strategies vs time-to-first-token — synchronous full
prefetch (REAP-style), asynchronous advisory (FaaSnap-style, suffers major
faults), and Spice's guaranteed pipelined prefetch with access-order layout.

Storage is simulated at 2 GB/s (bench images sit in the OS page cache on
this container, so reads alone can't model NVMe waits; the sleep-injected
bandwidth is identical for every system — labeled simnvme)."""
from __future__ import annotations

from benchmarks.common import PROMPT, build_zoo, fn_config

SIM_BW = 2e9


def run() -> list:
    node = build_zoo()
    rows = []
    for fname in ["py-json", "node-image", "py-rnn"]:
        cfg = fn_config(fname)
        node.invoke(fname, PROMPT, max_new_tokens=2, mode="spice_sync", cfg=cfg)
        for mode, label in [
            ("spice_sync", "sync_full_prefetch"),
            ("faasnap_star", "async_advisory"),
            ("spice", "pipelined_guaranteed"),
        ]:
            best_ttft = best_total = float("inf")
            faults = 0
            for _ in range(3):
                node.evict()
                r = node.invoke(fname, PROMPT, max_new_tokens=2, mode=mode, cfg=cfg,
                                simulate_read_bw=SIM_BW)
                best_ttft = min(best_ttft, r.ttft_s)
                best_total = min(best_total, r.total_s)
                if r.stats:
                    faults = r.stats.get("major_faults", 0)
            rows.append((f"prefetch_ttft_simnvme/{fname}/{label}", best_ttft * 1e6,
                         f"major_faults={faults}"))
            rows.append((f"prefetch_total_simnvme/{fname}/{label}", best_total * 1e6, ""))
    return rows
