"""Device-restore bandwidth: does restore speed track the storage roofline?

Three scenarios, merged into ``BENCH_coldstart.json`` under
``"device_restore"``:

* ``full_image`` — restore a full (no-parent) snapshot under a simulated
  storage bandwidth and compare achieved restore GB/s against that
  roofline.  The eager path serializes per-tensor device installs on the
  prefetcher thread (reads stall behind copies — measurably below the
  roofline); the fused path hands installs to the UploadStream, so reads
  and uploads overlap and the wall clock tracks the storage roofline
  (target: >= 0.8x at full size).
* ``delta`` — a ~25%-dirty fine-tune restored through the device fast
  path must upload only its private pages (<= 0.35x of the full image's
  bytes) while staying byte-identical to the eagerly-restored tree; a
  second restore against the now-resident device base re-uploads nothing
  base-resident.
* ``ttft`` — node-level cold-start TTFT, eager vs fused install policy
  (same zoo function, same simulated bandwidth).  CI asserts
  fused <= eager and zero ledger audit failures.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import PROMPT, build_zoo, fn_config, smoke
from repro.core import (
    BufferPool,
    NodeImageCache,
    NodeMemoryManager,
    SpiceRestorer,
    snapshot,
)
from repro.core.treeutil import flatten_state
from repro.core.upload import DeviceImageCache, DevicePath, UploadStream

BENCH_TARGET = "coldstart"
SUMMARY_KEY = "device_restore"
SUMMARY: dict = {}

# simulated storage roofline (bytes/s): slow enough that read sleeps
# dominate compute jitter (CPU contention between the uploader, the
# prefetcher, and the model's forward pass), fast enough to finish in CI
SIM_READ_BW = 75e6
# simulated host->device interconnect roofline (bytes/s).  On this
# container the jax backend is CPU, where a "device install" is a memcpy —
# without a modeled transfer cost both paths degenerate to host copies and
# the comparison measures nothing.  The sim charges each path for the
# bytes it actually moves: full tensors for eager (serialized on the
# prefetcher thread), private pages only for fused (overlapped on the
# upload ring)
SIM_UPLOAD_BW = 150e6


def _eager_install(a):
    """The eager baseline's per-tensor install under the same interconnect
    roofline the fused path's UploadStream simulates."""
    time.sleep(a.nbytes / SIM_UPLOAD_BW)
    return jnp.array(a, copy=True)


def _state(n_tensors: int, tensor_mb: int, zeros_mb: int, seed=7):
    rng = np.random.default_rng(seed)
    st = {}
    elems = tensor_mb * (1 << 20) // 4
    for i in range(n_tensors):
        st[f"w{i:02d}"] = jnp.asarray(
            rng.standard_normal(elems).astype(np.float32)
        )
    if zeros_mb:
        st["scratch"] = jnp.zeros((zeros_mb * (1 << 20) // 4,), jnp.float32)
    return st


def _restore_wall(path, *, device: bool, pool, repeats: int):
    """Min-of-repeats wall clock for a complete restore (uploads landed).
    Each repeat uses fresh restorer state but shares the pool (steady-state
    staging, like a warm node) and, for the device path, a fresh upload
    ring + device cache (full images carry no BASE pages, so nothing
    persists between repeats anyway)."""
    best = float("inf")
    stats = None
    for _ in range(repeats):
        cache = NodeImageCache()
        if device:
            up = UploadStream(simulate_bw=SIM_UPLOAD_BW)
            dpath = DevicePath(upload=up, images=DeviceImageCache())
            r = SpiceRestorer(
                pool=pool, node_cache=cache, device_path=dpath,
                simulate_read_bw=SIM_READ_BW,
            )
        else:
            up = None
            r = SpiceRestorer(
                pool=pool, node_cache=cache, transform=_eager_install,
                simulate_read_bw=SIM_READ_BW,
            )
        t0 = time.perf_counter()
        state, _, handles, st = r.restore(path, wait=True)
        jax.block_until_ready([h._arr for h in handles.values()])
        wall = time.perf_counter() - t0
        if up is not None:
            up.close()
        r.iosched.shutdown()
        if wall < best:
            best, stats = wall, st
    return best, stats


def _full_image_section(tmp, out):
    reps = 1 if smoke() else 3
    n, mb, zmb = (4, 1, 1) if smoke() else (8, 8, 8)
    st = _state(n, mb, zmb)
    path = f"{tmp}/full.jif"
    snapshot(st, path)
    pool = BufferPool()
    # untimed warm-up: amortize jit compiles (overlay-patch oracle, install)
    _restore_wall(path, device=True, pool=pool, repeats=1)
    _restore_wall(path, device=False, pool=pool, repeats=1)
    rows = []
    sect = {}
    for label, device in (("eager", False), ("fused", True)):
        wall, stats = _restore_wall(path, device=device, pool=pool, repeats=reps)
        payload = stats.bytes_read + stats.zero_bytes  # logical restore bytes
        achieved = stats.bytes_read / wall  # vs the STORAGE roofline
        frac = achieved / SIM_READ_BW
        sect[label] = {
            "wall_s": wall,
            "bytes_read": stats.bytes_read,
            "upload_s": stats.upload_s,
            "uploaded_bytes": stats.uploaded_bytes,
            "achieved_bw": achieved,
            "roofline_frac": frac,
        }
        rows.append((
            f"restore_bandwidth/full/{label}",
            wall * 1e6,
            f"bw={achieved/1e6:.1f}MBps,frac={frac:.3f},"
            f"upload={stats.upload_s:.3f}s,payload={payload/1e6:.1f}MB",
        ))
    sect["image_bytes"] = int(sum(
        np.asarray(a).nbytes for a in jax.tree.leaves(st)
    ))
    out["full_image"] = sect
    if not smoke():
        # acceptance: fused tracks the storage roofline, eager sits below it
        assert sect["fused"]["roofline_frac"] >= 0.8, sect
        assert sect["eager"]["roofline_frac"] < sect["fused"]["roofline_frac"], sect
    return rows


def _delta_section(tmp, out):
    n, mb = (4, 1) if smoke() else (8, 8)
    base_st = _state(n, mb, zeros_mb=0, seed=11)
    ft = dict(base_st)
    # dirty ~25% of every tensor (leading quarter, page-aligned at this size)
    for k in list(ft):
        a = np.array(ft[k])
        cut = a.size // 4
        a[:cut] += 0.5
        ft[k] = jnp.asarray(a)
    parent = f"{tmp}/parent.jif"
    delta = f"{tmp}/delta.jif"
    snapshot(base_st, parent)
    dstats = snapshot(ft, delta, parent=parent)

    mem = NodeMemoryManager(4 << 30)
    cache = NodeImageCache()
    cache.attach(mem)
    up = UploadStream()
    images = DeviceImageCache()
    images.attach(mem)
    dpath = DevicePath(upload=up, images=images)

    # reference: eager host-assembled restore of the same delta
    r_ref = SpiceRestorer(
        node_cache=cache, transform=lambda a: jnp.array(a, copy=True)
    )
    ref_state, _, _, _ = r_ref.restore(delta)
    r_ref.iosched.shutdown()

    def fused_restore():
        r = SpiceRestorer(node_cache=cache, device_path=dpath, memory=mem)
        state, _, _, st = r.restore(delta, wait=True)
        r.iosched.shutdown()
        return state, st

    state1, st1 = fused_restore()  # builds the device-resident base
    stats_mid = images.snapshot_stats()
    state2, st2 = fused_restore()  # base already HBM-resident
    full_bytes = sum(np.asarray(a).nbytes for a in jax.tree.leaves(base_st))

    l_ref, _ = flatten_state(ref_state)
    l_fused, _ = flatten_state(state1)
    identical = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for (_, a), (_, b) in zip(l_ref, l_fused)
    )
    audit_ok = True
    try:
        mem.audit()
    except AssertionError:
        audit_ok = False
    hits_after = images.snapshot_stats()
    out["delta"] = {
        "full_bytes": int(full_bytes),
        "delta_private_bytes": int(dstats.private_bytes),
        "uploaded_bytes": int(st2.uploaded_bytes),
        "upload_vs_full": st2.uploaded_bytes / full_bytes,
        "identical": bool(identical),
        "first_restore_uploaded_bytes": int(st1.uploaded_bytes),
        "device_base_resident_bytes": images.resident_bytes(),
        "device_cache_hits": hits_after["hits"],
        "device_cache_misses": hits_after["misses"],
        "audit_ok": audit_ok,
    }
    up.close()
    assert identical, "fused delta restore diverged from eager restore"
    # fused restores move only private pages; the second restore must hit
    # the resident device base for every BASE tensor (no re-uploads)
    assert st2.uploaded_bytes <= 0.35 * full_bytes, out["delta"]
    assert hits_after["misses"] == stats_mid["misses"], (
        "second restore rebuilt device bases already HBM-resident"
    )
    return [(
        "restore_bandwidth/delta/fused",
        0.0,
        f"uploaded={st2.uploaded_bytes/1e6:.1f}MB,"
        f"full={full_bytes/1e6:.1f}MB,"
        f"ratio={st2.uploaded_bytes/full_bytes:.3f},identical={identical}",
    )]


def _ttft_section(out):
    reps = 1 if smoke() else 2
    sim_bw = SIM_READ_BW
    fname = "py-hello"
    cfg = fn_config(fname)
    audit_failures = 0
    sect = {}
    for label, kwargs in (
        ("eager", {"install": _eager_install}),
        ("fused", {"install": "fused", "simulate_upload_bw": SIM_UPLOAD_BW}),
    ):
        node = build_zoo(**kwargs)
        best = float("inf")
        # warm-up invoke compiles the model's forward pass; evict so the
        # timed invokes are genuinely cold (restore path, warm jit)
        node.invoke(fname, PROMPT, max_new_tokens=4, mode="spice",
                    cfg=cfg, simulate_read_bw=sim_bw)
        for _ in range(reps):
            node.evict(fname)
            res = node.invoke(fname, PROMPT, max_new_tokens=4, mode="spice",
                              cfg=cfg, simulate_read_bw=sim_bw)
            assert res.cold
            best = min(best, res.ttft_s)
        try:
            node._sched.memory.audit()
        except AssertionError:
            audit_failures += 1
        sect[f"{label}_s"] = best
        node.close()
    sect["fused_vs_eager"] = sect["fused_s"] / max(sect["eager_s"], 1e-12)
    out["ttft"] = sect
    out["audit_failures"] = audit_failures
    return [(
        "restore_bandwidth/ttft",
        sect["fused_s"] * 1e6,
        f"eager={sect['eager_s']*1e3:.1f}ms,"
        f"fused={sect['fused_s']*1e3:.1f}ms,"
        f"ratio={sect['fused_vs_eager']:.3f},audit_failures={audit_failures}",
    )]


def run() -> list:
    import tempfile

    rows = []
    SUMMARY.clear()
    SUMMARY["sim_read_bw"] = SIM_READ_BW
    with tempfile.TemporaryDirectory() as tmp:
        rows += _full_image_section(tmp, SUMMARY)
        rows += _delta_section(tmp, SUMMARY)
    rows += _ttft_section(SUMMARY)
    return rows
