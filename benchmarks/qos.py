"""QoS scenario: open-loop mixed workload — a LATENCY zipf head against a
BATCH tail over 3 nodes — measuring what the typed invocation surface buys.

The schedule is deterministic (seeded): LATENCY-class requests hit a small
set of hot functions (zipf-weighted, kept warm by keep-alive), BATCH-class
requests sweep a tail of cold functions (no keep-alive — every invocation
is a fresh restore) through the same nodes, I/O arbiter, and ledgers, at
simulated NVMe bandwidth.  Arrivals are open-loop: submitted at schedule
time without waiting, so queues actually form and admission control, the
QoS-ordered run queue, and QoS-weighted stream priorities all matter.

Roughly half of the BATCH invocations are cancelled mid-restore (a watcher
cancels once the RESTORING event is recorded): the benchmark asserts the
per-node ledgers audit clean afterwards — aborted streams must return
every reservation.

Reported per class: TTFT p50/p99 (submit → first token: queue wait +
restore wait + generation), the queue/restore split, rejection rate, and
cancellation counts.  Asserted (the PR's acceptance bar): LATENCY p99 ≤
0.5 × BATCH p99, ≥ 25% of BATCH invocations cancelled mid-restore, zero
audit failures.  Merges into ``BENCH_coldstart.json`` under ``"qos"``.
"""
from __future__ import annotations

import tempfile
import threading
import time

import numpy as np

from benchmarks.common import PROMPT, smoke

BENCH_TARGET = "coldstart"
SUMMARY_KEY = "qos"
SUMMARY: dict = {}

N_NODES = 3
N_HOT = 2     # LATENCY zipf head (kept warm)
ZIPF_S = 1.1
SIM_READ_BW = 1.5e8
CANCEL_FRAC = 0.6  # fraction of BATCH arrivals a watcher cancels mid-restore


def _n_tail() -> int:
    # BATCH tail (warm_ttl=0: always a cold restore).  The full run uses a
    # wider tail so arrivals of one function rarely overlap — an overlapped
    # arrival JOINS the in-flight restore, and a join both serves without a
    # fresh restore and (correctly) blocks the owner's cancellation.
    return 6 if _smoke() else 16


def _smoke() -> bool:
    return smoke()


def _cfg():
    import dataclasses

    from repro.configs import get_config

    cfg = get_config("qwen1.5-0.5b").reduced()
    if not _smoke():
        cfg = dataclasses.replace(
            cfg, pattern_reps=8, n_layers=8, d_model=256, d_ff=512, head_dim=32
        )
    return cfg


def _publish(catalog, cfg, dirpath):
    import jax

    from repro.models import lm

    hot, tail = [], []
    n_tail = _n_tail()
    extra = {"opt": np.ones((1 << 20,), np.float32)}  # 4 MB residual tail
    for i in range(N_HOT + n_tail):
        params = lm.init_params(cfg, jax.random.PRNGKey(200 + i))
        fname = f"hot-{i}" if i < N_HOT else f"tail-{i - N_HOT}"
        ttl = 3600.0 if i < N_HOT else 0.0
        catalog.publish(fname, cfg, params, dirpath, warm_ttl_s=ttl,
                        formats=("jif",), extra_state=extra)
        (hot if i < N_HOT else tail).append(fname)
    return hot, tail


def _build_cluster(catalog):
    from repro.serve.cluster import ClusterRouter, LocalityFirst
    from repro.serve.invocation import AdmissionController
    from repro.serve.node import NodeScheduler

    nodes = [
        NodeScheduler(
            registry=catalog.registry,
            name=f"node{i}",
            max_workers=12,
            admission=AdmissionController(max_queue_depth=64,
                                          max_batch_queued=24,
                                          max_batch_inflight=4),
        )
        for i in range(N_NODES)
    ]
    return ClusterRouter(catalog, nodes, placement=LocalityFirst(),
                         latency_spill_depth=4)


def _schedule(hot, tail, n_lat, n_batch, span_s):
    """Deterministic open-loop arrival list: (t, qos, fname, cancel)."""
    from repro.serve.invocation import QosClass

    rng = np.random.default_rng(42)
    w = 1.0 / np.arange(1, len(hot) + 1) ** ZIPF_S
    p = w / w.sum()
    arrivals = []
    for t in np.sort(rng.uniform(0, span_s, size=n_lat)):
        fname = hot[int(rng.choice(len(hot), p=p))]
        arrivals.append((float(t), QosClass.LATENCY, fname, False))
    for k, t in enumerate(np.sort(rng.uniform(0, span_s, size=n_batch))):
        fname = tail[k % len(tail)]
        arrivals.append((float(t), QosClass.BATCH, fname,
                         rng.random() < CANCEL_FRAC))
    arrivals.sort(key=lambda a: a[0])
    return arrivals


def _cancel_when_restoring(handle, counters, lock):
    """Watcher: cancel as soon as the invocation owns a restore (RESTORING
    recorded); a queued cancel (never ran) is counted separately."""
    from repro.serve.invocation import EVT_RESTORING

    deadline = time.time() + 30
    while time.time() < deadline and not handle.done():
        if any(e == EVT_RESTORING for e, _ in handle.events()):
            break
        time.sleep(0.001)
    restoring = any(e == EVT_RESTORING for e, _ in handle.events())
    if handle.cancel():
        with lock:
            counters["midrestore" if restoring else "queued"] += 1


def run() -> list:
    from repro.serve.cluster import FunctionCatalog
    from repro.serve.invocation import (
        DeadlineExceeded,
        Invocation,
        InvocationCancelled,
        Overloaded,
        QosClass,
        deadline_in,
    )
    from repro.serve.node import NodeScheduler

    cfg = _cfg()
    n_lat, n_batch, span = (40, 24, 1.5) if _smoke() else (120, 96, 8.0)
    rows: list = []
    SUMMARY.clear()

    with tempfile.TemporaryDirectory() as d:
        catalog = FunctionCatalog()
        hot, tail = _publish(catalog, cfg, d)
        # compile-cache warmup on a throwaway node (shared jit cache)
        warm_node = NodeScheduler(registry=catalog.registry)
        warm_node.invoke(hot[0], PROMPT, max_new_tokens=2, mode="spice_sync",
                         cfg=cfg)
        router = _build_cluster(catalog)
        # seed the zipf head warm through the router (sticky placement)
        for f in hot:
            router.invoke(f, PROMPT, max_new_tokens=2, cfg=cfg,
                          simulate_read_bw=SIM_READ_BW)
        router.drain_residual()

        arrivals = _schedule(hot, tail, n_lat, n_batch, span)
        handles = []      # (qos, fname, handle)
        rejected = {QosClass.LATENCY: 0, QosClass.BATCH: 0}
        cancel_counters = {"midrestore": 0, "queued": 0}
        clock = threading.Lock()
        watchers = []
        t0 = time.perf_counter()
        for t_arr, qos, fname, cancel in arrivals:
            delay = t_arr - (time.perf_counter() - t0)
            if delay > 0:
                time.sleep(delay)
            inv = Invocation(
                function=fname, prompt=PROMPT, max_new_tokens=2, cfg=cfg,
                simulate_read_bw=SIM_READ_BW, qos=qos,
                deadline_s=deadline_in(30.0) if qos is QosClass.LATENCY else None,
            )
            try:
                h = router.submit_invocation(inv)
            except (Overloaded, DeadlineExceeded):
                rejected[qos] += 1
                continue
            handles.append((qos, fname, h))
            if cancel:
                w = threading.Thread(target=_cancel_when_restoring,
                                     args=(h, cancel_counters, clock),
                                     daemon=True)
                w.start()
                watchers.append(w)

        per_class = {
            QosClass.LATENCY: {"ok": [], "cancelled": 0, "failed": 0,
                               "deadline_expired": 0},
            QosClass.BATCH: {"ok": [], "cancelled": 0, "failed": 0,
                             "deadline_expired": 0},
        }
        for qos, fname, h in handles:
            try:
                per_class[qos]["ok"].append(h.result(120))
            except InvocationCancelled:
                per_class[qos]["cancelled"] += 1
            except DeadlineExceeded:
                # admitted, expired in queue: NOT an admission rejection
                per_class[qos]["deadline_expired"] += 1
            except Exception:
                per_class[qos]["failed"] += 1
        for w in watchers:
            w.join(30)
        router.drain_residual()

        # ledger cleanliness after mass cancellation: every node must audit
        audit_failures = 0
        for n in router.nodes:
            try:
                n.memory.audit()
            except AssertionError:
                audit_failures += 1
        hw = {n.name: n.memory.high_water() for n in router.nodes}
        node_stats = {n.name: dict(n.stats) for n in router.nodes}
        router.close()

    def _cls(qos):
        res = per_class[qos]["ok"]
        ttfts = [r.queue_wait_s + r.ttft_s for r in res]
        sub = sum(1 for q, _, _ in handles if q is qos) + rejected[qos]
        return {
            "submitted": sub,
            "ok": len(res),
            "rejected": rejected[qos],
            "cancelled": per_class[qos]["cancelled"],
            "deadline_expired": per_class[qos]["deadline_expired"],
            "failed": per_class[qos]["failed"],
            "ttft_p50_s": float(np.percentile(ttfts, 50)) if ttfts else None,
            "ttft_p99_s": float(np.percentile(ttfts, 99)) if ttfts else None,
            "queue_wait_mean_s": float(np.mean([r.queue_wait_s for r in res]))
            if res else None,
            "restore_wait_mean_s": float(np.mean([r.restore_wait_s for r in res]))
            if res else None,
            "warm": sum(1 for r in res if not r.cold),
            "cold": sum(1 for r in res if r.cold and not r.joined),
            "joined": sum(1 for r in res if r.joined),
        }

    lat, bat = _cls(QosClass.LATENCY), _cls(QosClass.BATCH)
    ratio = (
        lat["ttft_p99_s"] / max(bat["ttft_p99_s"], 1e-12)
        if lat["ttft_p99_s"] is not None and bat["ttft_p99_s"] is not None
        else float("nan")
    )
    submitted = lat["submitted"] + bat["submitted"]
    rejected_total = lat["rejected"] + bat["rejected"]
    SUMMARY.update({
        "nodes": N_NODES,
        "latency_functions": N_HOT,
        "batch_functions": _n_tail(),
        "span_s": span,
        "sim_read_bw": SIM_READ_BW,
        "classes": {"latency": lat, "batch": bat},
        "latency_vs_batch_p99": ratio,
        "rejection_rate": rejected_total / max(submitted, 1),
        "batch_cancelled_midrestore": cancel_counters["midrestore"],
        "batch_cancelled_queued": cancel_counters["queued"],
        "audit_failures": audit_failures,
        "per_node_high_water_bytes": hw,
        "per_node_stats": {
            name: {k: s[k] for k in ("cancellations", "rejected_overloaded",
                                     "rejected_deadline", "cold_starts",
                                     "warm_hits")}
            for name, s in node_stats.items()
        },
    })
    rows.append(("qos/latency_ttft_p99", (lat["ttft_p99_s"] or 0) * 1e6, ""))
    rows.append(("qos/batch_ttft_p99", (bat["ttft_p99_s"] or 0) * 1e6, ""))
    rows.append(("qos/latency_vs_batch_p99", ratio, "x (must be <=0.5)"))
    rows.append(("qos/rejection_rate", SUMMARY["rejection_rate"], "frac"))
    rows.append(("qos/batch_cancelled_midrestore",
                 float(cancel_counters["midrestore"]), ""))

    # ---- the PR's acceptance bar, enforced where the numbers are made ----
    assert audit_failures == 0, "ledger audit failed after mass cancellation"
    assert lat["ttft_p99_s"] is not None and bat["ttft_p99_s"] is not None
    assert ratio <= 0.5, (
        f"LATENCY p99 {lat['ttft_p99_s']:.4f}s must be <= 0.5x BATCH p99 "
        f"{bat['ttft_p99_s']:.4f}s (got {ratio:.3f})"
    )
    batch_admitted = bat["submitted"] - bat["rejected"]
    assert cancel_counters["midrestore"] >= 0.25 * batch_admitted, (
        f"only {cancel_counters['midrestore']} of {batch_admitted} admitted "
        "BATCH invocations were cancelled mid-restore (need >= 25%)"
    )
    return rows
