"""Fig 11: memory-restore ablation — start from a CRIU-like configuration
and enable Spice's optimizations one at a time on the py-rnn function."""
from __future__ import annotations

import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_config
from repro.core import (
    BaseImage,
    BufferPool,
    NodeImageCache,
    SpiceRestorer,
    snapshot,
)
from repro.core import baselines
from repro.core.trace import static_access_order
from repro.models import lm
from repro.serve.engine import layerwise_state


def _best(f, n=3):
    best = float("inf")
    for _ in range(n):
        best = min(best, f())
    return best


def run() -> list:
    cfg = bench_config("mamba2-780m")
    params = lm.init_params(cfg, jax.random.PRNGKey(17), jnp.float32)
    state = layerwise_state(cfg, params)
    # perturb a couple of layers so there is a private set over the base
    state["layers"][0] = jax.tree.map(lambda a: np.asarray(a) + 0.1, state["layers"][0])
    base_state = layerwise_state(cfg, lm.init_params(cfg, jax.random.PRNGKey(17), jnp.float32))
    order = static_access_order(cfg, state)

    rows = []
    with tempfile.TemporaryDirectory() as d:
        cache = NodeImageCache()
        cache.put(BaseImage.from_state("base", base_state))

        # 0. per-resource files, eager, no dedup (CRIU*-like floor)
        baselines.criu_star_snapshot(state, f"{d}/criu")

        def t0():
            t = time.perf_counter()
            baselines.criu_star_restore(f"{d}/criu")
            return time.perf_counter() - t

        rows.append(("ablation/0_per_resource_replay", _best(t0) * 1e6, ""))

        # 1. + batched metadata + single contiguous file (JIF, no dedup,
        #    no access order, sync, no pool)
        snapshot(state, f"{d}/v1.jif")

        def t1():
            r = SpiceRestorer(pool=BufferPool(capacity_bytes=0), pipelined=False)
            t = time.perf_counter()
            r.restore(f"{d}/v1.jif")
            return time.perf_counter() - t

        rows.append(("ablation/1_jif_batched_metadata", _best(t1) * 1e6, ""))

        # 2. + overlay dedup vs base + zero elision (fetch less)
        snapshot(state, f"{d}/v2.jif", base=cache.get("base"))

        def t2():
            r = SpiceRestorer(
                pool=BufferPool(capacity_bytes=0), node_cache=cache, pipelined=False
            )
            t = time.perf_counter()
            r.restore(f"{d}/v2.jif")
            return time.perf_counter() - t

        rows.append(("ablation/2_overlay_dedup_zero_elide", _best(t2) * 1e6, ""))

        # 3. + access-order relocation (sequential working-set read)
        snapshot(state, f"{d}/v3.jif", base=cache.get("base"), access_order=order)

        def t3():
            r = SpiceRestorer(
                pool=BufferPool(capacity_bytes=0), node_cache=cache, pipelined=False
            )
            t = time.perf_counter()
            r.restore(f"{d}/v3.jif")
            return time.perf_counter() - t

        rows.append(("ablation/3_access_order_layout", _best(t3) * 1e6, ""))

        # 4. + buffer/zero pool (allocation off the critical path)
        pool = BufferPool()
        SpiceRestorer(pool=pool, node_cache=cache).restore(f"{d}/v3.jif")  # prime

        def t4():
            r = SpiceRestorer(pool=pool, node_cache=cache, pipelined=False)
            t = time.perf_counter()
            _, _, _, st = r.restore(f"{d}/v3.jif")
            return time.perf_counter() - t

        rows.append(("ablation/4_zero_page_pool", _best(t4) * 1e6, ""))

        # 5. + pipelined prefetch (overlap metadata/base fill with I/O)
        def t5():
            r = SpiceRestorer(pool=pool, node_cache=cache, pipelined=True)
            t = time.perf_counter()
            r.restore(f"{d}/v3.jif")
            return time.perf_counter() - t

        rows.append(("ablation/5_pipelined_prefetch", _best(t5) * 1e6, ""))
    return rows
