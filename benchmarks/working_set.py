"""Fig 5 / Table 1: working-set composition — private vs shared(base) vs
zero chunk fractions per function snapshot."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BENCH_DIR, FUNCTIONS, bench_config, build_zoo
from repro.core.jif import JifReader
from repro.core.overlay import KIND_BASE, KIND_PRIVATE, KIND_ZERO, IntervalTable


def run() -> list:
    build_zoo()
    rows = []
    for fname, arch in FUNCTIONS:
        r = JifReader(str(BENCH_DIR / f"{fname}.jif"))
        counts = {KIND_ZERO: 0, KIND_BASE: 0, KIND_PRIVATE: 0}
        n_intervals = 0
        for t in r.tensors:
            it = r.itable(t.name)
            n_intervals += len(it.table)
            for k, v in it.counts().items():
                counts[k] += v
        total = sum(counts.values())
        rows.append(
            (
                f"working_set/{fname}/shared_pct",
                100.0 * counts[KIND_BASE] / total,
                f"vmas={len(r.tensors)},delta_intervals={n_intervals},"
                f"private={counts[KIND_PRIVATE]},zero={counts[KIND_ZERO]},"
                f"ws_mb={total * r.page_size / 1e6:.1f}",
            )
        )
        # JIF v2 working-set boundary: the fraction of the data segment a
        # cold start must read before the instance promotes WARM; the rest
        # streams as residual at background priority
        n_chunks = max(r.n_data_chunks, 1)
        ws_tensors = len(r.meta.get("working_set", []))
        rows.append(
            (
                f"working_set/{fname}/ws_boundary_pct",
                100.0 * r.ws_boundary / n_chunks,
                f"jif_v{r.version},ws_chunks={r.ws_boundary},"
                f"data_chunks={n_chunks},ws_tensors={ws_tensors},"
                f"residual_tensors={len(r.tensors) - ws_tensors}",
            )
        )
        r.close()
    return rows
