"""Fig 2/10: metadata restore latency + replayed-op counts vs application
complexity (number of kernel resources ~ number of tensors)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import SpiceRestorer, snapshot
from repro.core import baselines
from repro.core.jif import JifReader


def _state(n_tensors: int, seed=0):
    r = np.random.RandomState(seed)
    return {f"t{i:04d}": r.randn(64, 64).astype(np.float32) for i in range(n_tensors)}


def run() -> list:
    import tempfile

    rows = []
    for n in [32, 128, 512, 2048]:  # "python fn" ... "JVM app" complexity
        state = _state(n)
        with tempfile.TemporaryDirectory() as d:
            snapshot(state, f"{d}/f.jif")
            baselines.criu_star_snapshot(state, f"{d}/criu")

            # spice metadata restore: ONE batched header+itable decode
            best = float("inf")
            for _ in range(5):
                t0 = time.perf_counter()
                rr = JifReader(f"{d}/f.jif")
                rr.load_all_itables()
                best = min(best, time.perf_counter() - t0)
                rr.close()
            rows.append((f"metadata/spice/{n}_tensors", best * 1e6, "restore_ops=1"))

            # criu*: per-resource replay (meta walk + per-tensor open/read)
            best = float("inf")
            ops = 0
            for _ in range(3):
                t0 = time.perf_counter()
                _, stats = baselines.criu_star_restore(f"{d}/criu")
                best = min(best, time.perf_counter() - t0)
                ops = stats.restore_ops
            rows.append((f"metadata/criu_star/{n}_tensors", best * 1e6, f"restore_ops={ops}"))
    return rows
