"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline
reads this). Reports the three terms per (arch x shape x mesh) cell."""
from __future__ import annotations

import glob
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def rows_from_disk():
    out = []
    for f in sorted(glob.glob(str(RESULTS / "*.json"))):
        if ".hlo" in f or "." in Path(f).stem.replace(".json", "").split("__")[-1]:
            pass
        d = json.load(open(f))
        if "skipped" in d or "error" in d or "roofline" not in d:
            continue
        out.append(d)
    return out


def run() -> list:
    rows = []
    for d in rows_from_disk():
        r = d["roofline"]
        rows.append(
            (
                f"roofline/{d['cell']}/bound_time",
                r["bound_time_s"] * 1e6,
                f"dominant={r['dominant']},useful={r['useful_flop_ratio']:.2f},"
                f"fraction={r['roofline_fraction']:.4f},fits={d['memory']['fits_hbm']}",
            )
        )
    if not rows:
        rows.append(("roofline/none", 0.0, "run launch/dryrun first"))
    return rows
