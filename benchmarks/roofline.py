"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline
reads this). Reports the three terms per (arch x shape x mesh) cell, plus
the restore-bandwidth roofline (achieved restore GB/s vs the simulated
storage bandwidth) when ``BENCH_coldstart.json`` carries a
``device_restore`` section."""
from __future__ import annotations

import glob
import json
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]
RESULTS = REPO_ROOT / "results" / "dryrun"


def rows_from_disk():
    out = []
    for f in sorted(glob.glob(str(RESULTS / "*.json"))):
        # sidecar artifacts (HLO dumps, dotted variant stems) are not
        # roofline cells: skip them
        if ".hlo" in f or "." in Path(f).stem.replace(".json", "").split("__")[-1]:
            continue
        d = json.load(open(f))
        if "skipped" in d or "error" in d or "roofline" not in d:
            continue
        out.append(d)
    return out


def restore_bandwidth_rows() -> list:
    """Storage-roofline view of the device-restore benchmark: achieved
    restore bandwidth per install path against ``sim_read_bw`` (the
    simulated storage ceiling both paths read through)."""
    bench = REPO_ROOT / "BENCH_coldstart.json"
    if not bench.exists():
        return []
    try:
        d = json.loads(bench.read_text())
    except json.JSONDecodeError:
        return []
    sect = d.get("device_restore") or {}
    bw = sect.get("sim_read_bw")
    full = sect.get("full_image") or {}
    rows = []
    for label in ("eager", "fused"):
        r = full.get(label)
        if not r or not bw:
            continue
        rows.append((
            f"roofline/restore_bandwidth/{label}",
            r["wall_s"] * 1e6,
            f"achieved={r['achieved_bw']/1e6:.1f}MBps,"
            f"roofline={bw/1e6:.1f}MBps,fraction={r['roofline_frac']:.3f}",
        ))
    return rows


def run() -> list:
    rows = []
    for d in rows_from_disk():
        r = d["roofline"]
        rows.append(
            (
                f"roofline/{d['cell']}/bound_time",
                r["bound_time_s"] * 1e6,
                f"dominant={r['dominant']},useful={r['useful_flop_ratio']:.2f},"
                f"fraction={r['roofline_fraction']:.4f},fits={d['memory']['fits_hbm']}",
            )
        )
    if not rows:
        rows.append(("roofline/none", 0.0, "run launch/dryrun first"))
    rows += restore_bandwidth_rows()
    return rows
