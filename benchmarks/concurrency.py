"""Fig 12: maximum invocation latency under a burst of concurrent cold
restores of the same function: spice vs spice(no pool) vs userspace-only
(criu*-style)."""
from __future__ import annotations

import threading
import time

import numpy as np

from benchmarks.common import PROMPT, build_zoo, fn_config
from repro.core import BufferPool


def _burst(node, fname, cfg, mode, n, pool_capacity=None):
    if pool_capacity is not None:
        node.pool = BufferPool(capacity_bytes=pool_capacity)
        # prime the pool so acquisition is off the critical path
        if pool_capacity:
            node.invoke(fname, PROMPT, max_new_tokens=2, mode=mode, cfg=cfg)
    node.evict()
    lat = [0.0] * n

    def one(i):
        t0 = time.perf_counter()
        node.invoke(fname, PROMPT, max_new_tokens=2, mode=mode, cfg=cfg)
        lat[i] = time.perf_counter() - t0

    ths = [threading.Thread(target=one, args=(i,)) for i in range(n)]
    for t in ths:
        t.start()
    for t in ths:
        t.join()
    return max(lat)


def run() -> list:
    node = build_zoo()
    fname = "py-json"
    cfg = fn_config(fname)
    node.invoke(fname, PROMPT, max_new_tokens=2, mode="spice_sync", cfg=cfg)  # compile
    rows = []
    for n in [1, 2, 4, 8]:
        rows.append(
            (f"concurrency/{n}/spice", _burst(node, fname, cfg, "spice", n, 2 << 30) * 1e6, "")
        )
        rows.append(
            (f"concurrency/{n}/spice_no_pool",
             _burst(node, fname, cfg, "spice", n, 0) * 1e6, "")
        )
        rows.append(
            (f"concurrency/{n}/userspace_criu",
             _burst(node, fname, cfg, "criu_star", n) * 1e6, "")
        )
    return rows
