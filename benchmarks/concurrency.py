"""Fig 12 extended: cold restores under real multi-tenant contention.

Two regimes, both through the node's SHARED prefetch I/O scheduler:

* ``multi``  — N distinct functions cold-start simultaneously (the node's
  steady-state burst); reports per-function TTFT, max latency, and the
  aggregate read bandwidth the arbiter sustained across all tenants.
  spice (tracked completion + demand boost) vs faasnap* (advisory async
  prefetch, one private stream per restore, major faults under contention).
* ``burst``  — N invocations of the SAME function at once: one owner
  restores, the rest join the in-flight handle tree (no duplicate I/O);
  spice vs spice(no pool) vs userspace-only (criu*-style).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import PROMPT, build_zoo, fn_config
from repro.core import BufferPool

# simulated storage so contention is visible even with page-cache-resident
# bench images (identical for every system — labeled simnvme)
SIM_BW = 2e9


def _multi_tenant(node, fnames, mode, n):
    """n distinct functions restored concurrently through one node."""
    node.evict()
    t0 = time.perf_counter()
    before = node.iosched.snapshot_stats()
    futures = [
        node.submit(f, PROMPT, max_new_tokens=2, mode=mode, cfg=fn_config(f),
                    simulate_read_bw=SIM_BW)
        for f in fnames[:n]
    ]
    results = [f.result() for f in futures]
    wall = time.perf_counter() - t0
    after = node.iosched.snapshot_stats()
    sched_bytes = after["bytes_read"] - before["bytes_read"]
    boosts = after["demand_boosts"] - before["demand_boosts"]
    # faasnap streams bypass the arbiter (that is the point): take bytes
    # from its own restore stats for a comparable aggregate
    if sched_bytes == 0:
        sched_bytes = sum((r.stats or {}).get("bytes_read", 0) for r in results)
    per_fn_ttft = {r.function: r.ttft_s for r in results}
    agg_bw = sched_bytes / wall if wall > 0 else 0.0
    return per_fn_ttft, max(r.total_s for r in results), agg_bw, boosts


def _burst(node, fname, cfg, mode, n, pool_capacity=None):
    """n simultaneous invocations of one cold function."""
    if pool_capacity is not None:
        node.pool = BufferPool(capacity_bytes=pool_capacity)
        # prime the pool so acquisition is off the critical path
        if pool_capacity:
            node.invoke(fname, PROMPT, max_new_tokens=2, mode=mode, cfg=cfg)
    node.evict()
    futures = [
        node.submit(fname, PROMPT, max_new_tokens=2, mode=mode, cfg=cfg)
        for _ in range(n)
    ]
    return max(f.result().total_s for f in futures)


def run() -> list:
    node = build_zoo()
    fnames = node.registry.names()
    rows = []

    # warm the compile caches for every arch in the zoo
    for f in fnames:
        node.invoke(f, PROMPT, max_new_tokens=2, mode="spice_sync",
                    cfg=fn_config(f))

    # ---- multi-tenant contention: N>=4 distinct functions at once --------
    for n in [2, 4, min(5, len(fnames))]:
        for mode in ["spice", "faasnap_star"]:
            ttfts, max_total, agg_bw, boosts = _multi_tenant(node, fnames, mode, n)
            for f, ttft in ttfts.items():
                rows.append((f"concurrency_multi/{n}/{mode}/ttft/{f}",
                             ttft * 1e6, ""))
            rows.append((f"concurrency_multi/{n}/{mode}/max_total",
                         max_total * 1e6, ""))
            rows.append((f"concurrency_multi/{n}/{mode}/agg_read_bw",
                         agg_bw / 1e9, "GB/s"))
            if mode == "spice":
                rows.append((f"concurrency_multi/{n}/spice/demand_boosts",
                             boosts, ""))

    d = {name: v for name, v, _ in rows}
    for n in [2, 4, min(5, len(fnames))]:
        rows.append((
            f"concurrency_multi/{n}/faasnap_vs_spice",
            d[f"concurrency_multi/{n}/faasnap_star/max_total"]
            / d[f"concurrency_multi/{n}/spice/max_total"],
            "x",
        ))

    # ---- same-function burst (the seed's Fig 12 regime) ------------------
    fname = "py-json"
    cfg = fn_config(fname)
    for n in [1, 2, 4, 8]:
        rows.append(
            (f"concurrency/{n}/spice", _burst(node, fname, cfg, "spice", n, 2 << 30) * 1e6, "")
        )
        rows.append(
            (f"concurrency/{n}/spice_no_pool",
             _burst(node, fname, cfg, "spice", n, 0) * 1e6, "")
        )
        rows.append(
            (f"concurrency/{n}/userspace_criu",
             _burst(node, fname, cfg, "criu_star", n) * 1e6, "")
        )
    return rows
