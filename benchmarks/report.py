"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run
artifacts, and the §Cold-start tables from ``BENCH_coldstart.json``.

  PYTHONPATH=src:. python -m benchmarks.report            # markdown to stdout
  PYTHONPATH=src:. python -m benchmarks.report --tag x    # tagged variants
  PYTHONPATH=src:. python -m benchmarks.report --section coldstart
"""
from __future__ import annotations

import argparse
import glob
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"
COLDSTART = Path(__file__).resolve().parents[1] / "BENCH_coldstart.json"


def load(tag: str = ""):
    cells = {}
    for f in sorted(glob.glob(str(RESULTS / "*.json"))):
        stem = Path(f).stem
        parts = stem.split(".")
        cell_tag = parts[1] if len(parts) > 1 else ""
        if cell_tag != tag:
            continue
        d = json.load(open(f))
        cells[d["cell"]] = d
    return cells


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(cells) -> str:
    lines = [
        "| cell | mesh | compile_s | per-dev HBM model (GiB) | fits | HLO GFLOP/dev | coll MB/dev | collective mix |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for cid in sorted(cells):
        d = cells[cid]
        if "skipped" in d:
            lines.append(f"| {cid} | {d['mesh']} | — | — | skip | — | — | {d['skipped'][:60]}… |")
            continue
        if "error" in d:
            lines.append(f"| {cid} | — | — | — | ERR | — | — | {d['error'][:60]} |")
            continue
        m = d["memory"]["modeled"]
        coll = d["collectives"]
        mix = ",".join(
            f"{k.replace('all-','a')[:7]}:{v/1e6:.0f}M"
            for k, v in sorted(coll.items())
            if k != "total" and v > 1e6
        )
        lines.append(
            f"| {cid} | {d['mesh']} | {d['compile_s']} | "
            f"{fmt_bytes(m['total_bytes'])} | {'Y' if m['fits_hbm'] else 'N'} | "
            f"{d['cost']['flops_per_device']/1e9:.0f} | "
            f"{coll.get('total',0)/1e6:.0f} | {mix} |"
        )
    return "\n".join(lines)


def roofline_table(cells) -> str:
    lines = [
        "| cell | compute_s | memory_s | collective_s | dominant | MODEL_FLOPS | useful ratio | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for cid in sorted(cells):
        d = cells[cid]
        if "skipped" in d or "error" in d:
            continue
        r = d["roofline"]
        lines.append(
            f"| {cid} | {r['compute_s']:.4f} | {r['memory_s']:.4f} | "
            f"{r['collective_s']:.4f} | {r['dominant'].replace('_s','')} | "
            f"{r['model_flops']:.2e} | {r['useful_flop_ratio']:.2f} | "
            f"{r['roofline_fraction']:.4f} |"
        )
    return "\n".join(lines)


def dedup_table(dd) -> str:
    """Markdown for the ``"dedup"`` key: pull bytes per regime, the
    dedup ratio, cache high-water growth, and the identity/audit gates."""
    out = [
        "#### Cross-tenant chunk dedup "
        f"(1 base + {dd.get('deltas', '?')} deltas / "
        f"{dd.get('nodes', '?')} nodes)",
        "",
        "| regime | image pull (MB) | peer fetch (MB) | audit failures |",
        "|---|---|---|---|",
    ]
    for rname, r in sorted(dd.get("regimes", {}).items()):
        out.append(
            f"| {rname} | {r['image_pull_bytes']/1e6:.1f} | "
            f"{r.get('peer_fetch_bytes', 0)/1e6:.1f} | "
            f"{r.get('audit_failures', '?')} |"
        )
    ratio = dd.get("pull_ratio")
    if ratio is not None:
        out.append("")
        out.append(
            f"dedup pull bytes / no-dedup = **{ratio:.3f}** (must be <=0.5); "
            f"byte mismatches: **{dd.get('byte_mismatches', '?')}** (must be 0)"
        )
    growth = dd.get("hw_growth_half_to_full")
    if growth:
        grew = ", ".join(
            f"{n}: {g:.2f}x" for n, g in sorted(growth.items())
        )
        out.append(
            f"per-node chunk_cas+image_cache high-water, K/2 -> K tenants: "
            f"{grew} (each <2.0x = sublinear)"
        )
    if dd.get("error"):
        out.append(f"**SCENARIO FAILED**: {dd['error']}")
    out.append("")
    return "\n".join(out)


def prewarm_table(pw) -> str:
    """Markdown for the ``"prewarm"`` key: per-regime cold/warm/joined
    counts, LATENCY p99 TTFT, peak-node memory, and the acceptance
    ratios (cold reduction vs memory premium vs p99 impact)."""
    out = [
        "#### Warmth policy engine "
        f"({pw.get('head_functions', '?')} head / "
        f"{pw.get('sparse_functions', '?')} sparse / "
        f"{pw.get('tail_functions', '?')} tail fns over "
        f"{pw.get('nodes', '?')} nodes, {pw.get('span_s', '?')} s trace)",
        "",
        "| regime | cold | joined | warm | p50 ttft (ms) | p99 ttft (ms) |"
        " speculative | peak node mem (MB) | audit fail |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    order = ("reactive", "adaptive_nospec", "predictive")
    regimes = pw.get("regimes", {})
    for rname in [r for r in order if r in regimes] + sorted(
        set(regimes) - set(order)
    ):
        r = regimes[rname]

        def ms(v):
            return "—" if v is None else f"{v*1e3:.2f}"
        out.append(
            f"| {rname} | {r['cold']} | {r['joined']} | {r['warm']} | "
            f"{ms(r.get('latency_ttft_p50_s'))} | "
            f"{ms(r.get('latency_ttft_p99_s'))} | "
            f"{r.get('speculative_restores', 0)} | "
            f"{r.get('hw_max_node_bytes', 0)/1e6:.1f} | "
            f"{r.get('audit_failures', '?')} |"
        )
    cold = pw.get("cold_vs_reactive")
    if cold is not None:
        out.append("")
        out.append(
            f"predictive cold / reactive = **{cold:.3f}** (must be <=0.5) at "
            f"**{pw.get('hw_vs_reactive', 0):.2f}x** reactive peak-node "
            f"memory (must be <=1.5); LATENCY p99 vs reactive "
            f"**{pw.get('p99_vs_reactive', 0):.3f}x**, vs speculation-off "
            f"**{pw.get('p99_vs_nospec', 0):.3f}x** (each must be <=1.05)"
        )
    if pw.get("error"):
        out.append(f"**SCENARIO FAILED**: {pw['error']}")
    out.append("")
    return "\n".join(out)


def scale_table(sc) -> str:
    """Markdown for the ``"scale"`` key: per-regime latency / cost /
    cold-start table, the drain-conversion comparison, and the acceptance
    ratios (p99 vs static-over, node-seconds vs static-over, handoff
    delta vs full re-restore)."""
    tr = sc.get("trace", {})
    fl = sc.get("fleet", {})
    out = [
        "#### Trace-replay scale harness "
        f"({tr.get('functions', '?')} fns, {tr.get('arrivals', '?')} "
        f"arrivals over {tr.get('duration_s', '?')} s, "
        f"{tr.get('flash_crowds', '?')} flash crowd(s); static "
        f"{fl.get('static_small', '?')}/{fl.get('static_over', '?')} nodes, "
        f"autoscale {fl.get('autoscale_min', '?')}-"
        f"{fl.get('autoscale_max', '?')})",
        "",
        "| regime | p50 ttft (ms) | p99 ttft (ms) | cold | joined | warm |"
        " node-s | final nodes | drain colds | audit fail |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    order = ("static_over", "static_small",
             "autoscale_handoff", "autoscale_evict")
    regimes = sc.get("regimes", {})
    for rname in [r for r in order if r in regimes] + sorted(
        set(regimes) - set(order)
    ):
        r = regimes[rname]

        def ms(v):
            return "—" if v is None else f"{v*1e3:.2f}"
        dc = r.get("drain_converted_colds")
        out.append(
            f"| {rname} | {ms(r.get('latency_ttft_p50_s'))} | "
            f"{ms(r.get('latency_ttft_p99_s'))} | {r['cold']} | "
            f"{r['joined']} | {r['warm']} | {r['node_seconds']:.1f} | "
            f"{r.get('final_nodes', '?')} | "
            f"{'—' if dc is None else dc} | "
            f"{r.get('audit_failures', '?')} |"
        )
    p99 = sc.get("p99_vs_static_over")
    if p99 is not None:
        out.append("")
        out.append(
            f"autoscale_handoff p99 / static_over = **{p99:.3f}x** (must be "
            f"<=1.5) at **{sc.get('node_seconds_vs_static_over', 0):.3f}x** "
            f"its node-seconds (must be <=0.7); handoff delta "
            f"**{sc.get('handoff_mean_delta_bytes', 0)/1e3:.1f} KB**/instance "
            f"vs **{sc.get('evict_mean_rerestore_bytes', 0)/1e6:.1f} MB** "
            f"full re-restore (must be <=0.5x)"
        )
    if sc.get("error"):
        out.append(f"**SCENARIO FAILED**: {sc['error']}")
    out.append("")
    return "\n".join(out)


def rollout_table(ro) -> str:
    """Markdown for the ``"rollout"`` key: per-regime latency and rollout
    counters, the per-version delta economics, and the acceptance gates
    (colocated p99, delta ratio, rollback warm/zero-read/byte-identity)."""
    tr = ro.get("trace", {})
    out = [
        "#### Train→serve rollout pipeline "
        f"({tr.get('functions', '?')} fns, {tr.get('arrivals', '?')} "
        f"arrivals over {tr.get('duration_s', '?')} s on "
        f"{ro.get('fleet_nodes', '?')} nodes; {ro.get('n_versions', '?')} "
        f"versions published mid-flight at "
        f"{ro.get('canary_fraction', 0):.0%} canary)",
        "",
        "| regime | p50 ttft (ms) | p99 ttft (ms) | cold | warm | versions |"
        " train steps | rollback warm | audit fail |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    regimes = ro.get("regimes", {})
    order = ("serve_only", "colocated")
    for rname in [r for r in order if r in regimes] + sorted(
        set(regimes) - set(order)
    ):
        r = regimes[rname]

        def ms(v):
            return "—" if v is None else f"{v*1e3:.2f}"
        rb = r.get("rollback", {})
        out.append(
            f"| {rname} | {ms(r.get('latency_ttft_p50_s'))} | "
            f"{ms(r.get('latency_ttft_p99_s'))} | {r['cold']} | {r['warm']} | "
            f"{r.get('versions_published', '?')} | "
            f"{r.get('trainer', {}).get('steps', '—')} | "
            f"{'Y' if rb.get('served_warm') else 'n/a' if rb.get('skipped') else 'N'} | "
            f"{r.get('audit_failures', '?')} |"
        )
    p99 = ro.get("p99_colocated_vs_serve_only")
    if p99 is not None:
        first = ro.get("publish_to_first_canary_serve_mean_s")
        rb_s = ro.get("rollback_s")
        out.append("")
        out.append(
            f"colocated p99 / serve-only = **{p99:.3f}x** (must be <=1.5); "
            f"max per-version delta **{ro.get('delta_bytes_max_ratio', 0):.3f}x** "
            f"full image (must be <=0.5); publish→first-canary-serve "
            f"**{'—' if first is None else f'{first*1e3:.0f} ms'}**; rollback "
            f"**{'—' if rb_s is None else f'{rb_s*1e6:.0f} us'}** pointer move, "
            f"byte-identical: **{ro.get('rollback_byte_identical')}**, zero new "
            f"reads: **{ro.get('rollback_zero_new_reads')}**"
        )
    if ro.get("error"):
        out.append(f"**SCENARIO FAILED**: {ro['error']}")
    out.append("")
    return "\n".join(out)


def coldstart_tables(d) -> str:
    """Markdown for BENCH_coldstart.json: per-mode TTFT, delta economics,
    memory-pressure high-water marks, and the cluster placement table."""
    out = []
    fns = d.get("functions", {})
    if fns:
        out += [
            "#### Per-mode TTFT (WARM-at-working-set vs full-restore wait)",
            "",
            "| function | ws_promotion ttft (ms) | full_wait ttft (ms) | ratio | ws time (ms) |",
            "|---|---|---|---|---|",
        ]
        for fname in sorted(fns):
            ws = fns[fname].get("ws_promotion", {})
            fw = fns[fname].get("full_wait", {})
            w, f = ws.get("ttft_s", 0.0), fw.get("ttft_s", 0.0)
            out.append(
                f"| {fname} | {w*1e3:.1f} | {f*1e3:.1f} | "
                f"{w/max(f, 1e-12):.2f} | {ws.get('working_set_s', 0.0)*1e3:.1f} |"
            )
        out.append("")
    delta = d.get("delta")
    if delta:
        out += [
            "#### Delta-chain economics",
            "",
            f"- private vs full: **{delta['private_vs_full']:.3f}** "
            f"({delta['delta_private_bytes']/1e6:.1f} MB of "
            f"{delta['full_private_bytes']/1e6:.1f} MB)",
            f"- restore identical through chain: **{delta['restore_identical']}**",
            "",
        ]
    mp = d.get("memory_pressure")
    if mp:
        out += [
            "#### Memory pressure (budget < Σ images)",
            "",
            f"- budget {mp['budget_bytes']/1e6:.1f} MB vs images "
            f"{mp['image_bytes_sum']/1e6:.1f} MB across {mp['tenants']} tenants; "
            f"all completed: **{mp['all_completed']}** "
            f"({mp['reclaims']} reclaims, {mp['reclaimed_bytes']/1e6:.1f} MB)",
            "",
            "| kind | high-water (MB) |",
            "|---|---|",
        ]
        for k, v in sorted(mp.get("high_water_bytes", {}).items()):
            out.append(f"| {k} | {v/1e6:.1f} |")
        out.append("")
    cl = d.get("cluster")
    if cl:
        out += [
            "#### Cluster placement "
            f"({cl['functions']} fns / {cl['nodes']} nodes / zipf "
            f"s={cl['zipf_s']} / {cl['requests']} requests)",
            "",
            "| policy | p50 ttft (ms) | p99 ttft (ms) | cold | joined | warm |"
            " image pull (MB) | dup concurrent colds | peak node mem (MB) |",
            "|---|---|---|---|---|---|---|---|---|",
        ]
        for pname, p in sorted(cl.get("policies", {}).items()):
            peak = max(
                (hw.get("total", 0) for hw in
                 p.get("per_node_high_water_bytes", {}).values()),
                default=0,
            )
            dup = p.get("duplicate_concurrent_colds")
            out.append(
                f"| {pname} | {p['ttft_p50_s']*1e3:.2f} | {p['ttft_p99_s']*1e3:.2f} | "
                f"{p['cold']} | {p['joined']} | {p['warm']} | "
                f"{p['image_pull_bytes']/1e6:.1f} | "
                f"{'—' if dup is None else dup} | {peak/1e6:.1f} |"
            )
        ratio = cl.get("locality_vs_roundrobin_p99")
        if ratio is not None:
            out.append("")
            out.append(
                f"locality_first p99 / round_robin p99 = **{ratio:.3f}** (must be <1)"
            )
        so = cl.get("scale_out")
        if so:
            out.append(
                f"scale-out knob (queue≥{so['queue_depth_knob']}): replicas "
                f"{so['replicas']} after burst ({so['scale_outs']} scale-outs)"
            )
        out.append("")
    qos = d.get("qos")
    if qos:
        out += [
            "#### QoS classes "
            f"({qos.get('latency_functions', '?')} LATENCY fns warm / "
            f"{qos.get('batch_functions', '?')} BATCH fns cold / "
            f"{qos.get('nodes', '?')} nodes, open loop)",
            "",
            "| class | ok | rejected | cancelled | p50 ttft (ms) |"
            " p99 ttft (ms) | queue wait (ms) | restore wait (ms) |",
            "|---|---|---|---|---|---|---|---|",
        ]
        for cname, c in sorted(qos.get("classes", {}).items()):
            def ms(v):
                return "—" if v is None else f"{v*1e3:.2f}"
            out.append(
                f"| {cname} | {c['ok']} | {c['rejected']} | {c['cancelled']} | "
                f"{ms(c['ttft_p50_s'])} | {ms(c['ttft_p99_s'])} | "
                f"{ms(c['queue_wait_mean_s'])} | {ms(c['restore_wait_mean_s'])} |"
            )
        ratio = qos.get("latency_vs_batch_p99")
        if ratio is not None:
            out.append("")
            out.append(
                f"LATENCY p99 / BATCH p99 = **{ratio:.3f}** (must be <=0.5); "
                f"{qos.get('batch_cancelled_midrestore', 0)} BATCH invocations "
                f"cancelled mid-restore with "
                f"{qos.get('audit_failures', '?')} ledger-audit failures"
            )
        if qos.get("error"):
            out.append(f"**SCENARIO FAILED**: {qos['error']}")
        out.append("")
    dd = d.get("dedup")
    if dd:
        out.append(dedup_table(dd))
    dr = d.get("device_restore")
    if dr:
        full = dr.get("full_image", {})
        if full:
            out += [
                "#### Device-restore fast path (storage roofline "
                f"{dr.get('sim_read_bw', 0)/1e6:.0f} MB/s)",
                "",
                "| install path | wall (ms) | read (MB) | achieved (MB/s) |"
                " roofline frac | upload wait (s) | uploaded (MB) |",
                "|---|---|---|---|---|---|---|",
            ]
            for label in ("eager", "fused"):
                r = full.get(label)
                if not r:
                    continue
                out.append(
                    f"| {label} | {r['wall_s']*1e3:.1f} | "
                    f"{r['bytes_read']/1e6:.1f} | {r['achieved_bw']/1e6:.1f} | "
                    f"{r['roofline_frac']:.3f} | {r['upload_s']:.3f} | "
                    f"{r['uploaded_bytes']/1e6:.1f} |"
                )
            out.append("")
        de = dr.get("delta")
        if de:
            out += [
                f"- delta upload economics: **{de['upload_vs_full']:.3f}** of "
                f"full-image bytes crossed to device "
                f"({de['uploaded_bytes']/1e6:.1f} MB of "
                f"{de['full_bytes']/1e6:.1f} MB), identical to eager: "
                f"**{de['identical']}**",
                f"- device base resident: {de['device_base_resident_bytes']/1e6:.1f} MB "
                f"({de['device_cache_hits']} hits / {de['device_cache_misses']} "
                f"builds), ledger audit ok: **{de['audit_ok']}**",
                "",
            ]
        tt = dr.get("ttft")
        if tt:
            out += [
                f"- cold-start TTFT eager {tt['eager_s']*1e3:.1f} ms vs fused "
                f"{tt['fused_s']*1e3:.1f} ms (ratio "
                f"**{tt['fused_vs_eager']:.3f}**, must be <=1); "
                f"{dr.get('audit_failures', '?')} ledger-audit failures",
                "",
            ]
        if dr.get("error"):
            out.append(f"**SCENARIO FAILED**: {dr['error']}")
            out.append("")
    pw = d.get("prewarm")
    if pw:
        out.append(prewarm_table(pw))
    sc = d.get("scale")
    if sc:
        out.append(scale_table(sc))
    ro = d.get("rollout")
    if ro:
        out.append(rollout_table(ro))
    return "\n".join(out) if out else "_no BENCH_coldstart.json data_"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="")
    ap.add_argument(
        "--section", default="all",
        choices=["dryrun", "roofline", "coldstart", "dedup", "prewarm",
                 "scale", "rollout", "both", "all"],
    )
    args = ap.parse_args()
    cells = load(args.tag)
    if args.section in ("dryrun", "both", "all"):
        print("### Dry-run table\n")
        print(dryrun_table(cells))
        print()
    if args.section in ("roofline", "both", "all"):
        print("### Roofline table\n")
        print(roofline_table(cells))
        print()
    if args.section in ("coldstart", "all"):
        print("### Cold-start table\n")
        if COLDSTART.exists():
            print(coldstart_tables(json.loads(COLDSTART.read_text())))
        else:
            print("_BENCH_coldstart.json not found — run benchmarks.run first_")
    if args.section == "dedup":
        print("### Chunk-dedup table\n")
        dd = (
            json.loads(COLDSTART.read_text()).get("dedup")
            if COLDSTART.exists() else None
        )
        if dd:
            print(dedup_table(dd))
        else:
            print("_no dedup data — run benchmarks.run --only dedup first_")
    if args.section == "prewarm":
        print("### Warmth-policy table\n")
        pw = (
            json.loads(COLDSTART.read_text()).get("prewarm")
            if COLDSTART.exists() else None
        )
        if pw:
            print(prewarm_table(pw))
        else:
            print("_no prewarm data — run benchmarks.run --only prewarm first_")
    if args.section == "scale":
        print("### Scale-harness table\n")
        sc = (
            json.loads(COLDSTART.read_text()).get("scale")
            if COLDSTART.exists() else None
        )
        if sc:
            print(scale_table(sc))
        else:
            print("_no scale data — run benchmarks.run --only scale first_")
    if args.section == "rollout":
        print("### Rollout-pipeline table\n")
        ro = (
            json.loads(COLDSTART.read_text()).get("rollout")
            if COLDSTART.exists() else None
        )
        if ro:
            print(rollout_table(ro))
        else:
            print("_no rollout data — run benchmarks.run --only rollout first_")


if __name__ == "__main__":
    main()
