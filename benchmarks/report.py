"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run
artifacts.

  PYTHONPATH=src:. python -m benchmarks.report            # markdown to stdout
  PYTHONPATH=src:. python -m benchmarks.report --tag x    # tagged variants
"""
from __future__ import annotations

import argparse
import glob
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load(tag: str = ""):
    cells = {}
    for f in sorted(glob.glob(str(RESULTS / "*.json"))):
        stem = Path(f).stem
        parts = stem.split(".")
        cell_tag = parts[1] if len(parts) > 1 else ""
        if cell_tag != tag:
            continue
        d = json.load(open(f))
        cells[d["cell"]] = d
    return cells


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(cells) -> str:
    lines = [
        "| cell | mesh | compile_s | per-dev HBM model (GiB) | fits | HLO GFLOP/dev | coll MB/dev | collective mix |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for cid in sorted(cells):
        d = cells[cid]
        if "skipped" in d:
            lines.append(f"| {cid} | {d['mesh']} | — | — | skip | — | — | {d['skipped'][:60]}… |")
            continue
        if "error" in d:
            lines.append(f"| {cid} | — | — | — | ERR | — | — | {d['error'][:60]} |")
            continue
        m = d["memory"]["modeled"]
        coll = d["collectives"]
        mix = ",".join(
            f"{k.replace('all-','a')[:7]}:{v/1e6:.0f}M"
            for k, v in sorted(coll.items())
            if k != "total" and v > 1e6
        )
        lines.append(
            f"| {cid} | {d['mesh']} | {d['compile_s']} | "
            f"{fmt_bytes(m['total_bytes'])} | {'Y' if m['fits_hbm'] else 'N'} | "
            f"{d['cost']['flops_per_device']/1e9:.0f} | "
            f"{coll.get('total',0)/1e6:.0f} | {mix} |"
        )
    return "\n".join(lines)


def roofline_table(cells) -> str:
    lines = [
        "| cell | compute_s | memory_s | collective_s | dominant | MODEL_FLOPS | useful ratio | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for cid in sorted(cells):
        d = cells[cid]
        if "skipped" in d or "error" in d:
            continue
        r = d["roofline"]
        lines.append(
            f"| {cid} | {r['compute_s']:.4f} | {r['memory_s']:.4f} | "
            f"{r['collective_s']:.4f} | {r['dominant'].replace('_s','')} | "
            f"{r['model_flops']:.2e} | {r['useful_flop_ratio']:.2f} | "
            f"{r['roofline_fraction']:.4f} |"
        )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="")
    ap.add_argument("--section", default="both", choices=["dryrun", "roofline", "both"])
    args = ap.parse_args()
    cells = load(args.tag)
    if args.section in ("dryrun", "both"):
        print("### Dry-run table\n")
        print(dryrun_table(cells))
        print()
    if args.section in ("roofline", "both"):
        print("### Roofline table\n")
        print(roofline_table(cells))


if __name__ == "__main__":
    main()
