"""Cross-tenant dedup scenario: 1 base + K fine-tune deltas over 3 nodes,
with the content-addressed chunk store on vs off.

Both runs publish the SAME zoo (one parent JIF, K deltas of it where
tenant pairs share identical fine-tune content — the cross-tenant overlap
the CAS exists to exploit) and cold-start every delta once through a
3-node router with deterministic round-robin spread.  The baseline run
has no chunk store: each node pulls the parent and every delta's private
chunks from the image store itself.  The dedup run shares ONE
:class:`repro.core.ChunkStore` cluster-wide: ``publish()`` ingests every
image's chunks at write time, restores partition their chunk lists into
resident / node-CAS / peer / miss, and only unique missing digests ever
touch storage — so K deltas of one base cost ~1 base pull cluster-wide,
with the rest travelling over the (simulated) interconnect or not at all.

Reported: total image-pull bytes per regime (the arbiter's storage reads
— cache and peer hits contribute zero), their ratio (the headline:
must be well under 0.5x with K=8), peer-fetch traffic, per-node
``chunk_cas``+``image_cache`` high-water at K/2 and K tenants (sublinear
growth check), a byte-identity sweep (every delta restored through the
dedup path must equal the plain restore bit-for-bit), and ledger + CAS
audit results.  Merges into ``BENCH_coldstart.json`` under ``"dedup"``.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile

import numpy as np

from benchmarks.common import PROMPT, smoke

# merged into BENCH_coldstart.json (written by benchmarks/run.py)
BENCH_TARGET = "coldstart"
SUMMARY_KEY = "dedup"
SUMMARY: dict = {}

N_NODES = 3
K_DELTAS = 8
SIM_READ_BW = 2e8        # mid-tier NVMe for image-store and CAS reads
INTERCONNECT_BW = 1e9    # node-to-node chunk transfers: ~5x faster than disk


def _smoke() -> bool:
    return smoke()


def _cfg():
    from repro.configs import get_config

    cfg = get_config("qwen1.5-0.5b").reduced()
    if not _smoke():
        cfg = dataclasses.replace(
            cfg, pattern_reps=10, n_layers=10, d_model=256, d_ff=512, head_dim=32
        )
    return cfg


def _publish_zoo(catalog, cfg, dirpath: str):
    """One parent JIF + K_DELTAS delta-published tenants.  Tenant pairs
    (2i, 2i+1) apply the SAME fine-tune to the pattern stack — distinct
    tenants, identical private chunks — plus a tiny per-tenant final_norm
    nudge so every image is still unique."""
    import jax

    from repro.core import snapshot
    from repro.models import lm
    from repro.serve.engine import layerwise_state

    base_params = lm.init_params(cfg, jax.random.PRNGKey(7))
    parent_path = f"{dirpath}/dedup-parent.jif"
    snapshot(layerwise_state(cfg, base_params), parent_path)

    fnames = []
    for i in range(K_DELTAS):
        pair = i // 2  # the shared fine-tune identity
        ft = dict(base_params)
        ft["pattern"] = list(base_params["pattern"])
        ft["final_norm"] = base_params["final_norm"] + 0.01 * (i + 1)
        for pi in range(len(cfg.pattern)):
            def bump(a, _p=pair):
                a = np.asarray(a)
                if a.ndim >= 1 and a.shape[0] == cfg.pattern_reps:
                    cut = int(cfg.pattern_reps * 0.7)
                    a = a.copy()
                    a[cut:] = a[cut:] * (1.0 + 0.02 * (_p + 1))
                return a
            ft["pattern"][pi] = jax.tree.map(bump, base_params["pattern"][pi])
        fname = f"dfn-{i}"
        catalog.publish(fname, cfg, ft, dirpath, parent=parent_path,
                        warm_ttl_s=3600.0, formats=("jif",))
        fnames.append(fname)
    return fnames


def _build_cluster(catalog, store):
    from repro.core import NodeChunkCache
    from repro.serve.cluster import ClusterRouter, RoundRobin
    from repro.serve.node import FixedTTLPolicy, NodeScheduler

    nodes = [
        NodeScheduler(
            registry=catalog.registry,
            keepalive=FixedTTLPolicy(3600.0),
            name=f"node{i}",
            chunks=(NodeChunkCache(store, node=f"node{i}")
                    if store is not None else None),
        )
        for i in range(N_NODES)
    ]
    # RoundRobin: delta i lands on node i % 3 — deterministic 3-node spread
    # in both regimes, so pull-byte totals compare like for like
    return ClusterRouter(
        catalog, nodes, placement=RoundRobin(),
        interconnect_bw=INTERCONNECT_BW if store is not None else None,
    )


def _node_hw(router):
    """Per-node chunk_cas + image_cache high-water (bytes)."""
    out = {}
    for n in router.nodes:
        hw = n.memory.high_water()
        out[n.name] = int(hw.get("chunk_cas", 0) + hw.get("image_cache", 0))
    return out


def _run_regime(cfg, dirpath: str, dedup: bool):
    from repro.core import ChunkStore
    from repro.serve.cluster import FunctionCatalog

    store = (
        ChunkStore(f"{dirpath}/cas", simulate_read_bw=SIM_READ_BW)
        if dedup else None
    )
    catalog = FunctionCatalog(chunk_store=store)
    fnames = _publish_zoo(catalog, cfg, dirpath)
    router = _build_cluster(catalog, store)

    hw_half = None
    for i, f in enumerate(fnames):
        r = router.invoke(f, PROMPT, max_new_tokens=2, mode="spice", cfg=cfg,
                          simulate_read_bw=SIM_READ_BW)
        assert r.cold, f"{f} expected cold"
        if i + 1 == len(fnames) // 2:
            router.drain_residual()
            hw_half = _node_hw(router)
    router.drain_residual()

    audit_failures = 0
    try:
        router.audit()
    except AssertionError:
        audit_failures += 1
    if store is not None:
        try:
            store.audit()
        except AssertionError:
            audit_failures += 1

    pull_bytes = sum(
        n.iosched.snapshot_stats()["bytes_read"] for n in router.nodes
    )
    out = {
        "image_pull_bytes": int(pull_bytes),
        "per_node_hw_half": hw_half,
        "per_node_hw_full": _node_hw(router),
        "peer_fetches": router.stats.get("peer_fetches", 0),
        "peer_fetch_bytes": router.stats.get("peer_fetch_bytes", 0),
        "audit_failures": audit_failures,
    }
    if store is not None:
        out["store"] = dict(store.stats)
        out["store_chunks"] = store.audit()["chunks"]
        chunk_stats = {
            n.name: n.chunks.snapshot_stats() for n in router.nodes
        }
        out["node_chunk_stats"] = chunk_stats
    router.close()
    return fnames, out


def _byte_identity_sweep(catalog_dir: str, fnames, registry):
    """Restore every delta twice — plain vs through one shared chunk cache
    (so later tenants hit the dedup fast paths) — and diff leaf-by-leaf."""
    from repro.core import (
        ChunkStore,
        NodeChunkCache,
        NodeImageCache,
        SpiceRestorer,
    )
    from repro.core.treeutil import flatten_state

    store = ChunkStore(f"{catalog_dir}/cas-identity")
    cache = NodeChunkCache(store, node="check")
    images = NodeImageCache()
    mismatches = 0
    for f in fnames:
        path = registry.get(f).jif_path
        plain, _, _, _ = SpiceRestorer(node_cache=NodeImageCache()).restore(path)
        deduped, _, _, _ = SpiceRestorer(
            node_cache=images, chunks=cache, pipelined=False
        ).restore(path)
        la, _ = flatten_state(plain)
        lb, _ = flatten_state(deduped)
        for (na, a), (_nb, b) in zip(la, lb):
            if not np.array_equal(np.asarray(a), np.asarray(b)):
                mismatches += 1
    cache.release_all()
    return mismatches


def run() -> list:
    cfg = _cfg()
    rows: list = []
    SUMMARY.clear()
    SUMMARY.update({
        "nodes": N_NODES,
        "deltas": K_DELTAS,
        "interconnect_bw": INTERCONNECT_BW,
        "regimes": {},
    })

    with tempfile.TemporaryDirectory() as d_off:
        fnames, base = _run_regime(cfg, d_off, dedup=False)
        SUMMARY["regimes"]["no_dedup"] = base
    with tempfile.TemporaryDirectory() as d_on:
        from repro.serve.cluster import FunctionCatalog  # registry for sweep

        fnames, ded = _run_regime(cfg, d_on, dedup=True)
        SUMMARY["regimes"]["dedup"] = ded
        # identity sweep reuses the published zoo before the tempdir dies
        catalog = FunctionCatalog()
        os.makedirs(d_on + "/identity", exist_ok=True)
        zoo = _publish_zoo(catalog, cfg, d_on + "/identity")
        SUMMARY["byte_mismatches"] = _byte_identity_sweep(
            d_on, zoo, catalog.registry
        )

    ratio = ded["image_pull_bytes"] / max(base["image_pull_bytes"], 1)
    SUMMARY["pull_ratio"] = ratio
    # per-node (chunk_cas + image_cache) growth from K/2 to K tenants:
    # < 2.0 everywhere = sublinear in tenant count
    growth = {
        n: (ded["per_node_hw_full"][n] / max(ded["per_node_hw_half"][n], 1))
        for n in ded["per_node_hw_full"]
    }
    SUMMARY["hw_growth_half_to_full"] = growth
    SUMMARY["audit_failures"] = (
        base["audit_failures"] + ded["audit_failures"]
    )

    rows.append(("dedup/pull_mb_no_dedup",
                 base["image_pull_bytes"] / 1e6, ""))
    rows.append(("dedup/pull_mb_dedup", ded["image_pull_bytes"] / 1e6, ""))
    rows.append(("dedup/pull_ratio", ratio, "x (must be <=0.5)"))
    rows.append(("dedup/peer_fetch_mb", ded["peer_fetch_bytes"] / 1e6, ""))
    rows.append(("dedup/byte_mismatches",
                 float(SUMMARY["byte_mismatches"]), "must be 0"))
    return rows
