"""Train→serve continuous-delta rollout benchmark.

A seeded production trace (zipf popularity, diurnal swing, LATENCY flash
crowds — ``benchmarks.common.generate_trace``) replays open-loop against a
small fleet while a fine-tune publishes new versions of the most popular
function **mid-flight** through the full pipeline: ``CheckpointManager.save``
→ ``DeltaPublishCallback`` → ``RolloutController.publish_version`` →
``begin_canary`` → gate → promote.  Two regimes over identical traces:

* ``serve_only`` — the fine-tune's training compute runs off-fleet (a
  dedicated trainer box); only the publishes touch the serving tier.
* ``colocated``  — every training step is admitted onto the serving fleet
  as a BATCH payload invocation (:class:`ColocatedTrainer`), contending
  with live traffic under the admission caps.

After replay each regime measures the rollback story: one pointer move,
then the logical name serves the parent version warm — zero new storage
reads — and a fresh restore of what now serves is byte-identical to the
reference state; retired versions GC down to a clean CAS audit.

Asserted (the PR's acceptance bar): colocated LATENCY p99 <= 1.5x
serve-only; every version's publish wrote <= 0.5x the full image in new
bytes; rollback served warm with zero reads and byte-identical state; all
ledger + CAS audits clean.  Merges into ``BENCH_coldstart.json`` under
``"rollout"``.
"""
from __future__ import annotations

import tempfile
import threading
import time

import numpy as np

from benchmarks.common import PROMPT, TraceSpec, generate_trace, smoke

BENCH_TARGET = "coldstart"
SUMMARY_KEY = "rollout"
SUMMARY: dict = {}

SIM_READ_BW = 1.5e8


def _smoke() -> bool:
    return smoke()


def _params():
    if _smoke():
        return {
            "n_functions": 4,
            "duration_s": 6.0,
            "base_rps": 6.0,
            "flash_crowds": 1,
            "flash_rps": 8.0,
            "flash_duration_s": 1.0,
            "nodes": 2,
            "n_versions": 2,
            "steps_per_version": 2,
            "train_step_ms": 25.0,
            "canary_fraction": 0.5,
            "ft_start_s": 1.0,
        }
    return {
        "n_functions": 6,
        "duration_s": 14.0,
        "base_rps": 8.0,
        "flash_crowds": 2,
        "flash_rps": 12.0,
        "flash_duration_s": 1.5,
        "nodes": 3,
        "n_versions": 3,
        "steps_per_version": 3,
        "train_step_ms": 40.0,
        "canary_fraction": 0.5,
        "ft_start_s": 2.0,
    }


def _cfg():
    import dataclasses

    from repro.configs import get_config

    cfg = get_config("qwen1.5-0.5b").reduced()
    if not _smoke():
        cfg = dataclasses.replace(
            cfg, pattern_reps=6, n_layers=6, d_model=256, d_ff=512, head_dim=32
        )
    return cfg


def _tuned(cfg, params, scale: float):
    """The repo's standard partial fine-tune: dirty the top ~40% of the
    stacked layers + final_norm, leaving the rest byte-identical to the
    base — the delta publish pays for roughly that fraction only."""
    import jax

    params = dict(params)
    params["pattern"] = list(params["pattern"])
    params["final_norm"] = params["final_norm"] + scale

    def bump(a):
        a = np.asarray(a)
        if a.ndim >= 1 and a.shape[0] == cfg.pattern_reps:
            cut = int(cfg.pattern_reps * 0.6)
            a = a.copy()
            a[cut:] = a[cut:] * (1.0 + scale)
        return a

    for pi in range(len(cfg.pattern)):
        params["pattern"][pi] = jax.tree.map(bump, params["pattern"][pi])
    return params


_SPIN = np.ones((96, 96), np.float32)


def _train_compute(ms: float) -> float:
    """~ms of real CPU — the stand-in for one training micro-step."""
    t_end = time.perf_counter() + ms / 1e3
    acc = 0.0
    while time.perf_counter() < t_end:
        acc += float(np.dot(_SPIN, _SPIN)[0, 0])
    return acc


def _make_node_factory(catalog, store):
    from repro.core import NodeChunkCache
    from repro.serve.invocation import AdmissionController
    from repro.serve.node import FixedTTLPolicy, NodeScheduler

    def factory(name: str):
        return NodeScheduler(
            registry=catalog.registry,
            name=name,
            max_workers=8,
            keepalive=FixedTTLPolicy(3600.0),
            admission=AdmissionController(max_queue_depth=96,
                                          max_batch_queued=16,
                                          max_batch_inflight=2),
            chunks=NodeChunkCache(store, node=name),
        )

    return factory


def _replay(router, trace, cfg):
    """Open-loop replay: sleep to each arrival, submit, never wait."""
    from repro.serve.invocation import (
        DeadlineExceeded,
        Invocation,
        Overloaded,
        QosClass,
    )

    handles = []
    rejected = 0
    t0 = time.perf_counter()
    for t_arr, qos_name, fname in trace:
        delay = t_arr - (time.perf_counter() - t0)
        if delay > 0:
            time.sleep(delay)
        inv = Invocation(function=fname, prompt=PROMPT, max_new_tokens=2,
                         cfg=cfg, simulate_read_bw=SIM_READ_BW,
                         qos=QosClass(qos_name))
        try:
            handles.append((QosClass(qos_name), router.submit_invocation(inv)))
        except (Overloaded, DeadlineExceeded):
            rejected += 1
    return handles, rejected, time.perf_counter() - t0


class _FineTune:
    """The mid-flight fine-tune: trains (inline or via the colocated
    trainer), checkpoints, and lets the publish callback drive the staged
    rollout.  Runs on its own thread; errors are captured, not swallowed."""

    def __init__(self, deploy, router, cfg, fname, base_params, p, trainer):
        self.deploy = deploy
        self.router = router
        self.cfg = cfg
        self.fname = fname
        self.base_params = base_params
        self.p = p
        self.trainer = trainer
        self.records = []
        self.tuned_by_version = {}
        self.first_canary_serve_s = []
        self.gate_verdicts = []
        self.error = None

    def run(self):
        try:
            self._run()
        except BaseException as exc:  # noqa: BLE001 — reported in SUMMARY
            self.error = repr(exc)

    def _run(self):
        from repro.ft.manager import CheckpointManager
        from repro.ft.publish import DeltaPublishCallback
        from repro.serve.deploy import TokenHealthGate
        from repro.serve.invocation import Overloaded

        p, cfg = self.p, self.cfg
        time.sleep(p["ft_start_s"])  # let baseline traffic establish
        with tempfile.TemporaryDirectory() as ckpt_dir:
            cb = DeltaPublishCallback(
                self.deploy, self.fname, cfg, every=1,
                canary_fraction=p["canary_fraction"],
            )
            mgr = CheckpointManager(ckpt_dir, async_save=False,
                                    callbacks=[cb])
            for v in range(p["n_versions"]):
                for _ in range(p["steps_per_version"]):
                    if self.trainer is not None:
                        self.trainer.step(_train_compute, p["train_step_ms"])
                    else:
                        _train_compute(p["train_step_ms"])
                tuned = _tuned(cfg, self.base_params, 0.01 * (v + 1))
                t_pub = time.perf_counter()
                mgr.save(v, {"params": tuned}, blocking=True)
                rec = cb.published[-1]
                self.tuned_by_version[rec.version] = tuned
                # publish -> first canary serve: invoke the LOGICAL name
                # until the A/B split hands us the new version
                first = None
                deadline = time.perf_counter() + 30.0
                while time.perf_counter() < deadline:
                    r = self.router.invoke(
                        self.fname, PROMPT, max_new_tokens=2, cfg=cfg,
                        simulate_read_bw=SIM_READ_BW,
                    )
                    if r.function == rec.name:
                        first = time.perf_counter() - t_pub
                        break
                self.first_canary_serve_s.append(first)
                while True:
                    try:
                        ok = self.deploy.evaluate_canary(
                            self.fname, PROMPT,
                            gate=TokenHealthGate(vocab_size=cfg.vocab_size),
                            n_probes=2, max_new_tokens=2, cfg=cfg,
                        )
                        break
                    except Overloaded:
                        # the batch lane is full of serving work: gate
                        # probes yield and retry, admission never bends
                        time.sleep(0.02)
                self.gate_verdicts.append(ok)
            mgr.wait()
            self.records = list(cb.published)


def _rollback_probe(deploy, router, cfg, ft, fname, base_params) -> dict:
    """Instant rollback, measured: pointer-move latency, then the logical
    name must serve the parent WARM (zero storage reads), and a fresh
    restore of what now serves must be byte-identical to the reference."""
    import jax

    from repro.core import SpiceRestorer
    from repro.serve.instance import layerwise_state

    cur = deploy.current(fname)
    if cur.parent is None:
        return {"skipped": True}
    t0 = time.perf_counter()
    back = deploy.rollback(fname)
    rollback_s = time.perf_counter() - t0
    r = router.invoke(fname, PROMPT, max_new_tokens=2, cfg=cfg,
                      simulate_read_bw=SIM_READ_BW)
    ref_params = (base_params if back.version == 1
                  else ft.tuned_by_version[back.version])
    state, _, _, _ = SpiceRestorer().restore(back.jif_path)
    ref = layerwise_state(cfg, ref_params)
    flat_a, _ = jax.tree.flatten(ref)
    flat_b, _ = jax.tree.flatten(state)
    identical = len(flat_a) == len(flat_b) and all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(flat_a, flat_b)
    )
    return {
        "skipped": False,
        "rolled_back_to": back.name,
        "rollback_s": rollback_s,
        "served_version": r.function,
        "served_warm": bool(not r.cold),
        "zero_new_reads": bool(r.stats is None),
        "byte_identical": bool(identical),
    }


def _run_regime(regime, cfg, trace, p, dirpath) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.core import ChunkStore
    from repro.models import lm
    from repro.serve.cluster import ClusterRouter, FunctionCatalog, LocalityFirst
    from repro.serve.deploy import ColocatedTrainer, RolloutController
    from repro.serve.invocation import QosClass

    d = f"{dirpath}/{regime}"
    store = ChunkStore(f"{d}/cas")
    catalog = FunctionCatalog(chunk_store=store)
    fnames = [f"fn-{i}" for i in range(p["n_functions"])]
    zoo = {}
    for i, fname in enumerate(fnames):
        params = lm.init_params(cfg, jax.random.PRNGKey(500 + i), jnp.float32)
        catalog.publish(fname, cfg, params, d, warm_ttl_s=3600.0,
                        formats=("jif",))
        zoo[fname] = params

    factory = _make_node_factory(catalog, store)
    nodes = [factory(f"{regime}-n{i}") for i in range(p["nodes"])]
    router = ClusterRouter(catalog, nodes, placement=LocalityFirst(),
                           latency_spill_depth=3,
                           interconnect_bw=4 * SIM_READ_BW)
    deploy = RolloutController(catalog, seed=17, dirpath=d).attach(router)
    target = fnames[0]  # the zipf head: versions roll out under real load
    trainer = (ColocatedTrainer(router, job_name="ft")
               if regime == "colocated" else None)
    ft = _FineTune(deploy, router, cfg, target, zoo[target], p, trainer)
    try:
        th = threading.Thread(target=ft.run, daemon=True)
        th.start()
        handles, rejected, span_s = _replay(router, trace, cfg)
        results = []
        failed = 0
        for qos, h in handles:
            try:
                results.append((qos, h.result(120)))
            except Exception:
                failed += 1
        th.join(120)
        router.drain_residual()

        probe = _rollback_probe(deploy, router, cfg, ft, target, zoo[target])
        retired = deploy.gc_retired(target)

        audit_failures = 0
        try:
            store.audit()
        except AssertionError:
            audit_failures += 1
        for n in router.nodes:
            try:
                n.memory.audit()
            except AssertionError:
                audit_failures += 1
    finally:
        router.close()

    lat = [r.queue_wait_s + r.ttft_s for q, r in results
           if q is QosClass.LATENCY]
    out = {
        "submitted": len(handles) + rejected,
        "rejected": rejected,
        "failed": failed,
        "cold": sum(1 for _, r in results if r.cold and not r.joined),
        "warm": sum(1 for _, r in results if not r.cold),
        "span_s": span_s,
        "latency_ttft_p50_s": float(np.percentile(lat, 50)) if lat else None,
        "latency_ttft_p99_s": float(np.percentile(lat, 99)) if lat else None,
        "ft_error": ft.error,
        "gate_verdicts": ft.gate_verdicts,
        "versions_published": len(ft.records),
        "version_bytes": [
            {"name": r.name, "step": r.step,
             "private_bytes": r.private_bytes, "total_bytes": r.total_bytes}
            for r in ft.records
        ],
        "publish_to_first_canary_serve_s": ft.first_canary_serve_s,
        "rollout_stats": dict(deploy.stats),
        "rollback": probe,
        "retired": retired,
        "audit_failures": audit_failures,
    }
    if trainer is not None:
        out["trainer"] = dict(trainer.stats)
    return out


def run() -> list:
    from repro.serve.node import NodeScheduler

    cfg = _cfg()
    p = _params()
    rows: list = []
    SUMMARY.clear()

    with tempfile.TemporaryDirectory() as d:
        # compile-cache warmup on a throwaway publish + node (shared jit cache)
        import jax
        import jax.numpy as jnp

        from repro.models import lm
        from repro.serve.cluster import FunctionCatalog

        warm_catalog = FunctionCatalog()
        warm_catalog.publish(
            "warmup", cfg,
            lm.init_params(cfg, jax.random.PRNGKey(1), jnp.float32),
            d, formats=("jif",),
        )
        NodeScheduler(registry=warm_catalog.registry).invoke(
            "warmup", PROMPT, max_new_tokens=2, mode="spice_sync", cfg=cfg
        )

        trace = generate_trace(TraceSpec(
            functions=tuple(f"fn-{i}" for i in range(p["n_functions"])),
            duration_s=p["duration_s"],
            base_rps=p["base_rps"],
            flash_crowds=p["flash_crowds"],
            flash_rps=p["flash_rps"],
            flash_duration_s=p["flash_duration_s"],
            seed=42,
        ))

        regimes = {}
        for regime in ("serve_only", "colocated"):
            regimes[regime] = _run_regime(regime, cfg, trace, p, d)

    serve = regimes["serve_only"]
    coloc = regimes["colocated"]
    p99_ratio = (
        coloc["latency_ttft_p99_s"] / max(serve["latency_ttft_p99_s"], 1e-12)
    )
    audit_failures = sum(r["audit_failures"] for r in regimes.values())
    all_versions = serve["version_bytes"] + coloc["version_bytes"]
    delta_ratios = [
        v["private_bytes"] / max(v["total_bytes"], 1)
        for v in all_versions
    ]
    first_serve = [
        s for r in regimes.values()
        for s in r["publish_to_first_canary_serve_s"] if s is not None
    ]

    SUMMARY.update({
        "trace": {
            "functions": p["n_functions"],
            "arrivals": len(trace),
            "duration_s": p["duration_s"],
            "base_rps": p["base_rps"],
            "seed": 42,
        },
        "fleet_nodes": p["nodes"],
        "n_versions": p["n_versions"],
        "canary_fraction": p["canary_fraction"],
        "sim_read_bw": SIM_READ_BW,
        "regimes": regimes,
        "p99_colocated_vs_serve_only": p99_ratio,
        "delta_bytes_max_ratio": max(delta_ratios) if delta_ratios else None,
        "publish_to_first_canary_serve_mean_s": (
            float(np.mean(first_serve)) if first_serve else None
        ),
        "rollback_s": coloc["rollback"].get("rollback_s"),
        "rollback_byte_identical": bool(
            serve["rollback"].get("byte_identical")
            and coloc["rollback"].get("byte_identical")
        ),
        "rollback_zero_new_reads": bool(
            serve["rollback"].get("zero_new_reads")
            and coloc["rollback"].get("zero_new_reads")
        ),
        "audit_failures": audit_failures,
    })

    for name, r in regimes.items():
        rows.append((f"rollout/{name}_latency_p99",
                     (r["latency_ttft_p99_s"] or 0) * 1e6, ""))
        rows.append((f"rollout/{name}_versions",
                     float(r["versions_published"]), "published mid-flight"))
    rows.append(("rollout/p99_colocated_vs_serve_only", p99_ratio,
                 "x (must be <=1.5)"))
    rows.append(("rollout/delta_bytes_max_ratio",
                 max(delta_ratios) if delta_ratios else 0.0,
                 "of full image (must be <=0.5)"))
    if first_serve:
        rows.append(("rollout/publish_to_first_canary_serve",
                     float(np.mean(first_serve)) * 1e6, "mean"))
    if coloc["rollback"].get("rollback_s") is not None:
        rows.append(("rollout/rollback",
                     coloc["rollback"]["rollback_s"] * 1e6, "pointer move"))

    # ---- the PR's acceptance bar, enforced where the numbers are made ----
    for r in regimes.values():
        assert r["ft_error"] is None, f"fine-tune thread died: {r['ft_error']}"
        assert r["versions_published"] == p["n_versions"]
        assert all(r["gate_verdicts"]), (
            f"quality gate rejected a healthy canary: {r['gate_verdicts']}"
        )
    assert audit_failures == 0, "ledger/CAS audit failed under rollout"
    assert delta_ratios and max(delta_ratios) <= 0.5, (
        f"a version's publish wrote {max(delta_ratios):.2f}x the full image "
        f"in new bytes (must be <=0.5x: deltas, not copies)"
    )
    for name, r in regimes.items():
        pr = r["rollback"]
        assert not pr.get("skipped"), f"{name}: no promote -> nothing to roll back"
        assert pr["served_version"] == pr["rolled_back_to"]
        assert pr["served_warm"] and pr["zero_new_reads"], (
            f"{name}: rollback paid a restore: {pr}"
        )
        assert pr["byte_identical"], (
            f"{name}: post-rollback state diverged from the parent snapshot"
        )
    assert len(first_serve) >= 1, "no canary ever served after publish"
    assert coloc["trainer"]["steps"] == p["n_versions"] * p["steps_per_version"]
    assert p99_ratio <= 1.5, (
        f"colocated LATENCY p99 {coloc['latency_ttft_p99_s']:.4f}s must be "
        f"<= 1.5x serve-only {serve['latency_ttft_p99_s']:.4f}s "
        f"(got {p99_ratio:.2f}x)"
    )
    return rows
