"""Fig 1/9: end-to-end cold-start invocation latency per restore system,
vs a warm invocation, across the function zoo.

Also measures the two headline properties of the snapshot lifecycle
subsystem (tracked in ``BENCH_coldstart.json`` at the repo root):

* **WARM-at-working-set TTFT** — pipelined spice with working-set promotion
  vs the full-restore-wait (``spice_sync``) TTFT of the same image;
* **delta-chain economics** — a fine-tuned state (<30% of pages dirty)
  snapshotted against its parent JIF writes a fraction of the full private
  bytes and restores byte-identically through the chain;
* **memory pressure** — a node whose budget is smaller than the sum of the
  invoked images runs N concurrent cold starts: all must complete via the
  reclaim ladder with the ledger invariant intact, and the per-kind
  high-water marks are recorded.
"""
from __future__ import annotations

import dataclasses
import tempfile

import numpy as np

from benchmarks.common import PROMPT, build_zoo, fn_config, smoke

MODES = ["spice", "criu_star", "reap_star", "faasnap_star"]

# per-mode TTFT / working-set time / total restore time, filled by run()
# and dumped to BENCH_coldstart.json by benchmarks/run.py
SUMMARY: dict = {}


def _smoke() -> bool:
    return smoke()


def _coldstart_rows(node, fnames, rows):
    """spice TTFT with working-set promotion vs full-restore-wait TTFT."""
    for fname in fnames:
        cfg = fn_config(fname)
        per_mode = SUMMARY.setdefault("functions", {}).setdefault(fname, {})
        spec = node.registry.get(fname)
        old_ttl = spec.warm_ttl_s
        spec.warm_ttl_s = 60.0  # keep-alive so WARM-at-working-set fires
        try:
            for mode, tag in [("spice", "ws_promotion"), ("spice_sync", "full_wait")]:
                best_ttft = best_total = float("inf")
                ws_s = 0.0
                reps = 1 if _smoke() else 3
                for _ in range(reps):
                    node.scheduler.drain_residual()
                    node.evict()
                    # mid-tier NVMe bandwidth: I/O dominates, so the promotion
                    # point (working set vs full image) is what separates modes
                    r = node.invoke(fname, PROMPT, max_new_tokens=4, mode=mode,
                                    cfg=cfg, simulate_read_bw=2e8)
                    assert r.cold, f"{fname}/{mode}: expected a cold start"
                    if r.ttft_s < best_ttft:
                        best_ttft = r.ttft_s
                        # keep the record internally consistent: ws time
                        # from the same repetition as the reported TTFT
                        if r.stats:
                            ws_s = (r.stats.get("working_set_s", 0.0)
                                    or r.stats.get("total_s", 0.0))
                    best_total = min(best_total, r.total_s)
                per_mode[tag] = {
                    "ttft_s": best_ttft,
                    "working_set_s": ws_s,
                    "total_restore_s": best_total,
                }
                rows.append((f"coldstart/{fname}/{tag}_ttft", best_ttft * 1e6, ""))
        finally:
            node.scheduler.drain_residual()
            spec.warm_ttl_s = old_ttl
            node.evict()
        ws = per_mode["ws_promotion"]["ttft_s"]
        full = per_mode["full_wait"]["ttft_s"]
        rows.append(
            (f"coldstart/{fname}/ws_vs_full_wait", ws / full, "x (must be <1)")
        )


def _delta_rows(rows):
    """Fine-tune delta snapshots: private bytes vs the full image, restored
    byte-identically through the parent chain from a cold cache."""
    import jax

    from repro.configs import get_config
    from repro.core import NodeImageCache, SpiceRestorer, snapshot
    from repro.core.treeutil import flatten_state
    from repro.models import lm
    from repro.serve.engine import layerwise_state

    cfg = get_config("qwen1.5-0.5b").reduced()
    cfg = dataclasses.replace(
        cfg, pattern_reps=10, n_layers=10, d_model=256, d_ff=512, head_dim=32
    )
    base = layerwise_state(cfg, lm.init_params(cfg, jax.random.PRNGKey(0)))

    with tempfile.TemporaryDirectory() as d:
        parent_path = f"{d}/base.jif"
        full = snapshot(base, parent_path)

        # fine-tune ~25% of the stack: <30% of pages dirty
        ft = jax.tree.map(np.asarray, base)
        cut = int(len(ft["layers"]) * 0.75)
        for li in range(cut, len(ft["layers"])):
            ft["layers"][li] = jax.tree.map(lambda a: a * 1.02, ft["layers"][li])

        delta_path = f"{d}/ft.jif"
        ds = snapshot(ft, delta_path, parent=parent_path)
        ratio = ds.private_bytes / max(full.private_bytes, 1)

        got, _, _, rstats = SpiceRestorer(node_cache=NodeImageCache()).restore(delta_path)
        identical = all(
            np.array_equal(np.asarray(x), np.asarray(y))
            for (_, x), (_, y) in zip(flatten_state(ft)[0], flatten_state(got)[0])
        )
        rows.append(("delta/private_vs_full", ratio, "frac (must be <0.4)"))
        rows.append(("delta/full_private_mb", full.private_bytes / 1e6, ""))
        rows.append(("delta/delta_private_mb", ds.private_bytes / 1e6, ""))
        rows.append(("delta/restore_identical", 1.0 if identical else 0.0, "bool"))
        rows.append(("delta/restore_ms", rstats.total_s * 1e3, ""))
        SUMMARY["delta"] = {
            "private_vs_full": ratio,
            "full_private_bytes": full.private_bytes,
            "delta_private_bytes": ds.private_bytes,
            "restore_identical": identical,
        }


def _memory_pressure_rows(rows):
    """Budget < sum of invoked images; N concurrent cold starts must all
    complete via the reclaim ladder with the ledger invariant intact."""
    import time as _time

    import jax

    from repro.configs import get_config
    from repro.core.jif import JifReader
    from repro.models import lm
    from repro.serve.engine import ServerlessNode
    from repro.serve.node import FixedTTLPolicy

    n_fns = 4
    cfg = get_config("qwen1.5-0.5b").reduced()
    if not _smoke():
        cfg = dataclasses.replace(
            cfg, pattern_reps=10, n_layers=10, d_model=256, d_ff=512, head_dim=32
        )
    # keep-alive ON so completed restores stay resident and later
    # admissions must actually reclaim (residual tails go first)
    node = ServerlessNode(keepalive=FixedTTLPolicy(3600.0))
    fnames = [f"mp-{i}" for i in range(n_fns)]
    with tempfile.TemporaryDirectory() as d:
        extra = {"opt": np.ones((1 << 20,), np.float32)}  # 4 MB residual
        for i, fname in enumerate(fnames):
            params = lm.init_params(cfg, jax.random.PRNGKey(80 + i))
            node.publish(fname, cfg, params, d, formats=("jif",),
                         extra_state=extra)
        node.invoke(fnames[0], PROMPT, max_new_tokens=2, mode="spice_sync",
                    cfg=cfg)  # compile-cache warmup
        node.scheduler.drain_residual()
        node.evict()

        img_bytes = {}
        for fname in fnames:
            with JifReader(node.registry.get(fname).jif_path) as r:
                img_bytes[fname] = sum(t.nbytes for t in r.tensors)
        budget = node.pool.held_bytes + int(1.6 * max(img_bytes.values()))
        assert sum(img_bytes.values()) > budget, "scenario must over-subscribe"
        node.scheduler.memory_budget = budget

        t0 = _time.perf_counter()
        futures = [
            node.submit(f, PROMPT, max_new_tokens=2, mode="spice", cfg=cfg)
            for f in fnames
        ]
        peak = 0
        while not all(f.done() for f in futures):
            snap = node.memory.audit()  # asserts the ledger invariant live
            peak = max(peak, snap["total"])
            _time.sleep(0.005)
        results = [f.result() for f in futures]
        wall = _time.perf_counter() - t0
        assert all(r.cold for r in results), "every pressure invocation completes"
        node.scheduler.drain_residual()
        node.memory.audit()

    mstats = node.memory.snapshot_stats()
    hw = node.memory.high_water()
    pstats = node.pool.snapshot_stats()
    rows.append(("memory_pressure/wall", wall * 1e6, f"{len(fnames)} tenants"))
    rows.append(("memory_pressure/peak_vs_budget", peak / budget,
                 "frac (must be <=1)"))
    rows.append(("memory_pressure/reclaimed_mb",
                 mstats["reclaimed_bytes"] / 1e6, ""))
    SUMMARY["memory_pressure"] = {
        "budget_bytes": budget,
        "image_bytes_sum": sum(img_bytes.values()),
        "tenants": len(fnames),
        "all_completed": True,
        "peak_held_bytes": peak,
        "wall_s": wall,
        "reclaims": mstats["reclaims"],
        "reclaimed_bytes": mstats["reclaimed_bytes"],
        "pressure_failures": mstats["pressure_failures"],
        "residual_evictions": node.scheduler.stats["residual_evictions"],
        "lru_evictions": node.scheduler.stats["lru_evictions"],
        "high_water_bytes": hw,  # per-kind ledger high-water marks
        # staging bytes the ledger could not admit (unmanaged transients):
        # the honest overshoot above the budget, not hidden by the invariant
        "pool_unmanaged_allocs": pstats["unmanaged_allocs"],
        "pool_unmanaged_bytes_hw": pstats["unmanaged_bytes_hw"],
    }


def run() -> list:
    node = build_zoo()
    rows: list = []
    fnames = node.registry.names()[:1] if _smoke() else node.registry.names()

    for fname in fnames:
        cfg = fn_config(fname)
        # compile-cache warmup (the restored "JIT state"): one throwaway run
        node.invoke(fname, PROMPT, max_new_tokens=4, mode="spice_sync", cfg=cfg)
        if not _smoke():
            for mode in MODES:
                for bw, tag in [(None, ""), (2e9, "_simnvme")]:
                    node.evict()
                    best = float("inf")
                    for _ in range(3):
                        node.evict()
                        r = node.invoke(fname, PROMPT, max_new_tokens=4, mode=mode,
                                        cfg=cfg, simulate_read_bw=bw)
                        best = min(best, r.total_s)
                    rows.append((f"e2e_cold{tag}/{fname}/{mode}", best * 1e6, ""))
            # warm comparison
            node.evict()
            node.registry.get(fname).warm_ttl_s = 60
            node.invoke(fname, PROMPT, max_new_tokens=4, mode="spice", cfg=cfg)
            r = node.invoke(fname, PROMPT, max_new_tokens=4, cfg=cfg)
            rows.append((f"e2e_warm/{fname}/warm", r.total_s * 1e6, ""))
            node.registry.get(fname).warm_ttl_s = 0
            node.evict()

    _coldstart_rows(node, fnames, rows)
    _delta_rows(rows)
    _memory_pressure_rows(rows)

    if not _smoke():
        # derived: spice slowdown vs warm, speedup vs baselines
        d = {n: v for n, v, _ in rows}
        for fname in fnames:
            warm = d[f"e2e_warm/{fname}/warm"]
            for tag in ["", "_simnvme"]:
                spice = d[f"e2e_cold{tag}/{fname}/spice"]
                criu = d[f"e2e_cold{tag}/{fname}/criu_star"]
                reap = d[f"e2e_cold{tag}/{fname}/reap_star"]
                faas = d[f"e2e_cold{tag}/{fname}/faasnap_star"]
                rows.append((f"e2e_ratio{tag}/{fname}/spice_vs_warm", spice / warm, "x"))
                rows.append((f"e2e_ratio{tag}/{fname}/criu_vs_spice", criu / spice, "x"))
                rows.append((f"e2e_ratio{tag}/{fname}/reap_vs_spice", reap / spice, "x"))
                rows.append((f"e2e_ratio{tag}/{fname}/faasnap_vs_spice", faas / spice, "x"))
    return rows
