"""Fig 1/9: end-to-end cold-start invocation latency per restore system,
vs a warm invocation, across the function zoo."""
from __future__ import annotations

from benchmarks.common import PROMPT, build_zoo, fn_config

MODES = ["spice", "criu_star", "reap_star", "faasnap_star"]


def run() -> list:
    node = build_zoo()
    rows = []
    for fname in node.registry.names():
        cfg = fn_config(fname)
        # compile-cache warmup (the restored "JIT state"): one throwaway run
        node.invoke(fname, PROMPT, max_new_tokens=4, mode="spice_sync", cfg=cfg)
        for mode in MODES:
            for bw, tag in [(None, ""), (2e9, "_simnvme")]:
                node.evict()
                best = float("inf")
                for _ in range(3):
                    node.evict()
                    r = node.invoke(fname, PROMPT, max_new_tokens=4, mode=mode,
                                    cfg=cfg, simulate_read_bw=bw)
                    best = min(best, r.total_s)
                rows.append((f"e2e_cold{tag}/{fname}/{mode}", best * 1e6, ""))
        # warm comparison
        node.evict()
        node.registry.get(fname).warm_ttl_s = 60
        node.invoke(fname, PROMPT, max_new_tokens=4, mode="spice", cfg=cfg)
        r = node.invoke(fname, PROMPT, max_new_tokens=4, cfg=cfg)
        rows.append((f"e2e_warm/{fname}/warm", r.total_s * 1e6, ""))
        node.registry.get(fname).warm_ttl_s = 0
        node.evict()
    # derived: spice slowdown vs warm, speedup vs baselines
    d = {n: v for n, v, _ in rows}
    for fname in node.registry.names():
        warm = d[f"e2e_warm/{fname}/warm"]
        for tag in ["", "_simnvme"]:
            spice = d[f"e2e_cold{tag}/{fname}/spice"]
            criu = d[f"e2e_cold{tag}/{fname}/criu_star"]
            reap = d[f"e2e_cold{tag}/{fname}/reap_star"]
            faas = d[f"e2e_cold{tag}/{fname}/faasnap_star"]
            rows.append((f"e2e_ratio{tag}/{fname}/spice_vs_warm", spice / warm, "x"))
            rows.append((f"e2e_ratio{tag}/{fname}/criu_vs_spice", criu / spice, "x"))
            rows.append((f"e2e_ratio{tag}/{fname}/reap_vs_spice", reap / spice, "x"))
            rows.append((f"e2e_ratio{tag}/{fname}/faasnap_vs_spice", faas / spice, "x"))
    return rows
