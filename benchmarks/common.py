"""Shared benchmark fixtures: a small function zoo published in every
snapshot format, with a shared base image (page-cache analogue).

Functions are mid-sized (tens of MB) so restore I/O is measurable on this
container; relative comparisons between restore systems mirror the paper's
(all systems read through the same OS page cache here — no O_DIRECT)."""
from __future__ import annotations

import dataclasses
import os
import tempfile
import time
from pathlib import Path
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import BaseImage
from repro.models import lm
from repro.serve.engine import ServerlessNode, layerwise_state

BENCH_DIR = Path(__file__).resolve().parents[1] / "results" / "bench_fns"


def smoke() -> bool:
    """True in CI's BENCH_SMOKE=1 regime (one shared definition: the
    modules must agree on what smoke mode means)."""
    return os.environ.get("BENCH_SMOKE", "") not in ("", "0")


def _jif_version(path: Path) -> int:
    """Peek a cached image's format version (0 if unreadable)."""
    try:
        from repro.core.jif import JifReader

        with JifReader(str(path)) as r:
            return r.version
    except Exception:
        return 0


def bench_config(arch: str, d_model=512, reps=8, vocab=8192):
    """Mid-size config of the arch's family (~30-80 MB of weights)."""
    cfg = get_config(arch).reduced()
    return dataclasses.replace(
        cfg,
        name=f"{arch}-bench",
        d_model=d_model,
        n_heads=8,
        n_kv_heads=min(8, max(cfg.n_kv_heads, 1)) if cfg.n_kv_heads else 0,
        head_dim=64,
        d_ff=4 * d_model if cfg.d_ff else 0,
        vocab_size=vocab,
        pattern_reps=reps,
        n_layers=len(cfg.pattern) * reps + len(cfg.remainder),
        ssm_state=min(cfg.ssm_state, 64) if cfg.ssm_state else 0,
    )


# (function name, arch, perturbation seed) — a "language runtime" variety set
FUNCTIONS: List[Tuple[str, str]] = [
    ("py-hello", "qwen1.5-0.5b"),
    ("py-json", "qwen1.5-0.5b"),
    ("node-image", "starcoder2-7b"),
    ("java-mtml", "musicgen-large"),
    ("py-rnn", "mamba2-780m"),
]


def build_zoo(force: bool = False, **node_kwargs) -> ServerlessNode:
    """Publish the zoo once (cached on disk); rebuild the node each call.
    ``node_kwargs`` reach the underlying :class:`NodeScheduler` (e.g.
    ``install="fused"`` to benchmark the device-restore fast path)."""
    node = ServerlessNode(**node_kwargs)
    BENCH_DIR.mkdir(parents=True, exist_ok=True)

    # one shared base per arch: functions of the same arch dedup against it
    for i, (fname, arch) in enumerate(FUNCTIONS):
        cfg = bench_config(arch)
        base_key = f"base-{arch}"
        key = jax.random.PRNGKey(17)  # same base weights per arch
        params = lm.init_params(cfg, key, jnp.float32)
        if node.node_cache.get(base_key) is None:
            # operator-installed base: no JIF behind it, so the pressure
            # reclaimer must not sacrifice it (restores could not recover)
            node.node_cache.put(
                BaseImage.from_state(base_key, layerwise_state(cfg, params)),
                evictable=False,
            )
        # "fine-tune": perturb the top ~40% of the stack + output head, so
        # the shared fraction lands in the paper's 17-51% ballpark (Fig 5)
        params = dict(params)
        params["pattern"] = list(params["pattern"])
        params["final_norm"] = params["final_norm"] + 0.01 * (i + 1)
        if "unembed" in params["embed"]:
            params["embed"]["unembed"] = params["embed"]["unembed"] * (1.0 + 0.01 * (i + 1))
        for pi in range(len(cfg.pattern)):
            def bump(a, _pi=pi):
                a = np.asarray(a)
                if a.ndim >= 1 and a.shape[0] == cfg.pattern_reps:
                    cut = int(cfg.pattern_reps * 0.6)
                    a = a.copy()
                    a[cut:] = a[cut:] * (1.0 + 0.02 * (i + 1))
                return a
            params["pattern"][pi] = jax.tree.map(bump, params["pattern"][pi])
        jif = BENCH_DIR / f"{fname}.jif"
        # v1 images predate the ws boundary: republish so the working-set
        # promotion path (and residual extra state) is exercised
        if force or not jif.exists() or _jif_version(jif) < 2:
            # fake optimizer/scratch state the VM-style snapshots also capture
            extra = {"opt": np.ones((4 << 20,), np.float32),
                     "scratch": np.zeros((2 << 20,), np.float32)}
            node.publish(fname, cfg, params, str(BENCH_DIR), base_name=base_key,
                         extra_state=extra)
        else:
            from repro.core import FunctionSpec

            node.registry.register(
                FunctionSpec(name=fname, arch=arch, jif_path=str(jif),
                             base_image=base_key)
            )
    return node


def fn_config(fname: str):
    arch = dict(FUNCTIONS)[fname]
    return bench_config(arch)


PROMPT = np.arange(1, 9, dtype=np.int32).reshape(1, 8)


# ------------------------------------------------------- trace generation
@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Declarative, seeded workload for open-loop trace replay.

    The shape mirrors production serverless traces: a zipf-popular
    function mix (``zipf_s``), a diurnal rate swing (sinusoidal around
    ``base_rps``, ±``diurnal_amplitude``), and flash crowds — short
    ``flash_rps`` bursts of LATENCY-class traffic aimed at an unpopular
    (hence likely-cold) function.  Same seed → same trace, across
    processes and runs."""

    functions: Tuple[str, ...]
    duration_s: float = 20.0
    base_rps: float = 4.0
    zipf_s: float = 1.1
    diurnal_amplitude: float = 0.6
    diurnal_period_s: float = 0.0  # 0 = one full cycle over the duration
    flash_crowds: int = 1
    flash_rps: float = 20.0
    flash_duration_s: float = 2.0
    # (QosClass value, weight) mix for the background process
    qos_mix: Tuple[Tuple[str, float], ...] = (
        ("latency", 0.3), ("standard", 0.5), ("batch", 0.2),
    )
    seed: int = 42


def generate_trace(spec: TraceSpec) -> List[Tuple[float, str, str]]:
    """``[(arrival_s, qos_value, fname), ...]`` sorted by arrival time.

    The background process is a non-homogeneous Poisson process (thinning
    against the diurnal peak rate); flash crowds are appended uniformly
    over their burst window.  Everything draws from one seeded
    ``default_rng`` — the trace is a pure function of the spec."""
    import math

    rng = np.random.default_rng(spec.seed)
    ranks = np.arange(1, len(spec.functions) + 1, dtype=np.float64)
    pop = ranks ** -spec.zipf_s
    pop /= pop.sum()
    qos_names = [q for q, _ in spec.qos_mix]
    qos_w = np.array([w for _, w in spec.qos_mix], dtype=np.float64)
    qos_w /= qos_w.sum()
    period = spec.diurnal_period_s or spec.duration_s

    events: List[Tuple[float, str, str]] = []
    peak = spec.base_rps * (1.0 + spec.diurnal_amplitude)
    t = 0.0
    while True:
        t += rng.exponential(1.0 / peak)
        if t >= spec.duration_s:
            break
        rate = spec.base_rps * (
            1.0 + spec.diurnal_amplitude * math.sin(2 * math.pi * t / period)
        )
        if rng.random() < rate / peak:  # thinning
            fname = spec.functions[rng.choice(len(spec.functions), p=pop)]
            qos = qos_names[rng.choice(len(qos_names), p=qos_w)]
            events.append((t, qos, fname))
    # flash crowds: LATENCY bursts on tail functions — the hardest case
    # (an unpopular function is cold everywhere when the crowd arrives)
    for b in range(spec.flash_crowds):
        t0 = spec.duration_s * (b + 1) / (spec.flash_crowds + 1)
        target = spec.functions[-(1 + b % len(spec.functions))]
        for _ in range(max(1, int(spec.flash_rps * spec.flash_duration_s))):
            tt = t0 + rng.random() * spec.flash_duration_s
            if tt < spec.duration_s:
                events.append((tt, "latency", target))
    events.sort(key=lambda e: e[0])
    return events


def timed(f, *args, repeats=3, **kw):
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = f(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best
