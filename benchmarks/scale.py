"""Trace-replay scale harness: SLO-driven elastic autoscaling with
warm-state handoff vs static fleets.

A seeded trace (zipf function popularity, diurnal rate swing, a LATENCY
flash crowd aimed at an unpopular function — ``benchmarks.common
.generate_trace``) replays open-loop against the same catalog four times:

* ``static_over``        — an overprovisioned fleet sized for the flash
                           crowd: the latency gold standard, paying
                           node-seconds all day for its worst minute.
* ``static_small``       — the autoscaler's floor as a static fleet: what
                           "just run fewer nodes" costs at the tail.
* ``autoscale_handoff``  — the full system: SLO-driven scale-out,
                           drain + warm-state handoff on scale-in.
* ``autoscale_evict``    — the ablation: identical control loop, but
                           scale-in evicts warm state instead of handing
                           it off.

After replay, each autoscale regime force-drains its warmest node and
re-requests exactly the functions that node held WARM: with handoff every
probe is served warm (scale-in converted ZERO warm instances into cold
starts); with drain-and-evict at least one pays a full cold restore.

Asserted (the PR's acceptance bar): handoff drain-conversion == 0 and
evict >= 1; mean handoff delta bytes <= 0.5x the mean bytes a full
re-restore sources; the autoscaled fleet holds LATENCY p99 TTFT <= 1.5x
static-overprovisioned while spending <= 0.7x its node-seconds; every
node's ledger audit (including each drained node's, taken at drain time)
is clean.  Merges into ``BENCH_coldstart.json`` under ``"scale"``.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from benchmarks.common import PROMPT, TraceSpec, generate_trace, smoke

BENCH_TARGET = "coldstart"
SUMMARY_KEY = "scale"
SUMMARY: dict = {}

SIM_READ_BW = 1.5e8


def _smoke() -> bool:
    return smoke()


def _params():
    """Trace + fleet knobs, sized for CI smoke vs the full run."""
    if _smoke():
        return {
            "n_functions": 5,
            "duration_s": 6.0,
            "base_rps": 6.0,
            "flash_crowds": 1,
            "flash_rps": 10.0,
            "flash_duration_s": 1.2,
            "static_over": 4,
            "static_small": 1,
            "as_min": 2,
            "as_max": 3,
            "tick_s": 0.15,
            "slo_ttft_p99_s": 0.30,
            "slo_queue_p95_s": 0.30,
            "scale_out_after": 2,
            "scale_in_after": 6,
        }
    return {
        "n_functions": 8,
        "duration_s": 18.0,
        "base_rps": 8.0,
        "flash_crowds": 2,
        "flash_rps": 14.0,
        "flash_duration_s": 2.0,
        "static_over": 10,
        "static_small": 2,
        "as_min": 2,
        "as_max": 6,
        "tick_s": 0.2,
        "slo_ttft_p99_s": 0.35,
        "slo_queue_p95_s": 0.35,
        "scale_out_after": 2,
        "scale_in_after": 8,
    }


def _cfg():
    import dataclasses

    from repro.configs import get_config

    cfg = get_config("qwen1.5-0.5b").reduced()
    if not _smoke():
        cfg = dataclasses.replace(
            cfg, pattern_reps=6, n_layers=6, d_model=256, d_ff=512, head_dim=32
        )
    return cfg


def _publish(catalog, cfg, dirpath, p):
    import jax

    from repro.models import lm

    fnames = [f"fn-{i}" for i in range(p["n_functions"])]
    extra = {"opt": np.ones((1 << 20,), np.float32)}  # 4 MB residual tail
    for i, fname in enumerate(fnames):
        params = lm.init_params(cfg, jax.random.PRNGKey(500 + i))
        catalog.publish(fname, cfg, params, dirpath, warm_ttl_s=3600.0,
                        formats=("jif",), extra_state=extra)
    return fnames


def _make_node_factory(catalog, store):
    from repro.core import NodeChunkCache
    from repro.serve.invocation import AdmissionController
    from repro.serve.node import FixedTTLPolicy, NodeScheduler

    def factory(name: str) -> NodeScheduler:
        return NodeScheduler(
            registry=catalog.registry,
            name=name,
            max_workers=12,
            keepalive=FixedTTLPolicy(3600.0),
            admission=AdmissionController(max_queue_depth=96,
                                          max_batch_queued=16,
                                          max_batch_inflight=4),
            chunks=(NodeChunkCache(store, node=name)
                    if store is not None else None),
        )

    return factory


def _replay(router, trace, cfg, tracker=None):
    """Open-loop replay: sleep to each arrival, submit, never wait."""
    from repro.serve.invocation import (
        DeadlineExceeded,
        Invocation,
        Overloaded,
        QosClass,
    )

    handles = []  # (qos, fname, handle)
    rejected = 0
    t0 = time.perf_counter()
    for t_arr, qos_name, fname in trace:
        delay = t_arr - (time.perf_counter() - t0)
        if delay > 0:
            time.sleep(delay)
        qos = QosClass(qos_name)
        if tracker is not None:
            tracker.record(fname)
        inv = Invocation(function=fname, prompt=PROMPT, max_new_tokens=2,
                         cfg=cfg, simulate_read_bw=SIM_READ_BW, qos=qos)
        try:
            handles.append((qos, fname, router.submit_invocation(inv)))
        except (Overloaded, DeadlineExceeded):
            rejected += 1
    return handles, rejected, time.perf_counter() - t0


_SOURCED_KEYS = (
    # every tier a restore sources bytes from: image-store reads, in-memory
    # base dedup, zero pool, and the three chunk-CAS tiers
    "bytes_read", "base_bytes", "zero_bytes",
    "chunk_resident_bytes", "chunk_cas_bytes", "chunk_peer_bytes",
)


def _forced_drain_probe(router, scaler, cfg) -> dict:
    """Deterministic scale-in measurement: drain the node holding the most
    warm instances, then re-request EXACTLY those functions.  Handoff must
    serve every probe warm; drain-and-evict pays cold restores."""
    victim = max(router.nodes, key=lambda n: len(n.warm_instances()))
    warm_fns = sorted(i.spec.name for i in victim.warm_instances())
    out = {
        "drained_node": victim.name,
        "drained_warm": warm_fns,
        "converted_colds": 0,
        "probe_sourced_bytes": 0,
        "probe_cold_restores": 0,
    }
    if not warm_fns or len(router.nodes) < 2:
        return out
    scaler.drain_node(victim.name)  # audits the drained ledger (raises)
    for fname in warm_fns:
        r = router.invoke(fname, PROMPT, max_new_tokens=2, cfg=cfg,
                          simulate_read_bw=SIM_READ_BW)
        if r.cold and not r.joined:
            out["converted_colds"] += 1
        if r.stats:
            out["probe_cold_restores"] += 1
            out["probe_sourced_bytes"] += sum(
                int(r.stats.get(k, 0)) for k in _SOURCED_KEYS
            )
    return out


def _run_regime(regime, catalog, store, cfg, trace, p, dirpath) -> dict:
    from repro.serve.autoscale import AutoScaler, SLOMonitor, ServiceSLO
    from repro.serve.cluster import ClusterRouter, LocalityFirst
    from repro.serve.invocation import QosClass
    from repro.serve.prewarm import ArrivalTracker, PrewarmPolicy

    factory = _make_node_factory(catalog, store)
    n_init = {
        "static_over": p["static_over"],
        "static_small": p["static_small"],
    }.get(regime, p["as_min"])
    nodes = [factory(f"node{i}") for i in range(n_init)]
    router = ClusterRouter(catalog, nodes, placement=LocalityFirst(),
                           latency_spill_depth=3,
                           interconnect_bw=4 * SIM_READ_BW)
    autoscaled = regime.startswith("autoscale")
    scaler = None
    tracker = None
    try:
        if autoscaled:
            tracker = ArrivalTracker()
            scaler = AutoScaler(
                router,
                [ServiceSLO(qos=QosClass.LATENCY,
                            ttft_p99_s=p["slo_ttft_p99_s"],
                            queue_wait_p95_s=p["slo_queue_p95_s"])],
                handoff_dir=f"{dirpath}/handoff-{regime}",
                node_factory=factory,
                monitor=SLOMonitor(window_s=2.0, min_samples=6),
                keepalive=PrewarmPolicy(tracker),
                min_nodes=p["as_min"],
                max_nodes=p["as_max"],
                scale_out_after=p["scale_out_after"],
                scale_in_after=p["scale_in_after"],
                handoff=(regime == "autoscale_handoff"),
                drain_timeout_s=30.0,
                simulate_read_bw=SIM_READ_BW,
            )
            ns0 = scaler.node_seconds()
            scaler.start(p["tick_s"])  # control loop off the replay thread

        handles, rejected, span_s = _replay(router, trace, cfg, tracker)
        results = []
        failed = 0
        for qos, fname, h in handles:
            try:
                results.append((qos, fname, h.result(120)))
            except Exception:
                failed += 1
        if scaler is not None:
            scaler.stop()
        node_seconds = (
            (scaler.node_seconds() - ns0) if scaler is not None
            else len(router.nodes) * span_s
        )
        router.drain_residual()

        probe = None
        if autoscaled:
            probe = _forced_drain_probe(router, scaler, cfg)
            router.drain_residual()

        audit_failures = 0
        for n in router.nodes:
            try:
                n.memory.audit()
            except AssertionError:
                audit_failures += 1
        hw = {n.name: n.memory.high_water() for n in router.nodes}
        demand_colds = sum(n.stats["cold_starts"] for n in router.nodes)
    finally:
        if scaler is not None:
            scaler.stop()
        router.close()

    lat = [r.queue_wait_s + r.ttft_s for q, _, r in results
           if q is QosClass.LATENCY]
    per_class = {}
    for qcls in QosClass:
        vals = [r.queue_wait_s + r.ttft_s for q, _, r in results
                if q is qcls]
        if vals:
            per_class[qcls.value] = {
                "n": len(vals),
                "ttft_p50_s": float(np.percentile(vals, 50)),
                "ttft_p99_s": float(np.percentile(vals, 99)),
            }
    out = {
        "submitted": len(handles) + rejected,
        "rejected": rejected,
        "failed": failed,
        "cold": sum(1 for _, _, r in results if r.cold and not r.joined),
        "joined": sum(1 for _, _, r in results if r.joined),
        "warm": sum(1 for _, _, r in results if not r.cold),
        "span_s": span_s,
        "node_seconds": float(node_seconds),
        "final_nodes": len(hw),
        "latency_ttft_p50_s": float(np.percentile(lat, 50)) if lat else None,
        "latency_ttft_p99_s": float(np.percentile(lat, 99)) if lat else None,
        "per_class": per_class,
        "node_cold_starts_total": demand_colds,
        "audit_failures": audit_failures,
        "hw_max_node_bytes": max(
            (h.get("total", 0) for h in hw.values()), default=0
        ),
    }
    if scaler is not None:
        out["autoscaler"] = dict(scaler.stats)
        out["events"] = [
            {"action": e["action"], "node": e["node"], "detail": e["detail"]}
            for e in scaler.events
        ]
        out["drain_probe"] = probe
        out["drain_converted_colds"] = probe["converted_colds"]
        out["handoffs_ok"] = scaler.stats["handoffs_ok"]
        out["handoff_delta_bytes"] = scaler.stats["handoff_delta_bytes"]
    return out


def run() -> list:
    from repro.core import ChunkStore
    from repro.serve.cluster import FunctionCatalog
    from repro.serve.node import NodeScheduler

    cfg = _cfg()
    p = _params()
    rows: list = []
    SUMMARY.clear()

    with tempfile.TemporaryDirectory() as d:
        store = ChunkStore(f"{d}/cas")
        catalog = FunctionCatalog(chunk_store=store)
        fnames = _publish(catalog, cfg, d, p)
        # compile-cache warmup on a throwaway node (shared jit cache)
        warm_node = NodeScheduler(registry=catalog.registry)
        warm_node.invoke(fnames[0], PROMPT, max_new_tokens=2,
                         mode="spice_sync", cfg=cfg)

        trace = generate_trace(TraceSpec(
            functions=tuple(fnames),
            duration_s=p["duration_s"],
            base_rps=p["base_rps"],
            flash_crowds=p["flash_crowds"],
            flash_rps=p["flash_rps"],
            flash_duration_s=p["flash_duration_s"],
            seed=42,
        ))

        regimes = {}
        for regime in ("static_over", "static_small",
                       "autoscale_handoff", "autoscale_evict"):
            regimes[regime] = _run_regime(
                regime, catalog, store, cfg, trace, p, d
            )

    over = regimes["static_over"]
    hand = regimes["autoscale_handoff"]
    evic = regimes["autoscale_evict"]
    p99_ratio = (
        hand["latency_ttft_p99_s"] / max(over["latency_ttft_p99_s"], 1e-12)
    )
    ns_ratio = hand["node_seconds"] / max(over["node_seconds"], 1e-9)
    audit_failures = sum(r["audit_failures"] for r in regimes.values())
    handoffs = max(hand["handoffs_ok"], 1)
    mean_delta = hand["handoff_delta_bytes"] / handoffs
    rr_colds = max(evic["drain_probe"]["probe_cold_restores"], 1)
    mean_rerestore = evic["drain_probe"]["probe_sourced_bytes"] / rr_colds

    SUMMARY.update({
        "trace": {
            "functions": len(fnames),
            "arrivals": len(trace),
            "duration_s": p["duration_s"],
            "base_rps": p["base_rps"],
            "flash_crowds": p["flash_crowds"],
            "seed": 42,
        },
        "fleet": {
            "static_over": p["static_over"],
            "static_small": p["static_small"],
            "autoscale_min": p["as_min"],
            "autoscale_max": p["as_max"],
        },
        "slo": {
            "latency_ttft_p99_s": p["slo_ttft_p99_s"],
            "latency_queue_wait_p95_s": p["slo_queue_p95_s"],
        },
        "sim_read_bw": SIM_READ_BW,
        "regimes": regimes,
        "p99_vs_static_over": p99_ratio,
        "node_seconds_vs_static_over": ns_ratio,
        "handoff_mean_delta_bytes": mean_delta,
        "evict_mean_rerestore_bytes": mean_rerestore,
        "audit_failures": audit_failures,
    })
    for name, r in regimes.items():
        rows.append((f"scale/{name}_latency_p99",
                     (r["latency_ttft_p99_s"] or 0) * 1e6, ""))
        rows.append((f"scale/{name}_node_seconds",
                     r["node_seconds"] * 1e6, "node-seconds (us)"))
        rows.append((f"scale/{name}_cold", float(r["cold"]), "cold starts"))
    rows.append(("scale/handoff_drain_converted_colds",
                 float(hand["drain_converted_colds"]), "must be 0"))
    rows.append(("scale/evict_drain_converted_colds",
                 float(evic["drain_converted_colds"]), "must be >=1"))
    rows.append(("scale/p99_vs_static_over", p99_ratio, "x (must be <=1.5)"))
    rows.append(("scale/node_seconds_vs_static_over", ns_ratio,
                 "x (must be <=0.7)"))
    rows.append(("scale/handoff_mean_delta_bytes", mean_delta, "bytes"))

    # ---- the PR's acceptance bar, enforced where the numbers are made ----
    assert audit_failures == 0, "ledger audit failed under the scale trace"
    assert hand["handoffs_ok"] >= 1, (
        "autoscale_handoff never handed off a warm instance"
    )
    assert hand["drain_converted_colds"] == 0, (
        f"handoff scale-in converted "
        f"{hand['drain_converted_colds']} warm instances to cold starts "
        f"(drained {hand['drain_probe']['drained_warm']})"
    )
    assert evic["drain_converted_colds"] >= 1, (
        "drain-and-evict converted no warm instance to a cold start — the "
        "ablation shows no cost, so the handoff comparison is vacuous"
    )
    assert mean_rerestore > 0, "evict probe sourced zero restore bytes"
    assert mean_delta <= 0.5 * mean_rerestore, (
        f"handoff delta {mean_delta/1e3:.1f} KB/instance must be <= 0.5x a "
        f"full re-restore's {mean_rerestore/1e6:.1f} MB"
    )
    assert p99_ratio <= 1.5, (
        f"autoscaled LATENCY p99 {hand['latency_ttft_p99_s']:.4f}s must be "
        f"<= 1.5x static-overprovisioned "
        f"{over['latency_ttft_p99_s']:.4f}s (got {p99_ratio:.2f}x)"
    )
    assert ns_ratio <= 0.7, (
        f"autoscaled node-seconds {hand['node_seconds']:.1f} must be <= "
        f"0.7x static-overprovisioned {over['node_seconds']:.1f} "
        f"(got {ns_ratio:.2f}x)"
    )
    return rows
