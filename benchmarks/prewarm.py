"""Predictive pre-warm scenario: the warmth policy engine vs reactive TTL.

A heavy-tailed trace with bursty arrivals replays open-loop over a 2-node
cluster three times, identical schedule, three warmth regimes:

* ``reactive``        — static ``FixedTTLPolicy`` (short TTL, the SPES-style
                        fleet-wide knob): the pre-policy baseline.
* ``adaptive_nospec`` — ``PrewarmPolicy`` adaptive per-function TTLs fed by
                        the arrival histogram, speculation OFF (the
                        ablation separating the TTL win from speculation).
* ``predictive``      — the full engine: adaptive TTLs + speculative
                        BATCH-class restores ahead of predicted arrivals.

The trace is zipf-flavored with three populations: a periodic *head*
(LATENCY class, short periods — the arrival histogram's head, covered by
adaptive TTLs), a periodic *sparse* set (LATENCY, periods beyond any sane
keep-alive window — only speculation keeps them warm; this is where
predictive beats adaptive-without-speculation), and a one-shot heavy
*tail* (STANDARD — unpredictable, cold in every regime, the memory the
policy must NOT burn).  Two bursts (head + tail) exercise joining under
each regime.  Metrics come from the steady-state window after a learning
prefix, standard practice for prediction-based keep-alive.

Asserted (the PR's acceptance bar): predictive cold-start count ≤ 0.5× the
reactive baseline; predictive ledger high-water ≤ 1.5× reactive (peak
node); predictive LATENCY p99 TTFT no worse than reactive AND no worse
than speculation-off (BATCH-class speculation must never dent the demand
path); zero ledger-audit failures anywhere.  Merges into
``BENCH_coldstart.json`` under ``"prewarm"``.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from benchmarks.common import PROMPT, smoke

BENCH_TARGET = "coldstart"
SUMMARY_KEY = "prewarm"
SUMMARY: dict = {}

N_NODES = 2
SIM_READ_BW = 1.5e8
REACTIVE_TTL = 0.15   # the static keep-alive knob (and the adaptive fallback)
TTL_MARGIN = 1.25
MIN_OBS = 2           # gaps before the histogram drives TTLs/speculation


def _smoke() -> bool:
    return smoke()


def _params():
    """Trace + policy knobs, sized for CI smoke vs the full run."""
    if _smoke():
        return {
            "span_s": 5.5, "warmup_s": 3.0,
            "head_periods": (0.32, 0.42),
            "sparse_periods": (1.1, 1.3),
            "n_tail": 3,
            "max_ttl_s": 0.8, "tail_ttl_s": 0.8, "horizon_s": 0.5,
        }
    return {
        "span_s": 10.0, "warmup_s": 5.0,
        "head_periods": (0.36, 0.44, 0.52),
        "sparse_periods": (1.5, 1.7, 1.9),
        "n_tail": 6,
        "max_ttl_s": 1.0, "tail_ttl_s": 1.0, "horizon_s": 0.7,
    }


def _cfg():
    import dataclasses

    from repro.configs import get_config

    cfg = get_config("qwen1.5-0.5b").reduced()
    if not _smoke():
        cfg = dataclasses.replace(
            cfg, pattern_reps=8, n_layers=8, d_model=256, d_ff=512, head_dim=32
        )
    return cfg


def _publish(catalog, cfg, dirpath, p):
    import jax

    from repro.models import lm

    head = [f"head-{i}" for i in range(len(p["head_periods"]))]
    sparse = [f"sparse-{i}" for i in range(len(p["sparse_periods"]))]
    tail = [f"tail-{i}" for i in range(p["n_tail"])]
    extra = {"opt": np.ones((1 << 20,), np.float32)}  # 4 MB residual tail
    for i, fname in enumerate(head + sparse + tail):
        params = lm.init_params(cfg, jax.random.PRNGKey(300 + i))
        catalog.publish(fname, cfg, params, dirpath, warm_ttl_s=0.0,
                        formats=("jif",), extra_state=extra)
    return head, sparse, tail


def _schedule(head, sparse, tail, p):
    """Deterministic open-loop arrival list: (t, qos, fname, measured).
    ``measured`` = the arrival lands after the learning prefix."""
    from repro.serve.invocation import QosClass

    span, warmup = p["span_s"], p["warmup_s"]
    arrivals = []
    # periodic head + sparse populations (phase-staggered so restores of
    # different functions overlap — queues and joins actually form)
    for fname, period, phase in (
        [(f, per, 0.07 * i) for i, (f, per) in enumerate(zip(head, p["head_periods"]))]
        + [(f, per, 0.23 + 0.31 * i)
           for i, (f, per) in enumerate(zip(sparse, p["sparse_periods"]))]
    ):
        t = phase
        while t < span:
            arrivals.append((t, QosClass.LATENCY, fname, t >= warmup))
            t += period
    # heavy tail: one-shot functions spread over the measured window —
    # unpredictable demand that must stay cold-and-cheap in every regime
    window = span - warmup
    for k, fname in enumerate(tail):
        t = warmup + (k + 0.5) * window / max(len(tail), 1)
        arrivals.append((t, QosClass.STANDARD, fname, True))
    # bursts: 3 back-to-back arrivals of one head fn (warm/join under
    # load) and of one tail fn (cold + two joiners) inside the window
    for i in range(3):
        arrivals.append((warmup + 0.4 * window + 0.01 * i,
                         QosClass.LATENCY, head[0], True))
        arrivals.append((warmup + 0.7 * window + 0.01 * i,
                         QosClass.STANDARD, tail[0], True))
    arrivals.sort(key=lambda a: a[0])
    return arrivals


def _build_router(catalog, cfg, p, regime):
    from repro.serve.cluster import ClusterRouter, LocalityFirst
    from repro.serve.invocation import AdmissionController
    from repro.serve.node import FixedTTLPolicy, NodeScheduler
    from repro.serve.prewarm import ArrivalTracker, PrewarmEngine, PrewarmPolicy

    tracker = ArrivalTracker()

    def policy():
        if regime == "reactive":
            return FixedTTLPolicy(REACTIVE_TTL)
        return PrewarmPolicy(
            tracker,
            default_ttl_s=REACTIVE_TTL,  # unknown fns behave like reactive
            max_ttl_s=p["max_ttl_s"],
            tail_ttl_s=p["tail_ttl_s"],
            ttl_margin=TTL_MARGIN,
            min_observations=MIN_OBS,
        )

    nodes = [
        NodeScheduler(
            registry=catalog.registry,
            name=f"node{i}",
            max_workers=12,
            reap_interval_s=0.05,  # TTL expiry must actually evict
            admission=AdmissionController(max_queue_depth=64,
                                          max_batch_queued=8,
                                          max_batch_inflight=3),
            keepalive=policy(),
        )
        for i in range(N_NODES)
    ]
    engine = None
    if regime != "reactive":
        engine = PrewarmEngine(
            tracker,
            horizon_s=p["horizon_s"],
            interval_s=0.02,
            max_inflight=4,
            min_observations=MIN_OBS,
            speculative=(regime == "predictive"),
            simulate_read_bw=SIM_READ_BW,
        )
    router = ClusterRouter(catalog, nodes, placement=LocalityFirst(),
                           latency_spill_depth=4, prewarm=engine)
    return router, engine


def _replay(router, arrivals, cfg):
    from repro.serve.invocation import (
        DeadlineExceeded,
        Invocation,
        Overloaded,
    )

    handles = []  # (qos, fname, measured, handle)
    rejected = 0
    t0 = time.perf_counter()
    for t_arr, qos, fname, measured in arrivals:
        delay = t_arr - (time.perf_counter() - t0)
        if delay > 0:
            time.sleep(delay)
        inv = Invocation(function=fname, prompt=PROMPT, max_new_tokens=2,
                         cfg=cfg, simulate_read_bw=SIM_READ_BW, qos=qos)
        try:
            handles.append((qos, fname, measured, router.submit_invocation(inv)))
        except (Overloaded, DeadlineExceeded):
            rejected += 1
    return handles, rejected


def _run_regime(regime, catalog, cfg, arrivals, p) -> dict:
    from repro.serve.invocation import QosClass

    router, engine = _build_router(catalog, cfg, p, regime)
    try:
        handles, rejected = _replay(router, arrivals, cfg)
        results = []
        failed = 0
        for qos, fname, measured, h in handles:
            try:
                results.append((qos, measured, h.result(120)))
            except Exception:
                failed += 1
        if engine is not None:
            engine.stop()
            engine.drain(30.0)
        router.drain_residual()

        audit_failures = 0
        for n in router.nodes:
            try:
                n.memory.audit()
            except AssertionError:
                audit_failures += 1
        hw = {n.name: n.memory.high_water() for n in router.nodes}
        spec_restores = sum(n.stats["speculative_restores"] for n in router.nodes)
        spec_redundant = sum(n.stats["prewarm_redundant"] for n in router.nodes)
        demand_colds = sum(n.stats["cold_starts"] for n in router.nodes)
    finally:
        router.close()

    meas = [(q, r) for q, m, r in results if m]
    lat = [r.queue_wait_s + r.ttft_s for q, r in meas if q is QosClass.LATENCY]
    out = {
        "submitted": len(handles) + rejected,
        "rejected": rejected,
        "failed": failed,
        "measured": len(meas),
        # a cold start = a real request that had to wait on a restore
        # initiated on its own behalf (joins ride someone else's)
        "cold": sum(1 for _, r in meas if r.cold and not r.joined),
        "joined": sum(1 for _, r in meas if r.joined),
        "warm": sum(1 for _, r in meas if not r.cold),
        "latency_ttft_p50_s": float(np.percentile(lat, 50)) if lat else None,
        "latency_ttft_p99_s": float(np.percentile(lat, 99)) if lat else None,
        "node_cold_starts_total": demand_colds,
        "speculative_restores": spec_restores,
        "prewarm_redundant": spec_redundant,
        "audit_failures": audit_failures,
        "per_node_high_water_bytes": hw,
        "hw_max_node_bytes": max(h.get("total", 0) for h in hw.values()),
        "hw_sum_bytes": sum(h.get("total", 0) for h in hw.values()),
        "engine": dict(engine.stats) if engine is not None else None,
    }
    return out


def run() -> list:
    from repro.serve.cluster import FunctionCatalog
    from repro.serve.node import NodeScheduler

    cfg = _cfg()
    p = _params()
    rows: list = []
    SUMMARY.clear()

    with tempfile.TemporaryDirectory() as d:
        catalog = FunctionCatalog()
        head, sparse, tail = _publish(catalog, cfg, d, p)
        # compile-cache warmup on a throwaway node (shared jit cache)
        warm_node = NodeScheduler(registry=catalog.registry)
        warm_node.invoke(head[0], PROMPT, max_new_tokens=2, mode="spice_sync",
                         cfg=cfg)
        arrivals = _schedule(head, sparse, tail, p)

        regimes = {}
        for regime in ("reactive", "adaptive_nospec", "predictive"):
            regimes[regime] = _run_regime(regime, catalog, cfg, arrivals, p)

    rea, nos, pred = (regimes["reactive"], regimes["adaptive_nospec"],
                      regimes["predictive"])
    cold_ratio = pred["cold"] / max(rea["cold"], 1)
    hw_ratio = pred["hw_max_node_bytes"] / max(rea["hw_max_node_bytes"], 1)
    p99_vs_reactive = (
        pred["latency_ttft_p99_s"] / max(rea["latency_ttft_p99_s"], 1e-12)
    )
    p99_vs_nospec = (
        pred["latency_ttft_p99_s"] / max(nos["latency_ttft_p99_s"], 1e-12)
    )
    audit_failures = sum(r["audit_failures"] for r in regimes.values())
    SUMMARY.update({
        "nodes": N_NODES,
        "head_functions": len(head),
        "sparse_functions": len(sparse),
        "tail_functions": len(tail),
        "span_s": p["span_s"],
        "warmup_s": p["warmup_s"],
        "sim_read_bw": SIM_READ_BW,
        "reactive_ttl_s": REACTIVE_TTL,
        "max_ttl_s": p["max_ttl_s"],
        "horizon_s": p["horizon_s"],
        "regimes": regimes,
        "cold_vs_reactive": cold_ratio,
        "hw_vs_reactive": hw_ratio,
        "p99_vs_reactive": p99_vs_reactive,
        "p99_vs_nospec": p99_vs_nospec,
        "audit_failures": audit_failures,
    })
    for name, r in regimes.items():
        rows.append((f"prewarm/{name}_cold", float(r["cold"]), "cold starts"))
        rows.append((f"prewarm/{name}_latency_p99",
                     (r["latency_ttft_p99_s"] or 0) * 1e6, ""))
    rows.append(("prewarm/cold_vs_reactive", cold_ratio, "x (must be <=0.5)"))
    rows.append(("prewarm/hw_vs_reactive", hw_ratio, "x (must be <=1.5)"))
    rows.append(("prewarm/p99_vs_nospec", p99_vs_nospec, "x (must be <=1.05)"))
    rows.append(("prewarm/speculative_restores",
                 float(pred["speculative_restores"]), ""))

    # ---- the PR's acceptance bar, enforced where the numbers are made ----
    assert audit_failures == 0, "ledger audit failed under the prewarm trace"
    assert pred["speculative_restores"] > 0, (
        "predictive regime never speculated — the engine is not firing"
    )
    assert cold_ratio <= 0.5, (
        f"predictive cold starts {pred['cold']} must be <= 0.5x reactive "
        f"{rea['cold']} (got {cold_ratio:.3f})"
    )
    assert hw_ratio <= 1.5, (
        f"predictive peak-node high-water {pred['hw_max_node_bytes']/1e6:.1f} MB "
        f"must be <= 1.5x reactive {rea['hw_max_node_bytes']/1e6:.1f} MB "
        f"(got {hw_ratio:.2f}x)"
    )
    assert p99_vs_reactive <= 1.05, (
        f"predictive LATENCY p99 {pred['latency_ttft_p99_s']:.4f}s must not "
        f"exceed reactive {rea['latency_ttft_p99_s']:.4f}s"
    )
    assert p99_vs_nospec <= 1.05, (
        f"BATCH-class speculation dented LATENCY p99: "
        f"{pred['latency_ttft_p99_s']:.4f}s vs speculation-off "
        f"{nos['latency_ttft_p99_s']:.4f}s"
    )
    return rows
