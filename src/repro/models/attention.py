"""GQA attention: full (train), chunked prefill, and cached decode.

Pure-jnp reference path (used by the dry-run so roofline terms come from
clean HLO); the Pallas flash/decode kernels in ``repro.kernels`` are the TPU
deployment path and are validated against this module's math.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models.layers import apply_rope, rmsnorm
from repro.sharding.partition import ParamSpec, constrain

NEG_INF = -1e30


def attn_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, H, kvH, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    s = {
        "wq": ParamSpec((d, H * hd), ("fsdp", "model"), init="fanin"),
        "wk": ParamSpec((d, kvH * hd), ("fsdp", "model"), init="fanin"),
        "wv": ParamSpec((d, kvH * hd), ("fsdp", "model"), init="fanin"),
        "wo": ParamSpec((H * hd, d), ("model", "fsdp"), init="fanin"),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec((H * hd,), ("model",), init="zeros")
        s["bk"] = ParamSpec((kvH * hd,), ("model",), init="zeros")
        s["bv"] = ParamSpec((kvH * hd,), ("model",), init="zeros")
    if cfg.qk_norm:
        s["q_norm"] = ParamSpec((hd,), (None,), init="ones", dtype=jnp.float32)
        s["k_norm"] = ParamSpec((hd,), (None,), init="ones", dtype=jnp.float32)
    return s


def _project_qkv(cfg, p, x, positions, compute_dtype):
    B, S, _ = x.shape
    H, kvH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dk->bsk", x, p["wq"].astype(compute_dtype))
    k = jnp.einsum("bsd,dk->bsk", x, p["wk"].astype(compute_dtype))
    v = jnp.einsum("bsd,dk->bsk", x, p["wv"].astype(compute_dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(compute_dtype)
        k = k + p["bk"].astype(compute_dtype)
        v = v + p["bv"].astype(compute_dtype)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, kvH, hd)
    v = v.reshape(B, S, kvH, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)
    return q, k, v


def _repeat_kv(k, v, kv_repeat: int):
    """Replicate KV heads so the head dim divides the TP axis (memory for
    shardability — the standard GQA trick when kv_heads < model-axis size)."""
    if kv_repeat > 1:
        k = jnp.repeat(k, kv_repeat, axis=2)
        v = jnp.repeat(v, kv_repeat, axis=2)
    return k, v


def quantize_kv(x: jax.Array):
    """Symmetric per-(batch, head, position) int8 KV quantization."""
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1), 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype):
    return q.astype(dtype) * scale[..., None].astype(dtype)


def _sdpa_block(q, k, v, qpos, kpos, window, scale):
    """q: (B,Sq,kvH,G,hd)  k/v: (B,Sk,kvH,hd)  -> (B,Sq,kvH,G,hd).

    Masks are built from absolute positions so the same primitive serves
    full-causal, sliding-window, and chunked-prefill calls.
    """
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    mask = kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgqs,bskh->bqkgh", w, v)


def attn_full(
    cfg: ModelConfig,
    spec: LayerSpec,
    p: Dict,
    x: jax.Array,
    positions: jax.Array,
    compute_dtype,
    return_cache: bool = False,
    q_chunk: int = 2048,
    unroll: bool = False,
    kv_repeat: int = 1,
    kv_dtype=None,
    attn_stages: int = 1,
) -> Tuple[jax.Array, Optional[Dict]]:
    """Causal (optionally windowed) attention over a full sequence."""
    B, S, _ = x.shape
    H, hd = cfg.n_heads, cfg.hd
    kvH = cfg.n_kv_heads * kv_repeat
    assert H % kvH == 0, f"kv_repeat {kv_repeat} breaks GQA grouping"
    G = H // kvH
    q, k, v = _project_qkv(cfg, p, x, positions, compute_dtype)
    k, v = _repeat_kv(k, v, kv_repeat)
    qg = q.reshape(B, S, kvH, G, hd)
    scale = hd**-0.5
    kpos = jnp.arange(S)

    if S <= q_chunk:
        out = _sdpa_block(qg, k, v, jnp.arange(S), kpos, spec.window, scale)
    else:
        nq = S // q_chunk
        qs = qg.reshape(B, nq, q_chunk, kvH, G, hd)
        # Staged causal K-slicing (§Perf): stage g's query chunks can only
        # attend to keys < (g+1)·S/stages, a STATIC prefix — so later-masked
        # key bytes are never touched. stages=1 (default) = full-K chunks;
        # stages=8 cuts attention score traffic to ~(stages+1)/(2·stages).
        outs = []
        for g in range(attn_stages):
            lo_c, hi_c = g * nq // attn_stages, (g + 1) * nq // attn_stages
            if lo_c == hi_c:
                continue
            k_hi = hi_c * q_chunk
            # window-aware lower bound: sliding-window layers can never see
            # keys older than (first query of the stage) - window; rounding
            # to a chunk boundary keeps the slice static
            if spec.window is not None:
                k_lo = max(0, ((lo_c * q_chunk - spec.window) // q_chunk) * q_chunk)
            else:
                k_lo = 0
            kg, vg = k[:, k_lo:k_hi], v[:, k_lo:k_hi]
            kpos_g = jnp.arange(k_lo, k_hi)

            def chunk_body(_, args, kg=kg, vg=vg, kpos_g=kpos_g):
                qc, start = args
                qpos = start + jnp.arange(q_chunk)
                return None, _sdpa_block(qc, kg, vg, qpos, kpos_g, spec.window, scale)

            starts = jnp.arange(lo_c, hi_c) * q_chunk
            n_g = hi_c - lo_c
            _, out_g = jax.lax.scan(
                chunk_body,
                None,
                (qs[:, lo_c:hi_c].swapaxes(0, 1), starts),
                unroll=n_g if unroll else 1,
            )
            outs.append(out_g.swapaxes(0, 1))
        out = jnp.concatenate(outs, axis=1).reshape(B, S, kvH, G, hd)

    y = out.reshape(B, S, H * hd)
    y = jnp.einsum("bsk,kd->bsd", y, p["wo"].astype(compute_dtype))
    y = constrain(y, "batch", None, None)

    cache = None
    if return_cache:
        kc = k.swapaxes(1, 2)  # (B, kvH, S, hd)
        vc = v.swapaxes(1, 2)
        if spec.window is not None and spec.window < S:
            W = spec.window
            # keep slot invariant "abs position p lives at slot p % W"
            j = jnp.arange(W)
            a = j + W * ((S - 1 - j) // W)  # latest position congruent to j
            kc = jnp.take(kc, a, axis=2)
            vc = jnp.take(vc, a, axis=2)
        if kv_dtype is not None and jnp.dtype(kv_dtype) == jnp.int8:
            kq, ks = quantize_kv(kc)
            vq, vs = quantize_kv(vc)
            cache = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
        else:
            if kv_dtype is not None:
                kc, vc = kc.astype(kv_dtype), vc.astype(kv_dtype)
            cache = {"k": kc, "v": vc}
        cache = {
            key: constrain(val, "batch", "kv_heads", "kv_seq", None)
            if val.ndim == 4
            else constrain(val, "batch", "kv_heads", "kv_seq")
            for key, val in cache.items()
        }
    return y, cache


def attn_decode(
    cfg: ModelConfig,
    spec: LayerSpec,
    p: Dict,
    x: jax.Array,  # (B, 1, d)
    cache: Dict,
    pos: jax.Array,  # scalar int32: number of tokens already consumed
    compute_dtype,
    kv_repeat: int = 1,
    kv_block: int = 2048,
    unroll_inner: bool = False,
) -> Tuple[jax.Array, Dict]:
    B = x.shape[0]
    H, hd = cfg.n_heads, cfg.hd
    kvH = cfg.n_kv_heads * kv_repeat
    G = H // kvH
    positions = jnp.broadcast_to(pos, (B, 1))
    q, k, v = _project_qkv(cfg, p, x, positions, compute_dtype)
    k, v = _repeat_kv(k, v, kv_repeat)

    quantized = cache["k"].dtype == jnp.int8
    Sc = cache["k"].shape[2]
    slot = pos % Sc if spec.window is not None else pos
    new_cache = {}
    if quantized:
        kq, ks = quantize_kv(k.swapaxes(1, 2))
        vq, vs = quantize_kv(v.swapaxes(1, 2))
        new_cache["k"] = jax.lax.dynamic_update_slice(cache["k"], kq, (0, 0, slot, 0))
        new_cache["v"] = jax.lax.dynamic_update_slice(cache["v"], vq, (0, 0, slot, 0))
        new_cache["k_scale"] = jax.lax.dynamic_update_slice(cache["k_scale"], ks, (0, 0, slot))
        new_cache["v_scale"] = jax.lax.dynamic_update_slice(cache["v_scale"], vs, (0, 0, slot))
    else:
        kc = jax.lax.dynamic_update_slice(
            cache["k"], k.swapaxes(1, 2).astype(cache["k"].dtype), (0, 0, slot, 0)
        )
        vc = jax.lax.dynamic_update_slice(
            cache["v"], v.swapaxes(1, 2).astype(cache["v"].dtype), (0, 0, slot, 0)
        )
        new_cache = {
            "k": constrain(kc, "batch", "kv_heads", "kv_seq", None),
            "v": constrain(vc, "batch", "kv_heads", "kv_seq", None),
        }

    # Flash-decoding: stream the cache in KV blocks with an online softmax.
    # Blocks are read with dynamic_slice from the cache's native layout (a
    # scan-xs formulation would materialize a transposed full-cache copy),
    # bounding live converts/dequants to one block — on TPU this is also the
    # natural VMEM-tile structure (see kernels/decode_attention).
    qg = q.reshape(B, kvH, G, hd)
    blk = min(kv_block, Sc)
    if Sc % blk:
        blk = Sc
    nb = Sc // blk
    scale = hd**-0.5

    def body(carry, i):
        m_prev, s_prev, acc = carry
        start = i * blk
        kb = jax.lax.dynamic_slice(
            new_cache["k"], (0, 0, start, 0), (B, kvH, blk, hd)
        )
        vb = jax.lax.dynamic_slice(
            new_cache["v"], (0, 0, start, 0), (B, kvH, blk, hd)
        )
        if quantized:
            ksb = jax.lax.dynamic_slice(new_cache["k_scale"], (0, 0, start), (B, kvH, blk))
            vsb = jax.lax.dynamic_slice(new_cache["v_scale"], (0, 0, start), (B, kvH, blk))
            kb = dequantize_kv(kb, ksb, compute_dtype)
            vb = dequantize_kv(vb, vsb, compute_dtype)
        else:
            kb = kb.astype(compute_dtype)
            vb = vb.astype(compute_dtype)
        s = jnp.einsum("bkgh,bksh->bkgs", qg, kb, preferred_element_type=jnp.float32)
        s = s * scale
        valid = (start + jnp.arange(blk)) <= pos  # ring: all valid once full
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        corr = jnp.exp(m_prev - m_new)
        pblk = jnp.exp(s - m_new[..., None])
        s_new = s_prev * corr + jnp.sum(pblk, axis=-1)
        upd = jnp.einsum("bkgs,bksh->bkgh", pblk.astype(compute_dtype), vb)
        acc = acc * corr[..., None] + upd.astype(jnp.float32)
        return (m_new, s_new, acc), None

    init = (
        jnp.full((B, kvH, G), NEG_INF, jnp.float32),
        jnp.zeros((B, kvH, G), jnp.float32),
        jnp.zeros((B, kvH, G, hd), jnp.float32),
    )
    (m, s_sum, acc), _ = jax.lax.scan(
        body, init, jnp.arange(nb), unroll=nb if unroll_inner else 1
    )
    out = (acc / s_sum[..., None]).astype(compute_dtype)
    y = out.reshape(B, 1, H * hd)
    y = jnp.einsum("bsk,kd->bsd", y, p["wo"].astype(compute_dtype))
    return constrain(y, "batch", None, None), new_cache
