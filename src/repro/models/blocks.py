"""Pattern-block layer application: dense/MoE FFN x attn/mamba mixers."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import attention, mamba2, moe
from repro.models.layers import mlp, mlp_specs, rmsnorm
from repro.sharding.partition import ParamSpec


def layer_specs(cfg: ModelConfig, spec: LayerSpec) -> Dict:
    s: Dict = {"ln1": ParamSpec((cfg.d_model,), (None,), init="ones", dtype=jnp.float32)}
    if spec.kind == "attn":
        s["attn"] = attention.attn_specs(cfg)
    else:
        s["mamba"] = mamba2.mamba_specs(cfg)
    if spec.ffn:
        s["ln2"] = ParamSpec((cfg.d_model,), (None,), init="ones", dtype=jnp.float32)
        s["moe" if spec.moe else "mlp"] = (
            moe.moe_specs(cfg) if spec.moe else mlp_specs(cfg)
        )
    return s


def cache_specs_for_layer(
    cfg: ModelConfig,
    spec: LayerSpec,
    batch: int,
    cache_len: int,
    kv_dtype,
    compute_dtype,
    kv_repeat: int = 1,
) -> Dict:
    if spec.kind == "attn":
        Sc = min(spec.window, cache_len) if spec.window else cache_len
        kvH = cfg.n_kv_heads * kv_repeat
        shp = (batch, kvH, Sc, cfg.hd)
        ax = ("batch", "kv_heads", "kv_seq", None)
        import jax.numpy as _jnp

        out = {
            "k": ParamSpec(shp, ax, init="zeros", dtype=kv_dtype),
            "v": ParamSpec(shp, ax, init="zeros", dtype=kv_dtype),
        }
        if _jnp.dtype(kv_dtype) == _jnp.int8:
            sc = ParamSpec((batch, kvH, Sc), ax[:3], init="zeros", dtype=_jnp.float32)
            out["k_scale"] = sc
            out["v_scale"] = sc
        return out
    conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    out = {
        "ssm": ParamSpec(
            (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            ("batch", "heads", None, None),
            init="zeros",
            dtype=jnp.float32,
        )
    }
    gn = cfg.ssm_groups * cfg.ssm_state
    if cfg.mamba_split_proj:
        for key, c in [("conv_x", cfg.d_inner), ("conv_B", gn), ("conv_C", gn)]:
            out[key] = ParamSpec(
                (batch, cfg.conv_kernel - 1, c), ("batch", None, "model"),
                init="zeros", dtype=compute_dtype,
            )
    else:
        out["conv"] = ParamSpec(
            (batch, cfg.conv_kernel - 1, conv_dim),
            ("batch", None, "model"),
            init="zeros",
            dtype=compute_dtype,
        )
    return out


def apply_layer(
    cfg: ModelConfig,
    spec: LayerSpec,
    p: Dict,
    x,
    *,
    positions,
    mode: str,  # "train" | "prefill" | "decode"
    cache: Optional[Dict],
    pos,
    compute_dtype,
    q_chunk: int = 2048,
    unroll: bool = False,  # inner (attention-block) loops
    kv_repeat: int = 1,
    kv_dtype=None,
    kv_block: int = 2048,
    attn_stages: int = 1,
) -> Tuple:
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    new_cache = None
    if spec.kind == "attn":
        if mode == "decode":
            y, new_cache = attention.attn_decode(
                cfg, spec, p["attn"], h, cache, pos, compute_dtype,
                kv_repeat=kv_repeat, kv_block=kv_block, unroll_inner=unroll,
            )
        else:
            y, new_cache = attention.attn_full(
                cfg,
                spec,
                p["attn"],
                h,
                positions,
                compute_dtype,
                return_cache=(mode == "prefill"),
                q_chunk=q_chunk,
                unroll=unroll,
                kv_repeat=kv_repeat,
                kv_dtype=kv_dtype,
                attn_stages=attn_stages,
            )
    else:
        if mode == "decode":
            y, new_cache = mamba2.mamba_decode(cfg, p["mamba"], h, cache, compute_dtype)
        else:
            y, new_cache = mamba2.mamba_full(
                cfg, p["mamba"], h, compute_dtype, return_cache=(mode == "prefill")
            )
    x = x + y

    aux = jnp.zeros((), jnp.float32)
    if spec.ffn:
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if spec.moe:
            y, aux = moe.moe_ffn(cfg, p["moe"], h, compute_dtype)
        else:
            y = mlp(cfg, p["mlp"], h, compute_dtype)
        x = x + y
    return x, new_cache, aux
