"""Top-k MoE with capacity-based dispatch.

Two execution paths:

* **local** (no sharding rules active — smoke tests, benchmarks): dense
  scatter/gather dispatch on one device.
* **shard_map EP** (under ``axis_rules``): expert parallelism over the
  ``model`` mesh axis with *explicit* collectives, because GSPMD's handling
  of data-dependent scatter/gather across an expert-sharded buffer degrades
  to full rematerialization (observed: 288 GB/device temp on olmoe).
  - ``a2a`` mode (train/prefill: seq divisible by the model axis): tokens are
    sharded over (dp x model); each device dispatches into an (E, C_dev, d)
    buffer and a pair of all-to-alls moves tokens to/from expert owners —
    the GShard pattern.
  - ``replicated`` mode (decode: one token per sequence): every model rank
    routes the dp-local tokens, computes only its own E/m experts, and the
    outputs are psum'd over the model axis. Right trade-off for tiny T.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.sharding.partition import ParamSpec, current_rules, logical_to_spec

try:
    from jax import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                          check_vma=False)
except ImportError:  # older jax
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                              check_rep=False)

W_LOGICAL = {
    "w_gate": ("expert", "fsdp", "model"),
    "w_up": ("expert", "fsdp", "model"),
    "w_down": ("expert", "model", "fsdp"),
}


def moe_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ParamSpec((d, E), (None, None), init="fanin", dtype=jnp.float32),
        "w_gate": ParamSpec((E, d, f), W_LOGICAL["w_gate"], init="fanin"),
        "w_up": ParamSpec((E, d, f), W_LOGICAL["w_up"], init="fanin"),
        "w_down": ParamSpec((E, f, d), W_LOGICAL["w_down"], init="fanin"),
    }


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = math.ceil(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(4, min(c, n_tokens * cfg.top_k))


def _route(cfg, router_w, xf):
    """xf: (T, d) -> gates (T,k), idx (T,k), probs (T,E) [f32]."""
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    return gates, idx, probs


def _positions(idx, E: int, C: int):
    """Slot positions within each expert for (T,k) routed pairs."""
    T, k = idx.shape
    oh = jax.nn.one_hot(idx, E, dtype=jnp.int32).reshape(T * k, E)
    pos = jnp.cumsum(oh, axis=0) - oh
    flat_pos = jnp.sum(pos * oh, axis=-1)
    flat_e = idx.reshape(T * k)
    keep = flat_pos < C
    return flat_e, jnp.minimum(flat_pos, C - 1), keep


def _aux_loss(cfg, probs, idx):
    T = probs.shape[0]
    oh = jax.nn.one_hot(idx, cfg.n_experts, dtype=jnp.float32)  # (T,k,E)
    f_e = jnp.mean(oh.sum(axis=1), axis=0)
    P_e = jnp.mean(probs, axis=0)
    return cfg.n_experts * jnp.sum(f_e * P_e) / cfg.top_k


def _expert_mlp(h_in, wg, wu, wd):
    h = jnp.einsum("ecd,edf->ecf", h_in, wg)
    u = jnp.einsum("ecd,edf->ecf", h_in, wu)
    h = jax.nn.silu(h.astype(jnp.float32)).astype(h_in.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, wd)


def _moe_local(cfg: ModelConfig, p: Dict, x, compute_dtype):
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    C = capacity(cfg, T)
    xf = x.reshape(T, d)
    gates, idx, probs = _route(cfg, p["router"], xf)
    flat_e, flat_pos, keep = _positions(idx, E, C)

    xr = jnp.broadcast_to(xf[:, None, :], (T, k, d)).reshape(T * k, d)
    buf = jnp.zeros((E, C, d), compute_dtype)
    buf = buf.at[flat_e, flat_pos].add(
        jnp.where(keep[:, None], xr, 0).astype(compute_dtype), mode="drop"
    )
    out = _expert_mlp(
        buf,
        p["w_gate"].astype(compute_dtype),
        p["w_up"].astype(compute_dtype),
        p["w_down"].astype(compute_dtype),
    )
    vals = out[flat_e, flat_pos]
    w = jnp.where(keep, gates.reshape(T * k), 0.0).astype(compute_dtype)
    y = (vals * w[:, None]).reshape(T, k, d).sum(axis=1)
    return y.reshape(B, S, d), _aux_loss(cfg, probs, idx)


def _gather_fsdp(w, spec: P, compute_dtype):
    """Inside shard_map: all-gather any FSDP-sharded weight dims, cast."""
    for axis_pos, ax in enumerate(spec):
        if ax is None or axis_pos == 0:  # dim 0 is the expert (EP) dim: keep
            continue
        names = (ax,) if isinstance(ax, str) else tuple(ax)
        for name in names:
            w = jax.lax.all_gather(w, name, axis=axis_pos, tiled=True)
    return w.astype(compute_dtype)


def moe_ffn(cfg: ModelConfig, p: Dict, x, compute_dtype) -> Tuple[jax.Array, jax.Array]:
    rules = current_rules()
    if rules is None:
        return _moe_local(cfg, p, x, compute_dtype)

    mesh = rules.mesh
    m_ax = "model"
    m = mesh.shape.get(m_ax, 1)
    E = cfg.n_experts
    B, S, d = x.shape
    dp_axes = rules.mapping.get("batch") or ()
    dp_axes = (dp_axes,) if isinstance(dp_axes, str) else tuple(dp_axes)
    dp = int(math.prod(mesh.shape[a] for a in dp_axes)) if dp_axes else 1

    batch_shardable = B % dp == 0 and dp > 1
    bspec = dp_axes if batch_shardable else None
    a2a = (E % m == 0) and (S % m == 0) and S > 1 and m > 1

    w_specs = {
        k: logical_to_spec(W_LOGICAL[k], p[k].shape, rules) for k in W_LOGICAL
    }
    all_axes = tuple(mesh.axis_names)

    if a2a:
        fn = partial(_moe_a2a_local, cfg, compute_dtype, m_ax, m, all_axes, w_specs)
        in_specs = (
            P(bspec, m_ax, None),
            P(None, None),
            w_specs["w_gate"],
            w_specs["w_up"],
            w_specs["w_down"],
        )
        out_specs = (P(bspec, m_ax, None), P())
    else:
        fn = partial(_moe_repl_local, cfg, compute_dtype, m_ax, m, all_axes, w_specs)
        in_specs = (
            P(bspec, None, None),
            P(None, None),
            w_specs["w_gate"],
            w_specs["w_up"],
            w_specs["w_down"],
        )
        out_specs = (P(bspec, None, None), P())

    y, aux = shard_map(fn, mesh, in_specs, out_specs)(
        x, p["router"], p["w_gate"], p["w_up"], p["w_down"]
    )
    return y, aux


def _moe_a2a_local(cfg, compute_dtype, m_ax, m, all_axes, w_specs,
                   xl, router, wg, wu, wd):
    """Per-device body, tokens sharded (dp x model): dispatch -> a2a ->
    expert mlp -> a2a back -> combine."""
    E, k = cfg.n_experts, cfg.top_k
    E_loc = E // m
    Bl, Sl, d = xl.shape
    T = Bl * Sl
    C = capacity(cfg, T)
    xf = xl.reshape(T, d)

    gates, idx, probs = _route(cfg, router, xf)
    flat_e, flat_pos, keep = _positions(idx, E, C)

    xr = jnp.broadcast_to(xf[:, None, :], (T, k, d)).reshape(T * k, d)
    buf = jnp.zeros((E, C, d), compute_dtype)
    buf = buf.at[flat_e, flat_pos].add(
        jnp.where(keep[:, None], xr, 0).astype(compute_dtype), mode="drop"
    )

    send = buf.reshape(m, E_loc, C, d)
    recv = jax.lax.all_to_all(send, m_ax, split_axis=0, concat_axis=0, tiled=False)
    x_e = recv.transpose(1, 0, 2, 3).reshape(E_loc, m * C, d)

    wg = _gather_fsdp(wg, w_specs["w_gate"], compute_dtype)
    wu = _gather_fsdp(wu, w_specs["w_up"], compute_dtype)
    wd = _gather_fsdp(wd, w_specs["w_down"], compute_dtype)
    out_e = _expert_mlp(x_e, wg, wu, wd)

    back = out_e.reshape(E_loc, m, C, d).transpose(1, 0, 2, 3)
    got = jax.lax.all_to_all(back, m_ax, split_axis=0, concat_axis=0, tiled=False)
    out = got.reshape(E, C, d)

    vals = out[flat_e, flat_pos]
    w = jnp.where(keep, gates.reshape(T * k), 0.0).astype(compute_dtype)
    y = (vals * w[:, None]).reshape(T, k, d).sum(axis=1).reshape(Bl, Sl, d)

    aux = jax.lax.pmean(_aux_loss(cfg, probs, idx), all_axes)
    return y, aux


def _moe_repl_local(cfg, compute_dtype, m_ax, m, all_axes, w_specs,
                    xl, router, wg, wu, wd):
    """Per-device body, tokens replicated over the model axis: each rank
    computes its E/m experts, outputs psum'd."""
    E, k = cfg.n_experts, cfg.top_k
    divisible = E % m == 0
    E_loc = E // m if divisible else E
    Bl, Sl, d = xl.shape
    T = Bl * Sl
    C = capacity(cfg, T)
    xf = xl.reshape(T, d)

    gates, idx, probs = _route(cfg, router, xf)
    flat_e, flat_pos, keep = _positions(idx, E, C)

    rank = jax.lax.axis_index(m_ax) if m > 1 else 0
    if divisible:
        e_start = rank * E_loc
        mine = keep & (flat_e >= e_start) & (flat_e < e_start + E_loc)
    else:  # experts unshardable: rank 0 computes everything (rare fallback)
        e_start = 0
        mine = keep & (rank == 0) if m > 1 else keep
    e_rel = jnp.clip(flat_e - e_start, 0, E_loc - 1)

    xr = jnp.broadcast_to(xf[:, None, :], (T, k, d)).reshape(T * k, d)
    buf = jnp.zeros((E_loc, C, d), compute_dtype)
    buf = buf.at[e_rel, flat_pos].add(
        jnp.where(mine[:, None], xr, 0).astype(compute_dtype), mode="drop"
    )

    wg = _gather_fsdp(wg, w_specs["w_gate"], compute_dtype)
    wu = _gather_fsdp(wu, w_specs["w_up"], compute_dtype)
    wd = _gather_fsdp(wd, w_specs["w_down"], compute_dtype)
    out = _expert_mlp(buf, wg, wu, wd)

    vals = out[e_rel, flat_pos]
    w = jnp.where(mine, gates.reshape(T * k), 0.0).astype(compute_dtype)
    y = (vals * w[:, None]).reshape(T, k, d).sum(axis=1)
    if m > 1:
        y = jax.lax.psum(y, m_ax)
    y = y.reshape(Bl, Sl, d)

    aux = jax.lax.pmean(_aux_loss(cfg, probs, idx), all_axes)
    return y, aux
