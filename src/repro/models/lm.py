"""Unified causal LM over pattern blocks: init / train forward / prefill /
decode for all 10 assigned architectures.

Layer stacks are ``jax.lax.scan``s over the repeating pattern (params stacked
along a leading ``reps`` axis), which keeps lowered-HLO size O(pattern) and
makes 256/512-device SPMD dry-run compiles tractable.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks
from repro.models.frontends import overlay_patches
from repro.models.layers import embed, embed_specs, rmsnorm, unembed
from repro.sharding.partition import (
    ParamSpec,
    abstract_from_specs,
    init_from_specs,
    map_specs,
    shardings_from_specs,
)

DEFAULT_COMPUTE = jnp.bfloat16

REMAT_POLICIES = {
    "full": None,  # save nothing, recompute everything
    "dots": "dots_saveable",
    "dots_no_batch": "dots_with_no_batch_dims_saveable",
}


def _stack(spec_tree, reps: int):
    def add_dim(s: ParamSpec) -> ParamSpec:
        return dataclasses.replace(s, shape=(reps,) + s.shape, logical=(None,) + s.logical)

    return map_specs(spec_tree, add_dim)


def param_specs(cfg: ModelConfig) -> Dict:
    pattern = tuple(
        _stack(blocks.layer_specs(cfg, s), cfg.pattern_reps) for s in cfg.pattern
    )
    remainder = tuple(blocks.layer_specs(cfg, s) for s in cfg.remainder)
    return {
        "embed": embed_specs(cfg),
        "pattern": pattern,
        "remainder": remainder,
        "final_norm": ParamSpec((cfg.d_model,), (None,), init="ones", dtype=jnp.float32),
    }


def init_params(cfg: ModelConfig, key, dtype=jnp.float32):
    return init_from_specs(param_specs(cfg), key, dtype)


def abstract_params(cfg: ModelConfig, dtype=jnp.float32):
    return abstract_from_specs(param_specs(cfg), dtype)


def param_shardings(cfg: ModelConfig):
    return shardings_from_specs(param_specs(cfg))


def cache_specs(
    cfg: ModelConfig,
    batch: int,
    cache_len: int,
    kv_dtype=jnp.bfloat16,
    compute_dtype=DEFAULT_COMPUTE,
    kv_repeat: int = 1,
) -> Dict:
    pattern = tuple(
        _stack(
            blocks.cache_specs_for_layer(
                cfg, s, batch, cache_len, kv_dtype, compute_dtype, kv_repeat
            ),
            cfg.pattern_reps,
        )
        for s in cfg.pattern
    )
    remainder = tuple(
        blocks.cache_specs_for_layer(
            cfg, s, batch, cache_len, kv_dtype, compute_dtype, kv_repeat
        )
        for s in cfg.remainder
    )
    return {"pattern": pattern, "remainder": remainder}


def init_cache(cfg, batch, cache_len, kv_dtype=jnp.bfloat16,
               compute_dtype=DEFAULT_COMPUTE, kv_repeat: int = 1):
    specs = cache_specs(cfg, batch, cache_len, kv_dtype, compute_dtype, kv_repeat)
    return map_specs(specs, lambda s: jnp.zeros(s.shape, s.dtype))


def abstract_cache(cfg, batch, cache_len, kv_dtype=jnp.bfloat16,
                   compute_dtype=DEFAULT_COMPUTE, kv_repeat: int = 1):
    return abstract_from_specs(
        cache_specs(cfg, batch, cache_len, kv_dtype, compute_dtype, kv_repeat), None
    )


def cache_shardings(cfg, batch, cache_len, kv_dtype=jnp.bfloat16,
                    compute_dtype=DEFAULT_COMPUTE, kv_repeat: int = 1):
    return shardings_from_specs(
        cache_specs(cfg, batch, cache_len, kv_dtype, compute_dtype, kv_repeat)
    )


# ------------------------------------------------------------------ forward
def _embed_inputs(cfg: ModelConfig, params, batch: Dict, compute_dtype):
    if cfg.frontend == "audio":
        x = batch["frame_embeds"].astype(compute_dtype)
    else:
        x = embed(cfg, params["embed"], batch["tokens"], compute_dtype)
        if cfg.frontend == "vision" and "patch_embeds" in batch:
            x = overlay_patches(x, batch["patch_embeds"].astype(compute_dtype))
    positions = batch.get("positions")
    if positions is None:
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    return x, positions


def _run_stack(
    cfg: ModelConfig,
    params,
    x,
    positions,
    *,
    mode: str,
    caches: Optional[Dict],
    pos,
    compute_dtype,
    remat: Optional[str],
    q_chunk: int,
    unroll: bool = False,
    unroll_inner: Optional[bool] = None,
    kv_repeat: int = 1,
    kv_dtype=None,
    kv_block: int = 2048,
    attn_stages: int = 1,
):
    inner = unroll if unroll_inner is None else unroll_inner
    apply = partial(
        blocks.apply_layer,
        cfg,
        positions=positions,
        mode=mode,
        pos=pos,
        compute_dtype=compute_dtype,
        q_chunk=q_chunk,
        unroll=inner,
        kv_repeat=kv_repeat,
        kv_dtype=kv_dtype,
        kv_block=kv_block,
        attn_stages=attn_stages,
    )
    scan_unroll = cfg.pattern_reps if unroll else 1

    if mode == "train":

        def body(x, p_rep):
            aux = jnp.zeros((), jnp.float32)
            for i, spec in enumerate(cfg.pattern):
                x, _, a = apply(spec, p_rep[i], x, cache=None)
                aux = aux + a
            return x, aux

        policy_name = REMAT_POLICIES.get(remat or "full")
        policy = getattr(jax.checkpoint_policies, policy_name) if policy_name else None
        body = jax.checkpoint(body, policy=policy)
        x, auxs = jax.lax.scan(body, x, params["pattern"], unroll=scan_unroll)
        aux = jnp.sum(auxs)
        new_caches = None
        for j, spec in enumerate(cfg.remainder):
            # remainder layers are rematted too (saving their attention
            # intermediates costs multiple GB/layer at 4k sequal batch)
            def rem_body(x, p_j, _spec=spec):
                x, _, a = apply(_spec, p_j, x, cache=None)
                return x, a

            x, a = jax.checkpoint(rem_body, policy=policy)(x, params["remainder"][j])
            aux = aux + a
    else:

        def body(x, xs):
            p_rep, cache_rep = xs
            new_c = []
            for i, spec in enumerate(cfg.pattern):
                c_in = None if cache_rep is None else cache_rep[i]
                x, c, _ = apply(spec, p_rep[i], x, cache=c_in)
                new_c.append(c)
            return x, tuple(new_c)

        if mode == "prefill":
            # no input caches: scan only over params, emit fresh caches
            def body_prefill(x, p_rep):
                return body(x, (p_rep, None))

            x, pat_caches = jax.lax.scan(
                body_prefill, x, params["pattern"], unroll=scan_unroll
            )
        else:
            x, pat_caches = jax.lax.scan(
                body, x, (params["pattern"], caches["pattern"]), unroll=scan_unroll
            )
        rem_caches = []
        for j, spec in enumerate(cfg.remainder):
            c_in = None if mode == "prefill" else caches["remainder"][j]
            x, c, _ = apply(spec, params["remainder"][j], x, cache=c_in)
            rem_caches.append(c)
        new_caches = {"pattern": pat_caches, "remainder": tuple(rem_caches)}
        aux = jnp.zeros((), jnp.float32)
    return x, new_caches, aux


def forward(
    cfg: ModelConfig,
    params,
    batch: Dict,
    *,
    mode: str = "train",
    caches: Optional[Dict] = None,
    pos=None,
    compute_dtype=DEFAULT_COMPUTE,
    remat: Optional[str] = None,
    q_chunk: int = 2048,
    logits_mode: str = "all",  # "all" | "last"
    unroll: bool = False,
    unroll_inner: Optional[bool] = None,
    kv_repeat: int = 1,
    kv_dtype=None,
    kv_block: int = 2048,
    attn_stages: int = 1,
) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """Returns (logits, new_caches, aux_loss)."""
    x, positions = _embed_inputs(cfg, params, batch, compute_dtype)
    x, new_caches, aux = _run_stack(
        cfg,
        params,
        x,
        positions,
        mode=mode,
        caches=caches,
        pos=pos,
        compute_dtype=compute_dtype,
        remat=remat,
        q_chunk=q_chunk,
        unroll=unroll,
        unroll_inner=unroll_inner,
        kv_repeat=kv_repeat,
        kv_dtype=kv_dtype,
        kv_block=kv_block,
        attn_stages=attn_stages,
    )
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if logits_mode == "last":
        x = x[:, -1:]
    logits = unembed(cfg, params["embed"], x, compute_dtype)
    return logits, new_caches, aux


def prefill(cfg, params, batch, **kw):
    return forward(cfg, params, batch, mode="prefill", logits_mode="last", **kw)


def decode_step(cfg, params, batch, caches, pos, **kw):
    """One token step. ``batch`` holds (B,1) tokens or (B,1,d) frame embeds;
    ``pos`` is the number of tokens already in the cache (scalar int32)."""
    B = (
        batch["frame_embeds"].shape[0]
        if cfg.frontend == "audio"
        else batch["tokens"].shape[0]
    )
    batch = dict(batch)
    batch.setdefault("positions", jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32))
    return forward(
        cfg, params, batch, mode="decode", caches=caches, pos=pos, logits_mode="last", **kw
    )
