"""Shared primitive layers: norms, rotary embeddings, gated MLP, and the
sharded embedding lookup."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.sharding.partition import (
    ParamSpec,
    constrain,
    current_rules,
    logical_to_spec,
)


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def rope_freqs(hd_half: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(hd_half, dtype=jnp.float32) / hd_half))


def apply_rope(
    x: jax.Array,  # (B, S, H, hd)
    positions: jax.Array,  # (B, S) int32 or (3, B, S) for M-RoPE
    theta: float,
    mrope: bool = False,
) -> jax.Array:
    """Half-rotation RoPE; M-RoPE splits the rotary half-dim into (t,h,w)
    sections of proportion (1/2, 1/4, 1/4) rotated by per-axis positions."""
    hd = x.shape[-1]
    half = hd // 2
    inv = rope_freqs(half, theta)  # (half,)
    if mrope:
        if positions.ndim == 2:  # text-only: reuse positions for all sections
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        s_t = half // 2
        s_h = (half - s_t) // 2
        s_w = half - s_t - s_h
        sect = jnp.concatenate(
            [
                jnp.zeros((s_t,), jnp.int32),
                jnp.ones((s_h,), jnp.int32),
                jnp.full((s_w,), 2, jnp.int32),
            ]
        )  # (half,) -> which position stream drives each freq
        # angles: (B, S, half)
        pos_sel = jnp.take(positions, sect, axis=0)  # (half bound into axis0)? ->
        # positions: (3,B,S); select per-freq stream -> (half, B, S)
        ang = pos_sel.astype(jnp.float32) * inv[:, None, None]
        ang = jnp.moveaxis(ang, 0, -1)  # (B, S, half)
    else:
        ang = positions.astype(jnp.float32)[..., None] * inv  # (B, S, half)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)  # (B,S,1,half)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------- gated MLP
def mlp_specs(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamSpec((d, f), ("fsdp", "model"), init="fanin"),
        "w_up": ParamSpec((d, f), ("fsdp", "model"), init="fanin"),
        "w_down": ParamSpec((f, d), ("model", "fsdp"), init="fanin"),
    }


def mlp(cfg: ModelConfig, p: dict, x: jax.Array, compute_dtype) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(compute_dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(compute_dtype))
    h = jax.nn.silu(h.astype(jnp.float32)).astype(compute_dtype) * u
    h = constrain(h, "batch", None, "model")
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(compute_dtype))


def embed_specs(cfg: ModelConfig) -> dict:
    s = {"tok": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "fsdp"))}
    if not cfg.tie_embeddings:
        s["unembed"] = ParamSpec((cfg.d_model, cfg.vocab_size), ("fsdp", "vocab"), init="fanin")
    return s


def _shardmap_lookup(rules, w, tokens, compute_dtype):
    """Masked lookup + psum over the vocab-sharding axis, via shard_map.

    A plain gather from a vocab-sharded table makes GSPMD replicate the full
    table ("involuntary full rematerialization", multi-GB transients); the
    explicit form moves only (B, S, d) activation bytes.
    """
    from repro.models.moe import shard_map  # shared wrapper

    mesh = rules.mesh
    wspec = logical_to_spec(("vocab", "fsdp"), w.shape, rules)
    tspec = logical_to_spec(("batch", None), tokens.shape, rules)
    v_axes = (wspec[0],) if isinstance(wspec[0], str) else tuple(wspec[0])

    def local(wl, tl):
        if wspec[1] is not None:
            fs = (wspec[1],) if isinstance(wspec[1], str) else tuple(wspec[1])
            for ax in fs:
                wl = jax.lax.all_gather(wl, ax, axis=1, tiled=True)
        wl = wl.astype(compute_dtype)
        Vl = wl.shape[0]
        rank = jax.lax.axis_index(v_axes[0])
        for ax in v_axes[1:]:
            rank = rank * mesh.shape[ax] + jax.lax.axis_index(ax)
        rel = tl - rank * Vl
        ok = (rel >= 0) & (rel < Vl)
        out = jnp.where(
            ok[..., None], jnp.take(wl, jnp.clip(rel, 0, Vl - 1), axis=0), 0
        )
        return jax.lax.psum(out, v_axes)

    out_spec = P(*(tuple(tspec) + (None,)))
    return shard_map(local, mesh, in_specs=(wspec, tspec), out_specs=out_spec)(
        w, tokens
    )


def embed(cfg: ModelConfig, p: dict, tokens: jax.Array, compute_dtype) -> jax.Array:
    w = p["tok"]
    rules = current_rules()
    vocab_sharded = (
        rules is not None
        and logical_to_spec(("vocab", "fsdp"), w.shape, rules)[0] is not None
    )
    if vocab_sharded:
        out = _shardmap_lookup(rules, w, tokens, compute_dtype)
    else:
        out = jnp.take(w.astype(compute_dtype), tokens, axis=0)
    if cfg.name.startswith("gemma"):
        out = out * jnp.asarray(cfg.d_model**0.5, compute_dtype)
    return constrain(out, "batch", None, None)


def unembed(cfg: ModelConfig, p: dict, x: jax.Array, compute_dtype) -> jax.Array:
    if cfg.tie_embeddings:
        w = p["tok"].astype(compute_dtype)  # (V, d)
        logits = jnp.einsum("bsd,vd->bsv", x, w)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, p["unembed"].astype(compute_dtype))
    return constrain(logits, "batch", None, "vocab")
