"""Mamba2 / SSD (state-space duality) blocks: chunked scan + O(1) decode.

Pure-jnp SSD implementation (chunk-parallel form of arXiv:2405.21060 listing
1); the Pallas ``ssd_scan`` kernel is the TPU deployment path validated
against this module.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import rmsnorm
from repro.sharding.partition import ParamSpec, constrain


def mamba_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    di, N, H, G, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_groups, cfg.conv_kernel
    conv_dim = di + 2 * G * N
    zdim = 2 * di + 2 * G * N + H
    if cfg.mamba_split_proj:
        # shard-aligned streams: no slicing of a sharded fused dim
        return {
            "w_z": ParamSpec((d, di), ("fsdp", "model"), init="fanin"),
            "w_x": ParamSpec((d, di), ("fsdp", "model"), init="fanin"),
            "w_B": ParamSpec((d, G * N), ("fsdp", "model"), init="fanin"),
            "w_C": ParamSpec((d, G * N), ("fsdp", "model"), init="fanin"),
            "w_dt": ParamSpec((d, H), ("fsdp", "model"), init="fanin"),
            "conv_x_w": ParamSpec((K, di), (None, "model"), init="normal"),
            "conv_x_b": ParamSpec((di,), ("model",), init="zeros"),
            "conv_B_w": ParamSpec((K, G * N), (None, "model"), init="normal"),
            "conv_B_b": ParamSpec((G * N,), ("model",), init="zeros"),
            "conv_C_w": ParamSpec((K, G * N), (None, "model"), init="normal"),
            "conv_C_b": ParamSpec((G * N,), ("model",), init="zeros"),
            "A_log": ParamSpec(
                (H,), (None,), dtype=jnp.float32,
                init_fn=lambda key, shape, dtype: jnp.log(
                    jax.random.uniform(key, shape, minval=1.0, maxval=16.0)
                ).astype(dtype),
            ),
            "D": ParamSpec((H,), (None,), init="ones", dtype=jnp.float32),
            "dt_bias": ParamSpec((H,), (None,), init="zeros", dtype=jnp.float32),
            "norm_w": ParamSpec((di,), ("model",), init="ones", dtype=jnp.float32),
            "out_proj": ParamSpec((di, d), ("model", "fsdp"), init="fanin"),
        }
    return {
        "in_proj": ParamSpec((d, zdim), ("fsdp", "model"), init="fanin"),
        "conv_w": ParamSpec((K, conv_dim), (None, "model"), init="normal"),
        "conv_b": ParamSpec((conv_dim,), ("model",), init="zeros"),
        "A_log": ParamSpec(
            (H,), (None,), dtype=jnp.float32,
            init_fn=lambda key, shape, dtype: jnp.log(
                jax.random.uniform(key, shape, minval=1.0, maxval=16.0)
            ).astype(dtype),
        ),
        "D": ParamSpec((H,), (None,), init="ones", dtype=jnp.float32),
        "dt_bias": ParamSpec((H,), (None,), init="zeros", dtype=jnp.float32),
        "norm_w": ParamSpec((di,), ("model",), init="ones", dtype=jnp.float32),
        "out_proj": ParamSpec((di, d), ("model", "fsdp"), init="fanin"),
    }


def segsum(x: jax.Array) -> jax.Array:
    """x: (..., T) -> (..., T, T) with out[i,j] = sum_{j < t <= i} x[t]; -inf above diag."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool))
    return jnp.where(mask, d, -jnp.inf)


def ssd(
    x: jax.Array,  # (b, s, h, p) — inputs already scaled by dt
    a: jax.Array,  # (b, s, h) — dt * A (negative)
    Bm: jax.Array,  # (b, s, g, n)
    Cm: jax.Array,  # (b, s, g, n)
    chunk: int,
    init_state: Optional[jax.Array] = None,  # (b, h, p, n)
) -> Tuple[jax.Array, jax.Array]:
    b, s, h, pdim = x.shape
    g, n = Bm.shape[-2:]
    chunk = min(chunk, s)
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    c = s // chunk
    rep = h // g

    xr = x.reshape(b, c, chunk, h, pdim)
    ar = a.reshape(b, c, chunk, h).transpose(0, 3, 1, 2).astype(jnp.float32)  # (b,h,c,l)
    Bh = jnp.repeat(Bm.reshape(b, c, chunk, g, n), rep, axis=3)  # (b,c,l,h,n)
    Ch = jnp.repeat(Cm.reshape(b, c, chunk, g, n), rep, axis=3)

    a_cs = jnp.cumsum(ar, axis=-1)  # (b,h,c,l)

    # 1. intra-chunk (diagonal) term
    L = jnp.exp(segsum(ar)).astype(x.dtype)  # (b,h,c,l,l)
    Y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp", Ch, Bh, L, xr)

    # 2. per-chunk final states
    decay_states = jnp.exp(a_cs[..., -1:] - a_cs).astype(x.dtype)  # (b,h,c,l)
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn", Bh, decay_states, xr)

    # 3. inter-chunk recurrence
    if init_state is None:
        init_state = jnp.zeros((b, h, pdim, n), states.dtype)
    states = jnp.concatenate([init_state[:, None], states], axis=1)  # (b,c+1,h,p,n)
    chunk_sum = a_cs[..., -1]  # (b,h,c)
    padded = jnp.pad(chunk_sum, ((0, 0), (0, 0), (1, 0)))
    decay_chunk = jnp.exp(segsum(padded)).astype(x.dtype)  # (b,h,c+1,c+1)
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", decay_chunk, states)
    states_in, final_state = new_states[:, :-1], new_states[:, -1]

    # 4. state -> output
    state_decay = jnp.exp(a_cs).astype(x.dtype)  # (b,h,c,l)
    Y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp", Ch, states_in, state_decay)

    return (Y_diag + Y_off).reshape(b, s, h, pdim), final_state


def _split_zxbcdt(cfg: ModelConfig, zxbcdt: jax.Array):
    di, N, G, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : di + di + 2 * G * N]
    dt = zxbcdt[..., di + di + 2 * G * N :]
    return z, xBC, dt


def _causal_conv(xs, w, b, K, S, compute_dtype):
    pad = jnp.pad(xs, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + S, :] * w[i].astype(compute_dtype) for i in range(K))
    out = out + b.astype(compute_dtype)
    return jax.nn.silu(out.astype(jnp.float32)).astype(compute_dtype), pad[:, -(K - 1) :, :]


def _gated_out(cfg, p, y, z, compute_dtype):
    # RMSNorm(y) * silu(z), then output projection
    y = rmsnorm(y, p["norm_w"], cfg.norm_eps) * jax.nn.silu(
        z.astype(jnp.float32)
    ).astype(compute_dtype)
    return jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(compute_dtype))


def mamba_full(
    cfg: ModelConfig,
    p: Dict,
    x: jax.Array,  # (B, S, d)
    compute_dtype,
    return_cache: bool = False,
) -> Tuple[jax.Array, Optional[Dict]]:
    B, S, _ = x.shape
    di, N, G, H, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_heads, cfg.conv_kernel
    P = cfg.ssm_head_dim

    if cfg.mamba_split_proj:
        z = jnp.einsum("bsd,dz->bsz", x, p["w_z"].astype(compute_dtype))
        xs = jnp.einsum("bsd,dz->bsz", x, p["w_x"].astype(compute_dtype))
        Bs = jnp.einsum("bsd,dz->bsz", x, p["w_B"].astype(compute_dtype))
        Cs = jnp.einsum("bsd,dz->bsz", x, p["w_C"].astype(compute_dtype))
        dt = jnp.einsum("bsd,dz->bsz", x, p["w_dt"].astype(compute_dtype))
        xs, pad_x = _causal_conv(xs, p["conv_x_w"], p["conv_x_b"], K, S, compute_dtype)
        Bs, pad_B = _causal_conv(Bs, p["conv_B_w"], p["conv_B_b"], K, S, compute_dtype)
        Cs, pad_C = _causal_conv(Cs, p["conv_C_w"], p["conv_C_b"], K, S, compute_dtype)
        x_in = constrain(xs.reshape(B, S, H, P), "batch", None, "heads", None)
        Bm = Bs.reshape(B, S, G, N)
        Cm = Cs.reshape(B, S, G, N)
    else:
        zxbcdt = jnp.einsum("bsd,dz->bsz", x, p["in_proj"].astype(compute_dtype))
        z, xBC, dt = _split_zxbcdt(cfg, zxbcdt)

        # causal depthwise conv over (x, B, C) features
        pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
        conv = sum(
            pad[:, i : i + S, :] * p["conv_w"][i].astype(compute_dtype) for i in range(K)
        ) + p["conv_b"].astype(compute_dtype)
        conv = jax.nn.silu(conv.astype(jnp.float32)).astype(compute_dtype)

        x_in = conv[..., :di].reshape(B, S, H, P)
        x_in = constrain(x_in, "batch", None, "heads", None)
        Bm = conv[..., di : di + G * N].reshape(B, S, G, N)
        Cm = conv[..., di + G * N :].reshape(B, S, G, N)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])  # (H,)

    y, final_state = ssd(
        x_in * dt[..., None].astype(compute_dtype),
        dt * A,
        Bm,
        Cm,
        cfg.ssm_chunk,
    )
    y = y + x_in * p["D"].astype(compute_dtype)[:, None]
    out = _gated_out(cfg, p, y.reshape(B, S, di), z, compute_dtype)
    out = constrain(out, "batch", None, None)

    cache = None
    if return_cache:
        cache = {"ssm": constrain(final_state.astype(jnp.float32),
                                  "batch", "heads", None, None)}
        if cfg.mamba_split_proj:
            cache["conv_x"] = pad_x.astype(compute_dtype)
            cache["conv_B"] = pad_B.astype(compute_dtype)
            cache["conv_C"] = pad_C.astype(compute_dtype)
        else:
            cache["conv"] = pad[:, -(K - 1) :, :].astype(compute_dtype)
    return out, cache


def mamba_decode(
    cfg: ModelConfig,
    p: Dict,
    x: jax.Array,  # (B, 1, d)
    cache: Dict,  # {"ssm": (B,H,P,N) f32, "conv": (B,K-1,conv_dim)}
    compute_dtype,
) -> Tuple[jax.Array, Dict]:
    B = x.shape[0]
    di, N, G, H, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_heads, cfg.conv_kernel
    P = cfg.ssm_head_dim

    def conv_step(feat, state, w, b):
        win = jnp.concatenate([state, feat[:, None]], axis=1)  # (B, K, c)
        out = jnp.einsum("bkc,kc->bc", win, w.astype(compute_dtype)) + b.astype(
            compute_dtype
        )
        return jax.nn.silu(out.astype(jnp.float32)).astype(compute_dtype), win[:, 1:]

    new_conv = {}
    if cfg.mamba_split_proj:
        z = jnp.einsum("bsd,dz->bsz", x, p["w_z"].astype(compute_dtype))
        xs = jnp.einsum("bsd,dz->bsz", x, p["w_x"].astype(compute_dtype))[:, 0]
        Bs = jnp.einsum("bsd,dz->bsz", x, p["w_B"].astype(compute_dtype))[:, 0]
        Cs = jnp.einsum("bsd,dz->bsz", x, p["w_C"].astype(compute_dtype))[:, 0]
        dt = jnp.einsum("bsd,dz->bsz", x, p["w_dt"].astype(compute_dtype))
        xs, new_conv["conv_x"] = conv_step(xs, cache["conv_x"], p["conv_x_w"], p["conv_x_b"])
        Bs, new_conv["conv_B"] = conv_step(Bs, cache["conv_B"], p["conv_B_w"], p["conv_B_b"])
        Cs, new_conv["conv_C"] = conv_step(Cs, cache["conv_C"], p["conv_C_w"], p["conv_C_b"])
        x_in = xs.reshape(B, H, P)
        Bm = Bs.reshape(B, G, N)
        Cm = Cs.reshape(B, G, N)
    else:
        zxbcdt = jnp.einsum("bsd,dz->bsz", x, p["in_proj"].astype(compute_dtype))
        z, xBC, dt = _split_zxbcdt(cfg, zxbcdt)
        xBC = xBC[:, 0]  # (B, conv_dim)
        conv, new_conv["conv"] = conv_step(xBC, cache["conv"], p["conv_w"], p["conv_b"])
        x_in = conv[:, :di].reshape(B, H, P)
        Bm = conv[:, di : di + G * N].reshape(B, G, N)
        Cm = conv[:, di + G * N :].reshape(B, G, N)
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1)  # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1)

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)  # (B,H)

    state = cache["ssm"]  # (B,H,P,N) f32
    upd = jnp.einsum(
        "bh,bhn,bhp->bhpn", dt, Bh.astype(jnp.float32), x_in.astype(jnp.float32)
    )
    state = state * dA[..., None, None] + upd
    state = constrain(state, "batch", "heads", None, None)

    y = jnp.einsum("bhpn,bhn->bhp", state.astype(compute_dtype), Ch)
    y = y + x_in * p["D"].astype(compute_dtype)[:, None]
    out = _gated_out(cfg, p, y.reshape(B, 1, di), z, compute_dtype)
    return out, {"ssm": state, **new_conv}
