"""Modality frontends — STUBS per the assignment: ``input_specs()`` provides
precomputed patch/frame embeddings; only the transformer backbone is real."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def overlay_patches(x: jax.Array, patch_embeds: jax.Array) -> jax.Array:
    """Overlay vision patch embeddings on the sequence front (VLM stub)."""
    P = patch_embeds.shape[1]
    return jnp.concatenate([x[:, :P] + patch_embeds, x[:, P:]], axis=1)


def make_patch_embeds(key, batch: int, n_patches: int, d_model: int, dtype=jnp.bfloat16):
    return (jax.random.normal(key, (batch, n_patches, d_model)) * 0.02).astype(dtype)


def make_frame_embeds(key, batch: int, seq: int, d_model: int, dtype=jnp.bfloat16):
    """EnCodec frame embeddings stub (audio decoder input)."""
    return (jax.random.normal(key, (batch, seq, d_model)) * 0.02).astype(dtype)


def mrope_positions(batch: int, seq: int, n_patches: int, grid: int = 16) -> np.ndarray:
    """(3, B, S) t/h/w position ids: image tokens get a 2-D grid at t=0;
    text tokens get equal t=h=w positions (qwen2-vl convention, stubbed)."""
    t = np.arange(seq, dtype=np.int32)
    h = t.copy()
    w = t.copy()
    n = min(n_patches, seq)
    ij = np.arange(n, dtype=np.int32)
    t[:n] = 0
    h[:n] = ij // grid
    w[:n] = ij % grid
    # text positions continue after the image box
    off = int(max(grid, grid)) - n
    t[n:] = np.arange(seq - n, dtype=np.int32) + grid
    h[n:] = t[n:]
    w[n:] = t[n:]
    pos = np.stack([t, h, w])  # (3, S)
    return np.broadcast_to(pos[:, None, :], (3, batch, seq)).copy()
