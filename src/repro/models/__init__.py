from repro.models import lm

__all__ = ["lm"]
