from repro.sharding.partition import (
    ParamSpec,
    axis_rules,
    constrain,
    current_rules,
    logical_to_spec,
    named_sharding,
)

__all__ = [
    "ParamSpec",
    "axis_rules",
    "constrain",
    "current_rules",
    "logical_to_spec",
    "named_sharding",
]
