"""Logical-axis sharding: models annotate *logical* axes; the launch layer
binds them to mesh axes via rules.

Outside a rules context every annotation is a no-op, so smoke tests and
benchmarks run single-device with zero overhead.  Divisibility is checked at
binding time: a logical axis whose dimension does not divide the mesh-axis
extent falls back to replication (e.g. mamba2's vocab of 50280 on a 16-way
``model`` axis), which keeps every (arch x mesh) cell lowerable.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

_STATE = threading.local()


@dataclasses.dataclass
class _Rules:
    mesh: Mesh
    mapping: Dict[str, MeshAxes]


def current_rules() -> Optional[_Rules]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def axis_rules(mesh: Mesh, mapping: Dict[str, MeshAxes]):
    prev = current_rules()
    _STATE.rules = _Rules(mesh, dict(mapping))
    try:
        yield
    finally:
        _STATE.rules = prev


def _axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def logical_to_spec(
    logical: Sequence[Optional[str]],
    shape: Optional[Sequence[int]] = None,
    rules: Optional[_Rules] = None,
) -> P:
    """Map a tuple of logical axis names to a PartitionSpec under the rules.

    If ``shape`` is given, any axis whose dim is not divisible by the bound
    mesh extent is replicated instead (with no error), and mesh axes are never
    used twice in one spec (first logical axis wins).
    """
    rules = rules or current_rules()
    if rules is None:
        return P(*([None] * len(logical)))
    used: set = set()
    out = []
    for i, name in enumerate(logical):
        axes = rules.mapping.get(name) if name else None
        if axes is None:
            out.append(None)
            continue
        ax_tuple = (axes,) if isinstance(axes, str) else tuple(axes)
        ax_tuple = tuple(a for a in ax_tuple if a not in used)
        if not ax_tuple:
            out.append(None)
            continue
        size = _axis_size(rules.mesh, ax_tuple)
        if shape is not None and shape[i] % size != 0:
            # try a prefix of the axes that divides
            while ax_tuple and shape[i] % _axis_size(rules.mesh, ax_tuple) != 0:
                ax_tuple = ax_tuple[:-1]
            if not ax_tuple:
                out.append(None)
                continue
        used.update(ax_tuple)
        out.append(ax_tuple[0] if len(ax_tuple) == 1 else ax_tuple)
    return P(*out)


def named_sharding(logical: Sequence[Optional[str]], shape=None) -> Optional[NamedSharding]:
    rules = current_rules()
    if rules is None:
        return None
    return NamedSharding(rules.mesh, logical_to_spec(logical, shape, rules))


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint against the active rules (no-op without)."""
    rules = current_rules()
    if rules is None:
        return x
    spec = logical_to_spec(logical, x.shape, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


@dataclasses.dataclass
class ParamSpec:
    """Single source of truth for one parameter tensor."""

    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | small_normal | custom
    dtype: Any = None  # overrides model default (e.g. f32 for norms)
    init_fn: Optional[Callable] = None

    def initialize(self, key, default_dtype):
        import jax.numpy as jnp

        dtype = self.dtype or default_dtype
        if self.init_fn is not None:
            return self.init_fn(key, self.shape, dtype)
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        scale = 0.02 if self.init == "normal" else 0.006
        fanin_last2 = self.init == "fanin"
        if fanin_last2 and len(self.shape) >= 2:
            scale = self.shape[-2] ** -0.5
        return (jax.random.normal(key, self.shape) * scale).astype(dtype)


def map_specs(specs, fn):
    """Apply fn to every ParamSpec leaf of a nested structure."""
    if isinstance(specs, ParamSpec):
        return fn(specs)
    if isinstance(specs, dict):
        return {k: map_specs(v, fn) for k, v in specs.items()}
    if isinstance(specs, (list, tuple)):
        return type(specs)(map_specs(v, fn) for v in specs)
    return specs


def init_from_specs(specs, key, dtype):
    """Materialize parameters from a ParamSpec tree with per-leaf keys."""
    leaves = []

    def collect(s):
        leaves.append(s)
        return s

    map_specs(specs, collect)
    keys = jax.random.split(key, max(len(leaves), 1))
    it = iter(range(len(leaves)))

    def build(s: ParamSpec):
        i = next(it)
        return s.initialize(keys[i], dtype)

    return map_specs(specs, build)


def abstract_from_specs(specs, dtype):
    import jax.numpy as jnp

    def build(s: ParamSpec):
        return jax.ShapeDtypeStruct(s.shape, s.dtype or dtype)

    return map_specs(specs, build)


def shardings_from_specs(specs, dtype=None):
    """NamedSharding tree for the current rules (None tree without rules)."""

    def build(s: ParamSpec):
        return named_sharding(s.logical, s.shape)

    return map_specs(specs, build)
