"""Deterministic synthetic LM data pipeline: seeded, shardable per host,
restartable from a step offset (checkpoint/restart needs the iterator state
to be part of the training state)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


class SyntheticLM:
    """Zipf-ish token streams with next-token structure (shift targets).

    Deterministic in (seed, step, host): any host can reproduce any step,
    which is what makes elastic re-sharding and restart trivial.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        assert cfg.global_batch % cfg.n_hosts == 0
        self.local_batch = cfg.global_batch // cfg.n_hosts

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.RandomState((c.seed * 1_000_003 + step) % 2**31)
        # zipf-ish marginal over the vocab, then a deterministic shift map
        z = rng.zipf(1.3, size=(c.global_batch, c.seq_len + 1)) % c.vocab_size
        toks = z.astype(np.int32)
        lo = self.cfg.host_id * self.local_batch
        hi = lo + self.local_batch
        return {"tokens": toks[lo:hi, :-1], "targets": toks[lo:hi, 1:]}

    def iter_from(self, step: int) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch_at(step)
            step += 1
