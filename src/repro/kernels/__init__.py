"""Pallas TPU kernels for the perf-critical layers.

Each kernel package ships: ``kernel.py`` (pl.pallas_call + BlockSpec VMEM
tiling), ``ops.py`` (jit'd public wrapper), ``ref.py`` (pure-jnp oracle).
Kernels are validated on CPU with ``interpret=True`` against the oracles;
they are the TPU deployment path (the dry-run lowers the pure-jnp path so
roofline terms come from clean XLA HLO).

- overlay_patch:    the paper's Overlay-VMA mechanism on device
- flash_attention:  causal/windowed tiled attention (prefill/train)
- decode_attention: flash-decoding over KV blocks w/ GQA + int8 KV
- ssd_scan:         Mamba2 chunked state-space scan
"""
