from repro.kernels.overlay_patch.ops import (
    compact_plan_from_itable,
    overlay_patch,
    overlay_patch_device,
    plan_from_itable,
)

__all__ = [
    "overlay_patch",
    "overlay_patch_device",
    "plan_from_itable",
    "compact_plan_from_itable",
]
