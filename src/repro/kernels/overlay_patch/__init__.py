from repro.kernels.overlay_patch.ops import overlay_patch

__all__ = ["overlay_patch"]
