"""Pure-jnp oracle for the overlay patch kernel."""
from __future__ import annotations

import jax.numpy as jnp

KIND_ZERO, KIND_BASE, KIND_PRIVATE = 0, 1, 2


def overlay_patch_ref(base, priv, kinds, src):
    n_pages, page = base.shape
    priv = priv if priv.shape[0] else jnp.zeros((1, page), priv.dtype)
    gathered = priv[jnp.clip(src, 0, priv.shape[0] - 1)]
    kinds = kinds[:, None]
    return jnp.where(
        kinds == KIND_PRIVATE,
        gathered,
        jnp.where(kinds == KIND_BASE, base, jnp.zeros_like(base)),
    )
