"""Overlay patch kernel — the Overlay-VMA mechanism as a TPU kernel.

Materializes a restored tensor from (a) a device-resident shared BASE image,
(b) a sparse stream of PRIVATE pages fetched from the snapshot, and (c)
implicit ZERO pages, according to a per-page classification table — in one
pass, on device.

TPU adaptation: the kernel-side analogue of installing PTEs from the
pre-balanced B-tree.  The page->source table rides in scalar-prefetch SMEM
so each grid step's BlockSpec ``index_map`` *chooses which private page to
stream into VMEM* (pages classified BASE/ZERO fetch an arbitrary clamped
private block but never read it — select masks it out).  One grid step =
one page; page size is the VMEM tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

KIND_ZERO, KIND_BASE, KIND_PRIVATE = 0, 1, 2


def _kernel(kinds_ref, src_ref, base_ref, priv_ref, out_ref):
    i = pl.program_id(0)
    kind = kinds_ref[i]
    base_page = base_ref[...]
    priv_page = priv_ref[...]
    zero = jnp.zeros_like(base_page)
    out_ref[...] = jnp.where(
        kind == KIND_PRIVATE, priv_page, jnp.where(kind == KIND_BASE, base_page, zero)
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def overlay_patch_kernel(
    base: jax.Array,  # (n_pages, page_elems) device-resident shared image
    priv: jax.Array,  # (n_priv, page_elems) private pages from the snapshot
    kinds: jax.Array,  # (n_pages,) int32 {ZERO, BASE, PRIVATE}
    src: jax.Array,  # (n_pages,) int32 private-page index (PRIVATE only)
    interpret: bool = False,
) -> jax.Array:
    n_pages, page = base.shape
    n_priv = max(priv.shape[0], 1)
    priv = priv if priv.shape[0] else jnp.zeros((1, page), priv.dtype)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # kinds, src ride in SMEM ahead of the grid
        grid=(n_pages,),
        in_specs=[
            pl.BlockSpec((1, page), lambda i, kinds, src: (i, 0)),
            # data-dependent streaming: which private page lands in VMEM
            pl.BlockSpec(
                (1, page),
                lambda i, kinds, src: (jnp.clip(src[i], 0, n_priv - 1), 0),
            ),
        ],
        out_specs=pl.BlockSpec((1, page), lambda i, kinds, src: (i, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_pages, page), base.dtype),
        interpret=interpret,
    )(kinds.astype(jnp.int32), src.astype(jnp.int32), base, priv)
