"""Public wrapper: restore a flat tensor from base/private/zero pages.

Also provides ``plan_from_itable`` to turn a JIF IntervalTable into the
dense (kinds, src) page tables the kernel consumes (built once at restore,
host-side — the "pre-balanced B-tree slotted directly in", §4.2).

Two plan flavors exist because the two restore paths stage private pages
differently:

* :func:`plan_from_itable` keeps ``src`` as ABSOLUTE data-segment chunk
  offsets — what a caller holding the whole data segment indexes with.
* :func:`compact_plan_from_itable` renumbers private pages 0..n_priv-1 in
  page order — what the device fast path uploads: the restorer reads ONLY
  the private chunks into a compact staging buffer (no intermediate full
  host tensor) and the kernel gathers from that dense array.

:func:`overlay_patch_device` is the serving-path entry: the Pallas kernel
on TPU, a jitted version of the pure-jnp oracle on CPU (interpret-mode
Pallas executes one Python step per page — far too slow for restores).
"""
from __future__ import annotations

from functools import lru_cache
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.overlay import KIND_PRIVATE, IntervalTable
from repro.kernels.overlay_patch.kernel import overlay_patch_kernel
from repro.kernels.overlay_patch.ref import overlay_patch_ref


def plan_from_itable(table: IntervalTable) -> Tuple[np.ndarray, np.ndarray]:
    n = table.n_pages
    kinds = np.zeros((n,), np.int32)
    src = np.zeros((n,), np.int32)
    for start, count, kind, s in table.table:
        kinds[start : start + count] = kind
        if kind == KIND_PRIVATE:
            src[start : start + count] = np.arange(s, s + count)
    return kinds, src


def compact_plan_from_itable(
    table: IntervalTable,
) -> Tuple[np.ndarray, np.ndarray, List[Tuple[int, int, int]], int]:
    """(kinds, src, runs, n_priv) with ``src`` indexing a COMPACT private
    array: private pages are numbered 0..n_priv-1 in page order.  ``runs``
    is the read plan — (compact_slot, data_chunk, count) per private run —
    mapping the JIF data segment onto the compact staging buffer."""
    n = table.n_pages
    kinds = np.zeros((n,), np.int32)
    src = np.zeros((n,), np.int32)
    runs: List[Tuple[int, int, int]] = []
    k = 0
    for start, count, kind, s in table.table:
        kinds[start : start + count] = kind
        if kind == KIND_PRIVATE:
            src[start : start + count] = np.arange(k, k + count)
            runs.append((k, int(s), int(count)))
            k += count
    return kinds, src, runs, k


def overlay_patch(
    base: jax.Array,
    priv: jax.Array,
    kinds: jax.Array,
    src: jax.Array,
    interpret: bool = False,
) -> jax.Array:
    """(n_pages, page_elems) patched output on device."""
    return overlay_patch_kernel(base, priv, kinds, src, interpret=interpret)


@lru_cache(maxsize=1)
def _ref_jit():
    return jax.jit(overlay_patch_ref)


def overlay_patch_device(
    base: jax.Array,
    priv: jax.Array,
    kinds: jax.Array,
    src: jax.Array,
) -> jax.Array:
    """Serving-path overlay patch: one fused on-device pass, dispatched by
    backend.  TPU runs the Pallas kernel (scalar-prefetch page table in
    SMEM); every other backend runs the jitted oracle — same math, same
    output, compiled gather instead of per-page interpret steps."""
    if jax.default_backend() == "tpu":
        return overlay_patch_kernel(base, priv, kinds, src)
    return _ref_jit()(base, priv, kinds, src)
