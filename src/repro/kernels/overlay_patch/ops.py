"""Public wrapper: restore a flat tensor from base/private/zero pages.

Also provides ``plan_from_itable`` to turn a JIF IntervalTable into the
dense (kinds, src) page tables the kernel consumes (built once at restore,
host-side — the "pre-balanced B-tree slotted directly in", §4.2)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.overlay import KIND_PRIVATE, IntervalTable
from repro.kernels.overlay_patch.kernel import overlay_patch_kernel


def plan_from_itable(table: IntervalTable) -> Tuple[np.ndarray, np.ndarray]:
    n = table.n_pages
    kinds = np.zeros((n,), np.int32)
    src = np.zeros((n,), np.int32)
    for start, count, kind, s in table.table:
        kinds[start : start + count] = kind
        if kind == KIND_PRIVATE:
            src[start : start + count] = np.arange(s, s + count)
    return kinds, src


def overlay_patch(
    base: jax.Array,
    priv: jax.Array,
    kinds: jax.Array,
    src: jax.Array,
    interpret: bool = False,
) -> jax.Array:
    """(n_pages, page_elems) patched output on device."""
    return overlay_patch_kernel(base, priv, kinds, src, interpret=interpret)
