"""Pure-jnp oracle: dense masked softmax attention with GQA + window."""
from __future__ import annotations

import jax.numpy as jnp
import jax


def flash_attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    B, H, S, hd = q.shape
    kvH = k.shape[1]
    G = H // kvH
    scale = hd**-0.5 if scale is None else scale
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32)).astype(q.dtype)
