"""Tiled causal/windowed flash attention (online softmax).

Grid: (batch, q_heads, q_blocks, kv_blocks); the kv axis is innermost, so
the running (m, l, acc) scratch in VMEM persists across kv steps of one
(b, h, i) tile — the canonical TPU flash structure.  GQA is handled by the
k/v index_map (q head -> kv head), window masks by absolute positions.
MXU alignment: block shapes should be multiples of (128, 128) on real
hardware; interpret mode relaxes this for CPU validation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale,
            causal, window, bq, bk, nk):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)  # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq, bk)
    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]  # (bq, 1)
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention_kernel(
    q: jax.Array,  # (B, H, S, hd)
    k: jax.Array,  # (B, kvH, S, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window=None,
    scale=None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, S, hd = q.shape
    kvH = k.shape[1]
    G = H // kvH
    bq = min(block_q, S)
    bk = min(block_k, S)
    assert S % bq == 0 and S % bk == 0
    nq, nk = S // bq, S // bk
    scale = hd**-0.5 if scale is None else scale

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, bq=bq, bk=bk, nk=nk
    )
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
