"""Public jit'd wrapper for the flash attention kernel."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention_kernel


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window=None,
    scale=None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """(B, H, S, hd) x (B, kvH, S, hd)^2 -> (B, H, S, hd); GQA when kvH < H."""
    return flash_attention_kernel(
        q, k, v, causal=causal, window=window, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
