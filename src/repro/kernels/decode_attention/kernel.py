"""Flash-decoding kernel: one query token vs a long KV cache.

Grid: (batch, kv_heads, kv_blocks) — kv blocks innermost so the running
(m, l, acc) scratch persists per (b, kvh).  GQA queries for one kv head
ride together as a (G, hd) tile (G = H/kvH), so the MXU sees a skinny
matmul per block instead of G vector products.  Valid-length masking uses
the scalar-prefetched ``pos`` (ring caches: all slots valid once full —
slot p%W invariant is maintained by the cache writer).  Supports int8 KV
with per-slot scales (dequantized block-wise in VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
            m_ref, l_ref, acc_ref, *, bk, nk, scale, quantized):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)  # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    if quantized:
        k = k * ks_ref[0, 0].astype(jnp.float32)[:, None]
        v = v * vs_ref[0, 0].astype(jnp.float32)[:, None]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (G, bk)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    s = jnp.where(kpos <= pos_ref[0], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "scale", "interpret"))
def decode_attention_kernel(
    q: jax.Array,  # (B, H, hd) single token
    k: jax.Array,  # (B, kvH, Sc, hd) — bf16 or int8
    v: jax.Array,
    pos: jax.Array,  # scalar int32: last valid absolute position
    k_scale=None,  # (B, kvH, Sc) for int8 KV
    v_scale=None,
    *,
    block_k: int = 512,
    scale=None,
    interpret: bool = False,
) -> jax.Array:
    B, H, hd = q.shape
    _, kvH, Sc, _ = k.shape
    G = H // kvH
    bk = min(block_k, Sc)
    assert Sc % bk == 0
    nk = Sc // bk
    scale = hd**-0.5 if scale is None else scale
    quantized = k.dtype == jnp.int8
    if not quantized:
        k_scale = jnp.zeros((B, kvH, Sc), jnp.float32)
        v_scale = jnp.zeros((B, kvH, Sc), jnp.float32)

    qg = q.reshape(B, kvH, G, hd)
    kernel = functools.partial(
        _kernel, bk=bk, nk=nk, scale=scale, quantized=quantized
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # pos
        grid=(B, kvH, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j, pos: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, j, pos: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, j, pos: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk), lambda b, h, j, pos: (b, h, j)),
            pl.BlockSpec((1, 1, bk), lambda b, h, j, pos: (b, h, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, j, pos: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, kvH, G, hd), q.dtype),
        interpret=interpret,
    )(pos.reshape(1).astype(jnp.int32), qg, k, v, k_scale, v_scale)
    return out.reshape(B, H, hd)
