"""Public jit'd wrapper for the decode attention kernel."""
from __future__ import annotations

import jax

from repro.kernels.decode_attention.kernel import decode_attention_kernel


def decode_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    pos: jax.Array,
    k_scale=None,
    v_scale=None,
    *,
    block_k: int = 512,
    scale=None,
    interpret: bool = False,
) -> jax.Array:
    """(B,H,hd) query vs (B,kvH,Sc,hd) cache -> (B,H,hd)."""
    return decode_attention_kernel(
        q, k, v, pos, k_scale, v_scale,
        block_k=block_k, scale=scale, interpret=interpret,
    )
