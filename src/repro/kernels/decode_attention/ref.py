"""Pure-jnp oracle for flash decoding (matches models/attention.attn_decode
math: masked softmax over the cache with optional int8 dequant)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k, v, pos, k_scale=None, v_scale=None, scale=None):
    B, H, hd = q.shape
    _, kvH, Sc, _ = k.shape
    G = H // kvH
    scale = hd**-0.5 if scale is None else scale
    if k.dtype == jnp.int8:
        k = k.astype(jnp.float32) * k_scale[..., None]
        v = v.astype(jnp.float32) * v_scale[..., None]
    qg = q.reshape(B, kvH, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bksh->bkgs", qg, k.astype(jnp.float32)) * scale
    valid = jnp.arange(Sc) <= pos
    s = jnp.where(valid[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bksh->bkgh", w, v.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)
