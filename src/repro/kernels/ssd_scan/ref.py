"""Oracle: the models/mamba2.ssd chunked implementation (itself validated
against step-by-step recurrence in the smoke tests)."""
from __future__ import annotations

from repro.models.mamba2 import ssd


def ssd_scan_ref(x, a, Bm, Cm, chunk=128):
    # models.mamba2.ssd takes a as (b, s, h); the kernel takes (b, h, s)
    return ssd(x, a.transpose(0, 2, 1), Bm, Cm, chunk)
