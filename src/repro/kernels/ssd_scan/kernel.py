"""Mamba2 SSD chunked scan kernel.

Grid: (batch, heads, chunks) — chunks innermost so the inter-chunk SSM
state (P, N) lives in VMEM scratch across the sequential chunk axis.  Each
step computes the intra-chunk (quadratic, MXU-friendly) term and folds the
carried state in, exactly the chunked decomposition of arXiv:2405.21060:

  Y[c]      = (C L C^T-masked) x[c]  +  C state_in decay
  state_out = state_in * exp(sum a)  +  (B * decay_states)^T x[c]

Inputs are pre-scaled on the host side of the op (x*dt, a=dt*A), keeping
the kernel purely tensor-algebraic.  B/C groups broadcast to heads via the
BlockSpec index_map (h -> h // rep).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, a_ref, b_ref, c_ref, y_ref, st_out_ref, state_ref, *, nc):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0][:, 0, :].astype(jnp.float32)  # (l, P)
    a = a_ref[0, 0].astype(jnp.float32)  # (l,)
    Bm = b_ref[0][:, 0, :].astype(jnp.float32)  # (l, N)
    Cm = c_ref[0][:, 0, :].astype(jnp.float32)  # (l, N)
    l = x.shape[0]

    a_cs = jnp.cumsum(a)  # (l,)
    # L[i,j] = exp(sum_{j<t<=i} a_t) for j<=i
    seg = a_cs[:, None] - a_cs[None, :]
    tril = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0) >= jax.lax.broadcasted_iota(
        jnp.int32, (l, l), 1
    )
    L = jnp.where(tril, jnp.exp(seg), 0.0)

    # intra-chunk: ((C B^T) * L) @ x
    scores = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32) * L  # (l, l)
    y = jnp.dot(scores, x, preferred_element_type=jnp.float32)  # (l, P)

    # inter-chunk contribution from the carried state
    state = state_ref[...]  # (P, N)
    y += jnp.dot(Cm, state.T, preferred_element_type=jnp.float32) * jnp.exp(a_cs)[
        :, None
    ]

    # state update
    decay = jnp.exp(a_cs[-1] - a_cs)  # (l,)
    new_state = state * jnp.exp(a_cs[-1]) + jnp.dot(
        x.T, Bm * decay[:, None], preferred_element_type=jnp.float32
    )  # (P, N)
    state_ref[...] = new_state

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _final():
        st_out_ref[0, 0] = new_state.astype(st_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_kernel(
    x: jax.Array,  # (B, S, H, P) — pre-multiplied by dt
    a: jax.Array,  # (B, H, S) f32 — dt * A (negative decay logs)
    Bm: jax.Array,  # (B, S, G, N)
    Cm: jax.Array,  # (B, S, G, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
):
    B, S, H, P = x.shape
    G, N = Bm.shape[-2:]
    rep = H // G
    l = min(chunk, S)
    assert S % l == 0
    nc = S // l

    kernel = functools.partial(_kernel, nc=nc)
    y, final_state = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, l, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, l), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, l, 1, N), lambda b, h, c: (b, c, h // rep, 0)),
            pl.BlockSpec((1, l, 1, N), lambda b, h, c: (b, c, h // rep, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, l, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, a, Bm, Cm)
    return y, final_state
