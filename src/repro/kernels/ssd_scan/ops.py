"""Public jit'd wrapper for the SSD scan kernel."""
from __future__ import annotations

import jax

from repro.kernels.ssd_scan.kernel import ssd_scan_kernel


def ssd_scan(
    x: jax.Array,  # (B, S, H, P) pre-multiplied by dt
    a: jax.Array,  # (B, H, S) = dt * A
    Bm: jax.Array,  # (B, S, G, N)
    Cm: jax.Array,
    *,
    chunk: int = 128,
    interpret: bool = False,
):
    """Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    return ssd_scan_kernel(x, a, Bm, Cm, chunk=chunk, interpret=interpret)
