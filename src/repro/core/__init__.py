"""The paper's primary contribution: snapshot/restore co-designed with the
runtime — JIF container, overlay dedup, zero pool, node base-image cache,
the Spice restore engine, and the baselines it is evaluated against."""
from repro.core.cache import BaseImage, NodeImageCache
from repro.core.chunkstore import ChunkStore, NodeChunkCache
from repro.core.digest import DIGEST_BYTES, chunk_digests, digest_key
from repro.core.overlay import (
    DEFAULT_PAGE,
    KIND_BASE,
    KIND_PRIVATE,
    KIND_ZERO,
    IntervalTable,
)
from repro.core.iosched import IOStream, PrefetchIOScheduler
from repro.core.lifecycle import SnapshotPipeline, delta_snapshot
from repro.core.memory import (
    KIND_CHUNK_CAS,
    KIND_DEVICE_IMAGE,
    KIND_IMAGE_CACHE,
    KIND_POOL,
    KIND_RESIDUAL,
    KIND_SCRATCH,
    KIND_WORKING_SET,
    MEMORY_KINDS,
    MemoryPressureError,
    MemoryRegion,
    NodeMemoryManager,
)
from repro.core.pool import BufferPool
from repro.core.restore import RestoreStats, SpiceRestorer, TensorHandle
from repro.core.snapshot import SnapshotStats, snapshot
from repro.core.registry import FunctionRegistry, FunctionSpec
from repro.core.upload import DeviceImageCache, DevicePath, UploadStream

__all__ = [
    "SnapshotPipeline",
    "delta_snapshot",
    "BaseImage",
    "NodeImageCache",
    "ChunkStore",
    "NodeChunkCache",
    "DIGEST_BYTES",
    "chunk_digests",
    "digest_key",
    "BufferPool",
    "NodeMemoryManager",
    "MemoryRegion",
    "MemoryPressureError",
    "MEMORY_KINDS",
    "KIND_POOL",
    "KIND_IMAGE_CACHE",
    "KIND_DEVICE_IMAGE",
    "KIND_CHUNK_CAS",
    "KIND_WORKING_SET",
    "KIND_RESIDUAL",
    "KIND_SCRATCH",
    "UploadStream",
    "DeviceImageCache",
    "DevicePath",
    "IOStream",
    "PrefetchIOScheduler",
    "SpiceRestorer",
    "TensorHandle",
    "RestoreStats",
    "snapshot",
    "SnapshotStats",
    "FunctionRegistry",
    "FunctionSpec",
    "IntervalTable",
    "DEFAULT_PAGE",
    "KIND_ZERO",
    "KIND_BASE",
    "KIND_PRIVATE",
]
