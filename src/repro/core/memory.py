"""Unified node memory subsystem — one ledger, region primitives, reclaim.

The paper's second pillar (after replay-free kernel restore) is dedicated OS
memory primitives that *reliably* materialize mappings: memory is reserved
before the prefetcher streams into it, population is tracked (never
advisory), and the node's byte budget is an invariant rather than an
estimate.  This module is the node-side reproduction of that contract:

* :class:`NodeMemoryManager` owns the node's entire byte budget.  Every
  byte the runtime holds — pool staging buffers, cached base images, warm
  working sets, residual tails, snapshot scratch — is charged to exactly
  one live :class:`MemoryRegion`, and::

      held_bytes() == sum(region.nbytes for live regions) <= budget

  holds at every transition (:meth:`NodeMemoryManager.audit` asserts it).

* **Region primitives** mirror the paper's mapping lifecycle:
  ``reserve(nbytes, kind)`` admits the bytes against the budget (fail fast
  or reclaim — never over-commit), ``populate()`` records the prefetcher's
  in-flight fill, ``commit(pinned=...)`` marks the region live (working
  set vs residual), ``release()`` returns the charge.

* **Registered reclaimers** replace per-subsystem private LRU loops: under
  pressure the manager walks reclaimers in ladder order — residual tails
  first (cheapest to re-restore), then device base copies, then the
  RAM-resident chunk CAS (re-readable from its disk CAS), then recoverable
  base images, then idle pool staging, then LRU warm instances — until the
  deficit is covered.
  Reclaimers run *outside* the manager lock, so they may release regions
  (and take their own locks) freely.

Charges are logical tensor bytes.  Two bounded forms of slack are
deliberately outside the ledger (documented, not hidden): a staging buffer
that is mid-flight between the pool free list and a region's device copy,
and an evicted image whose bytes a concurrent restore still references
until Python GC runs.  Both are transient and bounded by the I/O pipeline
depth — the ledger never *under*-admits because of them.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, FrozenSet, Iterable, List, Optional, Tuple

__all__ = [
    "KIND_POOL",
    "KIND_IMAGE_CACHE",
    "KIND_DEVICE_IMAGE",
    "KIND_CHUNK_CAS",
    "KIND_WORKING_SET",
    "KIND_RESIDUAL",
    "KIND_SCRATCH",
    "MEMORY_KINDS",
    "MemoryPressureError",
    "MemoryRegion",
    "NodeMemoryManager",
]

# Region kinds — the per-kind ledger columns.
KIND_POOL = "pool"                # BufferPool free list + outstanding buffers
KIND_IMAGE_CACHE = "image_cache"  # NodeImageCache resident base images
KIND_DEVICE_IMAGE = "device_image"  # DeviceImageCache HBM-resident base pages
KIND_CHUNK_CAS = "chunk_cas"      # NodeChunkCache RAM-resident unique chunks
KIND_WORKING_SET = "working_set"  # pinned working-set bytes of an instance
KIND_RESIDUAL = "residual"        # residual (post-ws-boundary) bytes
KIND_SCRATCH = "scratch"          # transient snapshot/relayout staging

MEMORY_KINDS = (
    KIND_POOL, KIND_IMAGE_CACHE, KIND_DEVICE_IMAGE, KIND_CHUNK_CAS,
    KIND_WORKING_SET, KIND_RESIDUAL, KIND_SCRATCH,
)


class MemoryPressureError(RuntimeError):
    """A reservation could not be admitted within the node budget, even
    after running the reclaim ladder (and waiting, for blocking reserves)."""


class MemoryRegion:
    """One charged extent of the node budget.

    Lifecycle: ``reserved`` (admitted, prefetcher filling) → ``committed``
    (live, optionally pinned as working-set/residual) → ``released``.
    The charge is constant from reserve to release unless :meth:`resize`
    is used (pool free-list growth/shrink); ``populate`` and ``note_io``
    only track fill progress, they never change the charge — admission
    control happened at reserve time, which is what makes population
    guaranteed rather than advisory.
    """

    __slots__ = ("manager", "kind", "owner", "nbytes", "filled", "io_bytes",
                 "pinned", "_state")

    def __init__(self, manager: "NodeMemoryManager", kind: str, nbytes: int,
                 owner: Optional[str] = None):
        self.manager = manager
        self.kind = kind
        self.owner = owner
        self.nbytes = int(nbytes)
        self.filled = 0       # logical bytes the prefetcher has landed
        self.io_bytes = 0     # storage bytes read into this region
        self.pinned: Optional[str] = None
        self._state = "reserved"

    # ------------------------------------------------------------- queries
    @property
    def state(self) -> str:
        return self._state

    @property
    def released(self) -> bool:
        return self._state == "released"

    # -------------------------------------------------------- transitions
    def populate(self, nbytes: int) -> None:
        """Record ``nbytes`` of in-flight fill landing in this region (the
        prefetcher calls this per finalized tensor)."""
        with self.manager._cv:
            self.filled = min(self.filled + int(nbytes), self.nbytes)

    def note_io(self, nbytes: int) -> None:
        """Record raw storage bytes read toward this region (called from
        the I/O scheduler's reader thread; PRIVATE chunks only, so
        ``io_bytes <= filled`` once the stream drains)."""
        with self.manager._cv:
            self.io_bytes += int(nbytes)

    def commit(self, pinned: Optional[str] = None) -> None:
        """Mark the region live.  ``pinned`` tags what the bytes are
        (``"working_set"`` / ``"residual"``) for the reclaim ladder."""
        with self.manager._cv:
            if self._state == "released":
                return
            self._state = "committed"
            if pinned is not None:
                self.pinned = pinned

    def resize(self, nbytes: int) -> bool:
        """Grow or shrink the charge in place (the pool's free list uses
        this).  Growth is admitted non-blocking against the budget; returns
        False (charge unchanged) when it does not fit.  Shrink always
        succeeds."""
        nbytes = int(nbytes)
        with self.manager._cv:
            if self._state == "released":
                return False
            delta = nbytes - self.nbytes
            if delta > 0 and not self.manager._fits_locked(delta):
                return False
            self.manager._charge_locked(self.kind, delta)
            self.nbytes = nbytes
            if delta < 0:
                self.filled = min(self.filled, self.nbytes)
                self.manager._cv.notify_all()
            return True

    def release(self) -> int:
        """Return the charge to the budget (idempotent).  Returns the bytes
        freed by THIS call (0 on a repeat release)."""
        with self.manager._cv:
            if self._state == "released":
                return 0
            freed = self.nbytes
            self._state = "released"
            self.manager._charge_locked(self.kind, -freed)
            self.manager._regions.discard(self)
            self.manager._cv.notify_all()
            return freed

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"MemoryRegion({self.kind}, {self.nbytes}B, {self._state}"
                + (f", pinned={self.pinned}" if self.pinned else "")
                + (f", owner={self.owner}" if self.owner else "") + ")")


class NodeMemoryManager:
    """The node's single memory ledger.

    ``budget_bytes=None`` means unlimited (accounting only, no admission
    control) — the semantics standalone restorers and zero-capacity pools
    relied on before this subsystem existed.
    """

    def __init__(self, budget_bytes: Optional[int] = None):
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._budget = budget_bytes
        self._held = 0
        self._by_kind: Dict[str, int] = {k: 0 for k in MEMORY_KINDS}
        self._hw: Dict[str, int] = {k: 0 for k in MEMORY_KINDS}
        self._hw_total = 0
        self._regions: set = set()
        # (order, name, fn) — fn(nbytes_needed, protect) -> bytes freed
        self._reclaimers: List[Tuple[int, str, Callable[[int, FrozenSet[str]], int]]] = []
        self._reclaim_lock = threading.Lock()  # serialize ladder walks
        self.stats = {
            "reserves": 0,
            "reclaims": 0,
            "reclaimed_bytes": 0,
            "pressure_waits": 0,
            "pressure_failures": 0,
        }

    # -------------------------------------------------------------- budget
    @property
    def budget(self) -> Optional[int]:
        return self._budget

    @budget.setter
    def budget(self, nbytes: Optional[int]) -> None:
        with self._cv:
            self._budget = nbytes
            over = 0 if nbytes is None else max(0, self._held - nbytes)
            self._cv.notify_all()
        if over:
            # shrinking below current residency runs the ladder so audit's
            # held <= budget invariant is restored; if the rungs cannot
            # cover it the node is genuinely over-budget and audit will
            # (correctly) flag that state
            self.reclaim(over)

    # ------------------------------------------------------ locked helpers
    def _fits_locked(self, delta: int) -> bool:
        return self._budget is None or self._held + delta <= self._budget

    def _charge_locked(self, kind: str, delta: int) -> None:
        self._held += delta
        self._by_kind[kind] = self._by_kind.get(kind, 0) + delta
        if delta > 0:
            self._hw[kind] = max(self._hw.get(kind, 0), self._by_kind[kind])
            self._hw_total = max(self._hw_total, self._held)

    # ------------------------------------------------------------- reserve
    def reserve(
        self,
        nbytes: int,
        kind: str,
        owner: Optional[str] = None,
        block: bool = True,
        timeout: float = 60.0,
        protect: Optional[Iterable[str]] = None,
    ) -> MemoryRegion:
        """Admit ``nbytes`` against the budget and return the region.

        When the reservation does not fit, the reclaim ladder runs (outside
        the manager lock); a ``block=True`` reserve then waits for releases
        up to ``timeout`` seconds, re-running reclaim as the deficit moves.
        Raises :class:`MemoryPressureError` when the bytes cannot be
        admitted — the caller fails fast instead of over-committing.
        ``protect`` names functions the ladder must not sacrifice (e.g. the
        instance this reservation is for)."""
        nbytes = int(nbytes)
        if nbytes < 0:
            raise ValueError(f"negative reservation: {nbytes}")
        protect = frozenset(protect or ())
        deadline = time.monotonic() + timeout
        waited = False
        freed = 0
        last_walk = None
        while True:
            with self._cv:
                if self._fits_locked(nbytes):
                    region = MemoryRegion(self, kind, nbytes, owner)
                    self._charge_locked(kind, nbytes)
                    self._regions.add(region)
                    self.stats["reserves"] += 1
                    return region
                deficit = self._held + nbytes - self._budget
            # walk the ladder at most every ~200ms while blocked: each walk
            # takes every rung's locks, and re-walking on every 50ms wake
            # when nothing moved is pure contention (the fits check above
            # still reacts to releases immediately)
            now = time.monotonic()
            if last_walk is None or now - last_walk >= 0.2:
                freed = self.reclaim(deficit, protect=protect)
                last_walk = time.monotonic()
            with self._cv:
                if self._fits_locked(nbytes):
                    continue  # re-enter the admission check above
                if not block or time.monotonic() >= deadline:
                    self.stats["pressure_failures"] += 1
                    raise MemoryPressureError(
                        f"cannot reserve {nbytes} bytes of {kind!r}: "
                        f"held={self._held} budget={self._budget} "
                        f"(reclaimed {freed} last walk)"
                    )
                if not waited:
                    self.stats["pressure_waits"] += 1
                    waited = True
                self._cv.wait(timeout=0.05)

    # ------------------------------------------------------------ pressure
    def held_bytes(self) -> int:
        with self._cv:
            return self._held

    def kind_bytes(self) -> Dict[str, int]:
        with self._cv:
            return dict(self._by_kind)

    def high_water(self) -> Dict[str, int]:
        """Per-kind and total high-water marks since construction."""
        with self._cv:
            hw = dict(self._hw)
            hw["total"] = self._hw_total
            return hw

    def pressure(self) -> float:
        """Fraction of the budget currently held (0.0 with no budget)."""
        with self._cv:
            if not self._budget:
                return 0.0
            return self._held / self._budget

    def over_budget(self) -> int:
        """Bytes held above the budget (0 when within it / unlimited)."""
        with self._cv:
            if self._budget is None:
                return 0
            return max(0, self._held - self._budget)

    # -------------------------------------------------------------- reclaim
    def register_reclaimer(
        self, name: str, fn: Callable[[int, FrozenSet[str]], int], order: int
    ) -> None:
        """Register a reclaimer rung.  ``fn(nbytes, protect)`` frees up to
        ``nbytes`` (by releasing regions) and returns the bytes it freed.
        Lower ``order`` runs first — the node ladder is residual (0) →
        device-image (1) → chunk-cas (2) → image-cache (3) → pool
        staging (4) → LRU warm instances (5)."""
        with self._cv:
            self._reclaimers = sorted(
                [r for r in self._reclaimers if r[1] != name]
                + [(order, name, fn)]
            )

    def reclaim(self, nbytes: int, protect: Optional[Iterable[str]] = None) -> int:
        """Walk the reclaim ladder until ``nbytes`` are freed (or every rung
        is exhausted).  Runs reclaimers OUTSIDE the manager lock; walks are
        serialized so concurrent pressure does not stampede every rung."""
        if nbytes <= 0:
            return 0
        protect = frozenset(protect or ())
        with self._cv:
            rungs = list(self._reclaimers)
        freed = 0
        with self._reclaim_lock:
            for _, _name, fn in rungs:
                if freed >= nbytes:
                    break
                freed += int(fn(nbytes - freed, protect) or 0)
        if freed:
            # count only walks that freed something: a blocked reserve may
            # poll the ladder repeatedly within one pressure episode, and
            # empty walks would make the benchmark's reclaim count noise
            with self._cv:
                self.stats["reclaims"] += 1
                self.stats["reclaimed_bytes"] += freed
                self._cv.notify_all()
        return freed

    # ---------------------------------------------------------------- audit
    def audit(self) -> Dict[str, int]:
        """Assert the ledger invariant and return a consistent snapshot:
        ``sum(live region charges) == held_bytes() <= budget`` and the
        per-kind sums agree with the per-kind counters."""
        with self._cv:
            by_kind = {k: 0 for k in self._by_kind}
            total = 0
            for region in self._regions:
                by_kind[region.kind] = by_kind.get(region.kind, 0) + region.nbytes
                total += region.nbytes
            assert total == self._held, (
                f"ledger drift: sum(regions)={total} != held={self._held}"
            )
            for k, v in by_kind.items():
                assert v == self._by_kind.get(k, 0), (
                    f"ledger drift[{k}]: sum={v} != counter={self._by_kind.get(k, 0)}"
                )
            if self._budget is not None:
                assert self._held <= self._budget, (
                    f"over budget: held={self._held} > budget={self._budget}"
                )
            snap = dict(by_kind)
            snap["total"] = total
            snap["budget"] = -1 if self._budget is None else self._budget
            return snap

    def snapshot_stats(self) -> Dict[str, int]:
        with self._cv:
            return dict(self.stats)
