"""State-tree (de)serialization helpers.

A *state* is a nested dict/list/tuple of array leaves. We flatten it to
``(name, leaf)`` pairs with slash-joined path names and a JSON-able structure
descriptor, so restore can rebuild the exact pytree in one batched pass — the
metadata-restore analogue of the paper's "no syscall replay".
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import numpy as np


def flatten_state(tree) -> Tuple[List[Tuple[str, np.ndarray]], Any]:
    leaves: List[Tuple[str, np.ndarray]] = []

    def walk(node, path):
        if isinstance(node, dict):
            keys = sorted(node.keys())
            return {"t": "dict", "k": keys, "c": [walk(node[k], path + (str(k),)) for k in keys]}
        if isinstance(node, (list, tuple)):
            return {
                "t": "list" if isinstance(node, list) else "tuple",
                "c": [walk(v, path + (str(i),)) for i, v in enumerate(node)],
            }
        name = "/".join(path) if path else "_root"
        arr = np.asarray(node)
        leaves.append((name, arr))
        return {"t": "leaf", "n": name}

    desc = walk(tree, ())
    return leaves, desc


def unflatten_state(desc, leaves: Dict[str, Any]):
    if desc["t"] == "dict":
        return {k: unflatten_state(c, leaves) for k, c in zip(desc["k"], desc["c"])}
    if desc["t"] == "list":
        return [unflatten_state(c, leaves) for c in desc["c"]]
    if desc["t"] == "tuple":
        return tuple(unflatten_state(c, leaves) for c in desc["c"])
    return leaves[desc["n"]]


def leaf_names(desc) -> List[str]:
    out: List[str] = []

    def walk(d):
        if d["t"] == "leaf":
            out.append(d["n"])
        else:
            for c in d["c"]:
                walk(c)

    walk(desc)
    return out
