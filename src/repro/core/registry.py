"""Function registry: the serverless control-plane view of the model zoo.

Each registered *function* is a model instance with a JIF snapshot on disk,
an optional base image (shared with sibling functions), and serving
parameters.  Ownership sits with the control plane
(:class:`repro.serve.cluster.FunctionCatalog`); data-plane nodes hold a
read-mostly reference and resolve invocations through it.  All operations
are thread-safe — in a cluster the catalog registers new functions while
every node's invoke pool reads concurrently."""
from __future__ import annotations

import dataclasses
import json
import threading
import time
from pathlib import Path
from typing import Dict, List, Optional


@dataclasses.dataclass
class FunctionSpec:
    name: str
    arch: str
    jif_path: str
    base_image: Optional[str] = None  # node-cache key
    warm_ttl_s: float = 0.0  # keep-alive window (0: rely on fast restore)
    max_new_tokens: int = 16
    registered_at: float = dataclasses.field(default_factory=time.time)


class FunctionRegistry:
    def __init__(self):
        self._fns: Dict[str, FunctionSpec] = {}
        self._lock = threading.Lock()

    def register(self, spec: FunctionSpec) -> None:
        with self._lock:
            self._fns[spec.name] = spec

    def unregister(self, name: str) -> Optional[FunctionSpec]:
        with self._lock:
            return self._fns.pop(name, None)

    def get(self, name: str) -> FunctionSpec:
        with self._lock:
            return self._fns[name]

    def __contains__(self, name: str) -> bool:
        with self._lock:
            return name in self._fns

    def __len__(self) -> int:
        with self._lock:
            return len(self._fns)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._fns)

    def save(self, path: str) -> None:
        with self._lock:
            payload = {n: dataclasses.asdict(s) for n, s in self._fns.items()}
        Path(path).write_text(json.dumps(payload, indent=2))

    @classmethod
    def load(cls, path: str) -> "FunctionRegistry":
        reg = cls()
        for n, d in json.loads(Path(path).read_text()).items():
            reg.register(FunctionSpec(**d))
        return reg
