"""Function registry: the serverless control-plane view of the model zoo.

Each registered *function* is a model instance with a JIF snapshot on disk,
an optional base image (shared with sibling functions), and serving
parameters. The engine resolves invocations through this registry."""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Dict, Optional

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class FunctionSpec:
    name: str
    arch: str
    jif_path: str
    base_image: Optional[str] = None  # node-cache key
    warm_ttl_s: float = 0.0  # keep-alive window (0: rely on fast restore)
    max_new_tokens: int = 16
    registered_at: float = dataclasses.field(default_factory=time.time)


class FunctionRegistry:
    def __init__(self):
        self._fns: Dict[str, FunctionSpec] = {}

    def register(self, spec: FunctionSpec) -> None:
        self._fns[spec.name] = spec

    def get(self, name: str) -> FunctionSpec:
        return self._fns[name]

    def __contains__(self, name: str) -> bool:
        return name in self._fns

    def names(self):
        return sorted(self._fns)

    def save(self, path: str) -> None:
        Path(path).write_text(
            json.dumps({n: dataclasses.asdict(s) for n, s in self._fns.items()}, indent=2)
        )

    @classmethod
    def load(cls, path: str) -> "FunctionRegistry":
        reg = cls()
        for n, d in json.loads(Path(path).read_text()).items():
            reg.register(FunctionSpec(**d))
        return reg
