"""The Spice restore engine.

Restore = batched metadata restore + pipelined, *guaranteed* memory restore:

* metadata: ONE header decode rebuilds the full state structure (no
  per-resource replay); interval tables are raw int64 arrays (zero
  deserialization cost).
* memory: chunk reads are submitted to a prefetch I/O scheduler (one shared
  arbiter per node, or a private one for standalone restores) that streams
  the data segment with large sequential reads in first-access order,
  filling pool buffers directly; BASE chunks are memcpy'd from the node
  base-image cache concurrently (VMA-creation/prefetch overlap, §4.2); ZERO
  chunks cost nothing (pool buffers are pre-zeroed).  Completion is
  *tracked per tensor* — unlike madvise-style hints, execution can wait on
  exactly the tensor it needs and never takes a "major fault" on data that
  was requested but not loaded.  Under contention, a wait on an unread
  tensor demand-boosts its chunks to the head of the scheduler queue.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import overlay
from repro.core.cache import BaseImage, NodeImageCache
from repro.core.chunkstore import NodeChunkCache
from repro.core.digest import digest_key
from repro.core.iosched import IOStream, PrefetchIOScheduler
from repro.core.jif import JifReader
from repro.core.memory import (
    KIND_RESIDUAL,
    KIND_WORKING_SET,
    MemoryRegion,
    NodeMemoryManager,
)
from repro.core.pool import BufferPool
from repro.core.treeutil import unflatten_state


@dataclasses.dataclass
class RestoreStats:
    metadata_s: float = 0.0
    first_tensor_s: float = 0.0
    working_set_s: float = 0.0  # all working-set tensors resident (phase 1)
    total_s: float = 0.0
    bytes_read: int = 0
    base_bytes: int = 0
    zero_bytes: int = 0
    io_ops: int = 0
    demand_boosts: int = 0
    restore_ops: int = 1  # ONE batched metadata restore (vs CRIU's replay)
    major_faults: int = 0  # guaranteed population: always 0 for spice
    image_bytes: int = 0      # logical bytes of the restored state tree
    ws_tensors: int = 0       # tensors inside the traced working set
    residual_tensors: int = 0  # tensors streaming after the ws boundary
    reused_bytes: int = 0     # bytes served from a pinned working set
    reused_tensors: int = 0   # tensors served from a pinned working set
    # device fast path: read-wait and upload-wait split apart so benchmarks
    # can attribute TTFT to storage vs PCIe/serialization
    upload_s: float = 0.0             # time spent in host->device transfers
    uploaded_bytes: int = 0           # bytes that actually crossed to HBM
    patched_on_device_bytes: int = 0  # tensor bytes materialized by the kernel
    # content-addressed dedup: bytes served per tier instead of pulled from
    # the image store, plus the metadata-time plan partition (chunk counts)
    chunk_resident_bytes: int = 0  # served from the RAM chunk cache (zero I/O)
    chunk_cas_bytes: int = 0       # read from the node-local disk CAS
    chunk_peer_bytes: int = 0      # transferred node-to-node over the wire
    chunk_plan_resident: int = 0   # chunks planned as RAM hits
    chunk_plan_cas: int = 0        # chunks planned as local CAS hits
    chunk_plan_miss: int = 0       # chunks planned as image-store pulls
    ws_names: Optional[List[str]] = None  # traced working-set tensor names

    # Snapshot consistency: the prefetcher mutates counters concurrently
    # with readers (the engine reports stats while the stream is live), so
    # every mutation happens under a lock and ``as_dict`` takes a coherent
    # snapshot.  Completion is two-phase: ``mark_working_set`` fires when
    # every tensor before the ws boundary finalized (execution-ready),
    # ``mark_complete`` once the last residual tensor landed.
    def __post_init__(self):
        self._lock = threading.Lock()
        self._complete = threading.Event()
        self._ws = threading.Event()

    def add(self, **deltas) -> None:
        with self._lock:
            for k, v in deltas.items():
                setattr(self, k, getattr(self, k) + v)

    def set_once(self, field: str, value) -> None:
        with self._lock:
            if not getattr(self, field):
                setattr(self, field, value)

    def mark_working_set(self, working_set_s: float) -> None:
        with self._lock:
            self.working_set_s = working_set_s
        self._ws.set()

    def wait_working_set(self, timeout: Optional[float] = None) -> bool:
        return self._ws.wait(timeout)

    @property
    def ws_ready(self) -> bool:
        return self._ws.is_set()

    def mark_complete(self, total_s: float) -> None:
        with self._lock:
            self.total_s = total_s
        self._ws.set()  # a drained stream implies the working set landed
        self._complete.set()

    def wait_complete(self, timeout: Optional[float] = None) -> bool:
        return self._complete.wait(timeout)

    @property
    def complete(self) -> bool:
        return self._complete.is_set()

    def as_dict(self):
        with self._lock:
            d = dataclasses.asdict(self)
        d.pop("ws_names", None)  # bulky name list; read the attribute instead
        d["complete"] = self.complete
        d["ws_ready"] = self.ws_ready
        return d


def estimate_rerestore_cost(
    stats: Optional[RestoreStats],
    *,
    image_bytes: int = 0,
    ws_pinned: bool = False,
    residual_bytes: int = 0,
    chunks_hot: bool = False,
    device_base_resident: bool = False,
) -> int:
    """Estimated storage-pull bytes to bring an instance back after
    eviction — the currency cost-aware eviction ranks candidates in
    (:class:`repro.serve.prewarm.PrewarmPolicy`).

    Baseline: what the LAST restore actually pulled (``stats.bytes_read``
    already discounts base-image memcpys, zero pages, chunk-cache hits
    and pinned-ws reuse).  Refinements, cheapest state first:

    * ``ws_pinned`` — a residual-evicted instance re-reads only the
      dropped residual share of the image (``residual_bytes`` of
      ``stats.image_bytes``); a fully pinned ws with no residual left
      costs ~nothing.
    * ``chunks_hot`` — the pull lands through a node chunk cache whose
      CAS already holds the image's chunks (the last restore ingested
      them): re-reads come from the node-local CAS, not the image
      store — order-of-magnitude cheaper, not free (disk + verify).
    * ``device_base_resident`` — the HBM base survives eviction in the
      DeviceImageCache, shaving the re-upload (a mild discount here:
      this estimate prices storage, not PCIe).

    Returns >= 1 so penalty ratios stay well-defined; a stats-less
    instance (never restored through spice) prices at its full logical
    size — unknown is expensive, evict it last among equals."""
    if stats is None:
        return max(int(image_bytes), 1)
    total = stats.image_bytes or image_bytes
    paid = stats.bytes_read
    if ws_pinned:
        if total > 0 and residual_bytes > 0:
            paid = int(paid * min(1.0, residual_bytes / total))
        else:
            paid = 0
    if chunks_hot:
        paid //= 16
    if device_base_resident:
        paid = int(paid * 0.9)
    return max(paid, 1)


class TensorHandle:
    """Tracked-completion handle (the anti-madvise): ``wait`` blocks until
    the tensor is materialized; ``ready`` never lies.  Waiting on an unread
    tensor issues a demand boost to the I/O scheduler first, so execution
    demand overtakes background prefetch of other tensors/functions."""

    def __init__(self, name: str, shape, dtype):
        self.name = name
        self.shape = shape
        self.dtype = dtype
        self._ev = threading.Event()
        self._arr: Optional[np.ndarray] = None
        self._exc: Optional[BaseException] = None
        self._demand: Optional[Callable[[], bool]] = None

    def set(self, arr: np.ndarray):
        self._arr = arr
        self._ev.set()

    def fail(self, exc: BaseException) -> None:
        """Release waiters with the restore failure instead of hanging."""
        if not self._ev.is_set():
            self._exc = exc
            self._ev.set()

    def attach_demand(self, fn: Callable[[], bool]) -> None:
        self._demand = fn

    def wait(self, timeout: Optional[float] = None) -> np.ndarray:
        if not self._ev.is_set() and self._demand is not None:
            self._demand()
        if not self._ev.wait(timeout):
            raise TimeoutError(f"tensor {self.name} not restored in time")
        if self._exc is not None:
            raise RuntimeError(f"restore of {self.name} failed") from self._exc
        return self._arr

    @property
    def ready(self) -> bool:
        return self._ev.is_set()


# Residual tails yield to every demand stream — including BATCH-class
# restores, whose streams open at -1 (see repro.serve.invocation.QosClass
# .io_priority): demanded bytes of any class beat advisory background fill.
BACKGROUND_PRIORITY = -2


class SpiceRestorer:
    def __init__(
        self,
        pool: Optional[BufferPool] = None,
        node_cache: Optional[NodeImageCache] = None,
        io_chunk_bytes: int = 8 << 20,
        pipelined: bool = True,
        transform: Optional[Callable[[np.ndarray], Any]] = None,
        simulate_read_bw: Optional[float] = None,
        iosched: Optional[PrefetchIOScheduler] = None,
        stream_priority: int = 0,
        memory: Optional[NodeMemoryManager] = None,
        device_path=None,
        chunks: Optional[NodeChunkCache] = None,
    ):
        """``transform`` runs on the scheduler's reader thread per completed
        tensor (e.g. jnp.asarray = eager device install, off the critical
        path).  ``simulate_read_bw`` (bytes/s) sleeps during reads to model
        real storage latency when files are page-cache resident (labeled
        runs only).  ``iosched`` is the node-shared prefetch scheduler; when
        omitted a private one is created per restorer (standalone use).
        ``memory`` is the node ledger: when given, a restore reserves its
        working-set and residual regions up front — a restore that cannot
        fit fails fast (or triggers the reclaim ladder) instead of
        over-committing the node.

        ``device_path`` (a :class:`repro.core.upload.DevicePath`) switches
        tensor materialization to the device fast path: finalize enqueues
        uploads onto the node's shared :class:`UploadStream` instead of
        host-assembling + transforming on the reader thread.  Per tensor,
        the restore plans either a FUSED restore — only private pages are
        read (into a compact staging buffer) and uploaded; BASE pages come
        from the HBM-resident :class:`DeviceImageCache`, ZERO pages are
        free, and the overlay-patch kernel materializes the full tensor on
        device — or a full upload (host assembly as usual, whole-tensor
        upload off the reader thread) when fusion cannot apply: page size
        not a dtype multiple, all-private itable (nothing to fuse), BASE
        pages with no device base available (cache miss under pressure, or
        ``device_path.images is None``).  ``transform`` is ignored for
        device-path tensors; ``on_ready`` only fires for host-path
        tensors.

        ``chunks`` (a :class:`repro.core.chunkstore.NodeChunkCache`)
        enables dedup-aware restore planning: each host-path tensor's
        chunk list is partitioned by digest into resident hits (served
        from the RAM chunk cache, zero I/O), node-local CAS hits (one
        local disk read), peer hits (interconnect transfer), and misses —
        only the missing chunks are pulled from the image store, and each
        pull ingests into the cache so K deltas of one base cost ~1 base
        read across the node/cluster, not K."""
        self.pool = pool or BufferPool()
        self.node_cache = node_cache or NodeImageCache()
        self.io_chunk_bytes = io_chunk_bytes
        self.pipelined = pipelined
        self.transform = transform
        self.simulate_read_bw = simulate_read_bw
        self.iosched = iosched or PrefetchIOScheduler(name="spice-private")
        self.stream_priority = stream_priority
        self.memory = memory
        self.device_path = device_path
        self.chunks = chunks
        # (ws_region, residual_region) of the LAST restore() call — the
        # node scheduler transfers these onto the FunctionInstance, which
        # releases them on eviction (restorers are per-restore on that path)
        self.regions: Tuple[Optional[MemoryRegion], Optional[MemoryRegion]] = (None, None)
        # the LAST restore() call's live prefetch stream: the node holds it
        # to abort a cancelled invocation mid-restore (stream.abort fails
        # every handle and returns the admitted regions via on_complete)
        self.stream: Optional[IOStream] = None

    # ------------------------------------------------------------------
    def restore(
        self,
        path: str,
        on_ready: Optional[Callable[[str, np.ndarray], None]] = None,
        wait: bool = True,
        on_working_set: Optional[Callable[[], None]] = None,
        preloaded: Optional[Dict[str, Any]] = None,
        preloaded_region: Optional[MemoryRegion] = None,
    ) -> Tuple[Any, Dict, Dict[str, TensorHandle], RestoreStats]:
        """Returns (state, meta, handles, stats). With ``wait=False`` the
        state tree contains TensorHandles being filled by the scheduler —
        callers overlap execution with restore by waiting per tensor.

        Completion is two-phase: once every tensor inside the traced
        working set finalizes, ``stats.mark_working_set`` fires (and
        ``on_working_set``, if given, runs on the prefetcher thread) while
        the residual keeps streaming at background priority — demand boosts
        still promote individual residual tensors on ``TensorHandle.wait``.
        The JIF reader is closed (and ``stats`` marked complete) when the
        last tensor finalizes, whether or not the caller waited.

        ``preloaded`` maps tensor names to already-resident arrays (a
        residual-evicted instance's pinned working set): matching tensors
        are served without any storage read, so a re-restore reads only the
        bytes that were actually dropped.  Entries whose dtype/shape no
        longer match the image (e.g. after a relayout) fall back to a
        normal read.  ``preloaded_region`` is the ledger region still
        charging those resident bytes — it is resized in place into this
        restore's working-set region (ownership transfers here; the caller
        must not release it afterwards)."""
        stats = RestoreStats()
        t0 = time.perf_counter()
        r = None
        try:
            r = JifReader(path)  # missing/corrupt image raises here
            r.load_all_itables()
            meta = r.meta
            base = self._resolve_base(r)
        except BaseException:
            # _resolve_base closes r on its own failure paths, but a parent
            # bootstrap can also fail through node_cache.put (e.g.
            # MemoryPressureError) — close() is idempotent, never leak the
            # fd (nor the caller's retained ws charge)
            if preloaded_region is not None:
                preloaded_region.release()
            if r is not None:
                r.close()
            raise

        order = meta["access_order"]
        ws_names = set(meta.get("working_set") or order)
        reused: Dict[str, Any] = {}
        for t in r.tensors:
            arr = (preloaded or {}).get(t.name)
            if (
                arr is not None
                and getattr(arr, "nbytes", -1) == t.nbytes
                and tuple(getattr(arr, "shape", ())) == tuple(t.shape)
                and str(getattr(arr, "dtype", "")) == t.dtype
            ):
                reused[t.name] = arr

        # ---- device fast path: plan fused vs full uploads per tensor -----
        # Planned NOW (the itables are already resident, zero extra I/O) so
        # compact staging buffers can be sized before any read is issued.
        # The first restore against a base pays its one-time device install
        # here, synchronously; every later restore on the node shares it.
        dp = self.device_path
        plans: Dict[str, Any] = {}   # name -> FusedPlan
        full_upload: set = set()     # device path, whole-tensor upload
        if dp is not None:
            try:
                plans, full_upload = self._plan_device(r, base, reused)
            except BaseException:
                if preloaded_region is not None:
                    preloaded_region.release()
                r.close()
                raise

        # ---- dedup planning: partition chunk lists by digest -------------
        # Metadata-time only (the itables and digest regions are already
        # resident — zero data-segment I/O): record how many chunks the
        # node can serve without touching the image store.  The actual
        # short-circuit happens per op at read time (dedup_read_op), because
        # demand boosts reorder tensors and earlier ops ingest chunks later
        # ones need — the plan counters are the *forecast*, not the contract.
        dedup_digests: Dict[str, np.ndarray] = {}
        if self.chunks is not None:
            try:
                # v1 images backfill digests once (persisted sidecar) so
                # legacy images participate in dedup instead of being opaque
                have = r.has_digests or r.ensure_digests(base=base)
            except (ValueError, OSError):
                have = False  # e.g. unreadable sidecar dir: restore sans dedup
            if have:
                plan_hits = {"ram": 0, "cas": 0, None: 0}
                for t in r.tensors:
                    if t.name in reused or t.name in plans:
                        continue
                    dg = r.digests(t.name)
                    if dg is None:
                        continue
                    dedup_digests[t.name] = dg
                    for start, count, _src in r.itable(t.name).private_runs():
                        for j in range(start, start + count):
                            plan_hits[self.chunks.probe(dg[j])] += 1
                stats.add(
                    chunk_plan_resident=plan_hits["ram"],
                    chunk_plan_cas=plan_hits["cas"],
                    chunk_plan_miss=plan_hits[None],
                )

        # ---- admission: reserve regions BEFORE any data is staged --------
        region_ws = region_res = None
        if self.memory is not None:
            ws_bytes = sum(t.nbytes for t in r.tensors if t.name in ws_names)
            res_bytes = sum(t.nbytes for t in r.tensors) - ws_bytes
            tag = os.path.basename(path)
            try:
                if (
                    preloaded_region is not None
                    and not preloaded_region.released
                    and preloaded_region.resize(ws_bytes)
                ):
                    # re-restore: the pinned working set's charge carries
                    # over in place — the resident bytes are never
                    # uncharged, so concurrent reserves cannot admit
                    # against memory that is still physically held
                    region_ws = preloaded_region
                else:
                    if preloaded_region is not None:
                        # ws size changed (relayout): release the stale pin
                        # first so the fresh reserve does not stack on top
                        # of a charge the ladder has no way to reclaim
                        preloaded_region.release()
                    region_ws = self.memory.reserve(
                        ws_bytes, KIND_WORKING_SET, owner=tag
                    )
                if res_bytes:
                    region_res = self.memory.reserve(
                        res_bytes, KIND_RESIDUAL, owner=tag
                    )
            except BaseException:
                if region_ws is not None:
                    region_ws.release()
                r.close()
                raise
        elif preloaded_region is not None:
            preloaded_region.release()  # no ledger on this restorer
        self.regions = (region_ws, region_res)

        def _release_regions():
            for reg in (region_ws, region_res):
                if reg is not None:
                    reg.release()

        handles: Dict[str, TensorHandle] = {}
        buffers: Dict[str, np.ndarray] = {}
        # anything that fails between here and the stream owning its
        # on_complete (pool allocation, a shut-down scheduler) must return
        # the admitted charges and close the reader — a leaked reservation
        # would brick every later admission on the node
        try:
            for t in r.tensors:
                handles[t.name] = TensorHandle(t.name, t.shape, t.dtype)
                if t.name in reused:
                    continue
                plan = plans.get(t.name)
                if plan is not None:
                    # fused: stage ONLY the private pages, compactly; an
                    # all-BASE/ZERO tensor needs no staging buffer at all
                    if plan.n_priv:
                        buffers[t.name] = self.pool.acquire(plan.priv_bytes)
                else:
                    buffers[t.name] = self.pool.acquire(t.nbytes)
            ws_remaining = [sum(
                1 for t in r.tensors if t.name in ws_names and t.name not in reused
            )]
            stats.image_bytes = sum(t.nbytes for t in r.tensors)
            stats.ws_tensors = sum(1 for t in r.tensors if t.name in ws_names)
            stats.residual_tensors = len(r.tensors) - stats.ws_tensors
            stats.ws_names = [n for n in order if n in ws_names]
            stats.metadata_s = time.perf_counter() - t0

            # pinned tensors are resident already: serve them with zero I/O
            for t in r.tensors:
                if t.name not in reused:
                    continue
                handles[t.name].set(reused[t.name])
                stats.add(reused_bytes=t.nbytes, reused_tensors=1)
                region = region_ws if t.name in ws_names else region_res
                if region is not None:
                    region.populate(t.nbytes)
            if reused:
                stats.set_once("first_tensor_s", time.perf_counter() - t0)
        except BaseException:
            _release_regions()
            r.close()
            raise

        def finalize(name: str):
            t = r.by_name[name]
            if dp is not None and (name in plans or name in full_upload):
                # device path: hand the staged bytes to the upload ring and
                # return to reading immediately — the device transfer (and,
                # for fused tensors, the overlay patch) runs on the uploader
                # thread, overlapped with further reads.  The handle resolves
                # when the upload lands; upload jobs never touch the reader.
                rel = partial(self.pool.release, dirty=True)
                plan = plans.get(name)
                if plan is not None:
                    dp.upload.upload_fused(
                        handles[name], plan, buffers.pop(name, None),
                        stats=stats, release=rel,
                    )
                else:
                    dp.upload.upload_full(
                        handles[name], buffers.pop(name),
                        shape=tuple(t.shape), dtype=t.dtype,
                        nbytes=t.nbytes, stats=stats, release=rel,
                    )
            else:
                arr = buffers[name][: t.nbytes].view(np.dtype(t.dtype))
                arr = arr.reshape(t.shape) if t.shape else arr.reshape(())
                if self.transform is not None:  # eager install (device put)
                    arr = self.transform(arr)
                    # PJRT transfers are asynchronous (the source buffer is
                    # only immutable-until-transfer-completes): an installed
                    # array must land before its staging buffer is re-zeroed,
                    # or the device copy reads zeros mid-transfer
                    ready = getattr(arr, "block_until_ready", None)
                    if ready is not None:
                        ready()
                    # the host staging buffer is no longer referenced:
                    # recycle it into the pool, re-zeroing on THIS (reader)
                    # thread — allocation and zeroing stay off future
                    # critical paths
                    self.pool.release(buffers.pop(name), dirty=True)
                handles[name].set(arr)
                if on_ready is not None:
                    on_ready(name, arr)
            region = region_ws if name in ws_names else region_res
            if region is not None:
                region.populate(t.nbytes)
            stats.set_once("first_tensor_s", time.perf_counter() - t0)
            if name in ws_names:
                # the stream serves one tensor at a time, so this counter
                # only ever moves on the serving thread
                ws_remaining[0] -= 1
                if ws_remaining[0] == 0 and not stats.ws_ready:
                    if region_ws is not None:
                        region_ws.commit(pinned="working_set")
                    stats.mark_working_set(time.perf_counter() - t0)
                    # phase 2: residual streams on at background priority;
                    # per-tensor demand boosts still overtake it
                    stream.set_priority(BACKGROUND_PRIORITY)
                    stream.region = region_res  # residual I/O accounting
                    if on_working_set is not None:
                        on_working_set()

        def fill_base_zero(name: str) -> int:
            """memcpy BASE runs from the node cache; ZERO runs are free.
            Costs no storage reads (returns 0 bytes for the arbiter)."""
            t = r.by_name[name]
            it = r.itable(name)
            ps = r.page_size
            for start, count, kind, _src in it.table:
                if kind == overlay.KIND_PRIVATE:
                    continue
                nb = min(count * ps, t.nbytes - start * ps)
                if kind == overlay.KIND_BASE:
                    src = base.chunk_bytes(name, int(start), int(count))[:nb]
                    buffers[name][start * ps : start * ps + nb] = src
                    stats.add(base_bytes=nb)
                    self.node_cache.note_base_served(nb)
                else:  # ZERO: pool buffers are pre-zeroed
                    stats.add(zero_bytes=nb)
                    self.pool.note_zero_chunks(nb)
            return 0

        def read_op(name: str, src: int, dst_chunk: int, count: int) -> int:
            """One large sequential read into the tensor's staging buffer."""
            t = r.by_name[name]
            ps = r.page_size
            raw = r.pread_chunks(src, count)
            if self.simulate_read_bw:
                time.sleep(len(raw) / self.simulate_read_bw)
            dst0 = dst_chunk * ps
            nb = min(len(raw), t.nbytes - dst0)
            buffers[name][dst0 : dst0 + nb] = np.frombuffer(raw[:nb], np.uint8)
            stats.add(bytes_read=len(raw), io_ops=1)
            return len(raw)

        def dedup_read_op(name: str, src: int, dst_chunk: int, count: int) -> int:
            """read_op with content-addressed short-circuits: chunks already
            in the RAM chunk cache, the local CAS, or held by a peer are
            served without touching the image store; only runs of
            consecutive misses are pulled (one coalesced sequential read
            each), and every pulled chunk is ingested so the next tenant —
            on this node or a peer — hits instead.  Returns only the bytes
            actually pulled from the image store, so the arbiter's
            ``bytes_read`` keeps meaning storage pulls."""
            t = r.by_name[name]
            ps = r.page_size
            dgs = dedup_digests[name]
            cache = self.chunks
            pulled = [0]

            def clen(page: int) -> int:  # unpadded length of chunk `page`
                return min(ps, t.nbytes - page * ps)

            def pull(j0: int, n: int) -> None:
                raw = r.pread_chunks(src + j0, n)
                if self.simulate_read_bw:
                    time.sleep(len(raw) / self.simulate_read_bw)
                dst0 = (dst_chunk + j0) * ps
                nb = min(len(raw), t.nbytes - dst0)
                buffers[name][dst0 : dst0 + nb] = np.frombuffer(raw[:nb], np.uint8)
                stats.add(bytes_read=len(raw), io_ops=1)
                pulled[0] += len(raw)
                for j in range(j0, j0 + n):
                    off = (j - j0) * ps
                    cache.ingest(
                        dgs[dst_chunk + j], raw[off : off + clen(dst_chunk + j)]
                    )

            miss0 = miss_n = 0
            for j in range(count):
                page = dst_chunk + j
                dk = digest_key(dgs[page])
                data = cache.get(dk)
                if data is not None:
                    stats.add(chunk_resident_bytes=len(data))
                else:
                    data = cache.get_cas(dk)
                    if data is not None:
                        stats.add(chunk_cas_bytes=len(data))
                    else:
                        data = cache.fetch_peer(dk)
                        if data is not None:
                            stats.add(chunk_peer_bytes=len(data))
                if data is None:
                    if miss_n == 0:
                        miss0 = j
                    miss_n += 1
                    continue
                if miss_n:
                    pull(miss0, miss_n)
                    miss_n = 0
                nb = clen(page)
                buffers[name][page * ps : page * ps + nb] = np.frombuffer(
                    data[:nb], np.uint8
                )
            if miss_n:
                pull(miss0, miss_n)
            return pulled[0]

        def read_compact_op(name: str, src: int, dst_slot: int, count: int) -> int:
            """Sequential read of private chunks into the COMPACT staging
            buffer: ``dst_slot`` indexes private-page slots (0..n_priv-1),
            not tensor pages — the fused tensor never exists on host."""
            ps = r.page_size
            raw = r.pread_chunks(src, count)
            if self.simulate_read_bw:
                time.sleep(len(raw) / self.simulate_read_bw)
            dst0 = dst_slot * ps
            buffers[name][dst0 : dst0 + len(raw)] = np.frombuffer(raw, np.uint8)
            stats.add(bytes_read=len(raw), io_ops=1)
            return len(raw)

        def fused_account(name: str) -> int:
            """Fused tensors pay no host memcpy for BASE/ZERO pages —
            account the bytes the device tier serves (no storage reads)."""
            plan = plans[name]
            t = r.by_name[name]
            sizes = np.minimum(
                plan.page_bytes,
                t.nbytes - np.arange(plan.n_pages, dtype=np.int64) * plan.page_bytes,
            )
            nb_base = int(sizes[plan.kinds == overlay.KIND_BASE].sum())
            nb_zero = int(sizes[plan.kinds == overlay.KIND_ZERO].sum())
            if nb_base:
                stats.add(base_bytes=nb_base)
                if dp.images is not None:
                    dp.images.note_base_served(nb_base)
            if nb_zero:
                stats.add(zero_bytes=nb_zero)
            return 0

        def tensor_ops(name: str) -> List[Callable[[], int]]:
            ps = r.page_size
            chunk = max(self.io_chunk_bytes // ps, 1)
            plan = plans.get(name)
            if plan is not None:
                # fused: read ONLY the private runs, packed compactly
                ops = [partial(fused_account, name)]
                for slot, src, count in plan.runs:
                    done = 0
                    while done < count:
                        n = min(count - done, chunk)
                        ops.append(
                            partial(read_compact_op, name, src + done, slot + done, n)
                        )
                        done += n
                return ops
            ops = [partial(fill_base_zero, name)]
            # dedup applies per host-staged tensor (never fused-compact
            # slots); the op probes the chunk cache at read time
            rop = dedup_read_op if name in dedup_digests else read_op
            for start, count, src in r.itable(name).private_runs():
                done = 0
                while done < count:
                    n = min(count - done, chunk)
                    ops.append(partial(rop, name, src + done, start + done, n))
                    done += n
            return ops

        try:
            stream = self.iosched.open_stream(
                name=os.path.basename(path),
                priority=self.stream_priority,
                inline=not self.pipelined,
                region=region_ws,
            )
        except BaseException:
            _release_regions()
            r.close()
            raise
        self.stream = stream

        def on_complete():
            if stream.error is not None:
                # failed stream: release every waiter with the error, and
                # return the admitted regions to the budget (idempotent —
                # an instance that already adopted them releases too)
                for h in handles.values():
                    h.fail(stream.error)
                _release_regions()
            else:
                if region_ws is not None:
                    region_ws.commit(pinned="working_set")
                if region_res is not None:
                    region_res.commit(pinned="residual")
            stats.mark_complete(time.perf_counter() - t0)
            r.close()

        stream._on_complete = on_complete
        try:
            if ws_remaining[0] == 0 and not stats.ws_ready:
                # the whole working set was served from pinned memory:
                # promote immediately; the stream only reads residual now
                if region_ws is not None:
                    region_ws.commit(pinned="working_set")
                stats.mark_working_set(time.perf_counter() - t0)
                stream.set_priority(BACKGROUND_PRIORITY)
                stream.region = region_res
                if on_working_set is not None:
                    on_working_set()
            for name in order:
                if name in reused:
                    continue
                stream.submit(name, tensor_ops(name), partial(finalize, name))
            stream.seal()
        except BaseException as exc:
            # never leave a half-submitted stream registered (it would pin
            # the reader thread and leak the fd): fail it, which also runs
            # on_complete -> r.close()
            stream.abort(exc)
            raise
        for name, h in handles.items():
            h.attach_demand(partial(self._boost, stream, stats, name))

        if not self.pipelined:
            # synchronous path: drain on the caller's thread (no overlap)
            self.iosched.drain_inline(stream)
        elif wait:
            stream.wait()

        leaves: Dict[str, Any] = {name: handles[name] for name in handles}
        if wait:
            leaves = {name: h.wait() for name, h in leaves.items()}
        state = unflatten_state(meta["tree"], leaves)
        return state, meta, handles, stats

    def _plan_device(
        self, r: JifReader, base: Optional[BaseImage], reused: Dict[str, Any]
    ) -> Tuple[Dict[str, Any], set]:
        """Split this image's tensors between the two device-path modes:
        ``plans`` (name -> FusedPlan: upload private pages only, patch on
        device) and ``full_upload`` (host-assemble as usual, whole-tensor
        upload off the reader thread).  Fusion applies when the page size
        divides the dtype and the itable has BASE/ZERO pages to save; BASE
        pages additionally need the device-resident base — a cache miss
        under memory pressure falls back to full upload, never fails."""
        # imported here: the host-only restore path must not pull in jax
        from repro.core.upload import FusedPlan
        from repro.kernels.overlay_patch.ops import compact_plan_from_itable

        dp = self.device_path
        plans: Dict[str, Any] = {}
        full: set = set()
        ps = r.page_size
        for t in r.tensors:
            if t.name in reused:
                continue
            dtype = np.dtype(t.dtype)
            it = r.itable(t.name)
            kinds, src, runs, n_priv = compact_plan_from_itable(it)
            n_pages = it.n_pages
            if ps % dtype.itemsize != 0 or n_pages == 0 or n_priv == n_pages:
                full.add(t.name)  # nothing to fuse (or pages unviewable)
                continue
            page_elems = ps // dtype.itemsize
            base_pages = None
            if (kinds == overlay.KIND_BASE).any():
                if dp.images is None or base is None:
                    full.add(t.name)
                    continue
                base_pages = dp.images.get_pages(
                    base, t.name, n_pages, page_elems, dtype
                )
                if base_pages is None:  # pressure/mismatch: host fallback
                    full.add(t.name)
                    continue
            plans[t.name] = FusedPlan(
                name=t.name, shape=tuple(t.shape), dtype=t.dtype,
                nbytes=t.nbytes, page_bytes=ps, page_elems=page_elems,
                n_pages=n_pages, n_priv=n_priv, kinds=kinds, src=src,
                runs=runs, base_pages=base_pages,
            )
        return plans, full

    # one bootstrap per parent key at a time: N sibling delta restores that
    # all miss the parent must not each materialize the full image
    _bootstrap_meta = threading.Lock()
    _bootstrap_locks: Dict[str, threading.Lock] = {}

    def _resolve_base(self, r: JifReader) -> Optional[BaseImage]:
        """Resolve the image's base: from the node cache, or — for delta
        chains — bootstrapped from the parent JIF on disk (recursively, so a
        fresh node can restore any depth of chain from the snapshot store).
        The ref's name binds the parent file's identity (mtime+size): if the
        file on disk no longer matches what this image was classified
        against, the restore fails loudly instead of corrupting silently."""
        ref = r.base_ref
        if not ref:
            return None
        name = ref.get("name")
        base = self.node_cache.get(name)
        if base is None and ref.get("path"):
            from repro.core.lifecycle import parent_cache_key

            with SpiceRestorer._bootstrap_meta:
                lock = SpiceRestorer._bootstrap_locks.setdefault(
                    name, threading.Lock()
                )
            with lock:
                base = self.node_cache.get(name)  # won the race? already in
                if base is None:
                    try:
                        current_key = parent_cache_key(ref["path"])
                    except FileNotFoundError:
                        current_key = None
                    if current_key is not None and current_key != name:
                        r.close()
                        raise FileNotFoundError(
                            f"parent JIF {ref['path']!r} changed on disk "
                            f"since this delta was written (key mismatch)"
                        )
                    if current_key is not None:
                        try:
                            base = BaseImage.from_jif(
                                ref["path"], name=name,
                                node_cache=self.node_cache,
                                iosched=self.iosched,
                                simulate_read_bw=self.simulate_read_bw,
                                chunks=self.chunks,
                            )
                        except FileNotFoundError:
                            base = None
                    if base is not None:
                        self.node_cache.put(base)
        if base is None:
            r.close()
            raise FileNotFoundError(
                f"base image {ref.get('name')!r} not in node cache"
                + (f" and parent JIF {ref['path']!r} unusable" if ref.get("path") else "")
            )
        return base

    @staticmethod
    def _boost(stream: IOStream, stats: RestoreStats, name: str) -> bool:
        if stream.boost(name):
            stats.add(demand_boosts=1)
            return True
        return False
