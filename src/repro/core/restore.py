"""The Spice restore engine.

Restore = batched metadata restore + pipelined, *guaranteed* memory restore:

* metadata: ONE header decode rebuilds the full state structure (no
  per-resource replay); interval tables are raw int64 arrays (zero
  deserialization cost).
* memory: a dedicated prefetcher thread streams the data segment with large
  sequential reads in first-access order, filling pool buffers directly;
  BASE chunks are memcpy'd from the node base-image cache concurrently
  (VMA-creation/prefetch overlap, §4.2); ZERO chunks cost nothing (pool
  buffers are pre-zeroed).  Completion is *tracked per tensor* — unlike
  madvise-style hints, execution can wait on exactly the tensor it needs
  and never takes a "major fault" on data that was requested but not loaded.
"""
from __future__ import annotations

import dataclasses
import os
import queue
import threading
import time
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core import overlay
from repro.core.cache import BaseImage, NodeImageCache
from repro.core.jif import JifReader
from repro.core.pool import BufferPool
from repro.core.treeutil import unflatten_state


@dataclasses.dataclass
class RestoreStats:
    metadata_s: float = 0.0
    first_tensor_s: float = 0.0
    total_s: float = 0.0
    bytes_read: int = 0
    base_bytes: int = 0
    zero_bytes: int = 0
    io_ops: int = 0
    restore_ops: int = 1  # ONE batched metadata restore (vs CRIU's replay)
    major_faults: int = 0  # guaranteed population: always 0 for spice

    def as_dict(self):
        return dataclasses.asdict(self)


class TensorHandle:
    """Tracked-completion handle (the anti-madvise): ``wait`` blocks until
    the tensor is materialized; ``ready`` never lies."""

    def __init__(self, name: str, shape, dtype):
        self.name = name
        self.shape = shape
        self.dtype = dtype
        self._ev = threading.Event()
        self._arr: Optional[np.ndarray] = None

    def set(self, arr: np.ndarray):
        self._arr = arr
        self._ev.set()

    def wait(self, timeout: Optional[float] = None) -> np.ndarray:
        if not self._ev.wait(timeout):
            raise TimeoutError(f"tensor {self.name} not restored in time")
        return self._arr

    @property
    def ready(self) -> bool:
        return self._ev.is_set()


class SpiceRestorer:
    def __init__(
        self,
        pool: Optional[BufferPool] = None,
        node_cache: Optional[NodeImageCache] = None,
        io_chunk_bytes: int = 8 << 20,
        pipelined: bool = True,
        transform: Optional[Callable[[np.ndarray], Any]] = None,
        simulate_read_bw: Optional[float] = None,
    ):
        """``transform`` runs on the prefetcher thread per completed tensor
        (e.g. jnp.asarray = eager device install, off the critical path).
        ``simulate_read_bw`` (bytes/s) sleeps during reads to model real
        storage latency when files are page-cache resident (labeled runs
        only)."""
        self.pool = pool or BufferPool()
        self.node_cache = node_cache or NodeImageCache()
        self.io_chunk_bytes = io_chunk_bytes
        self.pipelined = pipelined
        self.transform = transform
        self.simulate_read_bw = simulate_read_bw

    # ------------------------------------------------------------------
    def restore(
        self,
        path: str,
        on_ready: Optional[Callable[[str, np.ndarray], None]] = None,
        wait: bool = True,
    ) -> Tuple[Any, Dict, Dict[str, TensorHandle], RestoreStats]:
        """Returns (state, meta, handles, stats). With ``wait=False`` the
        state tree contains TensorHandles being filled by the prefetcher —
        callers overlap execution with restore by waiting per tensor."""
        stats = RestoreStats()
        t0 = time.perf_counter()
        r = JifReader(path)
        r.load_all_itables()
        meta = r.meta
        base = self.node_cache.get((r.base_ref or {}).get("name"))
        if r.base_ref and base is None:
            raise FileNotFoundError(
                f"base image {r.base_ref['name']!r} not in node cache"
            )

        handles: Dict[str, TensorHandle] = {}
        buffers: Dict[str, np.ndarray] = {}
        order = meta["access_order"]
        for t in r.tensors:
            handles[t.name] = TensorHandle(t.name, t.shape, t.dtype)
            buffers[t.name] = self.pool.acquire(t.nbytes)
        stats.metadata_s = time.perf_counter() - t0

        def finalize(name: str):
            t = r.by_name[name]
            arr = buffers[name][: t.nbytes].view(np.dtype(t.dtype))
            arr = arr.reshape(t.shape) if t.shape else arr.reshape(())
            if self.transform is not None:  # eager install (e.g. device put)
                arr = self.transform(arr)
                # the host staging buffer is no longer referenced: recycle it
                # into the pool, re-zeroing on THIS (prefetcher) thread —
                # allocation and zeroing stay off future critical paths
                self.pool.release(buffers.pop(name), dirty=True)
            handles[name].set(arr)
            if on_ready is not None:
                on_ready(name, arr)

        def fill_base_zero(name: str) -> bool:
            """memcpy BASE runs from the node cache; ZERO runs are free.
            Returns True if the tensor has no PRIVATE chunks at all."""
            t = r.by_name[name]
            it = r.itable(name)
            ps = r.page_size
            has_private = False
            for start, count, kind, _src in it.table:
                if kind == overlay.KIND_PRIVATE:
                    has_private = True
                    continue
                nb = min(count * ps, t.nbytes - start * ps)
                if kind == overlay.KIND_BASE:
                    src = base.chunk_bytes(name, int(start), int(count))[:nb]
                    buffers[name][start * ps : start * ps + nb] = src
                    stats.base_bytes += nb
                    self.node_cache.stats["base_bytes_served"] += nb
                else:  # ZERO: pool buffers are pre-zeroed
                    stats.zero_bytes += nb
                    self.pool.note_zero_chunks(nb)
            return not has_private

        def prefetch():
            """Sequential streaming over the data segment in access order."""
            first_done = False
            for name in order:
                t = r.by_name[name]
                only_shared = fill_base_zero(name)
                ps = r.page_size
                for start, count, src in r.itable(name).private_runs():
                    # large sequential reads, io_chunk at a time
                    done = 0
                    while done < count:
                        n = min(count - done, max(self.io_chunk_bytes // ps, 1))
                        raw = r.pread_chunks(src + done, n)
                        stats.io_ops += 1
                        stats.bytes_read += len(raw)
                        if self.simulate_read_bw:
                            time.sleep(len(raw) / self.simulate_read_bw)
                        dst0 = (start + done) * ps
                        nb = min(len(raw), t.nbytes - dst0)
                        buffers[name][dst0 : dst0 + nb] = np.frombuffer(
                            raw[:nb], np.uint8
                        )
                        done += n
                finalize(name)
                if not first_done:
                    stats.first_tensor_s = time.perf_counter() - t0
                    first_done = True
            stats.total_s = time.perf_counter() - t0

        if self.pipelined:
            th = threading.Thread(target=prefetch, name="spice-prefetcher", daemon=True)
            th.start()
            if wait:
                th.join()
        else:
            prefetch()

        leaves = {name: handles[name] for name in handles}
        if wait:
            leaves = {name: h.wait() for name, h in leaves.items()}
        state = unflatten_state(meta["tree"], leaves)
        if wait:
            r.close()
        return state, meta, handles, stats
