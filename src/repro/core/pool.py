"""Zero page pool: pre-allocated, pre-zeroed host buffers.

The paper's zero page pool serves two purposes we reproduce exactly:
(1) buffer acquisition off the restore critical path (no allocator calls,
no page faults while the prefetcher is streaming), and (2) ZERO-classified
chunks are satisfied for free because pool buffers are already zeroed.

The pool is a size-classed free list living *inside* one ledger region
(:mod:`repro.core.memory`): ``held_bytes`` counts every byte under pool
management — free-list buffers AND outstanding buffers a caller acquired —
so capacity is an invariant, not an estimate.  The seed's hole (miss-path
``np.zeros`` allocations were never charged, so N concurrent restores
could stage unbounded untracked memory) is closed: misses charge on
allocation, and an allocation that does not fit the capacity (or the node
budget, when attached) is a tracked *unmanaged* transient that is dropped
— never pooled — at release.
"""
from __future__ import annotations

import threading
import weakref
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.memory import KIND_POOL, NodeMemoryManager


def _size_class(nbytes: int) -> int:
    c = 1 << 12
    while c < nbytes:
        c <<= 1
    return c


class BufferPool:
    def __init__(self, capacity_bytes: int = 2 << 30, prezero: bool = True):
        self.capacity = capacity_bytes
        self.prezero = prezero
        self._free: Dict[int, List[np.ndarray]] = defaultdict(list)
        # held = free-list bytes + outstanding (acquired, charged) bytes
        self._held = 0
        # id(buf) -> (weakref, size class, charged) for every buffer a
        # caller currently holds; the weakref lets release() verify the id
        # (no stale-id confusion) and lets _sweep reclaim the charge of
        # buffers a caller dropped without releasing (GC'd views).
        # ``charged=False`` marks unmanaged transients (miss did not fit
        # capacity/budget): their bytes are real RSS the ledger could not
        # admit, tracked in the ``unmanaged_bytes`` gauge so over-budget
        # staging overshoot is visible instead of silent.
        self._outstanding: Dict[int, Tuple[weakref.ref, int, bool]] = {}
        self._lock = threading.Lock()
        self._region = None       # ledger region mirroring _held
        self._memory: Optional[NodeMemoryManager] = None
        self.stats = {
            "hits": 0,
            "misses": 0,
            "released": 0,
            "zero_bytes_avoided": 0,
            "rezeroed_bytes": 0,
            "unmanaged_allocs": 0,   # miss did not fit capacity/budget
            "unmanaged_bytes": 0,    # gauge: live unmanaged bytes right now
            "unmanaged_bytes_hw": 0, # high-water of that gauge
            "dropped_releases": 0,   # released buffer not pooled
            "gc_reclaimed_bytes": 0, # charges swept from GC'd buffers
        }

    # --------------------------------------------------------------- ledger
    def attach(self, memory: NodeMemoryManager) -> None:
        """Charge this pool's bytes to a node ledger: one region of kind
        ``pool`` mirrors ``held_bytes`` from here on."""
        with self._lock:
            if self._memory is memory:
                return
            old = self._region
            self._region = None
            self._memory = None
        if old is not None:
            old.release()
        region = memory.reserve(0, KIND_POOL, owner="buffer-pool", block=False)
        with self._lock:
            self._memory = memory
            self._region = region
            if self._held and not region.resize(self._held):
                # existing bytes exceed the budget: trim free lists until
                # the region (and therefore the ledger) matches reality
                self._trim_free_locked()

    def detach(self) -> None:
        with self._lock:
            region, self._region, self._memory = self._region, None, None
        if region is not None:
            region.release()

    # Charging helpers: called under self._lock.  Lock order is always
    # pool lock -> manager lock (the manager never calls into the pool).
    def _charge_locked(self, sc: int) -> bool:
        if self._held + sc > self.capacity:
            return False
        if self._region is not None and not self._region.resize(self._held + sc):
            return False
        self._held += sc
        return True

    def _uncharge_locked(self, sc: int) -> None:
        self._held -= sc
        if self._region is not None:
            self._region.resize(self._held)

    def _trim_free_locked(self) -> None:
        """Drop free buffers until the ledger admits the held bytes."""
        while self._region is not None and not self._region.resize(self._held):
            for sc, lst in self._free.items():
                if lst:
                    lst.pop()
                    self._held -= sc
                    break
            else:
                return  # nothing left to trim; outstanding bytes stand

    def _record_outstanding_locked(self, buf: np.ndarray, sc: int, charged: bool) -> None:
        """Register an acquired buffer, first settling any stale entry at
        the same id — a new allocation can reuse the address of a GC'd
        buffer that was never released, and blindly overwriting its entry
        would leak that charge forever (release() defends the same way)."""
        stale = self._outstanding.get(id(buf))
        if stale is not None and stale[0]() is not buf:
            if stale[2]:
                self._uncharge_locked(stale[1])
                self.stats["gc_reclaimed_bytes"] += stale[1]
            else:
                self.stats["unmanaged_bytes"] -= stale[1]
        self._outstanding[id(buf)] = (weakref.ref(buf), sc, charged)

    def _sweep_locked(self) -> None:
        """Reclaim charges of outstanding buffers that were GC'd without a
        release (e.g. a non-pipelined restore whose state tree was dropped)."""
        dead = [k for k, (ref, _sc, _c) in self._outstanding.items() if ref() is None]
        for key in dead:
            _, sc, charged = self._outstanding.pop(key)
            if charged:
                self._uncharge_locked(sc)
                self.stats["gc_reclaimed_bytes"] += sc
            else:
                self.stats["unmanaged_bytes"] -= sc

    def reclaim(self, nbytes: int, protect=frozenset()) -> int:
        """Ladder rung: drop free-list buffers (largest first) until
        ``nbytes`` are uncharged.  Free buffers are pure performance cache
        — zeroed staging waiting for the next restore — so they go before
        any warm state is sacrificed; outstanding buffers (in use by live
        restores) are never touched.  Returns the bytes freed."""
        freed = 0
        with self._lock:
            while freed < nbytes:
                for sc in sorted(self._free, reverse=True):
                    if self._free[sc]:
                        self._free[sc].pop()
                        self._uncharge_locked(sc)
                        freed += sc
                        break
                else:
                    break
        return freed

    # ----------------------------------------------------------------- API
    def prime(self, sizes_bytes: List[int]) -> None:
        """Pre-populate the pool (amortized, function-agnostic setup)."""
        for nb in sizes_bytes:
            sc = _size_class(nb)
            with self._lock:
                if not self._charge_locked(sc):
                    return
                self._free[sc].append(np.zeros(sc, np.uint8))

    def acquire(self, nbytes: int) -> np.ndarray:
        """Returns a zeroed uint8 buffer of >= nbytes (view of pool block).
        Misses are charged against capacity (and the node ledger when
        attached); an allocation that does not fit is an unmanaged
        transient, dropped at release instead of pooled."""
        sc = _size_class(nbytes)
        with self._lock:
            lst = self._free.get(sc)
            if lst:
                buf = lst.pop()
                self.stats["hits"] += 1
                self._record_outstanding_locked(buf, sc, True)
                return buf
            self.stats["misses"] += 1
            self._sweep_locked()
            charged = self._charge_locked(sc)
        buf = np.zeros(sc, np.uint8)
        with self._lock:
            self._record_outstanding_locked(buf, sc, charged)
            if not charged:
                self.stats["unmanaged_allocs"] += 1
                self.stats["unmanaged_bytes"] += sc
                self.stats["unmanaged_bytes_hw"] = max(
                    self.stats["unmanaged_bytes_hw"], self.stats["unmanaged_bytes"]
                )
        return buf

    def release(self, buf: np.ndarray, dirty: bool = True) -> None:
        sc = buf.nbytes
        with self._lock:
            entry = self._outstanding.pop(id(buf), None)
            if entry is not None and entry[0]() is not buf:
                # stale id-reuse entry: its buffer was GC'd — settle that
                # entry's books, and treat the released buffer as foreign
                if entry[2]:
                    self._uncharge_locked(entry[1])
                    self.stats["gc_reclaimed_bytes"] += entry[1]
                else:
                    self.stats["unmanaged_bytes"] -= entry[1]
                entry = None
            if entry is not None and not entry[2]:  # unmanaged transient
                self.stats["unmanaged_bytes"] -= entry[1]
                entry = None
            if entry is None:
                # over-capacity / unmanaged / foreign release: drop on the
                # floor, GC reclaims — it was never charged, so pooling it
                # would exceed capacity
                self.stats["dropped_releases"] += 1
                return
            if dirty and self.prezero:
                buf[:] = 0  # re-zero off the critical path (caller's thread)
                self.stats["rezeroed_bytes"] += sc
            self._free[sc].append(buf)
            self.stats["released"] += 1

    def note_zero_chunks(self, nbytes: int) -> None:
        with self._lock:
            self.stats["zero_bytes_avoided"] += nbytes

    @property
    def held_bytes(self) -> int:
        """Bytes under pool management: free lists + outstanding acquired
        buffers (thread-safe)."""
        with self._lock:
            self._sweep_locked()
            return self._held

    def snapshot_stats(self) -> Dict[str, int]:
        with self._lock:
            return dict(self.stats)
