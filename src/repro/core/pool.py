"""Zero page pool: pre-allocated, pre-zeroed host buffers.

The paper's zero page pool serves two purposes we reproduce exactly:
(1) buffer acquisition off the restore critical path (no allocator calls,
no page faults while the prefetcher is streaming), and (2) ZERO-classified
chunks are satisfied for free because pool buffers are already zeroed.
"""
from __future__ import annotations

import threading
from collections import defaultdict
from typing import Dict, List

import numpy as np


def _size_class(nbytes: int) -> int:
    c = 1 << 12
    while c < nbytes:
        c <<= 1
    return c


class BufferPool:
    def __init__(self, capacity_bytes: int = 2 << 30, prezero: bool = True):
        self.capacity = capacity_bytes
        self.prezero = prezero
        self._free: Dict[int, List[np.ndarray]] = defaultdict(list)
        self._held = 0
        self._lock = threading.Lock()
        self.stats = {
            "hits": 0,
            "misses": 0,
            "released": 0,
            "zero_bytes_avoided": 0,
            "rezeroed_bytes": 0,
        }

    def prime(self, sizes_bytes: List[int]) -> None:
        """Pre-populate the pool (amortized, function-agnostic setup)."""
        for nb in sizes_bytes:
            sc = _size_class(nb)
            with self._lock:
                if self._held + sc > self.capacity:
                    return
                self._free[sc].append(np.zeros(sc, np.uint8))
                self._held += sc

    def acquire(self, nbytes: int) -> np.ndarray:
        """Returns a zeroed uint8 buffer of >= nbytes (view of pool block)."""
        sc = _size_class(nbytes)
        with self._lock:
            lst = self._free.get(sc)
            if lst:
                buf = lst.pop()
                self._held -= sc
                self.stats["hits"] += 1
                return buf
            self.stats["misses"] += 1
        return np.zeros(sc, np.uint8)

    def release(self, buf: np.ndarray, dirty: bool = True) -> None:
        sc = buf.nbytes
        with self._lock:
            if self._held + sc > self.capacity:
                return  # drop on the floor; GC reclaims
            if dirty and self.prezero:
                buf[:] = 0  # re-zero off the critical path (caller's thread)
                self.stats["rezeroed_bytes"] += sc
            self._free[sc].append(buf)
            self._held += sc
            self.stats["released"] += 1

    def note_zero_chunks(self, nbytes: int) -> None:
        with self._lock:
            self.stats["zero_bytes_avoided"] += nbytes

    @property
    def held_bytes(self) -> int:
        """Bytes currently resident in the free lists (thread-safe)."""
        with self._lock:
            return self._held

    def snapshot_stats(self) -> Dict[str, int]:
        with self._lock:
            return dict(self.stats)
