"""Device-resident restore fast path: host→HBM upload stream + base cache.

The host restore pipeline stops at host memory; the eager install path then
pays a synchronous per-tensor device copy on the prefetcher thread, so the
read stream stalls behind every upload (serialization-bound, not
read-bandwidth-bound).  This module closes that gap:

* :class:`UploadStream` — a double-buffered host→HBM upload engine.  The
  prefetcher's finalize enqueues an upload job and returns to reading; a
  dedicated uploader thread performs the device transfers.  The ring is
  bounded (``depth`` slots, default 2): while one slot uploads, the next
  is staged, and the reader only blocks when BOTH are in flight — uploads
  overlap with ongoing disk reads, and (because completion is tracked per
  tensor) with layer-gated decode in the function instance.  The pool's
  pre-zeroed staging buffers are the pinned-slot analogue: jobs hand them
  back to the pool after the device copy lands, re-zeroing on the uploader
  thread, off every critical path.

* :class:`DeviceImageCache` — base images resident in HBM once per node.
  Each (image, tensor) entry holds the base's pages on device, charged to
  the node ledger under the ``device_image`` kind and evictable via its
  own reclaim-ladder rung (order 1: after residual tails, before host base
  images — a dropped device base costs one re-upload from host, never a
  disk read).  Delta restores then upload ONLY private pages and
  materialize the full tensor on device with the overlay-patch kernel:
  BASE pages come from the shared HBM-resident base, ZERO pages are free,
  and no intermediate full host tensor is ever built.

* :class:`DevicePath` — the bundle a :class:`~repro.core.restore
  .SpiceRestorer` takes as its ``device_path=`` mode.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.cache import BaseImage
from repro.core.memory import (
    KIND_DEVICE_IMAGE,
    MemoryPressureError,
    NodeMemoryManager,
)


def _default_install(arr: np.ndarray):
    """Host array -> device array.  MUST copy: on CPU ``jnp.asarray`` can
    alias the staging buffer, which the pool recycles and re-zeroes (on TPU
    ``device_put`` always copies into HBM)."""
    import jax.numpy as jnp

    return jnp.array(arr, copy=True)


@dataclasses.dataclass
class FusedPlan:
    """Per-tensor device-patch plan, built host-side at restore planning
    time (the itable is already resident — zero deserialization).  ``src``
    indexes the COMPACT private staging buffer (pages 0..n_priv-1 in page
    order); ``runs`` maps JIF data-segment chunks onto compact slots."""

    name: str
    shape: Tuple[int, ...]
    dtype: str
    nbytes: int
    page_bytes: int
    page_elems: int
    n_pages: int
    n_priv: int
    kinds: np.ndarray
    src: np.ndarray
    runs: List[Tuple[int, int, int]]  # (compact_slot, data_chunk, count)
    base_pages: Optional[object] = None  # device (n_pages, page_elems) or None

    @property
    def priv_bytes(self) -> int:
        return self.n_priv * self.page_bytes


class UploadStream:
    """Bounded host→HBM upload ring shared by every restore on a node.

    One daemon uploader thread drains a queue of at most ``depth`` jobs.
    ``submit`` blocks the producer (the prefetch reader thread) only when
    the ring is full — the documented trade-off: brief reader stalls bound
    the staging memory in flight instead of letting uploads queue
    unboundedly.  Each job resolves exactly one :class:`TensorHandle`
    (``set`` on success, ``fail`` on error), so execution gates on real
    device arrays and a failed upload never hangs a waiter."""

    def __init__(self, depth: int = 2, name: str = "upload-stream",
                 install: Optional[Callable] = None,
                 simulate_bw: Optional[float] = None):
        """``simulate_bw`` (bytes/s) models the host→device interconnect
        roofline the same way ``simulate_read_bw`` models storage: each job
        sleeps for the bytes it actually moves (private pages only for
        fused jobs — the fast path's economy shows up as shorter sleeps).
        Labeled benchmark runs only; None on real hardware."""
        self.name = name
        self.depth = max(1, int(depth))
        self.install = install or _default_install
        self.simulate_bw = simulate_bw
        self._q: "queue.Queue" = queue.Queue(maxsize=self.depth)
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._pending = 0  # queued + executing jobs
        self._thread: Optional[threading.Thread] = None
        self._closed = False
        self.stats = {
            "uploads": 0,
            "fused_patches": 0,
            "uploaded_bytes": 0,
            "patched_bytes": 0,
            "upload_s": 0.0,
            "failures": 0,
        }

    # ------------------------------------------------------------ internals
    def _ensure_worker(self) -> None:
        with self._cv:
            if self._thread is None or not self._thread.is_alive():
                self._thread = threading.Thread(
                    target=self._loop, name=f"{self.name}-uploader", daemon=True
                )
                self._thread.start()

    def _submit(self, job: Callable[[], None]) -> None:
        with self._cv:
            if self._closed:
                raise RuntimeError(f"upload stream {self.name!r} is closed")
            self._pending += 1
        self._ensure_worker()
        self._q.put(job)  # blocks while the ring is full (backpressure)

    def _loop(self) -> None:
        while True:
            job = self._q.get()
            if job is None:
                return
            try:
                job()
            finally:
                with self._cv:
                    self._pending -= 1
                    self._cv.notify_all()

    def _note(self, dt: float, uploaded: int, patched: int, fused: bool) -> None:
        with self._cv:
            self.stats["uploads"] += 1
            self.stats["upload_s"] += dt
            self.stats["uploaded_bytes"] += uploaded
            if fused:
                self.stats["fused_patches"] += 1
                self.stats["patched_bytes"] += patched

    # ----------------------------------------------------------------- API
    def upload_full(self, handle, buf: np.ndarray, *, shape, dtype: str,
                    nbytes: int, stats=None, release=None) -> None:
        """Enqueue a whole-tensor upload: the staging buffer holds the full
        host tensor (base memcpy + private reads + zero pages); the device
        copy happens on the uploader thread, overlapped with further reads."""

        def job():
            import jax

            try:
                view = buf[:nbytes].view(np.dtype(dtype))
                view = view.reshape(shape) if shape else view.reshape(())
                t0 = time.perf_counter()
                if self.simulate_bw:
                    time.sleep(nbytes / self.simulate_bw)
                arr = self.install(view)
                jax.block_until_ready(arr)
                dt = time.perf_counter() - t0
                handle.set(arr)
                self._note(dt, nbytes, 0, fused=False)
                if stats is not None:
                    stats.add(upload_s=dt, uploaded_bytes=nbytes)
            except BaseException as exc:  # noqa: BLE001 — typed via handle
                with self._cv:
                    self.stats["failures"] += 1
                handle.fail(exc)
            finally:
                if release is not None:
                    release(buf)

        self._submit(job)

    def upload_fused(self, handle, plan: FusedPlan,
                     buf: Optional[np.ndarray], *, stats=None,
                     release=None) -> None:
        """Enqueue a fused upload+patch: only the compact private pages in
        ``buf`` cross to the device; the full tensor materializes there via
        the overlay-patch kernel against the HBM-resident base pages
        (``plan.base_pages``; ZERO pages cost nothing)."""

        def job():
            import jax
            import jax.numpy as jnp

            from repro.kernels.overlay_patch.ops import overlay_patch_device

            try:
                dtype = np.dtype(plan.dtype)
                t0 = time.perf_counter()
                if self.simulate_bw:
                    # only the private pages cross the interconnect
                    time.sleep(plan.priv_bytes / self.simulate_bw)
                if plan.n_priv and buf is not None:
                    priv_host = (
                        buf[: plan.priv_bytes]
                        .view(dtype)
                        .reshape(plan.n_priv, plan.page_elems)
                    )
                    priv = self.install(priv_host)
                else:
                    priv = jnp.zeros((1, plan.page_elems), dtype)
                base = plan.base_pages
                if base is None:  # ZERO/PRIVATE-only tensor: free base
                    base = jnp.zeros((plan.n_pages, plan.page_elems), dtype)
                out = overlay_patch_device(
                    base, priv,
                    jnp.asarray(plan.kinds, jnp.int32),
                    jnp.asarray(plan.src, jnp.int32),
                )
                n_elems = plan.nbytes // dtype.itemsize
                arr = out.reshape(-1)[:n_elems]
                arr = arr.reshape(plan.shape) if plan.shape else arr.reshape(())
                jax.block_until_ready(arr)
                dt = time.perf_counter() - t0
                handle.set(arr)
                self._note(dt, plan.priv_bytes, plan.nbytes, fused=True)
                if stats is not None:
                    stats.add(
                        upload_s=dt,
                        uploaded_bytes=plan.priv_bytes,
                        patched_on_device_bytes=plan.nbytes,
                    )
            except BaseException as exc:  # noqa: BLE001 — typed via handle
                with self._cv:
                    self.stats["failures"] += 1
                handle.fail(exc)
            finally:
                if release is not None and buf is not None:
                    release(buf)

        self._submit(job)

    def flush(self, timeout: Optional[float] = None) -> bool:
        """Block until every enqueued upload landed (tests/benchmarks)."""
        with self._cv:
            return self._cv.wait_for(lambda: self._pending == 0, timeout)

    def close(self, timeout: float = 5.0) -> None:
        """Drain outstanding uploads and stop the worker (idempotent)."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
            th = self._thread
        self.flush(timeout)
        if th is not None and th.is_alive():
            self._q.put(None)
            th.join(timeout)

    def snapshot_stats(self) -> Dict[str, float]:
        with self._cv:
            return dict(self.stats)


class DeviceImageCache:
    """HBM-resident base pages, shared by every fused restore on a node.

    One entry per (base image, tensor, dtype, page geometry): the base's
    raw bytes padded to the restored tensor's page count, viewed in the
    tensor's dtype, installed on device ONCE — the ROADMAP scenario where
    thousands of fine-tunes of one base share a single HBM-resident copy.
    Attached to the node ledger, entries are charged as ``device_image``
    regions and LRU-evicted by the pressure reclaimer (rung
    ``RECLAIM_ORDER``); every entry is recoverable from the host
    :class:`BaseImage`, so the rung may drain the cache entirely."""

    RECLAIM_ORDER = 1  # residual (0) -> device images -> chunk CAS (2) ->
    # host image cache (3)

    def __init__(self, capacity_bytes: int = 4 << 30,
                 install: Optional[Callable] = None):
        self.capacity = capacity_bytes
        self.install = install or _default_install
        self._entries: "OrderedDict[Tuple, Tuple[object, int]]" = OrderedDict()
        self._regions: Dict[Tuple, object] = {}
        self._lock = threading.Lock()
        self._memory: Optional[NodeMemoryManager] = None
        self.total_bytes = 0
        self.stats = {
            "hits": 0, "misses": 0, "evictions": 0,
            "built_bytes": 0, "base_bytes_served": 0,
        }

    # --------------------------------------------------------------- ledger
    def attach(self, memory: NodeMemoryManager) -> None:
        """Charge resident entries to the node ledger and register the LRU
        eviction as the ladder's device-image rung."""
        evicted = []
        with self._lock:
            if self._memory is memory:
                return
            self._memory = memory
            entries = list(self._entries.items())
        for key, (_dev, nbytes) in entries:
            try:
                region = memory.reserve(
                    nbytes, KIND_DEVICE_IMAGE,
                    owner="/".join(map(str, key[:2])), block=False,
                )
            except MemoryPressureError:
                # always recoverable from the host base: drop, don't raise
                self._drop(key)
                continue
            region.commit()
            with self._lock:
                if key in self._entries:
                    self._regions[key] = region
                else:
                    evicted.append(region)
        for r in evicted:
            r.release()
        memory.register_reclaimer("device-image", self.reclaim, self.RECLAIM_ORDER)

    # ----------------------------------------------------------------- API
    def get_pages(self, base: BaseImage, tensor_name: str, n_pages: int,
                  page_elems: int, dtype) -> Optional[object]:
        """Device (n_pages, page_elems) base pages for one tensor, building
        and charging the entry on first use.  Returns None when the entry
        cannot be served (page-size mismatch, tensor absent from the base,
        or the ledger cannot admit the bytes even after reclaim) — the
        caller falls back to the host path for that tensor."""
        dtype = np.dtype(dtype)
        page_bytes = page_elems * dtype.itemsize
        key = (base.name, tensor_name, dtype.str, int(n_pages), int(page_elems))
        with self._lock:
            hit = self._entries.get(key)
            if hit is not None:
                self.stats["hits"] += 1
                self._entries.move_to_end(key)
                return hit[0]
        if base.page_size != page_bytes or base.digests(tensor_name) is None:
            return None
        # build OUTSIDE the lock: pad the base's raw bytes to the restored
        # tensor's page count (a shorter base cannot own pages past its
        # length — classify never marks them BASE — so zero padding is safe)
        raw = base.chunk_bytes(tensor_name, 0, n_pages)
        host = np.zeros(n_pages * page_bytes, np.uint8)
        host[: len(raw)] = raw[: n_pages * page_bytes]
        import jax

        dev = self.install(host.view(dtype).reshape(n_pages, page_elems))
        jax.block_until_ready(dev)
        nbytes = int(getattr(dev, "nbytes", n_pages * page_bytes))
        region = None
        if self._memory is not None:
            # reserve BEFORE taking the cache lock: admission may run the
            # reclaim ladder, whose device-image rung locks this cache
            try:
                region = self._memory.reserve(
                    nbytes, KIND_DEVICE_IMAGE,
                    owner=f"{base.name}/{tensor_name}", block=False,
                )
            except MemoryPressureError:
                return None  # caller falls back to the host path
            region.commit()
        evicted = []
        with self._lock:
            raced = self._entries.get(key)
            if raced is not None:  # lost a build race: keep the winner
                self.stats["hits"] += 1
                if region is not None:
                    evicted.append(region)
                dev = raced[0]
            else:
                self.stats["misses"] += 1
                self.stats["built_bytes"] += nbytes
                self._entries[key] = (dev, nbytes)
                self.total_bytes += nbytes
                if region is not None:
                    self._regions[key] = region
                evicted.extend(self._evict_capacity())
        for r in evicted:
            r.release()
        return dev

    def note_base_served(self, nbytes: int) -> None:
        """Fused restores report BASE bytes materialized from device-resident
        pages (the device-tier analogue of the host cache's counter)."""
        with self._lock:
            self.stats["base_bytes_served"] += nbytes

    def resident_bytes(self) -> int:
        with self._lock:
            return self.total_bytes

    def resident_entries(self) -> int:
        with self._lock:
            return len(self._entries)

    # ------------------------------------------------------------- eviction
    def _drop(self, key) -> int:
        with self._lock:
            entry = self._entries.pop(key, None)
            if entry is None:
                return 0
            self.total_bytes -= entry[1]
            self.stats["evictions"] += 1
            return entry[1]

    def _evict_capacity(self):
        """Capacity LRU (under self._lock); returns regions to release once
        the lock drops (lock order is always cache -> manager)."""
        released = []
        while self.total_bytes > self.capacity and len(self._entries) > 1:
            key, (_dev, nbytes) = self._entries.popitem(last=False)
            self.total_bytes -= nbytes
            self.stats["evictions"] += 1
            region = self._regions.pop(key, None)
            if region is not None:
                released.append(region)
        return released

    def reclaim(self, nbytes: int, protect=frozenset()) -> int:
        """Ladder rung 1: LRU-evict device base pages until ``nbytes`` are
        freed.  Every entry is recoverable (one re-upload from the host
        base image), so the rung may drain the cache entirely."""
        freed = 0
        released = []
        with self._lock:
            while self._entries and freed < nbytes:
                key, (_dev, ebytes) = self._entries.popitem(last=False)
                self.total_bytes -= ebytes
                self.stats["evictions"] += 1
                freed += ebytes
                region = self._regions.pop(key, None)
                if region is not None:
                    released.append(region)
        for r in released:
            r.release()
        return freed

    def snapshot_stats(self) -> Dict[str, int]:
        with self._lock:
            return dict(self.stats)


@dataclasses.dataclass
class DevicePath:
    """The device-restore bundle a :class:`SpiceRestorer` takes as its
    ``device_path=`` mode: the node's shared upload ring, the HBM base
    cache (None disables fused patching — every tensor full-uploads), and
    the host→device install transform."""

    upload: UploadStream
    images: Optional[DeviceImageCache] = None
    install: Optional[Callable] = None

    def installer(self) -> Callable:
        return self.install or _default_install
