"""Snapshot writer: state -> JIF.

Reproduces the paper's offline JIF-preparation pipeline (§4.1):
  1. per-subsystem trimming (the MADV_FREE->DONTNEED / stack-trim analogue):
     caller-supplied trim rules drop state the function won't need;
  2. chunk classification {ZERO, BASE, PRIVATE} against an optional base
     image (overlay dedup; zero elision);
  3. working-set relocation: PRIVATE chunks are written contiguously in
     first-access order so restore is one sequential high-throughput read;
  4. batched metadata: one msgpack header (+ raw interval tables).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core import jif, overlay
from repro.core.cache import BaseImage
from repro.core.treeutil import flatten_state


@dataclasses.dataclass
class SnapshotStats:
    total_bytes: int = 0
    private_bytes: int = 0
    base_bytes: int = 0
    zero_bytes: int = 0
    n_tensors: int = 0
    n_intervals: int = 0
    write_s: float = 0.0
    classify_s: float = 0.0

    @property
    def file_fraction(self) -> float:
        return self.private_bytes / max(self.total_bytes, 1)

    def as_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["file_fraction"] = self.file_fraction
        return d


def snapshot(
    state,
    path: str,
    *,
    base: Optional[BaseImage] = None,
    access_order: Optional[List[str]] = None,
    page_size: int = overlay.DEFAULT_PAGE,
    meta: Optional[Dict[str, Any]] = None,
    trim_fn: Optional[Callable] = None,
) -> SnapshotStats:
    t0 = time.perf_counter()
    if trim_fn is not None:
        state = trim_fn(state)
    leaves, treedesc = flatten_state(state)
    by_name = dict(leaves)
    names = [n for n, _ in leaves]

    # access-order relocation: listed tensors first, stragglers after
    if access_order:
        listed = [n for n in access_order if n in by_name]
        rest = [n for n in names if n not in set(listed)]
        order = listed + rest
        ws_names = listed
    else:
        order = names
        ws_names = names

    stats = SnapshotStats(n_tensors=len(names))
    entries: List[jif.TensorEntry] = []
    itables: Dict[str, np.ndarray] = {}
    buffers: Dict[str, np.ndarray] = {}
    cursor = 0  # data-segment offset in chunks

    for name in order:
        arr = by_name[name]
        raw = np.ascontiguousarray(arr).view(np.uint8).reshape(-1)
        buffers[name] = raw
        kinds = overlay.classify(
            memoryview(raw), page_size, base.digests(name) if base else None
        )
        table = overlay.intervals_from_kinds(kinds)
        for row in table:
            if row[2] == overlay.KIND_PRIVATE:
                row[3] = cursor
                cursor += row[1]
        itables[name] = table
        stats.n_intervals += len(table)
        nb = raw.nbytes
        stats.total_bytes += nb
        counts = overlay.IntervalTable(table).counts()
        last_partial = nb - (overlay.n_chunks(nb, page_size) - 1) * page_size

        def _kind_bytes(k):
            n = counts[k]
            # last chunk may be partial; attribute to its kind
            if n and int(kinds[-1]) == k:
                return (n - 1) * page_size + last_partial
            return n * page_size

        stats.private_bytes += _kind_bytes(overlay.KIND_PRIVATE)
        stats.base_bytes += _kind_bytes(overlay.KIND_BASE)
        stats.zero_bytes += _kind_bytes(overlay.KIND_ZERO)
        entries.append(
            jif.TensorEntry(name=name, dtype=str(arr.dtype), shape=tuple(arr.shape), nbytes=nb)
        )
    stats.classify_s = time.perf_counter() - t0

    def data_iter():
        for name in order:
            raw = buffers[name]
            for start, n, _src in overlay.IntervalTable(itables[name]).private_runs():
                chunk = raw[start * page_size : (start + n) * page_size]
                if len(chunk) % page_size:  # pad the final partial chunk
                    chunk = np.concatenate(
                        [chunk, np.zeros(page_size - len(chunk) % page_size, np.uint8)]
                    )
                yield chunk.tobytes()

    header_meta = dict(meta or {})
    header_meta.setdefault("tree", treedesc)
    header_meta.setdefault("access_order", order)
    header_meta.setdefault("working_set", ws_names)
    header_meta.setdefault("created_at", time.time())

    t1 = time.perf_counter()
    jif.write_jif(
        path,
        header_meta,
        entries,
        itables,
        data_iter(),
        page_size,
        base_ref={"name": base.name} if base else None,
    )
    stats.write_s = time.perf_counter() - t1
    return stats
