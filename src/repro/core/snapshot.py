"""Snapshot writer: state -> JIF — compatibility wrapper.

The actual writer is the staged :class:`repro.core.lifecycle.SnapshotPipeline`
(trim → classify → relocate → write, §4.1); this free function keeps the
seed's call surface for tests, benchmarks, and the fine-tune manager.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

from repro.core import overlay
from repro.core.lifecycle import SnapshotPipeline, SnapshotStats

__all__ = ["snapshot", "SnapshotStats"]


def snapshot(
    state,
    path: str,
    *,
    base=None,
    parent: Optional[str] = None,
    access_order: Optional[List[str]] = None,
    working_set: Optional[List[str]] = None,
    page_size: int = overlay.DEFAULT_PAGE,
    meta: Optional[Dict[str, Any]] = None,
    trim_fn: Optional[Callable] = None,
    node_cache=None,
    memory=None,
) -> SnapshotStats:
    return SnapshotPipeline(
        page_size=page_size, trim_fn=trim_fn, node_cache=node_cache,
        memory=memory,
    ).run(
        state,
        path,
        base=base,
        parent=parent,
        access_order=access_order,
        working_set=working_set,
        meta=meta,
    )
