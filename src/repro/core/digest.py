"""Chunk identity — THE single definition of the content digest.

Chunk digests are first-class identity across the whole stack: the snapshot
writer stores them in the JIF v2 digest region, overlay classification
compares them against a base, and the content-addressed chunk store
(:mod:`repro.core.chunkstore`) keys its on-disk CAS and the node-resident
chunk cache by them.  All three MUST agree on the hash function, its width,
and the chunking convention (the final chunk of a tensor is hashed over its
*unpadded* tail bytes), or identity silently diverges — so the constants and
helpers live here and everywhere else imports them.
"""
from __future__ import annotations

import hashlib
from typing import Dict

import numpy as np

__all__ = [
    "DIGEST_BYTES",
    "chunk_digest",
    "chunk_digests",
    "digest_key",
    "zero_chunk_digest",
]

# blake2b truncated to 16 bytes: collision-safe at cluster scale while
# keeping the per-tensor digest region (n_chunks x 16) small enough to read
# in one pread at restore-planning time.
DIGEST_BYTES = 16


def chunk_digest(data) -> bytes:
    """Digest of ONE chunk's (unpadded) bytes."""
    return hashlib.blake2b(data, digest_size=DIGEST_BYTES).digest()


def chunk_digests(buf: memoryview, page_size: int) -> np.ndarray:
    """(n, 16) uint8 blake2b digests per chunk of ``buf``.  The last chunk
    is hashed over the actual tail length, not padded to ``page_size`` —
    restore-side CAS lookups must truncate the same way."""
    buf = memoryview(buf).cast("B")
    n = max(1, -(-len(buf) // page_size))
    out = np.empty((n, DIGEST_BYTES), np.uint8)
    for i in range(n):
        h = hashlib.blake2b(
            buf[i * page_size : (i + 1) * page_size], digest_size=DIGEST_BYTES
        )
        out[i] = np.frombuffer(h.digest(), np.uint8)
    return out


def digest_key(row) -> bytes:
    """Canonical hashable key for one digest (a (16,) uint8 row or bytes)."""
    if isinstance(row, (bytes, bytearray)):
        return bytes(row)
    return row.tobytes()


_zero_digests: Dict[int, bytes] = {}


def zero_chunk_digest(length: int) -> bytes:
    """Digest of an all-zero chunk of ``length`` bytes (memoized — v1
    backfill hashes the same zero run lengths over and over)."""
    dg = _zero_digests.get(length)
    if dg is None:
        dg = _zero_digests[length] = chunk_digest(bytes(length))
    return dg
