"""Working-set / first-touch-order tracing (the paper's kernel tracing
module, §5): record the order in which execution first touches each tensor,
iterating until the trace is stable, then feed it to the snapshot writer so
the JIF data segment is laid out in access order."""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core.treeutil import flatten_state, unflatten_state


class AccessRecorder:
    """Wrap a state tree so every leaf access is recorded (first touch)."""

    def __init__(self, state):
        self._order: List[str] = []
        self._seen = set()
        self._lock = threading.Lock()
        leaves, self._tree = flatten_state(state)
        self._leaves = dict(leaves)

    def _touch(self, name: str):
        with self._lock:
            if name not in self._seen:
                self._seen.add(name)
                self._order.append(name)

    def view(self):
        rec = self

        class _Proxy(np.ndarray):
            def __array_finalize__(self, obj):
                pass

        def wrap(name, arr):
            class _Lazy:
                """Touch-on-use leaf: coerces to the array on first use."""

                def __init__(self):
                    self.name = name

                def __jax_array__(self):
                    rec._touch(name)
                    return rec._leaves[name]

                def __array__(self, dtype=None, copy=None):
                    rec._touch(name)
                    a = rec._leaves[name]
                    return np.asarray(a, dtype=dtype)

                @property
                def shape(self):
                    return rec._leaves[name].shape

                @property
                def dtype(self):
                    return rec._leaves[name].dtype

                @property
                def ndim(self):
                    return rec._leaves[name].ndim

            return _Lazy()

        return unflatten_state(
            self._tree, {n: wrap(n, a) for n, a in self._leaves.items()}
        )

    @property
    def touched(self) -> List[str]:
        """Only the leaves execution actually touched — the traced working
        set; ``order`` appends the untouched stragglers after them."""
        with self._lock:
            return list(self._order)

    @property
    def order(self) -> List[str]:
        with self._lock:
            out = list(self._order)
        rest = [n for n in self._leaves if n not in set(out)]
        return out + rest


def trace_access_order(
    state,
    run_fn: Callable[[Any], None],
    max_iters: int = 3,
    return_touched: bool = False,
):
    """Run ``run_fn(state_view)`` under tracing until the first-touch order
    reaches a fixed point (paper: iterative re-tracing to kill tracer
    artifacts).  With ``return_touched`` also returns the touched-only
    prefix (the traced working set, without untouched stragglers)."""
    prev: Optional[List[str]] = None
    order: List[str] = []
    touched: List[str] = []
    for _ in range(max_iters):
        rec = AccessRecorder(state)
        run_fn(rec.view())
        order = rec.order
        touched = rec.touched
        if order == prev:
            break
        prev = order
    if return_touched:
        return order, touched
    return order


def static_access_order(cfg, params_like) -> List[str]:
    """Structure-derived order: embed -> blocks in execution order -> final
    norm -> unembed. Used when an instrumented run isn't available."""
    leaves, _ = flatten_state(params_like)
    names = [n for n, _ in leaves]

    def rank(n: str):
        if n.startswith("embed/tok"):
            return (0, n)
        if n.startswith("layers/"):
            try:
                return (1 + int(n.split("/")[1]), n)
            except ValueError:
                return (1, n)
        if n.startswith("pattern/"):
            parts = n.split("/")
            try:
                return (1 + int(parts[1]), n)
            except ValueError:
                return (1, n)
        if n.startswith("remainder/"):
            return (10_000, n)
        if n.startswith("final_norm"):
            return (20_000, n)
        if "unembed" in n:
            return (30_000, n)
        return (15_000, n)

    return sorted(names, key=rank)
