"""Snapshot lifecycle pipeline: the paper's §4.1/§5 closed loop, writer side.

The offline JIF preparation is a staged pipeline::

    trim ──▶ classify ──▶ relocate ──▶ write

* **trim** — per-subsystem trimming (the MADV_FREE→DONTNEED / stack-trim
  analogue): caller-supplied rules drop state the function won't need.
* **classify** — chunk classification {ZERO, BASE, PRIVATE} against a digest
  source: an in-memory :class:`BaseImage`, or a **parent JIF on disk** (delta
  snapshots — a fine-tuned warm instance checkpoints only its changed pages;
  JIF v2 parents serve digests straight from the file, v1 parents are
  materialized once through the node cache).
* **relocate** — PRIVATE chunks of the traced working set are laid out
  contiguously at the front of the data segment in first-access order, and
  the ``ws_boundary`` (data-segment chunk where the working set ends) is
  recorded so restore can promote the instance the moment one sequential
  read lands, while the residual streams at background priority.
* **write** — one msgpack header + raw interval tables + raw chunk digests
  + the data segment, atomically (tmp + rename).

The legacy free function :func:`repro.core.snapshot.snapshot` remains as a
thin compatibility wrapper over this pipeline.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import jif, overlay
from repro.core.digest import chunk_digests
from repro.core.treeutil import flatten_state


@dataclasses.dataclass
class SnapshotStats:
    total_bytes: int = 0
    private_bytes: int = 0
    base_bytes: int = 0
    zero_bytes: int = 0
    n_tensors: int = 0
    n_intervals: int = 0
    write_s: float = 0.0
    classify_s: float = 0.0
    ws_boundary: int = 0      # data-segment chunk where the working set ends
    ws_tensors: int = 0       # tensors inside the traced working set
    parent: Optional[str] = None  # parent JIF path for delta snapshots

    @property
    def file_fraction(self) -> float:
        return self.private_bytes / max(self.total_bytes, 1)

    def as_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["file_fraction"] = self.file_fraction
        return d


class _Classified:
    """Per-tensor classification artifacts flowing between pipeline stages."""

    __slots__ = ("names", "buffers", "kinds", "itables", "digests", "entries", "treedesc")

    def __init__(self):
        self.names: List[str] = []
        self.buffers: Dict[str, np.ndarray] = {}
        self.kinds: Dict[str, np.ndarray] = {}
        self.itables: Dict[str, np.ndarray] = {}
        self.digests: Dict[str, np.ndarray] = {}
        self.entries: Dict[str, jif.TensorEntry] = {}
        self.treedesc: Any = None


class _JifDigestSource:
    """Digest provider over a parent JIF: v2 parents serve stored digests
    with zero data-segment I/O.  v1 parents try the backfill path first
    (hash straight from the file, persisted sidecar — no BASE chunks means
    no materialization needed); delta v1 parents are materialized once into
    the node cache, and their digests are persisted as a sidecar so the
    NEXT classify against them is zero-I/O too."""

    def __init__(self, reader: jif.JifReader, node_cache=None):
        self._r = reader
        self._img = None
        self._node_cache = node_cache
        if not reader.has_digests:
            try:
                # in-memory backfill: classify must not leave sidecars next
                # to images it merely READ (e.g. checked-in goldens) — the
                # dedup paths (restore with a chunk cache, CAS ingest)
                # persist the sidecar when the image actually participates
                reader.ensure_digests(write_sidecar=False)
                return
            except ValueError:
                pass  # BASE chunks: parent bytes are not in this file
            self._img = _materialize_parent(reader.path, node_cache)
            try:
                reader.write_digest_sidecar({
                    t.name: self._img.digests(t.name)
                    for t in reader.tensors
                    if self._img.digests(t.name) is not None
                })
            except OSError:
                pass  # read-only store: backfill stays in-memory this run

    def digests(self, name: str) -> Optional[np.ndarray]:
        if self._img is not None:
            return self._img.digests(name)
        if name not in self._r.by_name:
            return None
        return self._r.digests(name)


_writer_parent_cache = None  # lazily-built; memoizes v1 parents across calls


def _materialize_parent(path: str, node_cache=None):
    from repro.core.cache import BaseImage, NodeImageCache

    global _writer_parent_cache
    if node_cache is None:
        # memoize across snapshot() calls: a loop of K deltas against one
        # v1 parent must materialize it once, not K times
        if _writer_parent_cache is None:
            _writer_parent_cache = NodeImageCache(capacity_bytes=2 << 30)
        node_cache = _writer_parent_cache
    name = parent_cache_key(path)
    img = node_cache.get(name)
    if img is None:
        img = BaseImage.from_jif(path, name=name, node_cache=node_cache)
        node_cache.put(img)
    return img


def parent_cache_key(path: str) -> str:
    """Node-cache key under which a parent JIF's materialized image lives —
    the writer and the restorer must agree on it.  The key binds the file's
    identity (mtime + size), so a parent rewritten in place (relayout does
    exactly that) gets a fresh key instead of serving stale cached bytes,
    and a restore whose on-disk parent no longer matches the key its child
    was classified against fails loudly instead of corrupting silently."""
    st = os.stat(path)
    return f"jif:{os.path.abspath(path)}#{st.st_mtime_ns:x}.{st.st_size:x}"


def delta_snapshot(
    state,
    path: str,
    parent: str,
    *,
    meta: Optional[Dict[str, Any]] = None,
    node_cache=None,
    memory=None,
) -> SnapshotStats:
    """Snapshot ``state`` as a delta against ``parent`` (a JIF on disk),
    inheriting the parent's access order and working-set boundary.

    This is the warm-state handoff writer: a live WARM instance's tree is
    classified against the function's own published image, so only dirty
    pages land in the data segment (typically KBs), while the child keeps
    the parent's restore layout — the successor node promotes at the same
    ws boundary the original restore would have.  ``stats.private_bytes``
    is the delta's wire cost; everything else restores through the parent
    chain (node caches / chunk CAS / peer fetch)."""
    from repro.core.jif import JifReader

    with JifReader(parent) as r:
        order = r.meta.get("access_order")
        ws = r.meta.get("working_set")
    pipeline = SnapshotPipeline(node_cache=node_cache, memory=memory)
    return pipeline.run(
        state, path, parent=parent,
        access_order=order, working_set=ws, meta=meta,
    )


class SnapshotPipeline:
    """Staged snapshot writer (trim → classify → relocate → write)."""

    def __init__(
        self,
        page_size: int = overlay.DEFAULT_PAGE,
        trim_fn: Optional[Callable] = None,
        node_cache=None,
        memory=None,
    ):
        self.page_size = page_size
        self.trim_fn = trim_fn
        self.node_cache = node_cache  # used to materialize v1 parents once
        # optional node ledger (repro.core.memory.NodeMemoryManager): the
        # writer's classification buffers are charged as scratch for the
        # duration of run(), so snapshot writes compete with live tenants
        self.memory = memory

    # ------------------------------------------------------------- stage 1
    def trim(self, state):
        return self.trim_fn(state) if self.trim_fn is not None else state

    # ------------------------------------------------------------- stage 2
    def classify(self, state, digest_source=None) -> Tuple[_Classified, SnapshotStats]:
        """Flatten the state and classify every chunk; digests are computed
        for every tensor (stored in the v2 image so children can delta
        against it without reading our data segment)."""
        ps = self.page_size
        leaves, treedesc = flatten_state(state)
        c = _Classified()
        c.treedesc = treedesc
        stats = SnapshotStats(n_tensors=len(leaves))
        for name, arr in leaves:
            raw = np.ascontiguousarray(arr).view(np.uint8).reshape(-1)
            c.names.append(name)
            c.buffers[name] = raw
            mv = memoryview(raw)
            dg = chunk_digests(mv, ps)  # shared identity (repro.core.digest)
            c.digests[name] = dg
            base_dg = digest_source.digests(name) if digest_source is not None else None
            c.kinds[name] = overlay.classify(mv, ps, base_dg, digests=dg)
            c.entries[name] = jif.TensorEntry(
                name=name, dtype=str(arr.dtype), shape=tuple(np.asarray(arr).shape),
                nbytes=raw.nbytes,
            )
            self._account(stats, name, c)
        return c, stats

    def _account(self, stats: SnapshotStats, name: str, c: _Classified) -> None:
        ps = self.page_size
        nb = c.buffers[name].nbytes
        kinds = c.kinds[name]
        stats.total_bytes += nb
        last_partial = nb - (overlay.n_chunks(nb, ps) - 1) * ps
        counts = np.bincount(kinds, minlength=3)

        def _kind_bytes(k):
            n = int(counts[k])
            # last chunk may be partial; attribute it to its kind
            if n and int(kinds[-1]) == k:
                return (n - 1) * ps + last_partial
            return n * ps

        stats.private_bytes += _kind_bytes(overlay.KIND_PRIVATE)
        stats.base_bytes += _kind_bytes(overlay.KIND_BASE)
        stats.zero_bytes += _kind_bytes(overlay.KIND_ZERO)

    # ------------------------------------------------------------- stage 3
    def relocate(
        self,
        c: _Classified,
        access_order: Optional[List[str]] = None,
        working_set: Optional[List[str]] = None,
    ) -> Tuple[List[str], List[str], int]:
        """Assign data-segment offsets in first-access order and compute the
        working-set boundary.  Returns (order, ws_names, ws_boundary)."""
        names = c.names
        if access_order:
            listed = [n for n in access_order if n in c.entries]
            listed_set = set(listed)
            rest = [n for n in names if n not in listed_set]
            order = listed + rest
        else:
            order = list(names)
            listed = order
        if working_set is not None:
            ws_names = [n for n in working_set if n in c.entries]
        else:
            ws_names = listed
        ws_set = set(ws_names)

        cursor = 0
        ws_boundary = 0
        for name in order:
            table = overlay.intervals_from_kinds(c.kinds[name])
            for row in table:
                if row[2] == overlay.KIND_PRIVATE:
                    row[3] = cursor
                    cursor += int(row[1])
            c.itables[name] = table
            if name in ws_set:
                ws_boundary = cursor
        if not ws_set:
            ws_boundary = cursor
        return order, ws_names, ws_boundary

    # ------------------------------------------------------------- stage 4
    def write(
        self,
        path: str,
        c: _Classified,
        order: List[str],
        meta: Dict[str, Any],
        base_ref: Optional[Dict],
        ws_boundary: int,
    ) -> None:
        ps = self.page_size
        scratch = np.zeros(ps, np.uint8)  # one shared pad buffer, not a
        # fresh np.concatenate per tensor's final partial chunk

        def data_iter():
            for name in order:
                raw = c.buffers[name]
                for start, n, _src in overlay.IntervalTable(c.itables[name]).private_runs():
                    chunk = raw[start * ps : (start + n) * ps]
                    full = (len(chunk) // ps) * ps
                    if full:
                        yield chunk[:full].tobytes()
                    tail = len(chunk) - full
                    if tail:
                        scratch[:tail] = chunk[full:]
                        scratch[tail:] = 0
                        yield scratch.tobytes()

        jif.write_jif(
            path,
            meta,
            [c.entries[n] for n in order],
            c.itables,
            data_iter(),
            ps,
            base_ref=base_ref,
            digests=c.digests,
            ws_boundary=ws_boundary,
        )

    # ----------------------------------------------------------------- run
    def run(
        self,
        state,
        path: str,
        *,
        base=None,
        parent: Optional[str] = None,
        access_order: Optional[List[str]] = None,
        working_set: Optional[List[str]] = None,
        meta: Optional[Dict[str, Any]] = None,
    ) -> SnapshotStats:
        """Run the full pipeline.  ``base`` is an in-memory
        :class:`BaseImage`; ``parent`` is a path to a parent JIF on disk
        (delta snapshot — at most one of the two)."""
        if base is not None and parent is not None:
            raise ValueError("pass either base= (in-memory) or parent= (on-disk), not both")

        t0 = time.perf_counter()
        state = self.trim(state)

        scratch = None
        if self.memory is not None:
            from repro.core.memory import KIND_SCRATCH

            nbytes = sum(
                getattr(arr, "nbytes", 0) for _, arr in flatten_state(state)[0]
            )
            scratch = self.memory.reserve(
                nbytes, KIND_SCRATCH, owner=f"snapshot:{os.path.basename(path)}"
            )
        try:
            return self._run(state, path, base, parent, access_order,
                             working_set, meta, t0)
        finally:
            if scratch is not None:
                scratch.release()

    def _run(self, state, path, base, parent, access_order, working_set,
             meta, t0) -> SnapshotStats:

        digest_source = base
        base_ref = {"name": base.name} if base is not None else None
        parent_reader = None
        if parent is not None:
            parent_reader = jif.JifReader(parent)
            if parent_reader.page_size != self.page_size:
                parent_reader.close()
                raise ValueError(
                    f"parent page_size {parent_reader.page_size} != {self.page_size}"
                )
            digest_source = _JifDigestSource(parent_reader, self.node_cache)
            base_ref = {
                "name": parent_cache_key(parent),
                "path": os.path.abspath(parent),
            }

        try:
            c, stats = self.classify(state, digest_source)
        finally:
            if parent_reader is not None:
                parent_reader.close()
        order, ws_names, ws_boundary = self.relocate(c, access_order, working_set)
        stats.classify_s = time.perf_counter() - t0
        stats.n_intervals = sum(len(c.itables[n]) for n in order)
        stats.ws_boundary = ws_boundary
        stats.ws_tensors = len(ws_names)
        stats.parent = os.path.abspath(parent) if parent else None

        header_meta = dict(meta or {})
        header_meta.setdefault("tree", c.treedesc)
        header_meta.setdefault("access_order", order)
        header_meta.setdefault("working_set", ws_names)
        header_meta.setdefault("created_at", time.time())

        t1 = time.perf_counter()
        self.write(path, c, order, header_meta, base_ref, ws_boundary)
        stats.write_s = time.perf_counter() - t1
        return stats
