"""Baseline restorers the paper compares against (faithfully re-implemented
in the model-instance setting; asterisks = tuned variants as in the paper).

* ``criu_star``  — process-level replay: one file per resource, restored by
  re-walking metadata and re-issuing per-tensor open/read/close ("syscall
  replay"); no dedup, no zero elision, no access-order layout, no overlap.
* ``reap_star``  — VM-style monolithic image with *synchronous* working-set
  prefetch: one blob capturing everything (no trim: optimizer state and
  scratch included — the "whole guest" effect), read fully before execution.
* ``faasnap_star`` — same image, *asynchronous advisory* prefetch: a
  background reader streams the blob in file order with no completion
  contract; execution-demanded tensors that aren't resident take a blocking
  "major fault" served by small reads.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pickle
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.core.treeutil import flatten_state, unflatten_state


@dataclasses.dataclass
class BaselineStats:
    metadata_s: float = 0.0
    total_s: float = 0.0
    bytes_read: int = 0
    io_ops: int = 0
    restore_ops: int = 0  # per-resource replay operations
    major_faults: int = 0

    def as_dict(self):
        return dataclasses.asdict(self)


# --------------------------------------------------------------- CRIU* -----
def criu_star_snapshot(state, dirpath: str) -> None:
    d = Path(dirpath)
    d.mkdir(parents=True, exist_ok=True)
    leaves, tree = flatten_state(state)
    index = []
    for i, (name, arr) in enumerate(leaves):
        fn = f"res{i:05d}.npy"
        np.save(d / fn, np.ascontiguousarray(arr))
        index.append({"name": name, "file": fn})
    (d / "meta.json").write_text(json.dumps({"tree": tree, "index": index}))


def criu_star_restore(dirpath: str, simulate_read_bw=None) -> Tuple[Any, BaselineStats]:
    stats = BaselineStats()
    t0 = time.perf_counter()
    d = Path(dirpath)
    meta = json.loads((d / "meta.json").read_text())
    stats.restore_ops += 1
    stats.metadata_s = time.perf_counter() - t0
    leaves = {}
    for ent in meta["index"]:
        # per-resource replay: open + header parse + read + close per tensor
        p = d / ent["file"]
        arr = np.load(p)
        stats.restore_ops += 3  # open / read / close
        stats.io_ops += 1
        stats.bytes_read += arr.nbytes
        if simulate_read_bw:
            time.sleep(arr.nbytes / simulate_read_bw)
        leaves[ent["name"]] = arr
    state = unflatten_state(meta["tree"], leaves)
    stats.total_s = time.perf_counter() - t0
    return state, stats


# ------------------------------------------------- monolithic image --------
def monolith_snapshot(state, path: str, extra_state: Optional[Any] = None) -> None:
    """Whole-instance capture: params AND everything else (no trim)."""
    leaves, tree = flatten_state(state)
    extra_leaves, extra_tree = flatten_state(extra_state) if extra_state is not None else ([], None)
    header = {"tree": tree, "extra_tree": extra_tree, "tensors": []}
    blobs = []
    off = 0
    # file order = tree order (NOT access order: the format is opaque)
    for name, arr in list(leaves) + [("__extra__/" + n, a) for n, a in extra_leaves]:
        raw = np.ascontiguousarray(arr)
        header["tensors"].append(
            {"name": name, "dtype": str(raw.dtype), "shape": list(raw.shape),
             "off": off, "nbytes": raw.nbytes}
        )
        blobs.append(raw.view(np.uint8).reshape(-1))
        off += raw.nbytes
    hb = pickle.dumps(header)
    with open(path, "wb") as f:
        f.write(len(hb).to_bytes(8, "little"))
        f.write(hb)
        for b in blobs:
            f.write(b.tobytes())
        f.flush()
        os.fsync(f.fileno())


class _MonolithReader:
    def __init__(self, path: str):
        self.f = open(path, "rb")
        hlen = int.from_bytes(self.f.read(8), "little")
        self.header = pickle.loads(self.f.read(hlen))
        self.data_off = 8 + hlen

    def read_span(self, off: int, nbytes: int) -> bytes:
        return os.pread(self.f.fileno(), nbytes, self.data_off + off)


def reap_star_restore(path: str, simulate_read_bw=None) -> Tuple[Any, BaselineStats]:
    """Synchronous prefetch of the ENTIRE image before execution."""
    stats = BaselineStats()
    t0 = time.perf_counter()
    r = _MonolithReader(path)
    stats.metadata_s = time.perf_counter() - t0
    total = sum(t["nbytes"] for t in r.header["tensors"])
    blob = r.read_span(0, total)  # one huge blocking read
    stats.io_ops += 1
    stats.bytes_read = len(blob)
    if simulate_read_bw:
        time.sleep(len(blob) / simulate_read_bw)
    leaves = {}
    for t in r.header["tensors"]:
        if t["name"].startswith("__extra__/"):
            continue  # captured, fetched... and unused (the VM-state tax)
        a = np.frombuffer(blob, np.dtype(t["dtype"]), count=t["nbytes"] // np.dtype(t["dtype"]).itemsize,
                          offset=t["off"])
        leaves[t["name"]] = a.reshape(t["shape"])
    state = unflatten_state(r.header["tree"], leaves)
    stats.total_s = time.perf_counter() - t0
    return state, stats


class FaasnapAsyncRestorer:
    """Advisory async prefetch: background reader with NO completion
    contract; ``ensure(name)`` models the major fault (blocking 64 KiB
    demand reads) when execution outruns the advisory stream."""

    FAULT_READ = 64 * 1024

    def __init__(self, path: str, lag_s: float = 0.0, simulate_read_bw=None):
        self.stats = BaselineStats()
        self._t0 = time.perf_counter()
        self.r = _MonolithReader(path)
        self.stats.metadata_s = time.perf_counter() - self._t0
        self.lag_s = lag_s
        self.simulate_read_bw = simulate_read_bw
        self._resident: Dict[str, np.ndarray] = {}
        self._lock = threading.Lock()
        self._tensors = [t for t in self.r.header["tensors"]]
        self._by_name = {t["name"]: t for t in self._tensors}
        self._thread = threading.Thread(target=self._advisory, daemon=True)
        self._thread.start()

    def _materialize(self, t, blob: bytes) -> np.ndarray:
        a = np.frombuffer(blob, np.dtype(t["dtype"]))
        return a.reshape(t["shape"])

    def _advisory(self):
        # file order, not access order; the kernel may also deprioritize us
        for t in self._tensors:
            if self.lag_s:
                time.sleep(self.lag_s)
            with self._lock:
                if t["name"] in self._resident:
                    continue
            blob = self.r.read_span(t["off"], t["nbytes"])
            self.stats.io_ops += 1
            self.stats.bytes_read += len(blob)
            if self.simulate_read_bw:
                time.sleep(len(blob) / self.simulate_read_bw)
            with self._lock:
                self._resident.setdefault(t["name"], self._materialize(t, blob))

    def ensure(self, name: str) -> np.ndarray:
        with self._lock:
            arr = self._resident.get(name)
        if arr is not None:
            return arr
        # major fault: blocking small-read loop for exactly this tensor
        t = self._by_name[name]
        parts = []
        for off in range(0, t["nbytes"], self.FAULT_READ):
            nb = min(self.FAULT_READ, t["nbytes"] - off)
            parts.append(self.r.read_span(t["off"] + off, nb))
            self.stats.io_ops += 1
            self.stats.bytes_read += nb
            self.stats.major_faults += 1
            if self.simulate_read_bw:
                # faults pay per-op latency on top of bandwidth
                time.sleep(nb / self.simulate_read_bw + 20e-6)
        arr = self._materialize(t, b"".join(parts))
        with self._lock:
            self._resident.setdefault(name, arr)
        return arr

    def state(self, wait: bool = True) -> Any:
        leaves = {
            t["name"]: self.ensure(t["name"])
            for t in self._tensors
            if not t["name"].startswith("__extra__/")
        }
        self.stats.total_s = time.perf_counter() - self._t0
        return unflatten_state(self.r.header["tree"], leaves)
