"""Overlay tables: the Overlay-VMA analogue.

Each tensor's bytes are split into fixed-size chunks ("pages").  Chunks are
classified {ZERO, BASE, PRIVATE}: ZERO chunks are never stored or fetched
(satisfied from the zero pool), BASE chunks are deduplicated against a shared
base image (the page-cache analogue), PRIVATE chunks are the sparse overlay
stored in the JIF.  The classification is run-length encoded into a flat,
sorted interval table — the paper's "pre-balanced B-tree stored in a compact
binary format that requires no deserialization at restore time" — and looked
up by binary search.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

# chunk identity lives in repro.core.digest (shared with jif/lifecycle/
# chunkstore); re-exported here for back-compat with existing callers
from repro.core.digest import DIGEST_BYTES as _DIGEST_BYTES
from repro.core.digest import chunk_digests

KIND_ZERO = 0
KIND_BASE = 1
KIND_PRIVATE = 2

DEFAULT_PAGE = 64 * 1024  # 16 OS pages; hash/dedup granularity


def n_chunks(nbytes: int, page_size: int) -> int:
    return max(1, -(-nbytes // page_size))


def zero_mask(buf: memoryview, page_size: int) -> np.ndarray:
    """(n,) bool: True where the chunk is entirely zero (vectorized)."""
    buf = memoryview(buf).cast("B")
    nb = len(buf)
    n = n_chunks(nb, page_size)
    full = nb // page_size
    mask = np.zeros((n,), bool)
    if full:
        body = np.frombuffer(buf[: full * page_size], np.uint8).reshape(full, page_size)
        mask[:full] = ~body.any(axis=1)
    if full < n:
        tail = np.frombuffer(buf[full * page_size :], np.uint8)
        mask[full] = not tail.any()
    return mask


def classify(
    buf: memoryview,
    page_size: int,
    base_digests: Optional[np.ndarray] = None,
    digests: Optional[np.ndarray] = None,
) -> np.ndarray:
    """(n,) uint8 chunk kinds for one tensor's bytes.  Pass precomputed
    ``digests`` of ``buf`` to avoid hashing twice (the snapshot pipeline
    hashes every tensor anyway for the v2 digest region)."""
    zm = zero_mask(buf, page_size)
    kinds = np.full(zm.shape, KIND_PRIVATE, np.uint8)
    kinds[zm] = KIND_ZERO
    if base_digests is not None and len(base_digests):
        dg = digests if digests is not None else chunk_digests(buf, page_size)
        m = min(len(dg), len(base_digests))
        same = (dg[:m] == base_digests[:m]).all(axis=1)
        # BASE beats ZERO only when the base chunk is also zero — prefer ZERO
        # (cheaper: no copy at all), so only flip PRIVATE chunks to BASE.
        flip = same & (kinds[:m] == KIND_PRIVATE)
        kinds[:m][flip] = KIND_BASE
    return kinds


def intervals_from_kinds(kinds: np.ndarray) -> np.ndarray:
    """Run-length encode kinds -> (n_iv, 4) int64 [start, count, kind, src].

    ``src`` (private-data chunk offset within the JIF data segment) is filled
    in by the snapshot writer; -1 otherwise.
    """
    if len(kinds) == 0:
        return np.zeros((0, 4), np.int64)
    change = np.flatnonzero(np.diff(kinds.astype(np.int16))) + 1
    starts = np.concatenate([[0], change])
    ends = np.concatenate([change, [len(kinds)]])
    out = np.empty((len(starts), 4), np.int64)
    out[:, 0] = starts
    out[:, 1] = ends - starts
    out[:, 2] = kinds[starts]
    out[:, 3] = -1
    return out


class IntervalTable:
    """Binary-searchable interval view (flat int64 array, zero-deserialize)."""

    def __init__(self, table: np.ndarray):
        self.table = np.ascontiguousarray(table, np.int64).reshape(-1, 4)
        self._starts = self.table[:, 0]

    def lookup(self, page: int) -> Tuple[int, int]:
        """-> (kind, src_chunk or -1) for one page index."""
        i = int(np.searchsorted(self._starts, page, side="right")) - 1
        start, count, kind, src = self.table[i]
        assert start <= page < start + count, "page out of table range"
        off = src + (page - start) if src >= 0 else -1
        return int(kind), int(off)

    def counts(self) -> Dict[int, int]:
        out = {KIND_ZERO: 0, KIND_BASE: 0, KIND_PRIVATE: 0}
        for start, count, kind, _ in self.table:
            out[int(kind)] += int(count)
        return out

    @property
    def n_pages(self) -> int:
        if len(self.table) == 0:
            return 0
        return int(self.table[-1, 0] + self.table[-1, 1])

    def private_runs(self):
        """Yield (page_start, n, src_chunk) runs of PRIVATE chunks."""
        for start, count, kind, src in self.table:
            if kind == KIND_PRIVATE:
                yield int(start), int(count), int(src)

    def base_runs(self):
        for start, count, kind, _ in self.table:
            if kind == KIND_BASE:
                yield int(start), int(count)
