"""JIF — Joint Image Format (the paper's ELF-inspired snapshot container).

One self-contained file holding everything needed to restore a model
instance::

    magic "JIF1" | u32 header_len | msgpack header | pad(64)
    | per-tensor interval tables (raw little-endian int64, zero-deserialize)
    | per-tensor chunk digests (raw (n,16) uint8 blake2b, v2 only)
    | pad(4096)
    | data segment: PRIVATE chunks, contiguous, in first-access order

The header carries batched metadata (pytree structure descriptor, dtypes/
shapes, logical sharding axes, access order, RNG/step/arch config) so the
whole metadata restore is ONE decode — no per-resource replay.  The data
segment layout enables restoring the working set with a single sequential
high-throughput read.

Version 2 additions (the v1 layout above is still read transparently):

* ``ws_boundary`` — the data-segment chunk where the traced working set
  ends: everything before it restores with one sequential read before
  execution resumes; everything after is residual background prefetch.
* ``parent`` — optional on-disk parent reference for delta snapshots: the
  image only stores chunks that differ from the parent JIF, and restore
  resolves BASE chunks through the parent chain (bootstrapping the node
  cache from disk when needed).
* per-tensor chunk digests — stored raw so a child snapshot can classify
  against this image without materializing its data segment.
"""
from __future__ import annotations

import dataclasses
import io
import os
from typing import Any, Dict, Iterable, List, Optional, Tuple

import msgpack
import numpy as np

from repro.core.digest import DIGEST_BYTES as _DIGEST_BYTES
from repro.core.digest import chunk_digest, zero_chunk_digest
from repro.core.overlay import IntervalTable

MAGIC = b"JIF1"
ALIGN_TABLE = 64
ALIGN_DATA = 4096
VERSION = 2

# v1 images carry no digest region; backfilled digests are persisted next to
# the image so the hash cost is paid once per image, not once per restore
SIDECAR_SUFFIX = ".digests"


def digest_sidecar_path(path: str) -> str:
    return path + SIDECAR_SUFFIX


@dataclasses.dataclass
class TensorEntry:
    name: str
    dtype: str
    shape: Tuple[int, ...]
    nbytes: int
    itable_off: int = 0
    itable_rows: int = 0
    digest_off: int = 0  # 0 = no stored digests (v1 images)
    digest_rows: int = 0

    def to_header(self) -> Dict:
        return {
            "name": self.name,
            "dtype": self.dtype,
            "shape": list(self.shape),
            "nbytes": self.nbytes,
            "itable_off": self.itable_off,
            "itable_rows": self.itable_rows,
            "digest_off": self.digest_off,
            "digest_rows": self.digest_rows,
        }

    @classmethod
    def from_header(cls, d: Dict) -> "TensorEntry":
        return cls(
            name=d["name"],
            dtype=d["dtype"],
            shape=tuple(d["shape"]),
            nbytes=d["nbytes"],
            itable_off=d["itable_off"],
            itable_rows=d["itable_rows"],
            digest_off=d.get("digest_off", 0),
            digest_rows=d.get("digest_rows", 0),
        )


def _pad(f, align: int):
    off = f.tell()
    rem = off % align
    if rem:
        f.write(b"\0" * (align - rem))


def write_jif(
    path: str,
    meta: Dict[str, Any],
    tensors: List[TensorEntry],
    itables: Dict[str, np.ndarray],
    data_chunks: Iterable[bytes],
    page_size: int,
    base_ref: Optional[Dict] = None,
    digests: Optional[Dict[str, np.ndarray]] = None,
    ws_boundary: Optional[int] = None,
) -> Dict[str, int]:
    """Write atomically (tmp + rename). Returns offsets/stats."""
    tmp = path + ".tmp"
    BIG = 2**62  # worst-case-width placeholders: patched header never grows
    with open(tmp, "wb", buffering=1024 * 1024) as f:
        f.write(MAGIC + b"\0\0\0\0")

        for t in tensors:  # rows known up front; offsets patched after layout
            t.itable_rows = np.ascontiguousarray(itables[t.name], np.int64).reshape(-1, 4).shape[0]
            t.itable_off = BIG
            if digests is not None and t.name in digests:
                t.digest_rows = len(digests[t.name])
                t.digest_off = BIG
        draft = _encode_header(meta, tensors, page_size, base_ref, BIG, BIG, ws_boundary)
        f.write(draft)
        _pad(f, ALIGN_TABLE)

        table_region = f.tell()
        for t in tensors:
            it = np.ascontiguousarray(itables[t.name], np.int64).reshape(-1, 4)
            _pad(f, ALIGN_TABLE)
            t.itable_off = f.tell()
            f.write(it.tobytes())

        if digests is not None:
            for t in tensors:
                dg = digests.get(t.name)
                if dg is None:
                    continue
                _pad(f, ALIGN_TABLE)
                t.digest_off = f.tell()
                f.write(np.ascontiguousarray(dg, np.uint8).tobytes())

        _pad(f, ALIGN_DATA)
        data_off = f.tell()
        data_len = 0
        for chunk in data_chunks:
            f.write(chunk)
            data_len += len(chunk)
        f.flush()
        os.fsync(f.fileno())

    # patch the header in place with final offsets (pad to reserved size)
    final = _encode_header(meta, tensors, page_size, base_ref, data_off, data_len, ws_boundary)
    assert len(final) <= len(draft), "header grew past its reservation"
    with open(tmp, "r+b") as f:
        f.seek(0)
        # u32 holds the TRUE header length; the reservation slack stays as
        # padding between header and tables (offsets are absolute anyway)
        f.write(MAGIC + len(final).to_bytes(4, "little"))
        f.write(final + b"\0" * (len(draft) - len(final)))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return {"data_off": data_off, "data_len": data_len, "table_region": table_region}


def _encode_header(meta, tensors, page_size, base_ref, data_off, data_len, ws_boundary=None) -> bytes:
    header = {
        "version": VERSION,
        "page_size": page_size,
        "base": base_ref,
        "meta": meta,
        "tensors": [t.to_header() for t in tensors],
        "data_off": data_off,
        "data_len": data_len,
    }
    if ws_boundary is not None:
        header["ws_boundary"] = ws_boundary
    if base_ref and base_ref.get("path"):
        header["parent"] = base_ref
    return msgpack.packb(header, use_bin_type=True)


class JifReader:
    """Header + interval tables in two small reads; data via pread ranges.

    All post-construction reads go through ``os.pread`` on the shared fd, so
    one reader is safe under concurrent itable/digest/data loads from the
    scheduler's threads (no shared seek pointer)."""

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "rb")
        try:
            magic = self._f.read(4)
            if magic != MAGIC:
                raise ValueError(f"{path}: not a JIF file")
            hlen = int.from_bytes(self._f.read(4), "little")
            self.header = msgpack.unpackb(self._f.read(hlen), raw=False)
            self.version: int = self.header.get("version", 1)
            self.page_size: int = self.header["page_size"]
            self.meta: Dict = self.header["meta"]
            self.base_ref = self.header.get("base")
            self.data_off: int = self.header["data_off"]
            self.data_len: int = self.header["data_len"]
            self.tensors = [TensorEntry.from_header(d) for d in self.header["tensors"]]
            self.by_name = {t.name: t for t in self.tensors}
        except BaseException:
            self._f.close()  # a corrupt image must not leak the fd to GC
            raise
        self._itables: Dict[str, IntervalTable] = {}
        # backfilled digests for v1 images: loaded lazily from the sidecar
        # (or computed by ensure_digests); None = not yet probed
        self._sidecar: Optional[Dict[str, np.ndarray]] = None
        self._sidecar_probed = False

    @property
    def n_data_chunks(self) -> int:
        return -(-self.data_len // self.page_size)

    @property
    def ws_boundary(self) -> int:
        """Data-segment chunk where the traced working set ends.  v1 images
        carry no boundary: the whole data segment is the working set."""
        ws = self.header.get("ws_boundary")
        return self.n_data_chunks if ws is None else int(ws)

    @property
    def parent(self) -> Optional[Dict]:
        """On-disk parent ref ({name, path}) for delta images, else None."""
        p = self.header.get("parent")
        if p is None and self.base_ref and self.base_ref.get("path"):
            p = self.base_ref
        return p

    # --- metadata restore: batched, zero-deserialize interval tables -------
    def itable(self, name: str) -> IntervalTable:
        if name not in self._itables:
            t = self.by_name[name]
            raw = os.pread(self._f.fileno(), t.itable_rows * 4 * 8, t.itable_off)
            self._itables[name] = IntervalTable(
                np.frombuffer(raw, np.int64).reshape(-1, 4)
            )
        return self._itables[name]

    def load_all_itables(self) -> None:
        for t in self.tensors:
            self.itable(t.name)

    def digests(self, name: str) -> Optional[np.ndarray]:
        """Per-tensor chunk digests ((n, 16) uint8): the stored v2 digest
        region, else a backfill sidecar if one exists, else None."""
        t = self.by_name[name]
        if not t.digest_off:
            side = self._load_sidecar()
            return side.get(name) if side else None
        raw = os.pread(self._f.fileno(), t.digest_rows * _DIGEST_BYTES, t.digest_off)
        return np.frombuffer(raw, np.uint8).reshape(-1, _DIGEST_BYTES)

    @property
    def has_digests(self) -> bool:
        """True when every tensor has digests available — stored in the
        image (v2) or backfilled via a valid sidecar (v1)."""
        if not self.tensors:
            return False
        if all(t.digest_off for t in self.tensors):
            return True
        side = self._load_sidecar()
        if not side:
            return False
        return all(t.digest_off or t.name in side for t in self.tensors)

    # --- v1 digest backfill (persisted sidecar) -----------------------------
    def _binding(self) -> Dict[str, int]:
        st = os.stat(self.path)
        return {"mtime_ns": st.st_mtime_ns, "size": st.st_size}

    def _load_sidecar(self) -> Optional[Dict[str, np.ndarray]]:
        """Load (once) the ``<path>.digests`` sidecar, if present and still
        bound to THIS file's identity (a rewritten image invalidates it)."""
        if self._sidecar_probed:
            return self._sidecar
        self._sidecar_probed = True
        sp = digest_sidecar_path(self.path)
        try:
            with open(sp, "rb") as f:
                doc = msgpack.unpackb(f.read(), raw=False)
            if doc.get("binding") != self._binding():
                return None  # stale: the jif was rewritten since backfill
            self._sidecar = {
                name: np.frombuffer(raw, np.uint8).reshape(-1, _DIGEST_BYTES)
                for name, raw in doc["tensors"].items()
            }
        except (OSError, ValueError, msgpack.UnpackException):
            return None
        return self._sidecar

    def write_digest_sidecar(self, digests: Dict[str, np.ndarray]) -> None:
        """Persist backfilled digests next to the image (atomic tmp+rename),
        bound to the jif's current identity, and adopt them in-process."""
        doc = {
            "binding": self._binding(),
            "tensors": {
                name: np.ascontiguousarray(dg, np.uint8).tobytes()
                for name, dg in digests.items()
            },
        }
        sp = digest_sidecar_path(self.path)
        tmp = sp + ".tmp"
        with open(tmp, "wb") as f:
            f.write(msgpack.packb(doc, use_bin_type=True))
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, sp)
        self._sidecar = {
            name: np.frombuffer(doc["tensors"][name], np.uint8).reshape(-1, _DIGEST_BYTES)
            for name in doc["tensors"]
        }
        self._sidecar_probed = True

    def ensure_digests(self, base=None, write_sidecar: bool = True) -> bool:
        """Backfill digests for a pre-v2 image so it participates in dedup.

        Hashes each tensor's chunks from what the image already encodes:
        PRIVATE chunks from the data segment (unpadded tails), ZERO chunks
        as zero runs, BASE chunks from ``base`` (a resolved
        :class:`~repro.core.cache.BaseImage`).  A delta image with BASE
        chunks and no ``base`` raises ``ValueError`` — its bytes are not in
        this file.  Persists a sidecar by default so the hash cost is paid
        once per image.  Returns True once digests cover every tensor."""
        if self.has_digests:
            return True
        ps = self.page_size
        out: Dict[str, np.ndarray] = {}
        for t in self.tensors:
            if t.digest_off:
                continue
            n = max(1, -(-t.nbytes // ps))
            dg = np.empty((n, _DIGEST_BYTES), np.uint8)

            def clen(page: int) -> int:  # unpadded length of chunk `page`
                return min(ps, t.nbytes - page * ps)

            for start, count, kind, src in self.itable(t.name).table:
                start, count, kind, src = int(start), int(count), int(kind), int(src)
                if kind == 2:  # PRIVATE: hash straight from the data segment
                    raw = self.pread_chunks(src, count)
                    for j in range(count):
                        dg[start + j] = np.frombuffer(
                            chunk_digest(raw[j * ps : j * ps + clen(start + j)]),
                            np.uint8,
                        )
                elif kind == 0:  # ZERO
                    for j in range(count):
                        dg[start + j] = np.frombuffer(
                            zero_chunk_digest(clen(start + j)), np.uint8
                        )
                else:  # BASE: bytes live in the parent, not this file
                    if base is None:
                        raise ValueError(
                            f"{self.path}: tensor {t.name!r} has BASE chunks; "
                            "backfilling digests needs the resolved base image"
                        )
                    raw = np.ascontiguousarray(
                        base.chunk_bytes(t.name, start, count), np.uint8
                    ).tobytes()
                    for j in range(count):
                        dg[start + j] = np.frombuffer(
                            chunk_digest(raw[j * ps : j * ps + clen(start + j)]),
                            np.uint8,
                        )
            out[t.name] = dg
        if write_sidecar:
            self.write_digest_sidecar(out)
        else:
            self._sidecar = dict(out)
            self._sidecar_probed = True
        return True

    # --- data segment I/O ---------------------------------------------------
    def pread_chunks(self, chunk_start: int, n: int) -> bytes:
        """Read n private chunks starting at data-segment chunk offset."""
        off = self.data_off + chunk_start * self.page_size
        ln = min(n * self.page_size, self.data_len - chunk_start * self.page_size)
        return os.pread(self._f.fileno(), ln, off)

    def pread_range(self, byte_off: int, nbytes: int) -> bytes:
        return os.pread(self._f.fileno(), nbytes, self.data_off + byte_off)

    def close(self):
        self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.close()
