"""Shared prefetch I/O scheduler — one arbiter for every restore on a node.

The seed restorer gave each `SpiceRestorer` a private prefetch thread, so N
concurrent cold starts issued N independent sequential streams and the disk
arbitrated them blindly (the piecemeal/contention regime of §4.2).  Here all
restorers submit their chunk-read work to one node-wide scheduler:

* **per-function streams** — each restore opens an `IOStream` holding an
  ordered queue of per-tensor jobs (the JIF access order).  A single reader
  thread serves streams round-robin (weighted by priority), so concurrent
  restores share read bandwidth fairly instead of FIFO-starving each other.
* **demand boost** — `TensorHandle.wait` on a tensor that is not yet
  resident promotes that tensor's pending reads to the head of its stream
  AND promotes the stream over background prefetch.  This is the paper's
  tracked-completion contract under contention: execution-demanded data is
  never stuck behind another function's advisory stream.
* **bandwidth arbitration** — one reader thread serializes storage access
  (the single-disk model); aggregate `stats` expose total bytes/ops so
  benchmarks can report achieved read bandwidth across all tenants.

Jobs are plain callables returning the number of bytes they read from
storage; the scheduler stays agnostic of JIF layout.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple


class _TensorJob:
    """All I/O for one tensor: ordered ops, then a finalize callback."""

    __slots__ = ("name", "ops", "finalize")

    def __init__(self, name: str, ops, finalize: Optional[Callable[[], None]]):
        self.name = name
        self.ops: Deque[Callable[[], int]] = deque(ops)
        self.finalize = finalize


class IOStream:
    """One restore's ordered I/O queue inside the shared scheduler."""

    def __init__(
        self,
        sched: "PrefetchIOScheduler",
        name: str,
        priority: int = 0,
        on_complete: Optional[Callable[[], None]] = None,
        region=None,
    ):
        self.sched = sched
        self.name = name
        self.priority = priority
        # optional ledger region (repro.core.memory.MemoryRegion): storage
        # bytes this stream reads are recorded as in-flight fill against
        # it, so the node's memory ledger sees prefetch progress live.  The
        # restorer swaps it for the residual region at the ws boundary.
        self.region = region
        self._jobs: Deque[_TensorJob] = deque()
        self._by_name: Dict[str, _TensorJob] = {}
        self._sealed = False
        self._active = 0  # ops/finalizes running outside the lock right now
        self._completed = False
        self._on_complete = on_complete
        self._done = threading.Event()
        self.error: Optional[BaseException] = None
        self.stats = {"bytes_read": 0, "io_ops": 0, "tensors": 0, "boosts": 0}

    # Called by the submitting (restorer) thread.
    def submit(self, tensor_name: str, ops, finalize=None) -> None:
        with self.sched._cv:
            if self.error is not None:
                return  # stream already failed: drop silently, done is set
            if self._sealed:
                raise RuntimeError(f"stream {self.name!r} already sealed")
            job = _TensorJob(tensor_name, ops, finalize)
            self._jobs.append(job)
            self._by_name[tensor_name] = job
            self.sched._cv.notify_all()

    def seal(self) -> None:
        """No more submissions; the stream completes when the queue drains.
        A stream sealed with an empty queue (every tensor was served from
        pinned memory) completes immediately."""
        with self.sched._cv:
            self._sealed = True
            self.sched._cv.notify_all()
        self.sched._maybe_complete(self)

    def boost(self, tensor_name: str) -> bool:
        """Demand-promote one tensor's pending I/O (see module docstring)."""
        return self.sched._boost(self, tensor_name)

    def set_priority(self, priority: int) -> None:
        """Re-prioritize a live stream (e.g. demote the residual tail of a
        restore to background once its working set has landed); pending
        demand boosts are unaffected — they are checked before priority."""
        with self.sched._cv:
            self.priority = priority
            self.sched._cv.notify_all()

    def abort(self, exc: BaseException) -> None:
        """Fail the stream: drop pending work, release waiters, complete."""
        self.sched._fail_stream(self, exc)

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._done.wait(timeout)

    @property
    def done(self) -> bool:
        return self._done.is_set()

    # internal, under scheduler lock
    def _has_work(self) -> bool:
        return bool(self._jobs)


class PrefetchIOScheduler:
    """Node-wide prefetch arbiter: per-stream queues, one reader thread."""

    def __init__(self, name: str = "iosched"):
        self.name = name
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._streams: List[IOStream] = []
        # (stream, job) pairs: a boost entry expires as soon as its demanded
        # job's I/O completes, so one boost cannot monopolize the reader
        # against other tenants' later demands
        self._boosted: Deque[Tuple[IOStream, _TensorJob]] = deque()
        self._rr = 0
        self._running = False
        self._shutdown = False
        self._thread: Optional[threading.Thread] = None
        self.stats = {
            "bytes_read": 0,
            "io_ops": 0,
            "tensors": 0,
            "streams_opened": 0,
            "streams_completed": 0,
            "demand_boosts": 0,
            "busy_s": 0.0,
        }

    # ------------------------------------------------------------- streams
    def open_stream(
        self,
        name: str,
        priority: int = 0,
        on_complete: Optional[Callable[[], None]] = None,
        inline: bool = False,
        region=None,
    ) -> IOStream:
        """``inline`` streams are never served by the reader thread — the
        caller drains them synchronously via :meth:`drain_inline`.
        ``region`` (optional ledger region) receives in-flight I/O
        accounting for every storage byte this stream reads."""
        stream = IOStream(self, name, priority=priority, on_complete=on_complete,
                          region=region)
        with self._cv:
            if self._shutdown:
                raise RuntimeError("scheduler is shut down")
            self.stats["streams_opened"] += 1
            if not inline:
                self._streams.append(stream)
                if not self._running:
                    self._running = True
                    self._thread = threading.Thread(
                        target=self._loop, name=f"{self.name}-reader", daemon=True
                    )
                    self._thread.start()
            self._cv.notify_all()
        return stream

    def drain_inline(self, stream: IOStream) -> None:
        """Execute a stream synchronously on the caller's thread (the
        non-pipelined restore path); the stream must be sealed."""
        while True:
            with self._cv:
                if not stream._jobs:
                    break
                job = stream._jobs[0]
                op = job.ops.popleft() if job.ops else None
                if op is None:
                    stream._jobs.popleft()
                    stream._by_name.pop(job.name, None)
            try:
                if op is not None:
                    self._run_op(stream, op)
                elif job.finalize is not None:
                    job.finalize()
                    with self._cv:
                        stream.stats["tensors"] += 1
                        self.stats["tensors"] += 1
            except BaseException as exc:  # noqa: BLE001
                self._fail_stream(stream, exc)
                raise
        self._maybe_complete(stream)

    # -------------------------------------------------------------- boost
    def _boost(self, stream: IOStream, tensor_name: str) -> bool:
        with self._cv:
            job = stream._by_name.get(tensor_name)
            if job is None or not stream._jobs:
                return False  # already finalized (or never submitted): no-op
            if stream._jobs[0] is not job:
                try:
                    stream._jobs.remove(job)
                except ValueError:
                    return False
                stream._jobs.appendleft(job)
            # promote the stream over background prefetch — but only until
            # THIS job's I/O is done (the entry expires with the job)
            if not any(j is job for _, j in self._boosted):
                self._boosted.append((stream, job))
            stream.stats["boosts"] += 1
            self.stats["demand_boosts"] += 1
            self._cv.notify_all()
            return True

    # --------------------------------------------------------------- loop
    def _pick_stream(self) -> Optional[IOStream]:
        """Under lock: demand-boosted first — QoS-weighted: among live
        boost entries the highest-priority STREAM wins (a LATENCY restore's
        demand overtakes a BATCH restore's earlier demand), FIFO within a
        tier — else stream priority + RR."""
        best = None
        for entry in list(self._boosted):
            s, job = entry
            # entry expires once the demanded job left the queue (I/O done)
            if s._by_name.get(job.name) is not job or not s._has_work():
                self._boosted.remove(entry)
                continue
            if best is None or s.priority > best.priority:
                best = s
        if best is not None:
            return best
        ready = [s for s in self._streams if s._has_work()]
        if not ready:
            return None
        top = max(s.priority for s in ready)
        ready = [s for s in ready if s.priority == top]
        self._rr = (self._rr + 1) % len(ready)
        return ready[self._rr]

    def _run_op(self, stream: IOStream, op: Callable[[], int]) -> None:
        t0 = time.perf_counter()
        nbytes = int(op() or 0)
        dt = time.perf_counter() - t0
        region = stream.region
        if region is not None and nbytes:
            region.note_io(nbytes)
        with self._cv:
            stream.stats["io_ops"] += 1
            stream.stats["bytes_read"] += nbytes
            self.stats["io_ops"] += 1
            self.stats["bytes_read"] += nbytes
            self.stats["busy_s"] += dt

    def _maybe_complete(self, stream: IOStream) -> None:
        with self._cv:
            # _active guards the window where the reader popped the last
            # job but its op/finalize is still executing outside the lock:
            # completing then would commit regions and close the JifReader
            # under a finalize that is still installing the tensor
            if (
                stream._completed or not stream._sealed
                or stream._jobs or stream._active
            ):
                return
            stream._completed = True
            if stream in self._streams:
                self._streams.remove(stream)
            self.stats["streams_completed"] += 1
        if stream._on_complete is not None:
            stream._on_complete()
        stream._done.set()

    def _fail_stream(self, stream: IOStream, exc: BaseException) -> None:
        """Fail one stream without killing the shared reader: drop its
        pending work, record the error, and run completion so waiters are
        released (the stream owner propagates ``stream.error`` to its
        tensor handles / caller)."""
        with self._cv:
            if stream._completed:
                return
            stream.error = exc
            stream._jobs.clear()
            stream._by_name.clear()
            stream._sealed = True
        self._maybe_complete(stream)

    def _loop(self) -> None:
        while True:
            finalize = None
            op = None
            with self._cv:
                stream = self._pick_stream()
                while stream is None:
                    if self._shutdown or not self._streams:
                        self._running = False
                        return
                    self._cv.wait(timeout=0.25)
                    stream = self._pick_stream()
                job = stream._jobs[0]
                op = job.ops.popleft() if job.ops else None
                if op is None:
                    stream._jobs.popleft()
                    stream._by_name.pop(job.name, None)
                    finalize = job.finalize
                stream._active += 1  # completion must wait for this work
            # a failing op/finalize fails ITS stream only; the shared
            # reader must survive to serve every other tenant
            error = None
            try:
                if op is not None:
                    self._run_op(stream, op)
                elif finalize is not None:
                    finalize()
            except BaseException as exc:  # noqa: BLE001
                error = exc
            finally:
                with self._cv:
                    stream._active -= 1
            if error is not None:
                self._fail_stream(stream, error)
                continue
            if op is not None:
                # a concurrent abort may have emptied the stream while this
                # op ran; its _fail_stream deferred completion to us
                self._maybe_complete(stream)
                continue
            with self._cv:
                stream.stats["tensors"] += 1
                self.stats["tensors"] += 1
            self._maybe_complete(stream)

    # ------------------------------------------------------------- probes
    def inflight(self) -> Dict[str, int]:
        """Live load probe for placement: the number of registered
        (uncompleted) streams and an estimate of the bytes still to land
        across them (``region.nbytes - region.filled`` for streams that
        carry a ledger region; region-less streams count bytes as 0).
        Inline streams never register here, so this is exactly the work
        queued against the reader thread."""
        with self._cv:
            streams = [s for s in self._streams if not s._completed]
            pending = 0
            for s in streams:
                region = s.region
                if region is not None:
                    pending += max(0, region.nbytes - region.filled)
        return {"streams": len(streams), "pending_bytes": pending}

    # ----------------------------------------------------------- lifecycle
    def shutdown(self, timeout: float = 5.0) -> None:
        with self._cv:
            self._shutdown = True
            self._cv.notify_all()
            th = self._thread
        if th is not None and th.is_alive():
            th.join(timeout)

    def snapshot_stats(self) -> Dict[str, float]:
        with self._cv:
            return dict(self.stats)
