"""Cluster-wide content-addressed chunk store — dedup on disk and in RAM.

JIF v2 images already carry per-tensor blake2b chunk digests
(:mod:`repro.core.digest`); this module promotes those digests to first-class
identity so thousands of fine-tunes of one base share ONE physical copy at
every layer:

* :class:`ChunkStore` — an on-disk CAS: one refcounted file per unique
  digest (``root/<hex[:2]>/<hex>``).  ``publish()`` ingests images at write
  time, so delta chains and sibling fine-tunes never store an identical
  chunk twice; restore reads chunks back by digest instead of re-pulling
  them from the (slow) image store.

* :class:`NodeChunkCache` — a node-resident read-only cache over the CAS.
  RAM-tier chunks are charged ONCE per unique digest to the node ledger
  under the ``chunk_cas`` kind, with their own reclaim-ladder rung
  (order 2: cheaper to drop than a host base image — a demoted chunk is one
  local CAS file read away, an evicted base is a full image restore).
  A pluggable ``peer_fetch`` hook (installed by the cluster router) pulls a
  missing chunk from whichever node already holds it over the simulated
  interconnect instead of re-reading the image store.

Thread-safety: both classes are locked internally.  The cache lock is taken
by restore worker threads, the reclaim ladder, and peer readers; no call
holds it while blocking on I/O against the manager lock — ``region.resize``
is non-blocking and never runs the ladder, which is what makes charging
under the cache lock deadlock-free (same contract as NodeImageCache).
"""
from __future__ import annotations

import os
import threading
import time
from collections import OrderedDict
from typing import Callable, Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.digest import DIGEST_BYTES, digest_key
from repro.core.memory import KIND_CHUNK_CAS, NodeMemoryManager

__all__ = ["ChunkStore", "NodeChunkCache"]


class ChunkStore:
    """On-disk content-addressed store of refcounted chunk files.

    The refcount tracks logical owners (published image manifests, node
    caches holding the chunk).  A chunk file is unlinked when its count
    drops to zero; :meth:`audit` asserts files-on-disk == refcounted set.
    """

    def __init__(self, root: str, simulate_read_bw: Optional[float] = None):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.simulate_read_bw = simulate_read_bw
        self._lock = threading.Lock()
        self._refs: Dict[bytes, int] = {}
        self.stats = {
            "puts": 0,
            "dedup_hits": 0,
            "bytes_stored": 0,
            "bytes_deduped": 0,
            "reads": 0,
            "bytes_read": 0,
            "unlinks": 0,
        }

    # ------------------------------------------------------------- layout
    def _path(self, digest: bytes) -> str:
        hx = digest.hex()
        return os.path.join(self.root, hx[:2], hx)

    # ------------------------------------------------------------- writes
    def put(self, digest, data) -> bool:
        """Store one chunk (or bump its refcount when already present).
        Returns True when the chunk was NEW — callers use this to count
        write-time dedup."""
        dk = digest_key(digest)
        with self._lock:
            if dk in self._refs:
                self._refs[dk] += 1
                self.stats["dedup_hits"] += 1
                self.stats["bytes_deduped"] += len(data)
                return False
            self._refs[dk] = 1
            self.stats["puts"] += 1
            self.stats["bytes_stored"] += len(data)
        p = self._path(dk)
        os.makedirs(os.path.dirname(p), exist_ok=True)
        tmp = p + ".tmp"
        with open(tmp, "wb") as f:
            f.write(bytes(data))
        os.replace(tmp, p)
        return True

    def incref(self, digest) -> None:
        dk = digest_key(digest)
        with self._lock:
            if dk not in self._refs:
                raise KeyError(f"incref on absent chunk {dk.hex()}")
            self._refs[dk] += 1

    def decref(self, digest) -> bool:
        """Drop one reference; unlink the chunk file at zero.  Returns True
        when the chunk was removed from the store."""
        dk = digest_key(digest)
        with self._lock:
            n = self._refs.get(dk)
            if n is None:
                raise KeyError(f"decref on absent chunk {dk.hex()}")
            if n > 1:
                self._refs[dk] = n - 1
                return False
            del self._refs[dk]
            self.stats["unlinks"] += 1
        try:
            os.unlink(self._path(dk))
        except FileNotFoundError:
            pass
        return True

    def release_many(self, digests: Iterable) -> None:
        for dg in digests:
            self.decref(dg)

    # -------------------------------------------------------------- reads
    def contains(self, digest) -> bool:
        with self._lock:
            return digest_key(digest) in self._refs

    def refcount(self, digest) -> int:
        with self._lock:
            return self._refs.get(digest_key(digest), 0)

    def get(self, digest) -> Optional[bytes]:
        """Read one chunk's bytes (None when absent).  Applies the store's
        simulated read bandwidth, mirroring how the image store's reads are
        paced — a CAS hit is a LOCAL disk read, not free."""
        dk = digest_key(digest)
        with self._lock:
            if dk not in self._refs:
                return None
        try:
            with open(self._path(dk), "rb") as f:
                data = f.read()
        except FileNotFoundError:
            return None
        if self.simulate_read_bw:
            time.sleep(len(data) / self.simulate_read_bw)
        with self._lock:
            self.stats["reads"] += 1
            self.stats["bytes_read"] += len(data)
        return data

    # ---------------------------------------------------------- ingestion
    def ingest_jif(self, path: str) -> Tuple[List[bytes], int, int]:
        """Walk a JIF's PRIVATE chunks and store each under its digest
        (one reference per occurrence).  Requires digests (v2 region or
        backfill sidecar).  Returns (digest per occurrence in data-segment
        order, unique_bytes stored, dup_bytes deduplicated)."""
        from repro.core.jif import JifReader

        manifest: List[bytes] = []
        unique = dup = 0
        with JifReader(path) as r:
            ps = r.page_size
            r.ensure_digests()  # raises for delta v1 images w/o base
            for t in r.tensors:
                dgs = r.digests(t.name)
                for start, count, src in r.itable(t.name).private_runs():
                    raw = r.pread_chunks(src, count)
                    for j in range(count):
                        page = start + j
                        clen = min(ps, t.nbytes - page * ps)
                        dk = digest_key(dgs[page])
                        if self.put(dk, raw[j * ps : j * ps + clen]):
                            unique += clen
                        else:
                            dup += clen
                        manifest.append(dk)
        return manifest, unique, dup

    # -------------------------------------------------------------- audit
    def audit(self) -> Dict[str, int]:
        """Assert store invariants: every refcounted digest has its file on
        disk, every file on disk is refcounted, all counts positive."""
        with self._lock:
            refs = dict(self._refs)
        on_disk = set()
        for sub in os.listdir(self.root):
            d = os.path.join(self.root, sub)
            if not os.path.isdir(d):
                continue
            for fn in os.listdir(d):
                if fn.endswith(".tmp"):
                    continue
                on_disk.add(bytes.fromhex(fn))
        ref_set = set(refs)
        assert on_disk == ref_set, (
            f"CAS drift: {len(on_disk - ref_set)} orphan files, "
            f"{len(ref_set - on_disk)} missing files"
        )
        assert all(n > 0 for n in refs.values()), "non-positive refcount"
        return {"chunks": len(refs), "refs": sum(refs.values())}


class NodeChunkCache:
    """Node-resident read-only chunk cache over a shared :class:`ChunkStore`.

    Two tiers: a RAM tier (LRU ``OrderedDict`` of digest → bytes, charged to
    the node ledger under ``chunk_cas``) and an implicit disk tier — every
    digest this node holds keeps ONE store reference, so demoting a chunk
    from RAM under pressure leaves it one local CAS read away.

    The cluster layer installs two hooks: ``announce`` (digest residency →
    the catalog's digest→holders index) and ``peer_fetch`` (pull a missing
    chunk from a holder over the simulated interconnect).
    """

    RECLAIM_ORDER = 2  # ladder rung: residual (0) -> device images (1) ->
    # chunk CAS -> image cache (3) -> pool (4) -> warm LRU (5).  RAM chunks
    # demote to the local disk CAS (cheap re-read); base images outrank them
    # because their eviction forces a full image re-restore.

    def __init__(
        self,
        store: ChunkStore,
        ram_capacity_bytes: int = 2 << 30,
        node: str = "node",
    ):
        self.store = store
        self.node = node
        self.capacity = ram_capacity_bytes
        self._lock = threading.Lock()
        self._ram: "OrderedDict[bytes, bytes]" = OrderedDict()
        self._ram_bytes = 0
        # digests this node holds at least on the disk tier (each owns one
        # store reference, dropped only by drop()/release_all())
        self._held: set = set()
        self._memory: Optional[NodeMemoryManager] = None
        self._region = None  # ONE resizable chunk_cas region for the RAM tier
        # hooks wired by the cluster router
        self.announce: Callable[[str, bytes, bool], None] = lambda node, dg, present: None
        self.peer_fetch: Optional[Callable[[bytes], Optional[bytes]]] = None
        self.stats = {
            "ram_hits": 0,
            "cas_hits": 0,
            "peer_hits": 0,
            "misses": 0,
            "ingests": 0,
            "demotions": 0,
            "ram_rejects": 0,
            "bytes_served_ram": 0,
            "bytes_served_cas": 0,
            "bytes_served_peer": 0,
        }

    # --------------------------------------------------------------- ledger
    def attach(self, memory: NodeMemoryManager) -> None:
        """Charge the RAM tier to the node ledger and register this cache's
        demotion as the ladder's chunk-cas reclaimer."""
        with self._lock:
            if self._memory is memory:
                return
            self._memory = memory
            nbytes = self._ram_bytes
        region = memory.reserve(nbytes, KIND_CHUNK_CAS, owner=f"chunk-cas:{self.node}")
        region.commit()
        with self._lock:
            self._region = region
        memory.register_reclaimer("chunk-cas", self.reclaim, self.RECLAIM_ORDER)

    def _charge_to(self, nbytes: int) -> bool:
        """Resize the RAM-tier region to ``nbytes`` (under self._lock).
        Non-blocking: never runs the reclaim ladder (lock order is always
        cache → manager).  True when the charge fits."""
        if self._region is None:
            return True
        return self._region.resize(nbytes)

    # --------------------------------------------------------------- writes
    def ingest(self, digest, data) -> None:
        """Install one chunk this node now holds: store it in the CAS (one
        reference per node), cache it in RAM, announce residency."""
        dk = digest_key(digest)
        data = bytes(data)
        with self._lock:
            if dk in self._held:
                self._insert_ram_locked(dk, data)
                return
        self.store.put(dk, data)
        announce = False
        with self._lock:
            if dk not in self._held:
                self._held.add(dk)
                self.stats["ingests"] += 1
                announce = True
            else:
                self.store.decref(dk)  # raced with another ingest of dk
            self._insert_ram_locked(dk, data)
        if announce:
            self.announce(self.node, dk, True)

    def _insert_ram_locked(self, dk: bytes, data: bytes) -> None:
        if dk in self._ram:
            self._ram.move_to_end(dk)
            return
        new_total = self._ram_bytes + len(data)
        if new_total > self.capacity or not self._charge_to(new_total):
            # no RAM room (capacity or ledger): the chunk still lives on
            # the disk tier — correctness never depends on the RAM tier
            self.stats["ram_rejects"] += 1
            return
        self._ram[dk] = data
        self._ram_bytes = new_total

    # ---------------------------------------------------------------- reads
    def probe(self, digest) -> Optional[str]:
        """Non-mutating residency probe for restore PLANNING: ``"ram"`` /
        ``"cas"`` / None.  No LRU bump, no stats — plans must not bias the
        cache they are about to read."""
        dk = digest_key(digest)
        with self._lock:
            if dk in self._ram:
                return "ram"
            if dk in self._held:
                return "cas"
        return None

    def get(self, digest) -> Optional[bytes]:
        """RAM-tier read (LRU bump).  None on RAM miss — callers fall
        through to :meth:`get_cas` / :meth:`fetch_peer` explicitly because
        each tier has different cost accounting."""
        dk = digest_key(digest)
        with self._lock:
            data = self._ram.get(dk)
            if data is None:
                return None
            self._ram.move_to_end(dk)
            self.stats["ram_hits"] += 1
            self.stats["bytes_served_ram"] += len(data)
            return data

    def get_cas(self, digest) -> Optional[bytes]:
        """Disk-tier read: pull the chunk from the local CAS file (paced by
        the store's simulated bandwidth) and promote it back to RAM."""
        dk = digest_key(digest)
        with self._lock:
            if dk not in self._held:
                return None
        data = self.store.get(dk)
        if data is None:
            return None
        with self._lock:
            self.stats["cas_hits"] += 1
            self.stats["bytes_served_cas"] += len(data)
            self._insert_ram_locked(dk, data)
        return data

    def fetch_peer(self, digest) -> Optional[bytes]:
        """Pull a chunk from a peer node over the interconnect (hook wired
        by the router).  A successful fetch installs the chunk locally, so
        the next tenant's restore hits RAM/CAS instead of the wire."""
        if self.peer_fetch is None:
            return None
        dk = digest_key(digest)
        data = self.peer_fetch(dk)
        if data is None:
            return None
        with self._lock:
            self.stats["peer_hits"] += 1
            self.stats["bytes_served_peer"] += len(data)
        self.ingest(dk, data)
        return data

    def peek(self, digest) -> Optional[bytes]:
        """Serve a chunk TO a peer: RAM first (no LRU bump — a peer read is
        not local reuse), else local CAS file, else None."""
        dk = digest_key(digest)
        with self._lock:
            data = self._ram.get(dk)
            if data is not None:
                return data
            if dk not in self._held:
                return None
        return self.store.get(dk)

    def holds(self, digest) -> bool:
        with self._lock:
            return digest_key(digest) in self._held

    def held_count(self) -> int:
        with self._lock:
            return len(self._held)

    def ram_bytes(self) -> int:
        with self._lock:
            return self._ram_bytes

    # -------------------------------------------------------------- reclaim
    def reclaim(self, nbytes: int, protect=frozenset()) -> int:
        """Ladder rung 2: demote LRU RAM chunks to the disk tier until
        ``nbytes`` are freed.  The store keeps this node's reference, so a
        demoted chunk costs one local CAS read to come back — never a pull
        from the image store or a peer."""
        freed = 0
        with self._lock:
            while self._ram and freed < nbytes:
                dk, data = self._ram.popitem(last=False)
                self._ram_bytes -= len(data)
                freed += len(data)
                self.stats["demotions"] += 1
            if freed:
                self._charge_to(self._ram_bytes)  # shrink always succeeds
        return freed

    # ------------------------------------------------------------- teardown
    def drop(self, digest) -> None:
        """Forget one chunk entirely (both tiers) and return its store ref."""
        dk = digest_key(digest)
        with self._lock:
            if dk not in self._held:
                return
            self._held.discard(dk)
            data = self._ram.pop(dk, None)
            if data is not None:
                self._ram_bytes -= len(data)
                self._charge_to(self._ram_bytes)
        self.store.decref(dk)
        self.announce(self.node, dk, False)

    def release_all(self) -> None:
        """Drop every held chunk and release the ledger region (node
        teardown)."""
        with self._lock:
            held = list(self._held)
            self._held.clear()
            self._ram.clear()
            self._ram_bytes = 0
            region, self._region = self._region, None
        for dk in held:
            self.store.decref(dk)
            self.announce(self.node, dk, False)
        if region is not None:
            region.release()

    def snapshot_stats(self) -> Dict[str, int]:
        with self._lock:
            d = dict(self.stats)
            d["held_chunks"] = len(self._held)
            d["ram_bytes"] = self._ram_bytes
            return d
