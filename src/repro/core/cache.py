"""Node-level base-image cache — the host page cache analogue.

Base images hold the bytes shared across function instances (a common base
model, language runtime weights, ...). They stay resident in node RAM after
container teardown, so subsequent restores of any function that deduplicated
against them fetch only private chunks from storage — the paper's
"specialized node pools / Python+AI pools" operating model builds on this.

Images can be bootstrapped straight from JIFs on disk
(:meth:`BaseImage.from_jif`): a delta-chain restore that misses its parent in
the cache materializes the parent image from its file (recursively through
the chain) and installs it, so a freshly provisioned node needs nothing but
the snapshot store.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Dict, Iterable, Optional, Tuple

import numpy as np

from repro.core import overlay
from repro.core.treeutil import flatten_state


class BaseImage:
    """Digests + chunk bytes of one shared snapshot, keyed by tensor name."""

    def __init__(self, name: str, page_size: int = overlay.DEFAULT_PAGE):
        self.name = name
        self.page_size = page_size
        self._bytes: Dict[str, np.ndarray] = {}
        self._digests: Dict[str, np.ndarray] = {}

    @classmethod
    def from_state(cls, name: str, state, page_size: int = overlay.DEFAULT_PAGE) -> "BaseImage":
        img = cls(name, page_size)
        leaves, _ = flatten_state(state)
        for lname, arr in leaves:
            raw = np.ascontiguousarray(arr).view(np.uint8).reshape(-1)
            img._bytes[lname] = raw.copy()
            img._digests[lname] = overlay.chunk_digests(memoryview(raw), page_size)
        return img

    @classmethod
    def from_jif(
        cls,
        path: str,
        name: Optional[str] = None,
        node_cache: Optional["NodeImageCache"] = None,
        iosched=None,
        simulate_read_bw: Optional[float] = None,
    ) -> "BaseImage":
        """Materialize a full image from a JIF on disk.  The restore runs
        synchronously through ``node_cache``, which resolves (and, for delta
        chains, recursively bootstraps) any parent the JIF references.
        Pass the node's ``iosched`` so the bootstrap's reads are arbitrated
        against live tenant streams instead of bypassing the scheduler."""
        from repro.core.jif import JifReader
        from repro.core.restore import SpiceRestorer

        with JifReader(path) as r:
            page_size = r.page_size
        if name is None:
            from repro.core.lifecycle import parent_cache_key

            name = parent_cache_key(path)
        # pipelined even though we wait: inline streams are drained on the
        # caller's thread and would bypass the scheduler's arbitration
        restorer = SpiceRestorer(
            node_cache=node_cache,
            iosched=iosched, simulate_read_bw=simulate_read_bw,
        )
        state, _, _, _ = restorer.restore(path)
        return cls.from_state(name, state, page_size)

    def digests(self, name: str) -> Optional[np.ndarray]:
        return self._digests.get(name)

    def chunk_bytes(self, name: str, start_chunk: int, n: int) -> np.ndarray:
        raw = self._bytes[name]
        return raw[start_chunk * self.page_size : (start_chunk + n) * self.page_size]

    @property
    def nbytes(self) -> int:
        return sum(b.nbytes for b in self._bytes.values())


class NodeImageCache:
    """LRU cache of BaseImages shared by every restore on this node."""

    def __init__(self, capacity_bytes: int = 8 << 30):
        self.capacity = capacity_bytes
        self._images: "OrderedDict[str, BaseImage]" = OrderedDict()
        self._lock = threading.Lock()
        # resident bytes, maintained incrementally (the evict loop used to
        # re-sum every image per iteration — O(n²) under churn)
        self.total_bytes = 0
        self.stats = {"hits": 0, "misses": 0, "evictions": 0, "base_bytes_served": 0}

    def put(self, img: BaseImage) -> None:
        with self._lock:
            old = self._images.get(img.name)
            if old is not None:
                self.total_bytes -= old.nbytes
            self._images[img.name] = img
            self.total_bytes += img.nbytes
            self._images.move_to_end(img.name)
            self._evict()

    def get(self, name: Optional[str]) -> Optional[BaseImage]:
        if name is None:
            return None  # "no base" is not a cache miss
        with self._lock:
            img = self._images.get(name)
            if img is None:
                self.stats["misses"] += 1
                return None
            self.stats["hits"] += 1
            self._images.move_to_end(name)
            return img

    def note_base_served(self, nbytes: int) -> None:
        """Restorers report BASE bytes they memcpy'd (thread-safe)."""
        with self._lock:
            self.stats["base_bytes_served"] += nbytes

    def _evict(self):
        while self.total_bytes > self.capacity and len(self._images) > 1:
            _, img = self._images.popitem(last=False)
            self.total_bytes -= img.nbytes
            self.stats["evictions"] += 1
