"""Node-level base-image cache — the host page cache analogue.

Base images hold the bytes shared across function instances (a common base
model, language runtime weights, ...). They stay resident in node RAM after
container teardown, so subsequent restores of any function that deduplicated
against them fetch only private chunks from storage — the paper's
"specialized node pools / Python+AI pools" operating model builds on this.

Images can be bootstrapped straight from JIFs on disk
(:meth:`BaseImage.from_jif`): a delta-chain restore that misses its parent in
the cache materializes the parent image from its file (recursively through
the chain) and installs it, so a freshly provisioned node needs nothing but
the snapshot store.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Dict, Iterable, Optional, Tuple

import numpy as np

from repro.core import overlay
from repro.core.memory import KIND_IMAGE_CACHE, MemoryPressureError, NodeMemoryManager
from repro.core.treeutil import flatten_state


class BaseImage:
    """Digests + chunk bytes of one shared snapshot, keyed by tensor name."""

    def __init__(self, name: str, page_size: int = overlay.DEFAULT_PAGE):
        self.name = name
        self.page_size = page_size
        self._bytes: Dict[str, np.ndarray] = {}
        self._digests: Dict[str, np.ndarray] = {}

    @classmethod
    def from_state(cls, name: str, state, page_size: int = overlay.DEFAULT_PAGE) -> "BaseImage":
        img = cls(name, page_size)
        leaves, _ = flatten_state(state)
        for lname, arr in leaves:
            raw = np.ascontiguousarray(arr).view(np.uint8).reshape(-1)
            img._bytes[lname] = raw.copy()
            img._digests[lname] = overlay.chunk_digests(memoryview(raw), page_size)
        return img

    @classmethod
    def from_jif(
        cls,
        path: str,
        name: Optional[str] = None,
        node_cache: Optional["NodeImageCache"] = None,
        iosched=None,
        simulate_read_bw: Optional[float] = None,
        chunks=None,
    ) -> "BaseImage":
        """Materialize a full image from a JIF on disk.  The restore runs
        synchronously through ``node_cache``, which resolves (and, for delta
        chains, recursively bootstraps) any parent the JIF references.
        Pass the node's ``iosched`` so the bootstrap's reads are arbitrated
        against live tenant streams instead of bypassing the scheduler."""
        from repro.core.jif import JifReader
        from repro.core.restore import SpiceRestorer

        with JifReader(path) as r:
            page_size = r.page_size
        if name is None:
            from repro.core.lifecycle import parent_cache_key

            name = parent_cache_key(path)
        # pipelined even though we wait: inline streams are drained on the
        # caller's thread and would bypass the scheduler's arbitration
        # ``chunks`` (the node's chunk cache) lets the bootstrap itself
        # dedup: a parent whose chunks a peer already pulled arrives over
        # the interconnect instead of the slow image store
        restorer = SpiceRestorer(
            node_cache=node_cache,
            iosched=iosched, simulate_read_bw=simulate_read_bw,
            chunks=chunks,
        )
        state, _, _, _ = restorer.restore(path)
        return cls.from_state(name, state, page_size)

    def digests(self, name: str) -> Optional[np.ndarray]:
        return self._digests.get(name)

    def chunk_bytes(self, name: str, start_chunk: int, n: int) -> np.ndarray:
        raw = self._bytes[name]
        return raw[start_chunk * self.page_size : (start_chunk + n) * self.page_size]

    @property
    def nbytes(self) -> int:
        return sum(b.nbytes for b in self._bytes.values())


class NodeImageCache:
    """LRU cache of BaseImages shared by every restore on this node.

    Attached to a :class:`~repro.core.memory.NodeMemoryManager`, every
    resident image is charged to an ``image_cache`` region and eviction
    becomes a registered *reclaimer* invoked under node memory pressure
    (rung 3 of the ladder: after residual tails, device-resident base
    pages, and the RAM chunk CAS, before warm instances) instead of only a
    private capacity LRU."""

    RECLAIM_ORDER = 3  # ladder rung: residual (0) -> device images (1) ->
    # chunk CAS (2) -> image cache -> pool staging -> warm LRU.  Host base
    # images outrank device copies and RAM chunks: dropping a device base
    # costs one re-upload from here, a RAM chunk one local CAS read, but
    # dropping a host base forces a disk re-read (or fails the restore).

    def __init__(self, capacity_bytes: int = 8 << 30):
        self.capacity = capacity_bytes
        self._images: "OrderedDict[str, BaseImage]" = OrderedDict()
        self._lock = threading.Lock()
        # resident bytes, maintained incrementally (the evict loop used to
        # re-sum every image per iteration — O(n²) under churn)
        self.total_bytes = 0
        self._memory: Optional[NodeMemoryManager] = None
        self._regions: Dict[str, "object"] = {}  # name -> MemoryRegion
        # names the pressure reclaimer must NOT evict: images with no
        # on-disk parent to re-materialize from (operator-installed bases).
        # Recoverable images (bootstrapped from a parent JIF) are fair game.
        self._pinned: set = set()
        self.stats = {"hits": 0, "misses": 0, "evictions": 0, "base_bytes_served": 0}

    # --------------------------------------------------------------- ledger
    def attach(self, memory: NodeMemoryManager) -> None:
        """Charge resident images to the node ledger and register this
        cache's LRU eviction as the ladder's image-cache reclaimer."""
        with self._lock:
            if self._memory is memory:
                return
            self._memory = memory
            imgs = list(self._images.values())
        for img in imgs:
            try:
                region = memory.reserve(
                    img.nbytes, KIND_IMAGE_CACHE, owner=img.name, block=False
                )
            except MemoryPressureError:
                with self._lock:
                    pinned = img.name in self._pinned
                if pinned:
                    # an unrecoverable base that does not fit must fail
                    # LOUDLY at attach time — silently dropping it would
                    # crash every later restore deduplicated against it
                    raise
                self._drop(img.name)
                continue
            region.commit()
            with self._lock:
                self._regions[img.name] = region
        memory.register_reclaimer("image-cache", self.reclaim, self.RECLAIM_ORDER)

    def put(self, img: BaseImage, evictable: bool = True) -> None:
        """Install an image.  ``evictable=False`` pins it against the
        *pressure* reclaimer (a restore that deduplicated against an
        in-memory-only base cannot recover it from disk); recoverable
        images — bootstrapped parents with a JIF behind them — stay
        evictable.  Capacity LRU is unaffected by the pin."""
        region = None
        if self._memory is not None:
            # a same-name replacement only needs the DELTA: resize the
            # resident image's region in place instead of double-charging
            # the full size (which would run the ladder, or fail, for a
            # net-zero operation)
            with self._lock:
                resident = self._regions.get(img.name)
            if resident is not None and resident.resize(img.nbytes):
                region = resident
            else:
                # reserve BEFORE taking the cache lock: admission may run
                # the reclaim ladder, whose image-cache rung locks this
                # cache.  A base that cannot fit even after reclaim fails
                # fast here — the restore that needed it must not
                # over-commit the node.
                region = self._memory.reserve(
                    img.nbytes, KIND_IMAGE_CACHE, owner=img.name
                )
            region.commit()
        evicted = []
        with self._lock:
            old = self._images.get(img.name)
            if old is not None:
                self.total_bytes -= old.nbytes
                old_region = self._regions.pop(img.name, None)
                if old_region is not None and old_region is not region:
                    evicted.append(old_region)
            self._images[img.name] = img
            self.total_bytes += img.nbytes
            if region is not None:
                self._regions[img.name] = region
            if evictable:
                self._pinned.discard(img.name)
            else:
                self._pinned.add(img.name)
            self._images.move_to_end(img.name)
            evicted.extend(self._evict())
        for r in evicted:
            r.release()

    def get(self, name: Optional[str]) -> Optional[BaseImage]:
        if name is None:
            return None  # "no base" is not a cache miss
        with self._lock:
            img = self._images.get(name)
            if img is None:
                self.stats["misses"] += 1
                return None
            self.stats["hits"] += 1
            self._images.move_to_end(name)
            return img

    def contains(self, name: Optional[str]) -> bool:
        """Non-mutating residency probe: no LRU bump, no hit/miss stats.
        Placement policies poll this per request — a probe that polluted
        the LRU order or the stats would bias both."""
        if name is None:
            return False
        with self._lock:
            return name in self._images

    def resident_names(self) -> frozenset:
        """Names of every resident image (non-mutating; for load probes)."""
        with self._lock:
            return frozenset(self._images)

    def note_base_served(self, nbytes: int) -> None:
        """Restorers report BASE bytes they memcpy'd (thread-safe)."""
        with self._lock:
            self.stats["base_bytes_served"] += nbytes

    def _drop(self, name: str) -> int:
        """Remove one image (no region bookkeeping); returns its bytes."""
        with self._lock:
            img = self._images.pop(name, None)
            if img is None:
                return 0
            self._pinned.discard(name)
            self.total_bytes -= img.nbytes
            self.stats["evictions"] += 1
            return img.nbytes

    def _evict(self):
        """Capacity LRU (under self._lock).  Pinned images are skipped —
        an unrecoverable base evicted for capacity would crash every
        restore deduplicated against it.  Returns regions to release once
        the lock is dropped (region release takes the manager lock; lock
        order is always cache -> manager)."""
        released = []
        victims = [n for n in self._images if n not in self._pinned]
        while (
            self.total_bytes > self.capacity and len(self._images) > 1 and victims
        ):
            name = victims.pop(0)
            img = self._images.pop(name)
            self.total_bytes -= img.nbytes
            self.stats["evictions"] += 1
            region = self._regions.pop(name, None)
            if region is not None:
                released.append(region)
        return released

    def reclaim(self, nbytes: int, protect=frozenset()) -> int:
        """Ladder rung 3: evict LRU *recoverable* images until ``nbytes``
        are freed (may drain them all — a restore mid-flight keeps its own
        reference to the base it resolved, and the next miss bootstraps the
        parent back from its JIF).  Pinned images (no disk backing) are
        never sacrificed here.  Returns the bytes uncharged."""
        freed = 0
        released = []
        with self._lock:
            for name in [n for n in self._images if n not in self._pinned]:
                if freed >= nbytes:
                    break
                img = self._images.pop(name)
                self.total_bytes -= img.nbytes
                self.stats["evictions"] += 1
                freed += img.nbytes
                region = self._regions.pop(name, None)
                if region is not None:
                    released.append(region)
        for r in released:
            r.release()
        return freed
