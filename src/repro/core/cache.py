"""Node-level base-image cache — the host page cache analogue.

Base images hold the bytes shared across function instances (a common base
model, language runtime weights, ...). They stay resident in node RAM after
container teardown, so subsequent restores of any function that deduplicated
against them fetch only private chunks from storage — the paper's
"specialized node pools / Python+AI pools" operating model builds on this.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Dict, Iterable, Optional, Tuple

import numpy as np

from repro.core import overlay
from repro.core.treeutil import flatten_state


class BaseImage:
    """Digests + chunk bytes of one shared snapshot, keyed by tensor name."""

    def __init__(self, name: str, page_size: int = overlay.DEFAULT_PAGE):
        self.name = name
        self.page_size = page_size
        self._bytes: Dict[str, np.ndarray] = {}
        self._digests: Dict[str, np.ndarray] = {}

    @classmethod
    def from_state(cls, name: str, state, page_size: int = overlay.DEFAULT_PAGE) -> "BaseImage":
        img = cls(name, page_size)
        leaves, _ = flatten_state(state)
        for lname, arr in leaves:
            raw = np.ascontiguousarray(arr).view(np.uint8).reshape(-1)
            img._bytes[lname] = raw.copy()
            img._digests[lname] = overlay.chunk_digests(memoryview(raw), page_size)
        return img

    def digests(self, name: str) -> Optional[np.ndarray]:
        return self._digests.get(name)

    def chunk_bytes(self, name: str, start_chunk: int, n: int) -> np.ndarray:
        raw = self._bytes[name]
        return raw[start_chunk * self.page_size : (start_chunk + n) * self.page_size]

    @property
    def nbytes(self) -> int:
        return sum(b.nbytes for b in self._bytes.values())


class NodeImageCache:
    """LRU cache of BaseImages shared by every restore on this node."""

    def __init__(self, capacity_bytes: int = 8 << 30):
        self.capacity = capacity_bytes
        self._images: "OrderedDict[str, BaseImage]" = OrderedDict()
        self._lock = threading.Lock()
        self.stats = {"hits": 0, "misses": 0, "evictions": 0, "base_bytes_served": 0}

    def put(self, img: BaseImage) -> None:
        with self._lock:
            self._images[img.name] = img
            self._images.move_to_end(img.name)
            self._evict()

    def get(self, name: Optional[str]) -> Optional[BaseImage]:
        if name is None:
            return None
        with self._lock:
            img = self._images.get(name)
            if img is None:
                self.stats["misses"] += 1
                return None
            self.stats["hits"] += 1
            self._images.move_to_end(name)
            return img

    def note_base_served(self, nbytes: int) -> None:
        """Restorers report BASE bytes they memcpy'd (thread-safe)."""
        with self._lock:
            self.stats["base_bytes_served"] += nbytes

    def _evict(self):
        while sum(i.nbytes for i in self._images.values()) > self.capacity and len(self._images) > 1:
            self._images.popitem(last=False)
            self.stats["evictions"] += 1
