"""AdamW with global-norm clipping and optional int8 gradient compression
(error-feedback) for cross-pod all-reduce bandwidth reduction."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100


def adamw_init(params) -> Dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def _schedule(cfg: AdamWConfig, count):
    warm = jnp.minimum(count.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def adamw_update(
    cfg: AdamWConfig, grads, opt: Dict, params
) -> Tuple[Any, Dict, Dict]:
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gnorm = jnp.sqrt(
        sum(jnp.sum(g * g) for g in jax.tree.leaves(grads)) + 1e-16
    )
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / gnorm)
        grads = jax.tree.map(lambda g: g * scale, grads)

    count = opt["count"] + 1
    lr = _schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, opt["m"], grads)
    new_v = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g, opt["v"], grads)

    def upd(p, m, v):
        mh = m / b1c
        vh = v / b2c
        step = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    new_opt = {"m": new_m, "v": new_v, "count": count}
    return new_params, new_opt, {"grad_norm": gnorm, "lr": lr}


# ------------------------------------------------- int8 gradient compression
def compress_int8(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Error-feedback int8 quantization: returns (q, scale, new_err)."""
    g = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, g - deq


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale
