"""Training loop with checkpoint/restart, health hooks, and failure
injection (for tests/examples). CPU-scale here; the pjit path is exercised
by launch/dryrun at the production mesh."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.ft.manager import CheckpointManager
from repro.train.steps import TrainStepConfig, init_train_state, make_train_step


@dataclasses.dataclass
class LoopConfig:
    steps: int = 50
    ckpt_every: int = 10
    log_every: int = 10
    seed: int = 0
    fail_at_step: Optional[int] = None  # failure injection


class SimulatedFailure(RuntimeError):
    pass


def train_loop(
    cfg: ModelConfig,
    tcfg: TrainStepConfig,
    lcfg: LoopConfig,
    data: SyntheticLM,
    mgr: Optional[CheckpointManager] = None,
    on_step: Optional[Callable[[int, Dict], None]] = None,
) -> Dict:
    """Runs/resumes training; returns final metrics + history."""
    step_fn = jax.jit(make_train_step(cfg, tcfg))

    start = 0
    state = None
    if mgr is not None and mgr.latest_step() is not None:
        restored, start = mgr.restore()
        params, opt = restored["params"], restored["opt"]
        params = jax.tree.map(jnp.asarray, params)
        opt = jax.tree.map(jnp.asarray, opt)
        start += 1
    else:
        params, opt = init_train_state(cfg, jax.random.PRNGKey(lcfg.seed))

    losses: List[float] = []
    t_begin = time.perf_counter()
    for step in range(start, lcfg.steps):
        if lcfg.fail_at_step is not None and step == lcfg.fail_at_step:
            raise SimulatedFailure(f"injected failure at step {step}")
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        t0 = time.perf_counter()
        params, opt, metrics = step_fn(params, opt, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if on_step is not None:
            on_step(step, {"loss": loss, "step_s": time.perf_counter() - t0})
        if mgr is not None and (step + 1) % lcfg.ckpt_every == 0:
            mgr.save(step, {"params": params, "opt": opt})
    if mgr is not None:
        mgr.wait()
    return {
        "params": params,
        "opt": opt,
        "losses": losses,
        "last_step": lcfg.steps - 1,
        "wall_s": time.perf_counter() - t_begin,
    }
