"""Training step: vocab-sharded cross entropy, microbatched gradient
accumulation, AdamW, donation."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.sharding.partition import constrain
from repro.train.optim import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    remat: str = "full"  # full | dots | dots_no_batch
    compute_dtype: str = "bfloat16"
    num_microbatches: int = 1
    aux_coeff: float = 0.01
    q_chunk: int = 2048
    kv_repeat: int = 1  # KV-head replication so GQA scores shard on the TP axis
    attn_stages: int = 1  # staged causal K-slicing in chunked attention
    unroll_scans: bool = False  # cost-measurement variants only
    optim: AdamWConfig = AdamWConfig()


def default_microbatches(
    cfg: ModelConfig, global_batch: int, n_data_shards: int, seq_len: int = 4096,
    model_shards: int = 16,
) -> int:
    """Pick grad-accum so rematted scan carries + CE logits fit HBM/chip."""
    per_dev = max(global_batch // max(n_data_shards, 1), 1)
    reps_total = cfg.pattern_reps + len(cfg.remainder)
    # non-divisible vocab (e.g. mamba2's 50280 on 16 shards) -> replicated logits
    vocab_loc = (
        cfg.vocab_size / model_shards
        if cfg.vocab_size % model_shards == 0
        else cfg.vocab_size
    )
    budget = 8e9
    for mb in (1, 2, 4, 8, 16):
        if per_dev % mb and mb != 1:
            continue
        tok = (per_dev / mb) * seq_len
        carries = reps_total * tok * cfg.d_model * 2  # bf16 saved block inputs
        logits = 3 * tok * vocab_loc * 4  # f32 logits + CE temps
        if carries + logits <= budget:
            return mb
    return min(16, per_dev) or 1


def softmax_xent(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean CE over all positions. The target logit is extracted with a
    masked sum (NOT take_along_axis: gathers on a vocab-sharded dim make
    GSPMD replicate the logits); reductions over the sharded vocab dim lower
    to psums under GSPMD."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    mask = iota == targets[..., None].astype(jnp.int32)
    tgt = jnp.sum(jnp.where(mask, logits, 0.0), axis=-1)
    return jnp.mean(lse - tgt)


def make_loss_fn(cfg: ModelConfig, tcfg: TrainStepConfig):
    compute_dtype = jnp.dtype(tcfg.compute_dtype)

    def loss_fn(params, batch):
        logits, _, aux = lm.forward(
            cfg,
            params,
            batch,
            mode="train",
            remat=tcfg.remat,
            compute_dtype=compute_dtype,
            q_chunk=tcfg.q_chunk,
            kv_repeat=tcfg.kv_repeat,
            attn_stages=tcfg.attn_stages,
            unroll=tcfg.unroll_scans,
        )
        loss = softmax_xent(logits, batch["targets"])
        return loss + tcfg.aux_coeff * aux, {"loss": loss, "aux": aux}

    return loss_fn


def make_train_step(cfg: ModelConfig, tcfg: TrainStepConfig):
    loss_fn = make_loss_fn(cfg, tcfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    n_mb = tcfg.num_microbatches

    def train_step(params, opt, batch):
        if n_mb <= 1:
            (_, metrics), grads = grad_fn(params, batch)
        else:

            def mb_split(key, x):
                ax = 1 if key == "positions" else 0  # positions are (3, B, S)
                shp = x.shape[:ax] + (n_mb, x.shape[ax] // n_mb) + x.shape[ax + 1 :]
                return jnp.moveaxis(x.reshape(shp), ax, 0)

            mbs = {k: mb_split(k, v) for k, v in batch.items()}

            def accum(carry, mb):
                g_acc, l_acc = carry
                (_, m), g = grad_fn(params, mb)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + m["loss"]), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(accum, (g0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / n_mb, grads)
            metrics = {"loss": loss_sum / n_mb, "aux": jnp.zeros((), jnp.float32)}

        params, opt, om = adamw_update(tcfg.optim, grads, opt, params)
        metrics.update(om)
        return params, opt, metrics

    return train_step


def init_train_state(cfg: ModelConfig, key, dtype=jnp.float32):
    params = lm.init_params(cfg, key, dtype)
    return params, adamw_init(params)
