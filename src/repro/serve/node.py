"""Node scheduler: multi-tenant admission, keep-alive, and eviction.

The serving stack is layered (bottom up):

* ``repro.core.iosched``  — ONE prefetch I/O scheduler per node; every
  concurrent restore submits chunk reads there (bandwidth arbitration +
  demand boost).
* ``repro.serve.instance`` — per-function lifecycle state machines that own
  restore handles and generation state.
* ``repro.serve.node``     — this module: the per-node DATA PLANE.  It
  admits concurrent invocations through a thread pool, routes them warm /
  joined / cold, enforces keep-alive TTLs (including a background reaper
  for idle nodes), and drives the pressure reclaim ladder (residual tails
  → cached base images → LRU warm state) over the node's single memory
  ledger (:class:`repro.core.memory.NodeMemoryManager`).  Restores admit
  images straight from disk on demand (delta parents bootstrap through
  the node's image cache via ``BaseImage.from_jif``), so a node needs
  nothing but the snapshot store and a registry reference.

The CONTROL PLANE — snapshot authoring (``publish`` / ``relayout``),
recorded-access bookkeeping, and registry ownership — lives in
:class:`repro.serve.cluster.FunctionCatalog`; this module only exposes the
data-plane *mechanisms* the catalog drives (:meth:`NodeScheduler.trace_warm`,
:meth:`NodeScheduler.warm_state`) and the :class:`NodeLoad` probe surface
that cluster placement policies read.

Invocations of a function whose restore is already in flight *join* that
restore (generate over the same tracked-handle tree) rather than re-reading
the snapshot — the paper's single-population guarantee per node.
"""
from __future__ import annotations

import dataclasses
import heapq
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, FrozenSet, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import (
    BufferPool,
    FunctionRegistry,
    FunctionSpec,
    NodeChunkCache,
    NodeImageCache,
    PrefetchIOScheduler,
    SpiceRestorer,
)
from repro.core import baselines
from repro.core.memory import (
    KIND_WORKING_SET,
    MemoryPressureError,
    NodeMemoryManager,
)
from repro.core.restore import RestoreStats, estimate_rerestore_cost
from repro.core.trace import AccessRecorder
from repro.core.upload import DeviceImageCache, DevicePath, UploadStream
from repro.serve.invocation import (
    EVT_ADMITTED,
    EVT_PLACED,
    EVT_RESTORING,
    EVT_RUNNING,
    EVT_WS_READY,
    AdmissionController,
    DeadlineExceeded,
    Invocation,
    InvocationCancelled,
    InvocationHandle,
    Overloaded,
    QosClass,
)
from repro.core.treeutil import unflatten_state
from repro.serve.instance import (
    FunctionInstance,
    InstanceState,
    NotWarmError,
    _FaasnapLeaf,
    _tree_bytes as _tree_nbytes,
    faasnap_wait,
    generate,
    wait_tree,
)


@dataclasses.dataclass
class InvokeResult:
    tokens: np.ndarray
    cold: bool
    mode: str
    restore_wait_s: float = 0.0
    ttft_s: float = 0.0
    total_s: float = 0.0
    stats: Optional[Dict] = None
    function: str = ""
    queue_s: float = 0.0  # admission delay in the node's invoke pool
    joined: bool = False  # rode an in-flight restore instead of starting one
    node: str = ""  # serving node's name ("" on single-node paths)
    qos: str = "standard"  # QosClass.value of the request
    # derived from the handle's event timeline (time.monotonic() domain):
    # queue_wait_s splits queueing delay from restore delay in benchmarks
    queue_wait_s: float = 0.0   # ADMITTED -> first work on the request
    admitted_ts: float = 0.0    # monotonic timestamps of the named events
    placed_ts: float = 0.0
    running_ts: float = 0.0
    timeline: Optional[List[Tuple[str, float]]] = None  # full event list


@dataclasses.dataclass(frozen=True)
class NodeLoad:
    """One node's probe surface for cluster placement — a consistent-enough
    snapshot (each field is read under its own lock; placement tolerates
    the skew, it only ranks nodes).  ``queue_depth`` counts invocations
    submitted but not yet finished (queued + running), ``pending_io_bytes``
    the bytes the node's prefetch arbiter still has to land."""

    node: str = ""
    queue_depth: int = 0
    pressure: float = 0.0          # memory ledger: held / budget
    pending_io_bytes: int = 0      # iosched: bytes still to land
    inflight_streams: int = 0      # iosched: live (uncompleted) streams
    warm: FrozenSet[str] = frozenset()       # WARM/WARMING function names
    restoring: FrozenSet[str] = frozenset()  # RESTORING (joinable) names
    images: FrozenSet[str] = frozenset()     # resident base-image names
    warm_bytes: int = 0
    batch_inflight: int = 0  # BATCH-class admitted (queued + running)
    urgent_depth: int = 0    # QUEUED non-BATCH invocations: the backlog an
    # urgent (LATENCY) arrival actually waits behind in the run queue.
    # Queued BATCH work is excluded (the QoS dispatcher jumps past it);
    # running work of any class is excluded too — worker occupancy is the
    # admission controller's problem (max_batch_inflight), and counting it
    # here made urgent placement steal replicas that queue-priority alone
    # would have served warm.  Under genuine worker saturation the queued
    # urgent arrivals themselves grow this number, so the spill still
    # fires after ~latency_spill_depth of them.


# a prewarm invocation's result carries no generation output
_EMPTY_TOKENS = np.zeros((0,), np.int32)


def _cancel_collateral(exc: BaseException) -> bool:
    """True when ``exc`` was caused by SOMEONE ELSE cancelling the restore
    this invocation merely rode (the cause chain bottoms out in
    InvocationCancelled): the rider is innocent and may retry once."""
    seen = set()
    while exc is not None and id(exc) not in seen:
        if isinstance(exc, InvocationCancelled):
            return True
        seen.add(id(exc))
        exc = exc.__cause__ or exc.__context__
    return False


# ------------------------------------------------------------ keep-alive
class KeepAlivePolicy:
    """Pluggable keep-alive: decides each instance's warm TTL and which
    warm instances to sacrifice under memory pressure (LRU default)."""

    def ttl_for(self, spec: FunctionSpec) -> float:
        return spec.warm_ttl_s

    def victims(
        self, warm: List[FunctionInstance], need_evict: int
    ) -> List[FunctionInstance]:
        """Pick AT MOST ``need_evict`` idle warm instances to sacrifice,
        in eviction order (LRU-first here).  ``need_evict`` is the
        caller's upper bound on how many evictions could possibly be
        needed — honoring it keeps a large warm set from being fully
        sorted (and lets policies stop scoring early); the caller still
        stops as soon as enough bytes came back."""
        return heapq.nsmallest(
            max(0, need_evict), warm, key=lambda i: i.last_used
        )


class FixedTTLPolicy(KeepAlivePolicy):
    """Same keep-alive window for every function (SPES-style knob)."""

    def __init__(self, ttl_s: float):
        self.ttl_s = ttl_s

    def ttl_for(self, spec: FunctionSpec) -> float:
        return self.ttl_s


class NoKeepAlive(KeepAlivePolicy):
    """Aggressive reclamation: every invocation is a cold start."""

    def ttl_for(self, spec: FunctionSpec) -> float:
        return 0.0


# ---------------------------------------------------------------- scheduler
class NodeScheduler:
    """Concurrent serving runtime for one node — pure data plane.

    ``registry`` is a *reference*: the control plane
    (:class:`repro.serve.cluster.FunctionCatalog`) owns registration; the
    node only resolves specs.  ``name`` identifies the node in a cluster
    (stamped on every :class:`InvokeResult`; "" on single-node paths).
    ``reap_interval_s`` starts a background keep-alive reaper so expired
    warm instances release their ledger bytes even on an idle node."""

    def __init__(
        self,
        registry: Optional[FunctionRegistry] = None,
        node_cache: Optional[NodeImageCache] = None,
        pool: Optional[BufferPool] = None,
        iosched: Optional[PrefetchIOScheduler] = None,
        max_workers: int = 8,
        memory_budget_bytes: Optional[int] = None,
        keepalive: Optional[KeepAlivePolicy] = None,
        memory: Optional[NodeMemoryManager] = None,
        name: str = "",
        reap_interval_s: Optional[float] = None,
        admission: Optional[AdmissionController] = None,
        install: object = "eager",
        upload_depth: int = 2,
        simulate_upload_bw: Optional[float] = None,
        chunks: Optional[NodeChunkCache] = None,
        load_ttl_s: float = 0.0,
    ):
        """``install`` selects the device-install policy for restores on
        this node — "eager" (per-tensor device copy on the prefetcher
        thread, the default), "host" (tensors stay host numpy), "fused"
        (device fast path: UploadStream + DeviceImageCache, private pages
        upload and overlay-patch against HBM-resident bases), or a callable
        (custom per-tensor transform, eager-style).  ``upload_depth`` sizes
        the fused path's upload ring (staging slots in flight);
        ``simulate_upload_bw`` models the interconnect roofline on the ring
        (labeled benchmark runs only, like ``simulate_read_bw``).
        ``chunks`` (a :class:`repro.core.chunkstore.NodeChunkCache` over
        the cluster's shared CAS) enables content-addressed dedup on every
        spice restore this node runs; its RAM tier attaches to the ledger
        as rung 2.  ``load_ttl_s`` > 0 caches the :meth:`load` probe for
        that long (staleness-bounded: any instance lifecycle transition
        invalidates it immediately via the load epoch) so cluster placement
        stays O(1)-amortized per node instead of taking several node locks
        on every submission; the router sets it fleet-wide."""
        self.name = name
        self.registry = registry or FunctionRegistry()
        self.node_cache = node_cache or NodeImageCache()
        self._pool = pool or BufferPool()
        self.iosched = iosched or PrefetchIOScheduler(name="node-iosched")
        self.keepalive = keepalive or KeepAlivePolicy()
        # a cost-aware policy (PrewarmPolicy) adopts this node's residency-
        # aware re-restore estimate for its eviction ranking
        bind = getattr(self.keepalive, "bind_node", None)
        if callable(bind):
            bind(self)
        # ONE ledger covers everything competing for node RAM: pool staging
        # buffers, cached base images, warm working sets, residual tails,
        # snapshot scratch.  The budget is an invariant of the manager, not
        # an estimate summed across subsystems.
        budget = (
            memory_budget_bytes if memory_budget_bytes is not None else self._pool.capacity
        )
        self.memory = memory or NodeMemoryManager(budget)
        self._pool.attach(self.memory)
        self.node_cache.attach(self.memory)  # registers ladder rung 3
        self.chunks = chunks
        if chunks is not None:
            chunks.attach(self.memory)  # chunk-cas RAM tier, ladder rung 2
        self.install = install
        self.upload_stream: Optional[UploadStream] = None
        self.device_images: Optional[DeviceImageCache] = None
        if install == "fused":
            # device fast path: one upload ring + one HBM base cache per
            # node, shared by every restore.  The cache attaches as ladder
            # rung 1 (cheaper to drop than host bases: re-upload, not
            # re-read); its capacity is ledger-bounded anyway, so the LRU
            # cap just tracks the node budget.
            self.upload_stream = UploadStream(
                depth=upload_depth, name=f"{name or 'node'}-upload",
                simulate_bw=simulate_upload_bw,
            )
            self.device_images = DeviceImageCache(
                capacity_bytes=budget if budget else 4 << 30
            )
            self.device_images.attach(self.memory)
        # reclaim ladder: residual tails first (cheapest to re-restore),
        # then device-resident base pages (rung 1, above, fused nodes only),
        # then RAM chunk-CAS demotions (rung 2, above, dedup nodes only —
        # re-readable from the local disk CAS), then recoverable host base
        # images (rung 3, above), then idle pool staging (pure perf cache —
        # without this rung the free list's charge would ratchet up
        # unreclaimably), then LRU warm instances
        self.memory.register_reclaimer("residual", self._reclaim_residual, order=0)
        self.memory.register_reclaimer("pool", self._reclaim_pool, order=4)
        self.memory.register_reclaimer("warm-lru", self._reclaim_warm_lru, order=5)
        self._instances: Dict[str, FunctionInstance] = {}
        self._ilock = threading.Lock()
        self._slock = threading.Lock()
        self._exec = ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="invoke"
        )
        # in-flight residual streams (fname -> RestoreStats of a WARMING
        # instance): counted against the memory budget until they drain
        self._residual: Dict[str, RestoreStats] = {}
        # invocations submitted but not finished (queued + running): the
        # cluster router's queue-depth signal
        self._pending = 0
        # QoS-ordered run queue: the pool's workers pull the best admitted
        # invocation (class rank, then priority, then earliest deadline,
        # then FIFO) instead of raw submission order
        self.admission = admission or AdmissionController()
        self._queue: List[Tuple] = []  # heap of (rank,-prio,deadline,seq,t,handle)
        self._queued = 0        # entries in the heap (not yet claimed)
        self._batch_queued = 0  # BATCH entries in the heap
        self._batch_active = 0  # BATCH admitted (queued + running)
        self._fn_active: Dict[str, int] = {}  # per-fn admitted (queued+running)
        self._seq = 0
        self._closed = False
        self._reaper_stop: Optional[threading.Event] = None
        self.reap_interval_s = reap_interval_s
        # cached NodeLoad probe: (monotonic ts, epoch at build, NodeLoad).
        # The epoch bumps on every instance lifecycle transition, so a
        # cached snapshot can never claim a function warm/restoring that
        # is not — queue-depth staleness is bounded by load_ttl_s.
        self.load_ttl_s = load_ttl_s
        self._load_epoch = 0
        self._load_cache: Optional[Tuple[float, int, NodeLoad]] = None
        # completion observer (autoscaler SLO feed): called with every
        # successful InvokeResult right after the handle resolves; must be
        # fast and non-raising (runs on the worker thread)
        self.on_result = None
        self.stats = {
            "invocations": 0,
            "warm_hits": 0,
            "cold_starts": 0,
            "joined_restores": 0,
            "ttl_evictions": 0,
            "lru_evictions": 0,
            "ws_promotions": 0,
            "residual_evictions": 0,
            "ws_rerestores": 0,
            "rejected_overloaded": 0,
            "rejected_deadline": 0,
            "cancellations": 0,
            "speculative_restores": 0,  # prewarm invocations that restored
            "prewarm_redundant": 0,     # prewarms finding warm/restoring state
            "payload_runs": 0,          # colocated compute thunks executed
        }
        if reap_interval_s is not None:
            self.start_reaper(reap_interval_s)

    def _bump(self, key: str, n: int = 1) -> None:
        with self._slock:
            self.stats[key] += n

    # ------------------------------------------------------- memory ledger
    @property
    def pool(self) -> BufferPool:
        return self._pool

    @pool.setter
    def pool(self, new_pool: BufferPool) -> None:
        """Swap the staging pool (benchmarks do this between runs): the old
        pool's ledger charge is released, the new pool is attached."""
        old, self._pool = self._pool, new_pool
        if old is not None and old is not new_pool:
            old.detach()
        new_pool.attach(self.memory)

    @property
    def memory_budget(self) -> Optional[int]:
        return self.memory.budget

    @memory_budget.setter
    def memory_budget(self, nbytes: Optional[int]) -> None:
        self.memory.budget = nbytes

    # --------------------------------------------------------------- invoke
    def submit_invocation(self, inv: Invocation,
                          handle: Optional[InvocationHandle] = None,
                          ) -> InvocationHandle:
        """Admit a typed :class:`Invocation` into the node's QoS-ordered
        run queue.  Admission-time refusals RAISE (typed
        :class:`Overloaded` / :class:`DeadlineExceeded`); anything after
        admission resolves through the returned handle."""
        fname = inv.function
        if handle is None:
            handle = InvocationHandle(inv, node=self.name)
        else:
            handle.node = self.name
        if inv.deadline_s is not None and time.monotonic() >= inv.deadline_s:
            self._bump("rejected_deadline")
            raise DeadlineExceeded(f"{fname}: deadline already passed at submit")
        t_submit = time.perf_counter()
        with self._slock:
            if self._closed:
                raise Overloaded(f"node {self.name or 'node'!r} is closed")
            try:
                self.admission.admit(
                    inv, queued=self._queued,
                    fn_active=self._fn_active.get(fname, 0),
                    batch_queued=self._batch_queued,
                    batch_active=self._batch_active,
                )
            except Overloaded:
                self.stats["rejected_overloaded"] += 1
                raise
            self._pending += 1
            self._queued += 1
            if inv.qos is QosClass.BATCH:
                self._batch_queued += 1
                self._batch_active += 1
            self._fn_active[fname] = self._fn_active.get(fname, 0) + 1
            seq = self._seq
            self._seq += 1
            # record BEFORE the entry becomes poppable: a free worker may
            # claim it the instant the lock drops, and the timeline must
            # still read ADMITTED -> PLACED -> <work>
            handle.record(EVT_ADMITTED)
            handle.record(EVT_PLACED)
            heapq.heappush(self._queue, (
                inv.qos.dispatch_rank, -inv.priority,
                inv.deadline_s if inv.deadline_s is not None else float("inf"),
                seq, t_submit, handle,
            ))
        try:
            self._exec.submit(self._drain_one)
        except BaseException:
            # raced a close(): the admission check above passed before the
            # flag flipped, so the entry is either in the queue close() is
            # draining (typed rejection incoming) or already claimed by a
            # worker — either way the handle resolves; return it instead
            # of surfacing the executor's untyped RuntimeError.  _retire
            # is idempotent, so the doubled return cannot skew the caps.
            self._retire(handle)
            if handle._done_ev.wait(5.0):
                return handle
            raise
        return handle

    def submit(
        self,
        fname: str,
        prompt: np.ndarray,
        max_new_tokens: int = 8,
        mode: str = "spice",
        cfg: Optional[ModelConfig] = None,
        simulate_read_bw: Optional[float] = None,
    ) -> InvocationHandle:
        """Legacy surface: a thin wrapper building a STANDARD-class
        :class:`Invocation` (the returned handle duck-types the Future the
        old surface handed back)."""
        return self.submit_invocation(Invocation(
            function=fname, prompt=prompt, max_new_tokens=max_new_tokens,
            mode=mode, cfg=cfg, simulate_read_bw=simulate_read_bw,
        ))

    def invoke(
        self,
        fname: str,
        prompt: np.ndarray,
        max_new_tokens: int = 8,
        mode: str = "spice",
        cfg: Optional[ModelConfig] = None,
        simulate_read_bw: Optional[float] = None,
    ) -> InvokeResult:
        return self.submit(
            fname, prompt, max_new_tokens, mode, cfg, simulate_read_bw
        ).result()

    def _retire(self, handle: InvocationHandle) -> None:
        """Return one admitted invocation's counters (dispatch done, or the
        enqueue failed after admission).  Idempotent per handle: a racing
        ``close()`` and a failed enqueue may both try to retire the same
        admission, and returning it twice would corrupt the caps."""
        fname = handle.invocation.function
        with self._slock:
            if handle._retired:
                return
            handle._retired = True
            self._pending -= 1
            if handle.invocation.qos is QosClass.BATCH:
                self._batch_active -= 1
            left = self._fn_active.get(fname, 0) - 1
            if left > 0:
                self._fn_active[fname] = left
            else:
                self._fn_active.pop(fname, None)

    def _drain_one(self) -> None:
        """Worker-pool entry: claim the best queued invocation and run it.
        One `_drain_one` is scheduled per enqueue, so the heap is non-empty
        unless `close()` drained it first."""
        with self._slock:
            if not self._queue:
                return  # close() rejected the queued work already
            _, _, _, _, t_submit, handle = heapq.heappop(self._queue)
            self._queued -= 1
            if handle.invocation.qos is QosClass.BATCH:
                self._batch_queued -= 1
        inv = handle.invocation
        try:
            if not handle._claim_for_run():
                self._bump("cancellations")
                handle._finish_cancelled(InvocationCancelled(
                    f"{inv.function}: cancelled while queued"
                ))
                return
            if inv.deadline_s is not None and time.monotonic() >= inv.deadline_s:
                self._bump("rejected_deadline")
                handle._finish_rejected(DeadlineExceeded(
                    f"{inv.function}: deadline passed after "
                    f"{time.perf_counter() - t_submit:.3f}s in queue"
                ))
                return
            result = None
            for attempt in range(3):
                try:
                    result = self._invoke_inner(inv, handle, t_submit)
                    break
                except BaseException as exc:
                    if handle.cancel_requested:
                        self._bump("cancellations")
                        handle._finish_cancelled(InvocationCancelled(
                            f"{inv.function}: cancelled mid-restore"
                        ))
                        return
                    if attempt < 2 and _cancel_collateral(exc):
                        # rode a restore someone ELSE cancelled: this
                        # invocation is innocent — restore afresh (under a
                        # cancellation wave the retry itself may join
                        # another doomed restore, hence more than one).
                        # Re-open the phase machine so the retry is
                        # cancellable again.
                        handle._reset_for_retry()
                        continue
                    raise
            result.qos = inv.qos.value
            result.queue_wait_s = handle.queue_wait_s()
            result.admitted_ts = handle.event_ts(EVT_ADMITTED) or 0.0
            result.placed_ts = handle.event_ts(EVT_PLACED) or 0.0
            result.running_ts = handle.event_ts(EVT_RUNNING) or 0.0
            result.timeline = handle.events()
            handle._finish_ok(result)
            if self.on_result is not None:
                try:
                    self.on_result(result)
                except Exception:
                    pass  # an observer must never fail the invocation path
        except BaseException as exc:  # noqa: BLE001 — typed via the handle
            handle._finish_failed(exc)
        finally:
            self._retire(handle)

    # ------------------------------------------------------------- teardown
    def close(self) -> None:
        """Idempotent node shutdown: stop the reaper, refuse new work, and
        drain the admission queue with typed rejections so queued BATCH
        work cannot hang fleet teardown.  Running invocations finish."""
        with self._slock:
            if self._closed:
                return
            self._closed = True
            drained = [entry[-1] for entry in self._queue]
            self._queue.clear()
            self._queued = 0
            self._batch_queued = 0
        self.stop_reaper()
        for handle in drained:
            if handle.cancel_requested:
                self._bump("cancellations")
                handle._finish_cancelled(InvocationCancelled(
                    f"{handle.invocation.function}: cancelled while queued"
                ))
            else:
                self._bump("rejected_overloaded")
                handle._finish_rejected(Overloaded(
                    f"node {self.name or 'node'!r}: shutting down"
                ))
            self._retire(handle)
        self._exec.shutdown(wait=False)
        if self.upload_stream is not None:
            self.upload_stream.close()
        if self.chunks is not None:
            # return this node's CAS references and ledger charge; chunks
            # other holders still reference stay in the shared store
            self.chunks.release_all()

    # ------------------------------------------------------------- eviction
    def evict(self, fname: Optional[str] = None, timeout: float = 30.0) -> None:
        """Force-evict warm instances (all, or one) — manual reclamation.
        A WARMING instance (residual still landing) is waited on until its
        finalizer flips it WARM, so a manual evict really leaves a cold
        slate instead of silently skipping the in-flight instance."""
        with self._ilock:
            insts = (
                list(self._instances.values())
                if fname is None
                else [i for n, i in self._instances.items() if n == fname]
            )
        for inst in insts:
            with inst.cond:
                inst.cond.wait_for(
                    lambda: inst.state is not InstanceState.WARMING,
                    timeout=timeout,
                )
                inst.evict("manual")

    def reap_expired(self, now: Optional[float] = None) -> int:
        """Enforce keep-alive TTLs across the node; returns evictions."""
        now = time.time() if now is None else now
        n = 0
        with self._ilock:
            insts = list(self._instances.values())
        for inst in insts:
            with inst.cond:
                if inst.expired(now) and inst.evict("ttl"):
                    n += 1
        if n:
            self._bump("ttl_evictions", n)
        return n

    # ------------------------------------------------------ background reaper
    def start_reaper(self, interval_s: float) -> None:
        """Enforce keep-alive TTLs periodically on a daemon thread, so an
        idle node releases expired warm instances' ledger bytes instead of
        holding them until the next invocation's budget sweep.  The thread
        holds only a weakref to the scheduler: a dropped node (benchmarks
        build short-lived per-policy fleets) is GC-able without an explicit
        ``stop_reaper`` and its reaper exits on the next tick."""
        import weakref

        self.stop_reaper()
        stop = threading.Event()
        self._reaper_stop = stop
        self.reap_interval_s = interval_s
        ref = weakref.ref(self)

        def loop():
            while not stop.wait(interval_s):
                node = ref()
                if node is None:
                    return  # scheduler got collected: nothing left to reap
                try:
                    if node.reap_expired():
                        # expired state released: settle the ledger too
                        # (frees any blocked reserve waiting on these bytes)
                        node._enforce_budget()
                except Exception:
                    pass  # a failed sweep must not kill the reaper
                finally:
                    node = None  # never hold the node across the sleep

        threading.Thread(
            target=loop, name=f"reaper-{self.name or 'node'}", daemon=True
        ).start()

    def stop_reaper(self) -> None:
        if self._reaper_stop is not None:
            self._reaper_stop.set()
            self._reaper_stop = None

    # -------------------------------------------------------------- probes
    def _bump_load_epoch(self, _inst=None) -> None:
        """Invalidate the cached load probe (instance lifecycle hook; may
        run under an instance's cond, so it must never take a lock)."""
        self._load_epoch += 1

    def load(self) -> NodeLoad:
        """The placement probe surface (see :class:`NodeLoad`).  With
        ``load_ttl_s`` set, a recent snapshot is served as long as no
        instance transitioned since it was built (the load epoch is the
        staleness bound on the warm/restoring sets; counters like
        queue_depth tolerate the sub-TTL skew — placement only ranks)."""
        ttl = self.load_ttl_s
        if ttl > 0:
            cached = self._load_cache
            if (
                cached is not None
                and cached[1] == self._load_epoch
                and time.monotonic() - cached[0] < ttl
            ):
                return cached[2]
        # capture the epoch BEFORE building: a transition racing the build
        # leaves a stale epoch behind, so the next probe rebuilds
        epoch = self._load_epoch
        fresh = self._load_uncached()
        if ttl > 0:
            self._load_cache = (time.monotonic(), epoch, fresh)
        return fresh

    def _load_uncached(self) -> NodeLoad:
        with self._slock:
            queue_depth = self._pending
            batch_inflight = self._batch_active
            urgent_depth = max(0, self._queued - self._batch_queued)
        with self._ilock:
            insts = list(self._instances.items())
        warm = frozenset(
            n for n, i in insts
            if i.state in (InstanceState.WARM, InstanceState.WARMING)
        )
        restoring = frozenset(
            n for n, i in insts if i.state is InstanceState.RESTORING
        )
        warm_bytes = sum(
            i.memory_bytes for n, i in insts if n in warm
        )
        io = self.iosched.inflight()
        return NodeLoad(
            node=self.name,
            queue_depth=queue_depth,
            pressure=self.memory.pressure(),
            pending_io_bytes=io["pending_bytes"],
            inflight_streams=io["streams"],
            warm=warm,
            restoring=restoring,
            images=self.node_cache.resident_names(),
            warm_bytes=warm_bytes,
            batch_inflight=batch_inflight,
            urgent_depth=urgent_depth,
        )

    def warm_bytes(self) -> int:
        """Resident warm-state bytes — WARMING instances count too: their
        working set is resident and their residual stream is landing into
        the same budgeted memory."""
        with self._ilock:
            insts = list(self._instances.values())
        return sum(
            i.memory_bytes
            for i in insts
            if i.state in (InstanceState.WARM, InstanceState.WARMING)
        )

    def residual_streams(self) -> int:
        """In-flight residual streams (WARMING instances' background tails)."""
        with self._slock:
            return sum(1 for s in self._residual.values() if not s.complete)

    def drain_residual(self, timeout: float = 60.0) -> bool:
        """Block until every residual stream has drained and every WARMING
        instance finalized (benchmarks/eviction barriers)."""
        deadline = time.time() + timeout
        while time.time() < deadline:
            with self._slock:
                pending = bool(self._residual)
            if not pending:
                with self._ilock:
                    insts = list(self._instances.values())
                if not any(i.state is InstanceState.WARMING for i in insts):
                    return True
            time.sleep(0.01)
        return False

    def quiesce(self, timeout: float = 60.0) -> bool:
        """Block until every admitted invocation (queued + running) has
        finished — the drain barrier: placement must already be stopped, or
        new arrivals keep the node busy forever."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._slock:
                if self._pending == 0:
                    return True
            time.sleep(0.005)
        return False

    def warm_instances(self) -> List[FunctionInstance]:
        """WARM/WARMING instances, unsorted (drain/handoff enumeration)."""
        with self._ilock:
            insts = list(self._instances.values())
        return [
            i for i in insts
            if i.state in (InstanceState.WARM, InstanceState.WARMING)
        ]

    def instance(self, fname: str) -> Optional[FunctionInstance]:
        with self._ilock:
            return self._instances.get(fname)

    def rerestore_cost(self, inst: FunctionInstance) -> int:
        """Estimated storage-pull bytes to bring ``inst`` back if evicted
        now — this node's residency (chunk CAS, HBM bases) folded into
        the instance-level estimate.  Cost-aware keep-alive policies
        (``PrewarmPolicy``) rank eviction candidates with it."""
        return estimate_rerestore_cost(
            inst.restore_stats,
            image_bytes=inst.memory_bytes,
            ws_pinned=inst.ws_pinned is not None,
            residual_bytes=(
                inst.residual_region.nbytes
                if inst.residual_region is not None else 0
            ),
            # the last spice restore ingested every pulled chunk into the
            # node CAS, so a re-read comes from local disk, not the store
            chunks_hot=self.chunks is not None,
            device_base_resident=(
                self.device_images is not None
                and self.device_images.resident_bytes() > 0
            ),
        )

    # ------------------------------------------------- residual finalization
    def _watch_residual(self, fname, inst, state, getter, stats) -> None:
        """Track a WARMING instance's residual stream and finalize WARM (on
        a dedicated thread) once it drains; a failed residual evicts."""
        with self._slock:
            self._residual[fname] = stats
        generation = inst.generation

        def finalize():
            try:
                if not stats.wait_complete(timeout=600):
                    # stalled residual: never leave an unevictable WARMING
                    # instance pinned against the budget forever
                    raise TimeoutError(f"{fname}: residual stream stalled")
                resolved = getter(state)
                with inst.cond:
                    if (
                        inst.state is InstanceState.WARMING
                        and inst.generation == generation
                    ):
                        inst.finalize_warm(resolved, time.time())
            except BaseException:
                with inst.cond:
                    if (
                        inst.state is InstanceState.WARMING
                        and inst.generation == generation
                    ):
                        inst.abort_warming()
            finally:
                with self._slock:
                    if self._residual.get(fname) is stats:
                        del self._residual[fname]
                self._enforce_budget(keep=fname)

        threading.Thread(
            target=finalize, name=f"residual-{fname}", daemon=True
        ).start()

    # ------------------------------------------------ warm-state mechanisms
    # Data-plane primitives the control plane (FunctionCatalog) drives: the
    # instances — and the locks guarding them — live here, so tracing and
    # state capture must too; what to DO with the results (record →
    # relayout bookkeeping, JIF rewrites) is the catalog's business.
    def trace_warm(
        self,
        fname: str,
        prompt: Optional[np.ndarray] = None,
        max_new_tokens: int = 4,
        cfg: Optional[ModelConfig] = None,
    ) -> List[str]:
        """Capture the ACTUAL first-touch order from a warm generation (the
        paper's §5 kernel tracing module, fed by production traffic instead
        of the offline pre-warm run).  The instance must be WARM."""
        from repro.configs import get_config

        spec = self.registry.get(fname)
        cfg = cfg or get_config(spec.arch)
        inst = self.instance(fname)
        if inst is None:
            raise RuntimeError(f"{fname}: trace_warm needs a WARM instance")
        if prompt is None:
            prompt = np.zeros((1, 4), np.int32)
        with inst.pinned_warm_tree() as tree:
            rec = AccessRecorder(tree)
            generate(cfg, None, rec.view(), prompt, max_new_tokens)
            return rec.touched

    def warm_state(self, fname: str):
        """Host (numpy) copy of a WARM instance's resolved tree, or None
        when the function is not warm on this node — the catalog uses it to
        re-snapshot live state without a disk restore."""
        inst = self.instance(fname)
        if inst is None:
            return None
        try:
            with inst.pinned_warm_tree() as tree:
                return jax.tree.map(np.asarray, tree)
        except NotWarmError:
            # ONLY the not-warm signal falls back; a failure materializing
            # the pinned tree is a real error and must surface
            return None

    # ------------------------------------------------------------ internals
    def _get_instance(self, fname: str, spec, cfg) -> FunctionInstance:
        with self._ilock:
            inst = self._instances.get(fname)
            if inst is None:
                inst = self._instances[fname] = FunctionInstance(spec, cfg)
                inst.on_transition = self._bump_load_epoch
            return inst

    def _invoke_inner(
        self, inv: Invocation, handle: InvocationHandle, t_submit: float
    ) -> InvokeResult:
        from repro.configs import get_config

        fname = inv.function
        prompt, max_new_tokens = inv.prompt, inv.max_new_tokens
        mode = inv.mode
        if inv.payload is not None:
            # colocated compute lane: no spec, no snapshot, no instance —
            # the thunk runs on this worker after waiting its turn in the
            # QoS-ordered queue under the admission caps (a BATCH payload
            # parks behind LATENCY work and max_batch_inflight bounds its
            # worker occupancy; that is the serve/train colocation contract)
            t0 = time.perf_counter()
            self._bump("invocations")
            self._bump("payload_runs")
            handle._pin()
            handle.record(EVT_RUNNING)
            out = inv.payload()
            return InvokeResult(
                _EMPTY_TOKENS, cold=False, mode="payload",
                total_s=time.perf_counter() - t0, function=fname,
                queue_s=t0 - t_submit, node=self.name,
                stats=out if isinstance(out, dict) else None,
            )
        spec = self.registry.get(fname)
        if inv.jif_override is not None:
            # warm-state handoff: restore THIS image (a delta of the live
            # warm state against the function's own base) instead of the
            # registered one; the override is per-invocation — later
            # restores of the function read the registered image again
            spec = dataclasses.replace(spec, jif_path=inv.jif_override)
        cfg = inv.cfg
        if cfg is None:
            # cfg-less invocations (speculative pre-warms) reuse the cfg the
            # function's prior real traffic ran with; named-arch lookup is
            # the last resort (reduced/bench variants aren't in the table)
            with self._ilock:
                prior = self._instances.get(fname)
            cfg = prior.cfg if prior is not None else get_config(spec.arch)
        t0 = time.perf_counter()
        queue_s = t0 - t_submit
        self._bump("invocations")
        inst = self._get_instance(fname, spec, cfg)
        role = None
        tree = getter = None
        preloaded = pinned_region = None
        with inst.cond:
            while role is None:
                now = time.time()
                if inst.expired(now) and inst.evict("ttl"):
                    self._bump("ttl_evictions")
                if inst.state in (InstanceState.WARM, InstanceState.WARMING):
                    # WARMING counts as warm: the working set is resident;
                    # generation stays layer-gated over the residual handles
                    role = "warm"
                    if not inv.prewarm:
                        # a speculative probe finding warm state is a no-op:
                        # it must not refresh recency or the TTL window
                        inst.counters["warm_hits"] += 1
                        inst.last_used = now
                        if inst.state is InstanceState.WARM:
                            # sliding keep-alive: every real hit re-derives
                            # the window (adaptive policies shrink/grow it
                            # as the arrival histogram evolves)
                            ttl = self.keepalive.ttl_for(spec)
                            if ttl > 0:
                                inst.warm_expiry = max(
                                    inst.warm_expiry, now + ttl
                                )
                    tree, getter = inst.tree, inst.getter
                    inst.inflight += 1
                elif inst.state is InstanceState.RESTORING:
                    if inst.tree is not None:
                        role = "joined"
                        inst.counters["joined"] += 1
                        tree, getter = inst.tree, inst.getter
                        inst.inflight += 1
                    else:  # owner claimed but handles not published yet
                        inst.cond.wait(timeout=0.05)
                else:  # COLD / EVICTED — this thread owns the restore
                    role = "owner"
                    inst.begin_restore(mode)
                    # EVICTED → RESTORING with a pinned working set: hand
                    # the resident ws to the restorer so only the dropped
                    # residual bytes are read again
                    preloaded, pinned_region = inst.take_ws_pinned()
                    inst.inflight += 1

        try:
            if role == "warm":
                handle._pin()  # state resident: cancel is a no-op from here
                handle.record(EVT_WS_READY)
                handle.record(EVT_RUNNING)
                if inv.prewarm:
                    # speculation raced a real arrival (or a stale
                    # prediction): the state it wanted resident already is
                    self._bump("prewarm_redundant")
                    return InvokeResult(
                        _EMPTY_TOKENS, cold=False, mode="prewarm",
                        total_s=time.perf_counter() - t0,
                        function=fname, queue_s=queue_s, node=self.name,
                    )
                toks, ttft = generate(cfg, getter, tree, prompt, max_new_tokens)
                dt = time.perf_counter() - t0
                self._bump("warm_hits")
                return InvokeResult(
                    toks, cold=False, mode="warm", ttft_s=ttft, total_s=dt,
                    function=fname, queue_s=queue_s, node=self.name,
                )
            if role == "joined":
                handle._pin()  # joiners ride a shared stream: not abortable
                handle.record(EVT_RESTORING)
                if inst.ws_ready:
                    handle.record(EVT_WS_READY)
                if inv.prewarm:
                    # someone else (most likely the real invocation the
                    # speculation aimed at) owns the restore: nothing to add
                    self._bump("prewarm_redundant")
                    return InvokeResult(
                        _EMPTY_TOKENS, cold=True, mode="prewarm",
                        total_s=time.perf_counter() - t0, joined=True,
                        function=fname, queue_s=queue_s, node=self.name,
                    )
                handle.record(EVT_RUNNING)
                toks, ttft = generate(cfg, getter, tree, prompt, max_new_tokens)
                dt = time.perf_counter() - t0
                self._bump("joined_restores")
                return InvokeResult(
                    toks, cold=True, mode=mode, ttft_s=ttft, total_s=dt,
                    function=fname, queue_s=queue_s, joined=True, node=self.name,
                )

            # ------------------------------------------------- owner (cold)
            # any failure before promotion (restore, generation, resolve)
            # must not strand the instance in RESTORING: abort releases
            # joiners and makes the next invocation restore afresh
            try:
                handle.record(EVT_RESTORING)
                if preloaded:
                    self._bump("ws_rerestores")

                def _ws_ready():  # fired by the restorer (prefetcher thread)
                    handle.record(EVT_WS_READY)
                    handle._pin()

                # pinned_region rides along: the spice restorer resizes it
                # in place into the new ws region, so the resident pinned
                # bytes stay charged across the re-restore
                state, stats, getter, regions, stream = self._cold_restore(
                    spec, mode, inv.simulate_read_bw, preloaded, pinned_region,
                    io_priority=inv.qos.io_priority, on_working_set=_ws_ready,
                )
                with inst.cond:
                    inst.publish_restore(state, getter, stats, regions)
                    generation = inst.generation
                if stream is not None:
                    # arm mid-restore cancellation: aborts the stream (which
                    # releases every ledger reservation through the restore
                    # failure paths) iff no joiner shares the handle tree
                    handle._attach_canceller(
                        self._restore_canceller(inst, stream, generation)
                    )
                else:
                    # synchronous restore: baseline modes never fire the
                    # callback; spice_sync already did (don't re-record)
                    handle._pin()
                    if handle.event_ts(EVT_WS_READY) is None:
                        handle.record(EVT_WS_READY)
                restore_wait = time.perf_counter() - t0  # sync restore part
                handle.record(EVT_RUNNING)
                if inv.prewarm:
                    # speculative restore: promote to warm below, but there
                    # is no request to serve — generation is skipped
                    toks, ttft = _EMPTY_TOKENS, 0.0
                else:
                    toks, ttft = generate(
                        cfg, getter, state, prompt, max_new_tokens
                    )
                ttl = self.keepalive.ttl_for(spec)
                now = time.time()
                if (
                    isinstance(stats, RestoreStats)
                    and stats.residual_tensors > 0
                    and ttl > 0
                    and getter is not None
                    # two-phase promotion: WARM-at-working-set.  Wait only
                    # for the traced working set, promote to WARMING so the
                    # next invocations route warm immediately, and finalize
                    # WARM in the background once the residual drains.  A
                    # timed-out working set (stalled storage) falls through
                    # to the synchronous full-restore path: an instance must
                    # never claim warm without its working set resident.
                    and stats.wait_working_set(timeout=300)
                ):
                    with inst.cond:
                        inst.promote_warming(ttl, now, est_bytes=stats.image_bytes)
                        inst.counters["ws_promotions"] += 1
                    self._bump("ws_promotions")
                    self._watch_residual(fname, inst, state, getter, stats)
                    total = time.perf_counter() - t0
                else:
                    if isinstance(stats, RestoreStats):
                        # snapshot-consistent stats: wait for the stream to
                        # finish (it closes the JIF reader) before reporting
                        stats.wait_complete(timeout=300)
                    total = time.perf_counter() - t0
                    with inst.cond:
                        resolved = getter(state) if (getter and ttl > 0) else state
                        inst.promote_warm(resolved, ttl, now)
            except BaseException:
                with inst.cond:
                    inst.abort_restore()
                raise
            # a speculative restore is accounted apart from demand cold
            # starts: the whole point is that it happens BEFORE a request
            # needs it, so it must not inflate the cold-start count
            self._bump("speculative_restores" if inv.prewarm else "cold_starts")
            if ttl > 0:
                self._charge_warm_instance(inst)
                self._enforce_budget(keep=fname)
            return InvokeResult(
                toks, cold=True, mode="prewarm" if inv.prewarm else mode,
                restore_wait_s=restore_wait,
                ttft_s=restore_wait + ttft,  # time-to-first-token from request
                total_s=total,
                stats=stats.as_dict() if stats else None,
                function=fname, queue_s=queue_s, node=self.name,
            )
        finally:
            with inst.cond:
                inst.inflight -= 1
                inst.cond.notify_all()

    def _enforce_budget(self, keep: Optional[str] = None) -> None:
        """Bring the ledger back under budget: reap expired TTLs, then run
        the reclaim ladder (residual → image cache → warm LRU) for exactly
        the overshoot.  ``keep`` protects a just-promoted instance."""
        if self.memory_budget is None:
            return
        self.reap_expired()  # free expired TTLs before sacrificing LRU state
        over = self.memory.over_budget()
        if over > 0:
            self.memory.reclaim(over, protect=frozenset((keep,)) if keep else None)

    # ------------------------------------------------------- reclaim ladder
    def evict_residual(self, fname: str) -> int:
        """Drop one WARM instance's residual pages, pinning its working set
        (manual trigger of ladder rung 0).  Returns the bytes freed."""
        inst = self.instance(fname)
        if inst is None:
            return 0
        with inst.cond:
            freed = inst.evict_residual()
        if freed:
            self._bump("residual_evictions")
        return freed

    def _reclaim_residual(self, nbytes: int, protect=frozenset()) -> int:
        """Ladder rung 0: drop residual tails of idle WARM instances (LRU
        order).  Their working sets stay pinned, so the re-restore reads
        only the bytes dropped here — the cheapest memory on the node."""
        with self._ilock:
            insts = list(self._instances.values())
        freed = 0
        for inst in sorted(insts, key=lambda i: i.last_used):
            if freed >= nbytes:
                break
            if inst.spec.name in protect:
                continue
            with inst.cond:
                got = inst.evict_residual()
            if got:
                freed += got
                self._bump("residual_evictions")
        return freed

    def _reclaim_pool(self, nbytes: int, protect=frozenset()) -> int:
        """Ladder rung 2: trim the pool's free staging buffers (the pool
        may have been swapped since registration, so resolve it live)."""
        return self._pool.reclaim(nbytes, protect)

    def _reclaim_warm_lru(self, nbytes: int, protect=frozenset()) -> int:
        """Ladder rung 3: first drop pinned working sets of residual-evicted
        instances, then LRU-evict idle WARM instances (keep-alive policy
        picks the order)."""
        with self._ilock:
            insts = list(self._instances.values())
        freed = 0
        pinned = [
            i for i in insts
            if i.ws_pinned is not None and i.spec.name not in protect
        ]
        for inst in sorted(pinned, key=lambda i: i.last_used):
            if freed >= nbytes:
                return freed
            with inst.cond:
                got = inst.drop_ws_pinned()
            if got:
                freed += got
                self._bump("lru_evictions")
        warm = [
            i for i in insts
            if i.state is InstanceState.WARM and i.idle
            and i.spec.name not in protect
        ]
        for victim in self.keepalive.victims(warm, need_evict=len(warm)):
            if freed >= nbytes:
                break
            with victim.cond:
                # count only what the ledger actually gets back (regions);
                # an uncharged instance still gets evicted, but reporting
                # its bytes as reclaimed would let reclaim() over-promise
                got = sum(
                    reg.nbytes
                    for reg in (victim.ws_region, victim.residual_region)
                    if reg is not None
                )
                if victim.evict("lru"):
                    freed += got
                    self._bump("lru_evictions")
        return freed

    def _restore_canceller(self, inst: FunctionInstance, stream, generation: int):
        """Build the mid-restore cancel hook for one restore generation:
        abort the prefetch stream (failing its handles and returning every
        ledger reservation through the restore's existing failure paths) —
        but only while this invocation is the restore's SOLE rider, so a
        cancel never fails joiners that trusted the shared tree."""

        def cancel() -> bool:
            if not inst.restore_abortable(generation):
                return False
            stream.abort(InvocationCancelled(
                f"{inst.spec.name}: invocation cancelled mid-restore"
            ))
            # abort() no-ops on a completed stream: only report success
            # when the stream actually died with our cancellation
            return isinstance(stream.error, InvocationCancelled)

        return cancel

    def _install_policy(self):
        """Resolve the node's ``install`` policy to SpiceRestorer kwargs:
        (transform, device_path) — exactly one is non-None, except "host"
        where both are (tensors stay host numpy)."""
        if callable(self.install):
            return self.install, None
        if self.install == "host":
            return None, None
        if self.install == "fused":
            return None, DevicePath(
                upload=self.upload_stream, images=self.device_images
            )
        if self.install == "eager":
            # eager install: numpy -> device array on the prefetcher thread
            # (the PTE-install analogue), so execution never pays conversion
            # copies.  MUST copy: on CPU jnp.asarray can alias the staging
            # buffer, which the restorer recycles into the zero pool (on TPU
            # device_put always copies into HBM).
            return (lambda a: jnp.array(a, copy=True)), None
        raise ValueError(f"unknown install policy {self.install!r}")

    @staticmethod
    def _baseline_install(transform, device_path):
        """Per-leaf install for baseline modes (no upload ring there):
        fused degrades to an eager device copy, host stays a no-op."""
        if transform is not None:
            return transform
        if device_path is not None:
            return device_path.installer()
        return lambda a: a

    def _cold_restore(self, spec: FunctionSpec, mode: str, sim_bw=None,
                      preloaded=None, pinned_region=None, io_priority: int = 0,
                      on_working_set=None):
        """Returns (state, stats, getter, (ws_region, residual_region),
        stream).  Spice restores reserve their regions up front through the
        node ledger — a restore that cannot fit fails fast
        (MemoryPressureError) or triggers the reclaim ladder instead of
        over-committing.  ``pinned_region`` (a residual-evicted instance's
        retained ws charge) transfers into the spice restore's ws region;
        baseline modes re-read everything, so it is released here.
        ``io_priority`` (the QoS class's stream priority) ranks this
        restore's reads at the shared arbiter; ``stream`` is the live
        prefetch stream for cancellation (None for baseline modes)."""
        if pinned_region is not None and mode not in ("spice", "spice_sync"):
            pinned_region.release()
            pinned_region = None
        transform, device_path = self._install_policy()
        install = self._baseline_install(transform, device_path)
        if mode == "spice":
            restorer = SpiceRestorer(
                pool=self.pool, node_cache=self.node_cache,
                transform=transform, simulate_read_bw=sim_bw,
                iosched=self.iosched, memory=self.memory,
                stream_priority=io_priority, device_path=device_path,
                chunks=self.chunks,
            )
            state, meta, handles, stats = restorer.restore(
                spec.jif_path, wait=False, preloaded=preloaded,
                preloaded_region=pinned_region, on_working_set=on_working_set,
            )
            return state, stats, wait_tree, restorer.regions, restorer.stream
        if mode == "spice_sync":
            restorer = SpiceRestorer(
                pool=self.pool, node_cache=self.node_cache, pipelined=False,
                transform=transform, simulate_read_bw=sim_bw,
                iosched=self.iosched, memory=self.memory,
                stream_priority=io_priority, device_path=device_path,
                chunks=self.chunks,
            )
            state, meta, handles, stats = restorer.restore(
                spec.jif_path, wait=True, preloaded=preloaded,
                preloaded_region=pinned_region, on_working_set=on_working_set,
            )
            # inline stream already drained: nothing left to cancel
            return state, stats, None, restorer.regions, None
        if mode == "criu_star":
            state, stats = baselines.criu_star_restore(
                spec.jif_path.replace(".jif", ".criu"), simulate_read_bw=sim_bw
            )
            state = jax.tree.map(install, state)
            return state, stats, None, (self._charge_baseline(spec, state), None), None
        if mode == "reap_star":
            state, stats = baselines.reap_star_restore(
                spec.jif_path.replace(".jif", ".mono"), simulate_read_bw=sim_bw
            )
            state = jax.tree.map(install, state)
            return state, stats, None, (self._charge_baseline(spec, state), None), None
        if mode == "faasnap_star":
            r = baselines.FaasnapAsyncRestorer(
                spec.jif_path.replace(".jif", ".mono"), simulate_read_bw=sim_bw
            )
            # rebuild a handle-like tree backed by ensure()
            leaves = {
                t["name"]: _FaasnapLeaf(r, t["name"])
                for t in r.r.header["tensors"]
                if not t["name"].startswith("__extra__/")
            }
            state = unflatten_state(r.r.header["tree"], leaves)
            return state, r.stats, faasnap_wait, (None, None), None
        raise ValueError(f"unknown restore mode {mode!r}")

    def _charge_baseline(self, spec: FunctionSpec, state):
        """Baseline restores bypass the spice admission path; charge their
        resident bytes to the ledger anyway so eviction pressure sees them.
        Best-effort: a baseline run on an over-subscribed node proceeds
        uncharged (the measured systems never refused admission either)."""
        try:
            return self.memory.reserve(
                _tree_nbytes(state), KIND_WORKING_SET,
                owner=spec.name, timeout=5.0, protect=(spec.name,),
            )
        except MemoryPressureError:
            return None

    def _charge_warm_instance(self, inst: FunctionInstance) -> None:
        """Post-promotion charge for instances that reached WARM without
        ledger regions — baseline modes whose state only materialized at
        promotion (faasnap's lazy fault-in tree).  Without this, their warm
        residency would be invisible to budget pressure."""
        with inst.cond:
            if inst.state is not InstanceState.WARM or inst.ws_region is not None:
                return
            nbytes = inst.memory_bytes
            generation = inst.generation
            fname = inst.spec.name
        if not nbytes:
            return
        try:
            region = self.memory.reserve(
                nbytes, KIND_WORKING_SET, owner=fname,
                timeout=5.0, protect=(fname,),
            )
        except MemoryPressureError:
            return  # best-effort, like _charge_baseline
        region.commit(pinned="working_set")
        with inst.cond:
            if (
                inst.state is InstanceState.WARM
                and inst.ws_region is None
                and inst.generation == generation
            ):
                inst.ws_region = region
            else:  # evicted/re-restored while we reserved
                region.release()
