"""Train→serve continuous-delta deployment pipeline.

The `ft/` training stack and the serving stack finally talk: every
checkpoint a running fine-tune writes can become a *versioned function*
in the :class:`~repro.serve.cluster.FunctionCatalog`, delta-published
against the version it was trained from — so version N+1 costs only its
dirty pages in new storage, shares every base chunk through the CAS, and
restores through the same near-warm path as any other function.

* :class:`VersionRecord` / :class:`VersionedFunction` — the lineage of one
  logical function: each version is an ordinary registered spec
  (``fname`` for v1, ``fname@v2`` …) whose JIF chains to its parent
  version's JIF on disk.
* :class:`RolloutController` — the control loop.  ``publish_version``
  delta-publishes a new version; ``begin_canary`` routes a seeded,
  deterministic fraction of the logical function's traffic to it (the
  router calls :meth:`resolve` before placement, so sticky routing,
  restore joining and warm hits all key on the version actually served);
  ``promote`` repoints the stable pointer; ``rollback`` is *instant* —
  a pointer move back to the parent snapshot, zero new bytes written,
  with the parent typically still WARM on its serving node; ``retire`` /
  ``gc_retired`` release a dead version's CAS refs and JIF.
* :class:`QualityGate` — pluggable promote/reject decision over real
  canary outputs; :meth:`RolloutController.evaluate_canary` drives probe
  invocations through the router and promotes or rejects on the verdict.
* :class:`ColocatedTrainer` — admits each training step onto the serving
  fleet as a BATCH-class *payload* invocation: the step waits its turn in
  the QoS-ordered run queue under the node's admission caps
  (``max_batch_inflight`` bounds its worker occupancy), which is the
  serve/train colocation contract — background training can contend for
  a node but never starve LATENCY dispatch.

The full loop — ``CheckpointManager.save`` →
:class:`repro.ft.publish.DeltaPublishCallback` → ``publish_version`` →
``begin_canary`` → ``evaluate_canary`` → promote/rollback — is exercised
end-to-end by ``benchmarks/rollout.py``.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import zlib
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.serve.cluster import FunctionCatalog
from repro.serve.invocation import Invocation, Overloaded, QosClass

__all__ = [
    "VersionRecord",
    "VersionedFunction",
    "RolloutController",
    "QualityGate",
    "TokenHealthGate",
    "ColocatedTrainer",
]

# VersionRecord.status lifecycle: "live" (published; may be pointed at by
# the stable/canary pointers) -> "rejected" (canary that failed its gate
# or was superseded) | "rolled_back" (former stable the lineage backed out
# of) -> "retired" (CAS refs released, spec unregistered, JIF unlinked).
LIVE = "live"
REJECTED = "rejected"
ROLLED_BACK = "rolled_back"
RETIRED = "retired"


@dataclasses.dataclass
class VersionRecord:
    """One published version of a logical function."""

    version: int
    name: str                 # concrete registered function name
    jif_path: str
    parent: Optional[int]     # parent version id (delta base); None for v1
    step: Optional[int]       # training step that produced it (None for v1)
    status: str = LIVE
    private_bytes: int = 0    # new storage this publish actually cost
    total_bytes: int = 0      # full logical image size
    published_mono: float = 0.0      # time.monotonic() at publish
    first_routed_mono: Optional[float] = None  # first canary route


class VersionedFunction:
    """The version lineage of one logical function.  ``current`` is the
    stable version every unsplit invocation serves; ``canary`` (when set)
    takes ``canary_fraction`` of the traffic via a seeded RNG so the split
    sequence is a pure function of (controller seed, version, name)."""

    def __init__(self, logical: str, base: VersionRecord):
        self.logical = logical
        self.records: Dict[int, VersionRecord] = {base.version: base}
        self.current: int = base.version
        self.canary: Optional[int] = None
        self.canary_fraction: float = 0.0
        self.rng: Optional[np.random.Generator] = None

    def record(self, version: int) -> VersionRecord:
        return self.records[version]

    def live_children(self, version: int) -> List[VersionRecord]:
        """Versions chaining directly off ``version`` that are not retired
        — while any exist, the parent's JIF must stay on disk (their delta
        restores read it)."""
        return [
            r for r in self.records.values()
            if r.parent == version and r.status != RETIRED
        ]


class QualityGate:
    """Promote/reject decision over a canary's real serving outputs."""

    def evaluate(self, results: Sequence[Any]) -> bool:
        raise NotImplementedError


class TokenHealthGate(QualityGate):
    """Default gate: every probe must have produced a non-empty integer
    token stream within the vocabulary — the cheapest "the new weights
    actually serve" check.  Real deployments plug in task metrics."""

    def __init__(self, vocab_size: Optional[int] = None):
        self.vocab_size = vocab_size

    def evaluate(self, results: Sequence[Any]) -> bool:
        if not results:
            return False
        for r in results:
            toks = np.asarray(r.tokens)
            if toks.size == 0 or not np.issubdtype(toks.dtype, np.integer):
                return False
            if self.vocab_size is not None and (
                int(toks.min()) < 0 or int(toks.max()) >= self.vocab_size
            ):
                return False
        return True


class RolloutController:
    """Versioned publish + staged rollout + instant rollback + retired-
    version GC for logical functions in one catalog.  Attach to a router
    (``controller.attach(router)`` or ``ClusterRouter(deploy=...)``-style
    wiring) to activate the per-invocation A/B split; without a router the
    controller still versions and publishes (single-node facades resolve
    manually)."""

    def __init__(
        self,
        catalog: FunctionCatalog,
        seed: int = 0,
        dirpath: Optional[str] = None,
    ):
        self.catalog = catalog
        self.seed = int(seed)
        self.dirpath = dirpath  # default publish directory for versions
        self._router = None
        self._lock = threading.RLock()
        self._functions: Dict[str, VersionedFunction] = {}
        self.stats = {
            "publishes": 0,
            "canaries": 0,
            "promotes": 0,
            "rollbacks": 0,
            "retired": 0,
            "gates_passed": 0,
            "gates_failed": 0,
            "canary_routed": 0,
            "stable_routed": 0,
        }

    # ------------------------------------------------------------- wiring
    def attach(self, router) -> "RolloutController":
        """Install this controller as ``router.deploy``: every submitted
        invocation's logical function name resolves through
        :meth:`resolve` before placement."""
        router.deploy = self
        self._router = router
        return self

    # ------------------------------------------------------------ lineage
    def track(self, fname: str) -> VersionedFunction:
        """Adopt an already-published function as version 1 of a lineage
        (idempotent).  The logical name IS v1's concrete name, so tracking
        changes nothing about how existing traffic serves."""
        with self._lock:
            vf = self._functions.get(fname)
            if vf is not None:
                return vf
            spec = self.catalog.registry.get(fname)
            st = self.catalog.publish_stats(fname)
            rec = VersionRecord(
                version=1, name=fname, jif_path=spec.jif_path, parent=None,
                step=None, status=LIVE,
                private_bytes=st.private_bytes if st else 0,
                total_bytes=st.total_bytes if st else 0,
                published_mono=time.monotonic(),
            )
            vf = VersionedFunction(fname, rec)
            self._functions[fname] = vf
            return vf

    def lineage(self, fname: str) -> VersionedFunction:
        with self._lock:
            return self._functions[fname]

    def versions(self, fname: str) -> List[VersionRecord]:
        with self._lock:
            vf = self._functions[fname]
            return [vf.records[v] for v in sorted(vf.records)]

    def current(self, fname: str) -> VersionRecord:
        with self._lock:
            vf = self._functions[fname]
            return vf.records[vf.current]

    def canary(self, fname: str) -> Optional[VersionRecord]:
        with self._lock:
            vf = self._functions[fname]
            return None if vf.canary is None else vf.records[vf.canary]

    # ------------------------------------------------------------ publish
    def publish_version(
        self,
        fname: str,
        cfg,
        params,
        step: Optional[int] = None,
        dirpath: Optional[str] = None,
        parent_version: Optional[int] = None,
        extra_state: Optional[Any] = None,
        memory=None,
    ) -> VersionRecord:
        """Delta-publish a new version of ``fname`` against its parent
        version's JIF (default: the current stable).  The new version is a
        full citizen of the catalog — registered spec, CAS-ingested
        chunks, restorable anywhere — but its publish writes only the
        pages that differ from the parent."""
        vf = self.track(fname)
        with self._lock:
            parent = vf.current if parent_version is None else parent_version
            parent_rec = vf.records[parent]
            n = max(vf.records) + 1
            base_spec = self.catalog.registry.get(vf.records[vf.current].name)
        where = dirpath or self.dirpath
        if where is None:
            raise ValueError("pass dirpath= (or set RolloutController(dirpath=))")
        name = f"{fname}@v{n}"
        # the expensive part (pre-warm trace + snapshot + CAS ingest) runs
        # outside the controller lock; versions inherit the lineage's
        # keep-alive window
        spec = self.catalog.publish(
            name, cfg, params, where, parent=parent_rec.jif_path,
            warm_ttl_s=base_spec.warm_ttl_s, formats=("jif",),
            extra_state=extra_state, memory=memory,
        )
        st = self.catalog.publish_stats(name)
        rec = VersionRecord(
            version=n, name=name, jif_path=spec.jif_path, parent=parent,
            step=step, status=LIVE,
            private_bytes=st.private_bytes if st else 0,
            total_bytes=st.total_bytes if st else 0,
            published_mono=time.monotonic(),
        )
        with self._lock:
            vf.records[n] = rec
            self.stats["publishes"] += 1
        return rec

    # ------------------------------------------------------------ rollout
    def begin_canary(
        self, fname: str, version: Optional[int] = None, fraction: float = 0.25
    ) -> VersionRecord:
        """Start routing ``fraction`` of ``fname``'s invocations to
        ``version`` (default: the newest published version).  A canary
        already in flight is superseded (marked rejected — continuous
        publishing outruns gating and the newest candidate wins)."""
        if not (0.0 < fraction <= 1.0):
            raise ValueError(f"canary fraction must be in (0, 1], got {fraction}")
        with self._lock:
            vf = self._functions[fname]
            if version is None:
                version = max(vf.records)
            rec = vf.records[version]
            if rec.status != LIVE or version == vf.current:
                raise ValueError(
                    f"{fname}@v{version} is not a canary candidate "
                    f"(status={rec.status}, current=v{vf.current})"
                )
            if vf.canary is not None and vf.canary != version:
                vf.records[vf.canary].status = REJECTED
            vf.canary = version
            vf.canary_fraction = float(fraction)
            # the split sequence is a pure function of (seed, version,
            # name): two controllers with the same seed route identically
            vf.rng = np.random.default_rng(
                [self.seed, version, zlib.crc32(fname.encode())]
            )
            self.stats["canaries"] += 1
            return rec

    def resolve(self, fname: str) -> str:
        """Map a logical function name to the concrete version this
        invocation serves.  Unknown names (including concrete version
        names invoked directly) pass through unchanged."""
        with self._lock:
            vf = self._functions.get(fname)
            if vf is None:
                return fname
            cur = vf.records[vf.current]
            if vf.canary is None:
                return cur.name
            can = vf.records[vf.canary]
            if float(vf.rng.random()) < vf.canary_fraction:
                self.stats["canary_routed"] += 1
                if can.first_routed_mono is None:
                    can.first_routed_mono = time.monotonic()
                return can.name
            self.stats["stable_routed"] += 1
            return cur.name

    def evaluate_canary(
        self,
        fname: str,
        prompt,
        gate: Optional[QualityGate] = None,
        n_probes: int = 3,
        max_new_tokens: int = 4,
        cfg=None,
        qos: QosClass = QosClass.BATCH,
        timeout: float = 300.0,
    ) -> bool:
        """Drive ``n_probes`` real invocations of the canary version
        through the router (BATCH class: probes queue behind live
        traffic), hand the results to the gate, and promote on pass /
        reject on fail.  Returns the verdict."""
        if self._router is None:
            raise RuntimeError("evaluate_canary needs an attached router")
        can = self.canary(fname)
        if can is None:
            raise RuntimeError(f"{fname}: no canary in flight")
        handles = [
            self._router.submit_invocation(Invocation(
                function=can.name, prompt=prompt,
                max_new_tokens=max_new_tokens, cfg=cfg, qos=qos,
            ))
            for _ in range(n_probes)
        ]
        results = [h.result(timeout) for h in handles]
        ok = (gate or TokenHealthGate()).evaluate(results)
        with self._lock:
            self.stats["gates_passed" if ok else "gates_failed"] += 1
        if ok:
            self.promote(fname, can.version)
        else:
            self.rollback(fname)
        return ok

    def promote(self, fname: str, version: Optional[int] = None) -> VersionRecord:
        """Repoint the stable pointer at the canary (or an explicit live
        version): from here every unsplit invocation serves it.  The old
        stable stays live — it is the new version's delta parent and the
        instant-rollback target."""
        with self._lock:
            vf = self._functions[fname]
            if version is None:
                if vf.canary is None:
                    raise RuntimeError(f"{fname}: nothing to promote")
                version = vf.canary
            rec = vf.records[version]
            if rec.status != LIVE:
                raise ValueError(f"cannot promote {rec.name} ({rec.status})")
            if vf.canary == version:
                vf.canary = None
                vf.canary_fraction = 0.0
            vf.current = version
            self.stats["promotes"] += 1
            return rec

    def rollback(self, fname: str) -> VersionRecord:
        """Instant rollback — a pointer move, zero new bytes published.
        With a canary in flight: the canary is rejected and the stable
        keeps serving.  Without one: the stable is backed out to its
        parent version, whose snapshot never left disk (and whose warm
        instances never left their nodes).  Returns the record now
        serving."""
        with self._lock:
            vf = self._functions[fname]
            if vf.canary is not None:
                vf.records[vf.canary].status = REJECTED
                vf.canary = None
                vf.canary_fraction = 0.0
                self.stats["rollbacks"] += 1
                return vf.records[vf.current]
            cur = vf.records[vf.current]
            if cur.parent is None:
                raise RuntimeError(f"{fname}: v{cur.version} has no parent")
            cur.status = ROLLED_BACK
            vf.current = cur.parent
            self.stats["rollbacks"] += 1
            return vf.records[vf.current]

    # ----------------------------------------------------------------- GC
    def retire(self, fname: str, version: int, unlink: bool = True) -> None:
        """Release one dead version: CAS manifest refs returned (private
        chunks no other image references are unlinked from the store),
        spec unregistered, warm instances evicted fleet-wide, JIF deleted.
        Refuses versions still routable (stable/canary/live) or with
        non-retired descendants (their delta restores read this JIF)."""
        with self._lock:
            vf = self._functions[fname]
            rec = vf.records[version]
            if version in (vf.current, vf.canary) or rec.status == LIVE:
                raise ValueError(f"{rec.name} is still routable ({rec.status})")
            if rec.status == RETIRED:
                return
            children = vf.live_children(version)
            if children:
                raise ValueError(
                    f"{rec.name} still parents live versions: "
                    f"{[c.name for c in children]}"
                )
            rec.status = RETIRED
            self.stats["retired"] += 1
        self.catalog.unpublish(rec.name, unlink=unlink)
        if self._router is not None:
            self._router.evict(rec.name)

    def gc_retired(self, fname: str) -> List[str]:
        """Retire every rejected/rolled-back version whose descendants are
        all retired, leaf-first until a fixed point.  Ancestors of the
        live head are never touched — they are the shared delta base the
        whole economics stands on."""
        done: List[str] = []
        while True:
            with self._lock:
                vf = self._functions[fname]
                victim = next(
                    (
                        r for r in vf.records.values()
                        if r.status in (REJECTED, ROLLED_BACK)
                        and not vf.live_children(r.version)
                    ),
                    None,
                )
            if victim is None:
                return done
            self.retire(fname, victim.version)
            done.append(victim.name)


class ColocatedTrainer:
    """Admit training compute onto the serving fleet as BATCH payload
    invocations.  Each :meth:`step` submits one thunk through the target
    (a :class:`~repro.serve.cluster.ClusterRouter` or a single
    :class:`~repro.serve.node.NodeScheduler`), waits its turn in the
    QoS-ordered run queue under the admission caps, and blocks for the
    result — training is sequential, so one step is in flight at a time,
    and a full batch lane backs the *trainer* off (bounded retry), never
    the serving traffic."""

    def __init__(
        self,
        target,
        job_name: str = "finetune",
        qos: QosClass = QosClass.BATCH,
        priority: int = 0,
        retry_backoff_s: float = 0.005,
    ):
        self.target = target
        self.job_name = job_name
        self.qos = qos
        self.priority = priority
        self.retry_backoff_s = retry_backoff_s
        self.stats = {"steps": 0, "admission_retries": 0, "queue_wait_s": 0.0}

    def step(self, fn: Callable, *args, timeout: float = 300.0, **kwargs):
        """Run ``fn(*args, **kwargs)`` as one admitted payload invocation
        and return its result."""
        cell: Dict[str, Any] = {}

        def thunk():
            cell["out"] = fn(*args, **kwargs)

        inv = Invocation(
            function=f"train:{self.job_name}", qos=self.qos,
            priority=self.priority, payload=thunk,
        )
        while True:
            try:
                handle = self.target.submit_invocation(inv)
                break
            except Overloaded:
                # the batch lane is full of *serving* batch work — training
                # yields and retries; admission never bends for it
                self.stats["admission_retries"] += 1
                time.sleep(self.retry_backoff_s)
        r = handle.result(timeout)
        self.stats["steps"] += 1
        self.stats["queue_wait_s"] += r.queue_wait_s
        return cell.get("out")
