"""Predictive pre-warm & cost-aware warmth policy engine.

Spice makes cold restores near-warm; this module drives the cold-start
*count* toward zero at a bounded memory premium, following the two
results PAPERS.md retrieved for this exact trade-off:

* SPES (arxiv 2403.17574): per-function invocation *prediction* — not a
  fleet-wide TTL knob — optimizes the performance/resource frontier.
* The cold-start survey (arxiv 2310.08437) taxonomizes hybrid-histogram
  keep-alive as the state of the art: serve the histogram *head* with an
  adaptive keep-alive window, and push the *long tail* onto the fast
  restore path instead of burning memory on idle instances.

Three pieces, each mapping onto machinery the stack already has:

* :class:`ArrivalTracker` — per-function inter-arrival histograms fed
  from the invocation front door (``ClusterRouter.submit_invocation``)
  and the control plane's warm-trace hook
  (``FunctionCatalog.record_access``).  Log-spaced buckets keep the
  state O(1) per function; per-bucket max gives tight upper-bound
  quantiles for periodic traffic.
* :class:`PrewarmPolicy` — a :class:`~repro.serve.node.KeepAlivePolicy`
  whose ``ttl_for`` derives a per-function window from the histogram
  head (gap quantile × margin, clamped), falling back to a short
  ``tail_ttl_s`` for long-tail functions (rely on restore + speculation
  instead of residency), and whose ``victims`` ranks eviction
  candidates by *expected re-restore penalty*: predicted
  time-to-next-invoke versus the estimated bytes a re-restore would
  actually pull (residual-only re-reads, chunk-CAS and device-image
  residency — :func:`repro.core.restore.estimate_rerestore_cost`).
* :class:`PrewarmEngine` — speculates restores of likely-next functions
  *through the existing admission/QoS path*: each speculation is a
  BATCH-class :class:`~repro.serve.invocation.Invocation` with
  ``prewarm=True`` submitted to the router, so it lands on the node
  ``LocalityFirst`` would pick, queues behind every LATENCY/STANDARD
  request, streams at BATCH I/O priority, bounces off the admission
  controller under load, and — because restores are joinable — merges
  with a real invocation that arrives mid-restore instead of doubling
  the I/O.  A mispredicted speculation is just an idle warm instance:
  the reaper or the reclaim ladder takes it back.
"""
from __future__ import annotations

import math
import threading
import time
import weakref
from typing import Dict, List, Optional, Tuple

from repro.core.restore import estimate_rerestore_cost
from repro.serve.invocation import (
    DeadlineExceeded,
    Invocation,
    InvocationHandle,
    Overloaded,
    QosClass,
)
from repro.serve.node import KeepAlivePolicy

__all__ = ["ArrivalTracker", "PrewarmPolicy", "PrewarmEngine"]

# log2-spaced gap buckets: bucket 0 holds gaps <= _BASE_S, bucket i holds
# (_BASE_S * 2**(i-1), _BASE_S * 2**i]; 40 buckets span 1 ms .. ~6 days.
_BASE_S = 1e-3
_N_BUCKETS = 40


def _bucket(gap_s: float) -> int:
    if gap_s <= _BASE_S:
        return 0
    return min(_N_BUCKETS - 1, 1 + int(math.log2(gap_s / _BASE_S)))


class _FnArrivals:
    __slots__ = ("last_ts", "gaps", "counts", "maxima")

    def __init__(self) -> None:
        self.last_ts: Optional[float] = None
        self.gaps = 0  # total inter-arrival samples
        self.counts = [0] * _N_BUCKETS
        self.maxima = [0.0] * _N_BUCKETS  # max gap seen per bucket


class ArrivalTracker:
    """Per-function inter-arrival histograms (``time.monotonic`` domain).

    ``record`` is called on the router's submit path, so it is O(1) and
    takes one short lock.  Quantiles come back as the *observed maximum*
    of the bucket the quantile falls in — a tight upper bound for the
    periodic traffic keep-alive windows are derived from."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._fns: Dict[str, _FnArrivals] = {}

    # ------------------------------------------------------------- feeding
    def record(self, fname: str, now: Optional[float] = None) -> None:
        now = time.monotonic() if now is None else now
        with self._lock:
            fn = self._fns.get(fname)
            if fn is None:
                fn = self._fns[fname] = _FnArrivals()
            if fn.last_ts is not None:
                gap = now - fn.last_ts
                if gap > 0:
                    b = _bucket(gap)
                    fn.counts[b] += 1
                    fn.gaps += 1
                    if gap > fn.maxima[b]:
                        fn.maxima[b] = gap
            fn.last_ts = now

    # ------------------------------------------------------------- queries
    def functions(self) -> List[str]:
        with self._lock:
            return list(self._fns)

    def observations(self, fname: str) -> int:
        """Inter-arrival samples recorded for ``fname`` (arrivals - 1)."""
        with self._lock:
            fn = self._fns.get(fname)
            return fn.gaps if fn else 0

    def last_arrival(self, fname: str) -> Optional[float]:
        with self._lock:
            fn = self._fns.get(fname)
            return fn.last_ts if fn else None

    def gap_quantile(
        self, fname: str, q: float, min_observations: int = 1
    ) -> Optional[float]:
        """The ``q``-quantile inter-arrival gap (seconds), or None when
        fewer than ``min_observations`` gaps were recorded."""
        with self._lock:
            fn = self._fns.get(fname)
            if fn is None or fn.gaps < max(1, min_observations):
                return None
            target = q * fn.gaps
            cum = 0
            for b in range(_N_BUCKETS):
                cum += fn.counts[b]
                if fn.counts[b] and cum >= target:
                    return fn.maxima[b]
            return fn.maxima[_N_BUCKETS - 1] or None

    def predict_eta(
        self,
        fname: str,
        now: Optional[float] = None,
        min_observations: int = 1,
        q: float = 0.5,
    ) -> Optional[float]:
        """Seconds until the *predicted* next arrival of ``fname`` (the
        median gap after its last arrival); negative = overdue; None =
        not enough history."""
        gap = self.gap_quantile(fname, q, min_observations)
        if gap is None:
            return None
        with self._lock:
            last = self._fns[fname].last_ts
        now = time.monotonic() if now is None else now
        return (last + gap) - now

    def snapshot(self) -> Dict[str, Dict[str, float]]:
        out: Dict[str, Dict[str, float]] = {}
        with self._lock:
            items = list(self._fns.items())
        for name, fn in items:
            out[name] = {
                "gaps": fn.gaps,
                "median_gap_s": self.gap_quantile(name, 0.5) or 0.0,
                "p90_gap_s": self.gap_quantile(name, 0.9) or 0.0,
            }
        return out


# ---------------------------------------------------------------- the policy
class PrewarmPolicy(KeepAlivePolicy):
    """Hybrid-histogram keep-alive + cost-aware eviction ranking.

    ``ttl_for``: the histogram-head window ``gap_quantile(head_quantile)
    × ttl_margin``, clamped into ``[min_ttl_s, max_ttl_s]``.  A function
    whose head window would exceed ``max_ttl_s`` is *long tail*: keeping
    it resident buys nothing per byte, so it gets the short
    ``tail_ttl_s`` grace (sized to cover the engine's speculation
    horizon) and relies on the fast restore path.  Unknown functions
    fall back to ``default_ttl_s`` (or the spec's static
    ``warm_ttl_s``).

    ``victims``: eviction candidates ranked by expected re-restore
    penalty ``cost_bytes / eta_s`` — the instance that is cheapest to
    bring back *and* least likely to be needed soon goes first; an
    imminent (or overdue) predicted arrival makes the penalty spike so
    the instance survives.  The cost estimate accounts for pinned
    working sets (residual-only re-read), chunk-CAS residency and
    device-resident bases via the node-bound ``cost_fn``
    (:meth:`repro.serve.node.NodeScheduler.rerestore_cost` — wired
    automatically when a node adopts this policy).  Honors
    ``need_evict``: at most that many instances come back.

    Share one :class:`ArrivalTracker` across a fleet but give each node
    its own policy instance, so each node's residency feeds its own
    cost function."""

    def __init__(
        self,
        tracker: ArrivalTracker,
        *,
        default_ttl_s: Optional[float] = None,
        min_ttl_s: float = 0.05,
        max_ttl_s: float = 30.0,
        tail_ttl_s: Optional[float] = None,
        head_quantile: float = 0.9,
        ttl_margin: float = 1.25,
        min_observations: int = 3,
        unknown_eta_s: float = 60.0,
        cost_fn=None,
    ):
        self.tracker = tracker
        self.default_ttl_s = default_ttl_s
        self.min_ttl_s = min_ttl_s
        self.max_ttl_s = max_ttl_s
        self.tail_ttl_s = tail_ttl_s if tail_ttl_s is not None else max(
            min_ttl_s, 0.5
        )
        self.head_quantile = head_quantile
        self.ttl_margin = ttl_margin
        self.min_observations = min_observations
        self.unknown_eta_s = unknown_eta_s
        self.cost_fn = cost_fn

    def bind_node(self, node) -> None:
        """Adopt the node's residency-aware re-restore cost estimate
        (called by :class:`~repro.serve.node.NodeScheduler` on
        construction; an explicitly injected ``cost_fn`` wins)."""
        if self.cost_fn is None:
            self.cost_fn = node.rerestore_cost

    # ---------------------------------------------------------------- TTL
    def ttl_for(self, spec) -> float:
        gap = self.tracker.gap_quantile(
            spec.name, self.head_quantile, self.min_observations
        )
        if gap is None:
            if self.default_ttl_s is not None:
                return self.default_ttl_s
            return spec.warm_ttl_s
        ttl = gap * self.ttl_margin
        if ttl > self.max_ttl_s:
            return self.tail_ttl_s  # long tail: restore, don't idle
        return max(ttl, self.min_ttl_s)

    # ----------------------------------------------------------- eviction
    def _cost(self, inst) -> int:
        if self.cost_fn is not None:
            return self.cost_fn(inst)
        return estimate_rerestore_cost(
            inst.restore_stats, image_bytes=inst.memory_bytes
        )

    def victims(self, warm, need_evict: int):
        now = time.monotonic()
        scored: List[Tuple[float, float, int, object]] = []
        for inst in warm:
            eta = self.tracker.predict_eta(
                inst.spec.name, now=now,
                min_observations=self.min_observations,
            )
            if eta is None:
                eta = self.unknown_eta_s
            # imminent or overdue arrival -> near-zero eta -> the penalty
            # spikes and the instance is sacrificed last
            eta = max(eta, 1e-3)
            penalty = self._cost(inst) / eta
            scored.append((penalty, inst.last_used, id(inst), inst))
        scored.sort(key=lambda s: (s[0], s[1]))
        return [s[3] for s in scored[: max(0, need_evict)]]


# ---------------------------------------------------------------- the engine
class PrewarmEngine:
    """Issues speculative restores of likely-next functions.

    Attach to a :class:`~repro.serve.cluster.ClusterRouter` (pass it as
    ``ClusterRouter(prewarm=engine)``); the router feeds every real
    arrival into the tracker and the engine ticks on a daemon thread
    (weakref'd, like the node reaper: a dropped fleet is GC-able).

    Admission rules for speculation — all inherited, none bespoke:

    * placement: the speculation is a normal router submit, so it lands
      on the node the placement policy (``LocalityFirst``) picks —
      warm > joinable > image-cached — and sticky routing guarantees a
      real invocation arriving mid-restore lands on the SAME node and
      joins the in-flight restore (exactly one set of storage reads).
    * QoS: BATCH class — dispatched after every LATENCY/STANDARD entry
      in the run queue, prefetch stream opened at I/O priority −1
      (above only residual tails), never triggers scale-out or steals.
    * backpressure: the node's :class:`AdmissionController` caps apply
      (``max_batch_queued`` / ``max_batch_inflight``); a refusal is
      counted and dropped, never retried into a loaded node.  The
      engine additionally caps its own in-flight speculations.

    A ``prewarm=True`` invocation restores and promotes but skips
    generation; one that finds its function already warm (or restoring)
    is a no-op."""

    def __init__(
        self,
        tracker: Optional[ArrivalTracker] = None,
        *,
        horizon_s: float = 0.3,
        overdue_grace_s: float = 0.25,
        interval_s: Optional[float] = 0.05,
        max_inflight: int = 4,
        min_observations: int = 3,
        speculative: bool = True,
        mode: str = "spice",
        simulate_read_bw: Optional[float] = None,
    ):
        """``horizon_s``: speculate when the predicted next arrival is
        within this window (pair with a ``PrewarmPolicy.tail_ttl_s``
        comfortably above it, so the speculative instance survives
        until the predicted arrival).  ``speculative=False`` keeps the
        arrival feed (adaptive TTLs still learn) but never restores —
        the "adaptive, no speculation" ablation regime."""
        self.tracker = tracker if tracker is not None else ArrivalTracker()
        self.horizon_s = horizon_s
        self.overdue_grace_s = overdue_grace_s
        self.interval_s = interval_s
        self.max_inflight = max_inflight
        self.min_observations = min_observations
        self.speculative = speculative
        self.mode = mode
        self.simulate_read_bw = simulate_read_bw
        self._router_ref = None
        self._lock = threading.Lock()
        self._inflight: Dict[str, InvocationHandle] = {}
        self._stop: Optional[threading.Event] = None
        self.stats = {
            "ticks": 0,
            "speculative_submitted": 0,
            "speculative_ok": 0,
            "speculative_failed": 0,
            "suppressed_resident": 0,
            "suppressed_inflight": 0,
            "suppressed_admission": 0,
        }

    # -------------------------------------------------------------- wiring
    def attach(self, router) -> None:
        """Bind to a router (called by ``ClusterRouter.__init__``): wire
        the catalog's access feed to the tracker and start ticking."""
        self._router_ref = weakref.ref(router)
        if router.catalog is not None:
            router.catalog.arrival_tracker = self.tracker
        if self.interval_s is not None and self.speculative:
            self.start(self.interval_s)

    def on_arrival(self, fname: str, now: Optional[float] = None) -> None:
        self.tracker.record(fname, now)

    def start(self, interval_s: Optional[float] = None) -> None:
        self.stop()
        interval = interval_s if interval_s is not None else self.interval_s
        if interval is None:
            return
        stop = threading.Event()
        self._stop = stop
        ref = weakref.ref(self)

        def loop():
            while not stop.wait(interval):
                eng = ref()
                if eng is None:
                    return
                try:
                    eng.tick()
                except Exception:
                    pass  # a failed tick must not kill the engine
                finally:
                    eng = None  # never hold the engine across the sleep

        threading.Thread(
            target=loop, name="prewarm-engine", daemon=True
        ).start()

    def stop(self) -> None:
        if self._stop is not None:
            self._stop.set()
            self._stop = None

    # --------------------------------------------------------------- ticking
    def _bump(self, key: str, n: int = 1) -> None:
        with self._lock:
            self.stats[key] += n

    def _reap_done(self) -> None:
        with self._lock:
            done = [(f, h) for f, h in self._inflight.items() if h.done()]
            for f, _ in done:
                del self._inflight[f]
        for _, h in done:
            ok = h.exception() is None
            self._bump("speculative_ok" if ok else "speculative_failed")

    def inflight(self) -> int:
        with self._lock:
            return len(self._inflight)

    def drain(self, timeout: float = 30.0) -> bool:
        """Block until every in-flight speculation resolved (benchmark
        barrier); returns False on timeout."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            self._reap_done()
            if not self._inflight:
                return True
            time.sleep(0.01)
        return False

    def tick(self, now: Optional[float] = None) -> int:
        """One speculation pass; returns speculations issued.  Runs on
        the background thread, but callable directly (tests)."""
        self._bump("ticks")
        router = self._router_ref() if self._router_ref is not None else None
        if router is None or not self.speculative:
            return 0
        self._reap_done()
        now = time.monotonic() if now is None else now
        due: List[Tuple[float, str]] = []
        for fname in self.tracker.functions():
            eta = self.tracker.predict_eta(
                fname, now=now, min_observations=self.min_observations
            )
            if eta is None or eta > self.horizon_s:
                continue
            if eta < -self.overdue_grace_s:
                continue  # stale prediction: the arrival never came
            due.append((eta, fname))
        if not due:
            return 0
        resident = set()
        for load in router.loads():
            resident |= load.warm | load.restoring
        issued = 0
        for _, fname in sorted(due):
            with self._lock:
                if fname in self._inflight:
                    self.stats["suppressed_inflight"] += 1
                    continue
                if len(self._inflight) >= self.max_inflight:
                    break
            if fname in resident:
                self._bump("suppressed_resident")
                continue
            try:
                router.catalog.registry.get(fname)
            except KeyError:
                continue  # tracked name that was never published here
            inv = Invocation(
                function=fname,
                prompt=None,
                max_new_tokens=0,
                mode=self.mode,
                simulate_read_bw=self.simulate_read_bw,
                qos=QosClass.BATCH,
                prewarm=True,
            )
            try:
                handle = router.submit_invocation(inv)
            except (Overloaded, DeadlineExceeded):
                self._bump("suppressed_admission")
                continue
            with self._lock:
                self._inflight[fname] = handle
                self.stats["speculative_submitted"] += 1
            issued += 1
        return issued
