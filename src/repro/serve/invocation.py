"""Invocation API v2 — typed requests, QoS classes, deadlines, cancellation.

The serving stack's original surface was an untyped ``invoke(*args)`` /
bare ``Future`` pair: no way to tell urgent work from background work, no
deadline, no cancellation, no backpressure.  "Near-warm" restores only stay
near-warm under load if the stack can rank work — a burst of batch traffic
must not starve latency-critical restores at the I/O arbiter or the memory
ledger.  This module is the typed front door every layer now speaks:

* :class:`Invocation` — one request: function, prompt, a
  :class:`QosClass` (LATENCY / STANDARD / BATCH), an optional absolute
  deadline, and a within-class priority.
* :class:`InvocationHandle` — replaces the raw Future.  ``result()``,
  best-effort ``cancel()``, and ``events()``: the ADMITTED → PLACED →
  RESTORING → WS_READY → RUNNING → DONE timeline with monotonic
  timestamps (benchmarks split queueing delay from restore delay with it).
* :class:`AdmissionController` — per-function concurrency caps and
  bounded queues; refusals are *typed* (:class:`Overloaded`,
  :class:`DeadlineExceeded`) instead of unbounded thread-pool growth.

QoS threads through every layer: the node dispatches its run queue in
class order, the restorer opens its prefetch stream at the class's I/O
priority (a LATENCY stream overtakes BATCH residual streaming at the
arbiter), and the cluster router may steal a least-loaded node for a
LATENCY invoke where a BATCH invoke waits.  ``invoke()``/``submit()``
survive as thin wrappers building a STANDARD-class :class:`Invocation`.
"""
from __future__ import annotations

import dataclasses
import enum
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = [
    "QosClass",
    "Invocation",
    "InvocationHandle",
    "AdmissionController",
    "InvocationError",
    "Overloaded",
    "DeadlineExceeded",
    "InvocationCancelled",
    "deadline_in",
    "EVT_ADMITTED",
    "EVT_PLACED",
    "EVT_RESTORING",
    "EVT_WS_READY",
    "EVT_RUNNING",
    "EVT_DONE",
    "EVT_CANCELLED",
    "EVT_REJECTED",
    "EVT_FAILED",
]

# Event names of the invocation timeline (recorded with time.monotonic()
# timestamps).  The canonical order is ADMITTED → PLACED → RESTORING →
# WS_READY → RUNNING → DONE; for a restore OWNER, RUNNING (layer-gated
# generation start) legitimately overlaps the restore and may precede
# WS_READY — execution resuming while memory streams is the paper's whole
# point, and the timeline reports what actually happened.
EVT_ADMITTED = "ADMITTED"     # passed the node's admission controller
EVT_PLACED = "PLACED"         # entered a node's run queue (handle.node set)
EVT_RESTORING = "RESTORING"   # owns (or rides) an in-flight restore
EVT_WS_READY = "WS_READY"     # traced working set resident (cancel no-ops after)
EVT_RUNNING = "RUNNING"       # generation started
EVT_DONE = "DONE"             # result delivered
EVT_CANCELLED = "CANCELLED"   # terminal: cancelled (queued or mid-restore)
EVT_REJECTED = "REJECTED"     # terminal: typed rejection (overload/deadline)
EVT_FAILED = "FAILED"         # terminal: real failure


class InvocationError(RuntimeError):
    """Base of every typed invocation outcome that is not a result."""


class Overloaded(InvocationError):
    """Admission refused: a bounded queue or concurrency cap is full (or
    the node/router is shutting down).  Back off and retry elsewhere."""


class DeadlineExceeded(InvocationError):
    """The invocation's absolute deadline passed before it could run."""


class InvocationCancelled(InvocationError):
    """The invocation was cancelled (while queued, or mid-restore)."""


def deadline_in(seconds: float) -> float:
    """Absolute deadline ``seconds`` from now, in the ``time.monotonic()``
    domain :class:`Invocation.deadline_s` uses."""
    return time.monotonic() + float(seconds)


class QosClass(enum.Enum):
    """Service class of one invocation — the single knob every layer reads.

    * ``LATENCY`` — interactive traffic: dispatched first at the node,
      prefetch stream opened above everyone else at the I/O arbiter, and
      the router may steal/scale out a node for it.
    * ``STANDARD`` — the default; exactly the pre-v2 behavior.
    * ``BATCH`` — background work: dispatched last, streams below demand
      traffic (but above residual tails), never triggers scale-out.
    """

    LATENCY = "latency"
    STANDARD = "standard"
    BATCH = "batch"

    @property
    def dispatch_rank(self) -> int:
        """Node run-queue order: lower runs first."""
        return {QosClass.LATENCY: 0, QosClass.STANDARD: 1, QosClass.BATCH: 2}[self]

    @property
    def io_priority(self) -> int:
        """Prefetch-stream priority at the I/O arbiter.  BATCH demand (-1)
        still sits above residual background tails (-2, see
        ``repro.core.restore.BACKGROUND_PRIORITY``)."""
        return {QosClass.LATENCY: 2, QosClass.STANDARD: 0, QosClass.BATCH: -1}[self]


@dataclasses.dataclass
class Invocation:
    """One typed request.  ``deadline_s`` is an *absolute*
    ``time.monotonic()`` value (build one with :func:`deadline_in`);
    ``priority`` breaks ties within a QoS class (higher first)."""

    function: str
    prompt: Any = None
    max_new_tokens: int = 8
    mode: str = "spice"
    cfg: Any = None
    simulate_read_bw: Optional[float] = None
    qos: QosClass = QosClass.STANDARD
    deadline_s: Optional[float] = None
    priority: int = 0
    # speculative pre-warm (PrewarmEngine): restore + promote but skip
    # generation; a no-op when the function is already warm/restoring.
    # Never fed back into the arrival tracker.
    prewarm: bool = False
    # warm-state handoff (repro.serve.handoff): restore this JIF — a delta
    # of live warm state against the function's own base — instead of the
    # registered image.  Per-invocation: the registry is never touched, so
    # any later restore of the function reads the published image.
    jif_override: Optional[str] = None
    # colocated compute lane (repro.serve.deploy.ColocatedTrainer): run
    # this thunk on a worker instead of restore+generate.  The function
    # name is a label (never resolved through the registry); admission
    # caps, QoS run-queue order, deadlines and queued-cancel all apply —
    # which is the point: BATCH-class training competes for the node
    # under the same contract as BATCH invocations.
    payload: Optional[Callable[[], Any]] = None

    def remaining_s(self, now: Optional[float] = None) -> Optional[float]:
        if self.deadline_s is None:
            return None
        return self.deadline_s - (time.monotonic() if now is None else now)


class InvocationHandle:
    """The caller's grip on one in-flight invocation (replaces the raw
    ``concurrent.futures.Future``; duck-types the parts the old surface
    used: ``result()`` / ``done()`` / ``exception()`` / ``cancelled()``).

    ``cancel()`` is best-effort and phase-aware:

    * queued            — always succeeds; the invocation never runs;
    * mid-restore       — succeeds iff this invocation *owns* the restore
      and no concurrent invocation joined it (aborting a shared stream
      would fail innocent riders); the stream is aborted and every ledger
      reservation is returned through the restore's failure paths;
    * after WS_READY    — no-op (returns False); the result is delivered.

    ``cancel() -> True`` means the cancel was *accepted*; the authoritative
    outcome is ``result()`` (a cancel racing the final tensor may lose).
    """

    def __init__(self, invocation: Invocation, node: str = ""):
        self.invocation = invocation
        self.node = node
        self._lock = threading.Lock()
        self._events: List[Tuple[str, float]] = []
        self._done_ev = threading.Event()
        self._result = None
        self._exc: Optional[BaseException] = None
        # phase: queued -> running -> (restoring | pinned) -> done
        self._phase = "queued"
        self._cancel_requested = False
        self._was_cancelled = False
        self._canceller: Optional[Callable[[], bool]] = None
        self._retired = False  # scheduler-side: admission counters returned

    # -------------------------------------------------------------- events
    def record(self, event: str, ts: Optional[float] = None) -> None:
        with self._lock:
            self._events.append((event, time.monotonic() if ts is None else ts))

    def events(self) -> List[Tuple[str, float]]:
        """The timeline so far: ``[(event, monotonic_ts), ...]``."""
        with self._lock:
            return list(self._events)

    def event_ts(self, event: str) -> Optional[float]:
        with self._lock:
            for name, ts in self._events:
                if name == event:
                    return ts
        return None

    def queue_wait_s(self) -> float:
        """ADMITTED → first of {RESTORING, WS_READY, RUNNING} (or the
        terminal event): how long the request sat in queues before any
        work happened on its behalf."""
        admitted = self.event_ts(EVT_ADMITTED)
        if admitted is None:
            return 0.0
        for evt in (EVT_RESTORING, EVT_WS_READY, EVT_RUNNING,
                    EVT_CANCELLED, EVT_REJECTED, EVT_FAILED, EVT_DONE):
            ts = self.event_ts(evt)
            if ts is not None:
                return max(0.0, ts - admitted)
        return 0.0

    # ------------------------------------------------------------- outcome
    def result(self, timeout: Optional[float] = None):
        """Block for the :class:`~repro.serve.node.InvokeResult`; raises
        the typed outcome (:class:`InvocationCancelled`,
        :class:`DeadlineExceeded`, :class:`Overloaded`) or the failure."""
        if not self._done_ev.wait(timeout):
            raise TimeoutError(
                f"invocation of {self.invocation.function!r} still in flight"
            )
        if self._exc is not None:
            raise self._exc
        return self._result

    def exception(self, timeout: Optional[float] = None) -> Optional[BaseException]:
        if not self._done_ev.wait(timeout):
            raise TimeoutError(
                f"invocation of {self.invocation.function!r} still in flight"
            )
        return self._exc

    def done(self) -> bool:
        return self._done_ev.is_set()

    def cancelled(self) -> bool:
        return self._was_cancelled

    @property
    def cancel_requested(self) -> bool:
        return self._cancel_requested

    # -------------------------------------------------------------- cancel
    def cancel(self) -> bool:
        with self._lock:
            if self._phase == "done":
                return self._was_cancelled
            if self._cancel_requested:
                return True
            if self._phase in ("queued", "running"):
                # queued: the dispatcher observes the flag at claim time and
                # never runs the invocation.  running (restore being set
                # up, role not yet determined): the flag is honored the
                # moment the owner arms its canceller — accepted now, so
                # the set-up window is not a cancellation dead zone.
                self._cancel_requested = True
                return True
            if self._phase == "restoring" and self._canceller is not None:
                canceller = self._canceller
                # set the flag BEFORE aborting: the abort releases the
                # owner's tensor waiters synchronously, and the owner must
                # never misread its own cancellation as collateral from
                # someone else's (which would trigger a retry restore)
                self._cancel_requested = True
            else:  # pinned (working set resident / warm hit): too late
                return False
        ok = canceller()  # aborts the stream; runs OUTSIDE the handle lock
        if ok:
            return True
        with self._lock:
            if self._phase != "done":
                self._cancel_requested = False  # abort did not take: revert
        return False

    # ----------------------------------------- dispatcher-side transitions
    def _claim_for_run(self) -> bool:
        """Queued → running (dispatcher thread).  False when a queued
        cancel already decided this invocation's fate."""
        with self._lock:
            if self._cancel_requested:
                return False
            self._phase = "running"
            return True

    def _attach_canceller(self, fn: Callable[[], bool]) -> None:
        """Arm mid-restore cancellation (restore owner only).  A no-op when
        the handle already pinned (working set landed before the owner got
        here — the synchronous restore path).  A cancel accepted during
        set-up fires the canceller immediately; its outcome surfaces
        through the restore failure path."""
        with self._lock:
            if self._phase != "running":
                return
            self._canceller = fn
            self._phase = "restoring"
            pending = self._cancel_requested
        if pending:
            fn()

    def _pin(self) -> None:
        """Point of no return (working set resident / warm hit): cancel()
        is a no-op from here on; the result will be delivered."""
        with self._lock:
            if self._phase != "done":
                self._phase = "pinned"
                self._canceller = None

    def _reset_for_retry(self) -> None:
        """Re-open the phase machine before a dispatcher retry (a rider
        failed by someone else's cancel restores afresh): without this the
        stale pinned/restoring phase would block the retry's canceller and
        make the retry un-cancellable."""
        with self._lock:
            if self._phase != "done":
                self._phase = "running"
                self._canceller = None

    def _finish(self, event: str, result=None, exc: Optional[BaseException] = None,
                cancelled: bool = False) -> None:
        with self._lock:
            if self._phase == "done":
                return
            self._phase = "done"
            self._canceller = None
            self._result = result
            self._exc = exc
            self._was_cancelled = cancelled
            if not cancelled:
                self._cancel_requested = False  # a raced cancel lost: outcome wins
            self._events.append((event, time.monotonic()))
        self._done_ev.set()

    def _finish_ok(self, result) -> None:
        self._finish(EVT_DONE, result=result)

    def _finish_cancelled(self, exc: InvocationCancelled) -> None:
        self._finish(EVT_CANCELLED, exc=exc, cancelled=True)

    def _finish_rejected(self, exc: InvocationError) -> None:
        self._finish(EVT_REJECTED, exc=exc)

    def _finish_failed(self, exc: BaseException) -> None:
        self._finish(EVT_FAILED, exc=exc)


class AdmissionController:
    """Typed backpressure at the node: bounded queues + per-function
    concurrency caps, refusing with :class:`Overloaded` instead of letting
    the run queue grow without bound.

    * ``max_queue_depth``     — cap on invocations *queued* (not yet
      running) on the node; ``None`` = unbounded (the pre-v2 behavior).
    * ``max_batch_queued``    — tighter bound on queued BATCH work, so a
      batch burst fills its own lane instead of the whole queue.
    * ``max_batch_inflight``  — cap on BATCH work admitted at all (queued +
      running).  A restore-blocked BATCH invocation holds a worker thread;
      without this cap a batch wave can occupy every worker and starve
      LATENCY dispatch no matter how the queue is ordered.
    * ``function_caps`` / ``default_function_cap`` — cap on one function's
      admitted (queued + running) invocations; joiners and warm hits count
      too, because each holds a worker thread.
    """

    def __init__(
        self,
        max_queue_depth: Optional[int] = None,
        max_batch_queued: Optional[int] = None,
        max_batch_inflight: Optional[int] = None,
        function_caps: Optional[Dict[str, int]] = None,
        default_function_cap: Optional[int] = None,
    ):
        self.max_queue_depth = max_queue_depth
        self.max_batch_queued = max_batch_queued
        self.max_batch_inflight = max_batch_inflight
        self.function_caps = dict(function_caps or {})
        self.default_function_cap = default_function_cap

    def cap_for(self, fname: str) -> Optional[int]:
        return self.function_caps.get(fname, self.default_function_cap)

    def admit(self, inv: Invocation, queued: int, fn_active: int,
              batch_queued: int, batch_active: int = 0) -> None:
        """Raise :class:`Overloaded` when ``inv`` must be refused; called
        under the scheduler's stats lock with its current counters."""
        if self.max_queue_depth is not None and queued >= self.max_queue_depth:
            raise Overloaded(
                f"{inv.function}: node queue full "
                f"({queued}/{self.max_queue_depth} queued)"
            )
        if inv.qos is QosClass.BATCH:
            if (
                self.max_batch_queued is not None
                and batch_queued >= self.max_batch_queued
            ):
                raise Overloaded(
                    f"{inv.function}: batch lane full "
                    f"({batch_queued}/{self.max_batch_queued} queued)"
                )
            if (
                self.max_batch_inflight is not None
                and batch_active >= self.max_batch_inflight
            ):
                raise Overloaded(
                    f"{inv.function}: batch in-flight cap reached "
                    f"({batch_active}/{self.max_batch_inflight} admitted)"
                )
        cap = self.cap_for(inv.function)
        if cap is not None and fn_active >= cap:
            raise Overloaded(
                f"{inv.function}: per-function concurrency cap reached "
                f"({fn_active}/{cap} in flight)"
            )
