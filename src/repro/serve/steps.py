"""Serving steps: prefill (build cache + first token) and decode (one new
token against an existing KV/SSM cache). ``decode_step`` is what the
``decode_*`` / ``long_*`` dry-run cells lower."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm


@dataclasses.dataclass(frozen=True)
class ServeStepConfig:
    compute_dtype: str = "bfloat16"
    kv_dtype: str = "bfloat16"
    kv_repeat: int = 1  # KV-head replication so heads divide the TP axis
    kv_block: int = 2048  # flash-decoding block length
    attn_stages: int = 1  # staged causal K-slicing in chunked prefill
    q_chunk: int = 512
    greedy: bool = True
    unroll_scans: bool = False  # layer scans (decode: in-place cache aliasing)
    unroll_inner: Optional[bool] = None  # attention block loops (cost runs)


def make_prefill_step(cfg: ModelConfig, scfg: ServeStepConfig):
    compute_dtype = jnp.dtype(scfg.compute_dtype)

    def prefill_step(params, batch):
        logits, caches, _ = lm.prefill(
            cfg,
            params,
            batch,
            compute_dtype=compute_dtype,
            q_chunk=scfg.q_chunk,
            unroll=scfg.unroll_scans,
            kv_repeat=scfg.kv_repeat,
            kv_dtype=jnp.dtype(scfg.kv_dtype),
            attn_stages=scfg.attn_stages,
        )
        next_tok = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1).astype(jnp.int32)
        return next_tok, caches

    return prefill_step


def make_decode_step(cfg: ModelConfig, scfg: ServeStepConfig):
    compute_dtype = jnp.dtype(scfg.compute_dtype)

    def decode_step(params, caches, batch, pos):
        logits, caches, _ = lm.decode_step(
            cfg, params, batch, caches, pos,
            compute_dtype=compute_dtype, unroll=scfg.unroll_scans,
            unroll_inner=scfg.unroll_inner, kv_repeat=scfg.kv_repeat,
            kv_block=scfg.kv_block,
        )
        next_tok = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1).astype(jnp.int32)
        return next_tok, caches

    return decode_step
