"""Function instance lifecycle + layer-gated generation.

One :class:`FunctionInstance` per published function per node, moving
through an explicit state machine::

    COLD ──begin_restore──▶ RESTORING ──ws complete──▶ WARMING ──residual──▶ WARM
      ▲                         │ (no residual: promote straight to WARM)      │
      └───────────── (next invocation) ◀── EVICTED ◀────────── evict/TTL ──────┘

WARMING is the paper's WARM-at-working-set promotion: every tensor before
the JIF's ws boundary is resident, so invocations route warm and generate
layer-gated over the residual handles while the tail streams at background
priority; the residual's completion finalizes WARM (resolved device tree).

The instance owns everything a live function needs: the restore handle tree
(TensorHandles while the prefetcher streams), the resolver used to gate
each layer on exactly its parameters, keep-alive/TTL accounting, and
memory-footprint bookkeeping for the node's LRU eviction.  Invocations that
arrive while a restore is in flight *join* it — they generate over the same
handle tree, waiting per tensor, instead of issuing a second restore of the
same snapshot.

Generation executes models layer by layer so the first layers run while the
prefetcher is still streaming later layers from storage (the paper's §4.2
"execution resumes immediately while the bulk of memory is fetched").  Layer
readiness is *tracked* (TensorHandle events), never advisory.  Per-layer
jitted functions act as the restored compile cache: metadata restore brings
back cache *keys*, not re-traces.
"""
from __future__ import annotations

import contextlib
import enum
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LayerSpec, ModelConfig
from repro.core.restore import RestoreStats, TensorHandle
from repro.models import blocks
from repro.models.layers import embed, rmsnorm, unembed


def layer_sequence(cfg: ModelConfig) -> List[LayerSpec]:
    seq: List[LayerSpec] = []
    for _ in range(cfg.pattern_reps):
        seq.extend(cfg.pattern)
    seq.extend(cfg.remainder)
    return seq


def layerwise_state(cfg: ModelConfig, params) -> Dict:
    """Stacked (scan-form) params -> per-layer list (serving layout)."""
    layers = []
    for rep in range(cfg.pattern_reps):
        for i in range(len(cfg.pattern)):
            layers.append(
                jax.tree.map(lambda a: np.asarray(a[rep]), params["pattern"][i])
            )
    for j in range(len(cfg.remainder)):
        layers.append(jax.tree.map(np.asarray, params["remainder"][j]))
    return {
        "embed": jax.tree.map(np.asarray, params["embed"]),
        "layers": layers,
        "final_norm": np.asarray(params["final_norm"]),
    }


# ----------------------------------------------------------- compile cache
_COMPILE_CACHE: Dict[Tuple, Any] = {}
_COMPILE_LOCK = threading.Lock()


def _cached(key, build):
    fn = _COMPILE_CACHE.get(key)
    if fn is None:
        with _COMPILE_LOCK:
            fn = _COMPILE_CACHE.get(key)
            if fn is None:
                fn = _COMPILE_CACHE[key] = build()
    return fn


def _layer_fn(cfg: ModelConfig, spec: LayerSpec, mode: str):
    def build():
        def fn(p, x, positions, cache, pos):
            x, c, _ = blocks.apply_layer(
                cfg, spec, p, x, positions=positions, mode=mode, cache=cache,
                pos=pos, compute_dtype=jnp.float32,
            )
            return x, c

        return jax.jit(fn)

    return _cached(("layer", cfg.name, spec, mode), build)


def _embed_fn(cfg: ModelConfig):
    return _cached(
        ("embed", cfg.name),
        lambda: jax.jit(lambda p, toks: embed(cfg, p, toks, jnp.float32)),
    )


def _head_fn(cfg: ModelConfig):
    def build():
        def fn(p_embed, p_norm, x):
            x = rmsnorm(x[:, -1:], p_norm, cfg.norm_eps)
            logits = unembed(cfg, p_embed, x, jnp.float32)
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

        return jax.jit(fn)

    return _cached(("head", cfg.name), build)


def wait_tree(tree):
    """Resolve TensorHandle leaves (blocking, tracked completion)."""
    return jax.tree.map(
        lambda leaf: leaf.wait() if isinstance(leaf, TensorHandle) else leaf,
        tree,
        is_leaf=lambda l: isinstance(l, TensorHandle),
    )


def state_layer(state, i, resolve):
    return resolve(state["layers"][i])


def generate(cfg, getter, state, prompt: np.ndarray, max_new: int):
    """Layer-gated generation: each layer waits for exactly its params.
    Returns (tokens, ttft_s).  Read-only over ``state``; safe to run
    concurrently from several invocations sharing one instance."""
    # default resolver materializes any lazy leaves (access-trace
    # proxies); a no-op for already-installed device arrays
    resolve = getter or (
        lambda t: jax.tree.map(lambda l: jnp.asarray(np.asarray(l)) if not isinstance(l, jax.Array) else l, t)
    )
    specs = layer_sequence(cfg)
    B, S = prompt.shape
    positions = np.broadcast_to(np.arange(S, dtype=np.int32), (B, S))

    t0 = time.perf_counter()
    p_embed = resolve(state["embed"])
    x = _embed_fn(cfg)(p_embed, prompt)
    caches = []
    for i, spec in enumerate(specs):
        p_i = resolve(state["layers"][i])
        x, c = _layer_fn(cfg, spec, "prefill")(p_i, x, positions, None, None)
        caches.append(c)
    p_norm = resolve(state["final_norm"])
    tok = _head_fn(cfg)(p_embed, p_norm, x)
    ttft = time.perf_counter() - t0
    out = [np.asarray(tok)]

    pos = S
    for _ in range(max_new - 1):
        x = _embed_fn(cfg)(p_embed, np.asarray(tok)[:, None])
        dpos = np.broadcast_to(np.int32(pos), (B, 1))
        for i, spec in enumerate(specs):
            x, caches[i] = _layer_fn(cfg, spec, "decode")(
                state_layer(state, i, resolve), x, dpos, caches[i], jnp.int32(pos)
            )
        tok = _head_fn(cfg)(p_embed, p_norm, x)
        out.append(np.asarray(tok))
        pos += 1
    return np.stack(out, axis=1), ttft


class _FaasnapLeaf:
    def __init__(self, r, name):
        self._r = r
        self.name = name

    def fault(self):
        return self._r.ensure(self.name)


def faasnap_wait(tree):
    return jax.tree.map(
        lambda l: jnp.asarray(l.fault()) if isinstance(l, _FaasnapLeaf) else l,
        tree,
        is_leaf=lambda l: isinstance(l, _FaasnapLeaf),
    )


class NotWarmError(RuntimeError):
    """The instance was not WARM when a warm-tree pin was requested —
    distinct from RuntimeErrors raised by work done *under* the pin, so
    callers with a not-warm fallback don't swallow real failures."""


# ---------------------------------------------------------- instance state
class InstanceState(enum.Enum):
    COLD = "cold"
    RESTORING = "restoring"
    WARMING = "warming"  # working set resident; residual streaming in
    WARM = "warm"
    EVICTED = "evicted"  # may keep a pinned working set (residual evicted):
    # the next restore then reads ONLY the residual bytes it dropped


class FunctionInstance:
    """Lifecycle container for one function on one node.

    Transitions are driven by the :class:`~repro.serve.node.NodeScheduler`;
    every mutation happens under ``cond``'s lock.  ``generation`` counts
    restore generations — a new restore after eviction bumps it, so stale
    joiners can detect they are looking at a dead tree."""

    def __init__(self, spec, cfg: ModelConfig):
        self.spec = spec
        self.cfg = cfg
        self.state = InstanceState.COLD
        self.generation = 0
        # state-change hook (set by the owning NodeScheduler): fired by
        # _notify_transition() after every lifecycle edge, while ``cond`` is
        # still held — it must be non-blocking (the node uses it to bump a
        # load-epoch counter so cached NodeLoad snapshots invalidate)
        self.on_transition: Optional[Callable[["FunctionInstance"], None]] = None
        self.cond = threading.Condition()
        self.tree: Optional[Any] = None          # handles while RESTORING,
        self.getter: Optional[Callable] = None   # resolved arrays once WARM
        self.restore_stats: Optional[RestoreStats] = None
        self.restore_mode: Optional[str] = None
        self.last_used = 0.0
        self.warm_expiry = 0.0   # 0 = no keep-alive
        self.memory_bytes = 0
        self.inflight = 0
        self.ws_ready = False    # working set resident (WARMING/WARM)
        # ledger regions adopted from the restorer (repro.core.memory):
        # ws_region charges the pinned working set, residual_region the
        # post-boundary tail.  Released on eviction; residual eviction
        # releases only residual_region and pins the ws leaves.
        self.ws_region = None
        self.residual_region = None
        self.ws_pinned: Optional[Dict[str, Any]] = None
        self.counters = {
            "cold_starts": 0, "warm_hits": 0, "joined": 0,
            "ttl_evictions": 0, "lru_evictions": 0, "ws_promotions": 0,
            "residual_evictions": 0, "ws_rerestores": 0,
        }

    # ------------------------------------------------------------ queries
    def expired(self, now: Optional[float] = None) -> bool:
        now = time.time() if now is None else now
        return (
            self.state is InstanceState.WARM
            and self.warm_expiry > 0
            and now >= self.warm_expiry
        )

    @property
    def idle(self) -> bool:
        return self.inflight == 0

    def restore_abortable(self, generation: int) -> bool:
        """True while restore ``generation`` may still be aborted by a
        cancellation: the instance is RESTORING that same generation and no
        joiner shares the handle tree (``inflight`` > 1 means concurrent
        invocations trusted the stream — aborting it would fail them for
        someone else's cancel).  Once the working set lands (WARMING/WARM)
        cancellation is a no-op by contract."""
        with self.cond:
            return (
                self.state is InstanceState.RESTORING
                and self.generation == generation
                and self.inflight <= 1
            )

    @contextlib.contextmanager
    def pinned_warm_tree(self):
        """Check-and-pin a WARM instance's tree atomically: yields the tree
        with ``inflight`` bumped so a concurrent eviction cannot null it
        mid-use (tracing, relayout state capture).  Raises ``NotWarmError``
        when the instance is not WARM — the check and the pin must happen
        under one lock hold, or an eviction could slip between them."""
        with self.cond:
            if self.state is not InstanceState.WARM:
                raise NotWarmError(
                    f"{self.spec.name}: needs a WARM instance (is {self.state.value})"
                )
            tree = self.tree
            self.inflight += 1
        try:
            yield tree
        finally:
            with self.cond:
                self.inflight -= 1
                self.cond.notify_all()

    # -------------------------------------------------------- transitions
    # All transition helpers assume ``self.cond`` is held by the caller.
    def _notify_transition(self) -> None:
        if self.on_transition is not None:
            try:
                self.on_transition(self)
            except Exception:
                pass  # an observer must never break a lifecycle edge

    def _clear(self, next_state: "InstanceState") -> None:
        """Drop all resident state and move to ``next_state`` (the single
        reset point: every field added to the instance clears here)."""
        self.state = next_state
        self.tree = None
        self.getter = None
        self.ws_ready = False
        self.warm_expiry = 0.0
        self.memory_bytes = 0
        self.ws_pinned = None
        for region in (self.ws_region, self.residual_region):
            if region is not None:
                region.release()
        self.ws_region = None
        self.residual_region = None
        self._notify_transition()
        self.cond.notify_all()

    def adopt_regions(self, ws_region, residual_region) -> None:
        """Take ownership of the restore's ledger regions: from here on the
        instance lifecycle (evict / residual-evict / clear) releases them."""
        for stale in (self.ws_region, self.residual_region):
            if stale is not None:
                stale.release()
        self.ws_region = ws_region
        self.residual_region = residual_region

    def begin_restore(self, mode: str) -> int:
        assert self.state in (InstanceState.COLD, InstanceState.EVICTED), self.state
        self.state = InstanceState.RESTORING
        self.generation += 1
        self.restore_mode = mode
        self.tree = None
        self.getter = None
        self.ws_ready = False
        self.counters["cold_starts"] += 1
        self._notify_transition()
        return self.generation

    def publish_restore(self, tree, getter, stats, regions=(None, None)) -> None:
        assert self.state is InstanceState.RESTORING, self.state
        self.tree = tree
        self.getter = getter
        self.restore_stats = stats
        self.adopt_regions(*regions)
        self.cond.notify_all()

    def promote_warming(self, ttl_s: float, now: float, est_bytes: int) -> None:
        """RESTORING → WARMING at working-set completion: the traced working
        set is resident, so invocations route warm (layer-gated over the
        residual handles) while the residual keeps streaming at background
        priority.  ``est_bytes`` (the image's logical size) stands in for
        memory accounting until the resolved tree replaces the handles."""
        assert self.state is InstanceState.RESTORING, self.state
        assert ttl_s > 0, "early promotion only makes sense with keep-alive"
        self.state = InstanceState.WARMING
        self.ws_ready = True
        self.warm_expiry = now + ttl_s
        self.memory_bytes = est_bytes
        self.last_used = now
        self._notify_transition()
        self.cond.notify_all()

    def finalize_warm(self, resolved_tree, now: float) -> None:
        """WARMING → WARM once the residual stream drained: swap the handle
        tree for the resolved (device-installed) one and account its real
        footprint.  The keep-alive window set at WARMING promotion stands."""
        assert self.state is InstanceState.WARMING, self.state
        self.state = InstanceState.WARM
        self.tree = resolved_tree
        self.getter = None
        self.memory_bytes = _tree_bytes(resolved_tree)
        self._notify_transition()
        self.cond.notify_all()

    def promote_warm(self, resolved_tree, ttl_s: float, now: float) -> None:
        assert self.state is InstanceState.RESTORING, self.state
        if ttl_s > 0:
            self.state = InstanceState.WARM
            self.ws_ready = True
            self.tree = resolved_tree
            self.getter = None
            self.warm_expiry = now + ttl_s
            self.memory_bytes = _tree_bytes(resolved_tree)
        else:
            # no keep-alive: drop straight back to COLD, free the state
            self._clear(InstanceState.COLD)
        self.last_used = now
        self._notify_transition()
        self.cond.notify_all()

    def evict(self, reason: str = "manual") -> bool:
        """WARM → EVICTED (idle instances only).  Returns True if evicted.
        An EVICTED instance still holding a pinned working set drops it too
        (full eviction — the next restore reads everything again)."""
        if self.state is InstanceState.EVICTED and self.ws_pinned is not None:
            self.drop_ws_pinned()
            return False  # state unchanged; only the pin was dropped
        if self.state is not InstanceState.WARM or not self.idle:
            return False  # WARMING is never evictable: its residual stream
            # is still in flight and would write into freed buffers
        self._clear(InstanceState.EVICTED)
        if reason == "ttl":
            self.counters["ttl_evictions"] += 1
        elif reason == "lru":
            self.counters["lru_evictions"] += 1
        return True

    def evict_residual(self) -> int:
        """WARM → EVICTED keeping the working set pinned (the reclaim
        ladder's cheapest rung): only the residual region is released, the
        ws leaves stay resident so the next restore — the EVICTED →
        RESTORING re-restore path — reads only the residual bytes it
        dropped here.  Returns the bytes freed (0 if not applicable)."""
        from repro.core.treeutil import flatten_state

        if (
            self.state is not InstanceState.WARM
            or not self.idle
            or self.residual_region is None
            or self.restore_stats is None
            or not self.restore_stats.ws_names
        ):
            return 0
        ws_names = set(self.restore_stats.ws_names)
        keep: Dict[str, Any] = {}
        try:
            leaves, _ = flatten_state(self.tree)
        except Exception:
            return 0  # unflattenable tree (shouldn't happen for WARM)
        for name, arr in leaves:
            if name in ws_names:
                keep[name] = arr
        freed = self.residual_region.nbytes
        self.residual_region.release()
        self.residual_region = None
        self.state = InstanceState.EVICTED
        self.tree = None
        self.getter = None
        self.ws_ready = False
        self.warm_expiry = 0.0
        self.ws_pinned = keep
        self.memory_bytes = (
            self.ws_region.nbytes if self.ws_region is not None
            else sum(getattr(a, "nbytes", 0) for a in keep.values())
        )
        self.counters["residual_evictions"] += 1
        self._notify_transition()
        self.cond.notify_all()
        return freed

    def drop_ws_pinned(self) -> int:
        """Release an EVICTED instance's pinned working set (the warm-LRU
        ladder rung).  Returns the bytes freed."""
        if self.ws_pinned is None:
            return 0
        freed = (
            self.ws_region.nbytes if self.ws_region is not None
            else sum(getattr(a, "nbytes", 0) for a in self.ws_pinned.values())
        )
        if self.ws_region is not None:
            self.ws_region.release()
        self.ws_region = None
        self.ws_pinned = None
        self.memory_bytes = 0
        self.cond.notify_all()
        return freed

    def take_ws_pinned(self):
        """Hand the pinned working set to the owner of a fresh restore.
        Returns (pinned dict or None, ws_region or None); the caller passes
        the dict as ``preloaded`` and the region as ``preloaded_region`` —
        the restorer resizes the region in place into the new ws region
        (ownership transfers there; do NOT release it separately), so the
        resident bytes stay charged across the re-restore."""
        pinned, region = self.ws_pinned, self.ws_region
        self.ws_pinned = None
        self.ws_region = None
        if pinned:
            self.counters["ws_rerestores"] += 1
        return pinned, region

    def abort_warming(self) -> None:
        """WARMING → EVICTED when residual finalization failed."""
        if self.state is InstanceState.WARMING:
            self._clear(InstanceState.EVICTED)

    def abort_restore(self) -> None:
        """RESTORING → EVICTED on a failed restore, releasing joiners."""
        if self.state is InstanceState.RESTORING:
            self._clear(InstanceState.EVICTED)


def _tree_bytes(tree) -> int:
    total = 0
    for leaf in jax.tree.leaves(tree):
        total += getattr(leaf, "nbytes", 0)
    return int(total)
