"""Warm-state handoff: move a WARM instance between nodes without a cold
start.

Scale-in is where keep-alive policies quietly pay: draining a node evicts
its warm instances, and the next request for each of them is a full cold
restore somewhere else.  This module converts that eviction into a
*handoff*:

1. wait for the source instance to be WARM and idle (an in-flight
   invocation always completes first — handoff never interrupts work);
2. snapshot the live warm tree as a DELTA against the function's own
   published image (:meth:`FunctionCatalog.publish_handoff`, built on
   :func:`repro.core.delta_snapshot`).  Warm generation is read-only over
   the restored tree, so the delta's private payload is the dirty warm
   state only — typically KBs against a multi-MB image;
3. restore it on the successor node through the ordinary invocation path
   (``Invocation(prewarm=True, jif_override=<handoff jif>)``): admission,
   QoS ordering, restore joining, chunk-CAS dedup and peer fetch all apply
   unchanged, and the restore is accounted a ``speculative_restore``,
   never a demand cold start;
4. repoint the router's sticky replica map at the successor and evict the
   source (its ledger returns to pre-restore residency), then retire the
   handoff image's CAS refs.

The destination reads the delta's private chunks plus whatever base chunks
it does not already hold — and because the base image was published into
the cluster CAS, those are peer-fetchable rather than re-read from the
image store.  ``HandoffStats.delta_bytes`` vs ``restore_read_bytes`` is
exactly the wire saving the scale benchmark asserts on.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

from repro.configs.base import ModelConfig
from repro.serve.instance import InstanceState
from repro.serve.invocation import Invocation, QosClass
from repro.serve.node import NodeScheduler

__all__ = ["HandoffStats", "handoff_warm", "wait_idle_warm"]


@dataclasses.dataclass
class HandoffStats:
    """One handoff's outcome and cost breakdown."""

    function: str
    src: str
    dst: str
    ok: bool = False
    reason: str = ""  # failure diagnostics ("" on success)
    delta_bytes: int = 0        # handoff image wire cost (private payload)
    total_bytes: int = 0        # logical bytes of the warm state tree
    restore_read_bytes: int = 0  # bytes the destination read to go WARM
    wait_s: float = 0.0      # waiting out WARMING / in-flight work
    snapshot_s: float = 0.0  # delta snapshot + CAS ingest
    restore_s: float = 0.0   # destination restore (submit -> WARM)


def _tree_nbytes(tree) -> int:
    """Logical bytes of a (possibly nested) state tree of arrays."""
    total, stack = 0, [tree]
    while stack:
        x = stack.pop()
        if isinstance(x, dict):
            stack.extend(x.values())
        elif isinstance(x, (list, tuple)):
            stack.extend(x)
        elif hasattr(x, "nbytes"):
            total += int(x.nbytes)
    return total


def wait_idle_warm(
    node: NodeScheduler, fname: str, timeout: float = 60.0
) -> bool:
    """Block until ``fname``'s instance on ``node`` is WARM with no
    invocation in flight.  WARMING (residual stream live) and RUNNING
    (generation in progress) both resolve by waiting; EVICTED or a missing
    instance fails fast."""
    inst = node.instance(fname)
    if inst is None:
        return False
    deadline = time.monotonic() + timeout
    with inst.cond:
        while True:
            if inst.state is InstanceState.WARM and inst.idle:
                return True
            if inst.state is InstanceState.EVICTED:
                return False
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return False
            # in-flight counts change without a cond notification; poll in
            # short beats so an idle edge is seen within ~10ms
            inst.cond.wait(min(remaining, 0.01))


def handoff_warm(
    router,
    fname: str,
    src_name: str,
    dst_name: str,
    *,
    handoff_dir: str,
    cfg: Optional[ModelConfig] = None,
    timeout: float = 60.0,
    simulate_read_bw: Optional[float] = None,
    qos: QosClass = QosClass.STANDARD,
    evict_source: bool = True,
    retire: bool = True,
    charge_source: bool = True,
) -> HandoffStats:
    """Hand one WARM function from ``src_name`` to ``dst_name`` through
    ``router`` (a :class:`~repro.serve.cluster.ClusterRouter`).

    Returns :class:`HandoffStats` with ``ok=False`` + ``reason`` instead of
    raising on the recoverable failures (source never went idle, source
    evicted under memory pressure mid-wait, destination rejected the
    restore) — the caller falls back to plain eviction.  ``cfg`` defaults
    to the source instance's config (bench-reduced variants are not in the
    named-arch table, so the destination could not look it up).
    ``charge_source=False`` skips charging the snapshot writer's state copy
    as scratch against the source ledger (useful when draining a node that
    is itself under pressure)."""
    src = router.node(src_name)
    dst = router.node(dst_name)
    st = HandoffStats(function=fname, src=src_name, dst=dst_name)
    t0 = time.perf_counter()
    if not wait_idle_warm(src, fname, timeout):
        st.reason = "source instance not WARM+idle within timeout"
        return st
    st.wait_s = time.perf_counter() - t0
    inst = src.instance(fname)
    if cfg is None and inst is not None:
        cfg = inst.cfg
    # host copy of the live tree (None if a racing eviction won — with the
    # node draining, only the pressure reclaim ladder can do that)
    state = src.warm_state(fname)
    if state is None:
        st.reason = "source warm state vanished before snapshot"
        return st
    st.total_bytes = _tree_nbytes(state)

    t1 = time.perf_counter()
    path, sstats = router.catalog.publish_handoff(
        fname, state, handoff_dir,
        memory=src.memory if charge_source else None,
    )
    st.snapshot_s = time.perf_counter() - t1
    st.delta_bytes = int(sstats.private_bytes)

    t2 = time.perf_counter()
    try:
        handle = dst.submit_invocation(Invocation(
            function=fname,
            prompt=None,
            max_new_tokens=0,
            cfg=cfg,
            qos=qos,
            prewarm=True,  # restore+promote, skip generation; accounted a
            # speculative_restore — a handoff is never a demand cold start
            simulate_read_bw=simulate_read_bw,
            jif_override=path,
        ))
        result = handle.result(timeout=timeout)
    except Exception as exc:  # Overloaded/DeadlineExceeded/restore errors
        st.reason = f"destination restore failed: {exc!r}"
        if retire:
            router.catalog.retire_handoff(fname, path)
        return st
    st.restore_s = time.perf_counter() - t2
    if result.stats:
        st.restore_read_bytes = int(result.stats.get("bytes_read", 0))

    # successor is WARM: repoint sticky routing, then release the source
    router.reassign(
        fname, to_name=dst_name,
        from_name=src_name if evict_source else None,
    )
    if evict_source:
        src.evict(fname)
    if retire:
        router.catalog.retire_handoff(fname, path)
    st.ok = True
    return st
