"""Serverless serving engine: function instances cold-started from JIF
snapshots with restore/execute overlap.

The engine executes models layer by layer so the first layers run while the
prefetcher is still streaming later layers from storage (the paper's §4.2
"execution resumes immediately while the bulk of memory is fetched").  Layer
readiness is *tracked* (TensorHandle events), never advisory.  Per-layer
jitted functions act as the restored compile cache: metadata restore brings
back cache *keys*, not re-traces.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LayerSpec, ModelConfig
from repro.core import (
    BaseImage,
    BufferPool,
    FunctionRegistry,
    FunctionSpec,
    NodeImageCache,
    SpiceRestorer,
    snapshot,
)
from repro.core import baselines
from repro.core.restore import TensorHandle
from repro.core.trace import trace_access_order
from repro.core.treeutil import flatten_state
from repro.models import blocks, lm
from repro.models.layers import embed, rmsnorm, unembed


def layer_sequence(cfg: ModelConfig) -> List[LayerSpec]:
    seq: List[LayerSpec] = []
    for _ in range(cfg.pattern_reps):
        seq.extend(cfg.pattern)
    seq.extend(cfg.remainder)
    return seq


def layerwise_state(cfg: ModelConfig, params) -> Dict:
    """Stacked (scan-form) params -> per-layer list (serving layout)."""
    layers = []
    for rep in range(cfg.pattern_reps):
        for i in range(len(cfg.pattern)):
            layers.append(
                jax.tree.map(lambda a: np.asarray(a[rep]), params["pattern"][i])
            )
    for j in range(len(cfg.remainder)):
        layers.append(jax.tree.map(np.asarray, params["remainder"][j]))
    return {
        "embed": jax.tree.map(np.asarray, params["embed"]),
        "layers": layers,
        "final_norm": np.asarray(params["final_norm"]),
    }


# ----------------------------------------------------------- compile cache
_COMPILE_CACHE: Dict[Tuple, Any] = {}


def _layer_fn(cfg: ModelConfig, spec: LayerSpec, mode: str):
    key = ("layer", cfg.name, spec, mode)
    if key not in _COMPILE_CACHE:

        def fn(p, x, positions, cache, pos):
            x, c, _ = blocks.apply_layer(
                cfg, spec, p, x, positions=positions, mode=mode, cache=cache,
                pos=pos, compute_dtype=jnp.float32,
            )
            return x, c

        _COMPILE_CACHE[key] = jax.jit(fn)
    return _COMPILE_CACHE[key]


def _embed_fn(cfg: ModelConfig):
    key = ("embed", cfg.name)
    if key not in _COMPILE_CACHE:
        _COMPILE_CACHE[key] = jax.jit(
            lambda p, toks: embed(cfg, p, toks, jnp.float32)
        )
    return _COMPILE_CACHE[key]


def _head_fn(cfg: ModelConfig):
    key = ("head", cfg.name)
    if key not in _COMPILE_CACHE:

        def fn(p_embed, p_norm, x):
            x = rmsnorm(x[:, -1:], p_norm, cfg.norm_eps)
            logits = unembed(cfg, p_embed, x, jnp.float32)
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

        _COMPILE_CACHE[key] = jax.jit(fn)
    return _COMPILE_CACHE[key]


def _wait_tree(tree):
    """Resolve TensorHandle leaves (blocking, tracked completion)."""
    return jax.tree.map(
        lambda leaf: leaf.wait() if isinstance(leaf, TensorHandle) else leaf,
        tree,
        is_leaf=lambda l: isinstance(l, TensorHandle),
    )


@dataclasses.dataclass
class InvokeResult:
    tokens: np.ndarray
    cold: bool
    mode: str
    restore_wait_s: float = 0.0
    ttft_s: float = 0.0
    total_s: float = 0.0
    stats: Optional[Dict] = None


class ServerlessNode:
    """One node: registry + base-image cache + buffer pool + warm instances."""

    def __init__(
        self,
        registry: Optional[FunctionRegistry] = None,
        node_cache: Optional[NodeImageCache] = None,
        pool: Optional[BufferPool] = None,
    ):
        self.registry = registry or FunctionRegistry()
        self.node_cache = node_cache or NodeImageCache()
        self.pool = pool or BufferPool()
        self._warm: Dict[str, Tuple[ModelConfig, Dict, float]] = {}

    # -------------------------------------------------------------- publish
    def publish(
        self,
        name: str,
        cfg: ModelConfig,
        params,
        dirpath: str,
        base_name: Optional[str] = None,
        warm_ttl_s: float = 0.0,
        formats: Tuple[str, ...] = ("jif", "criu", "monolith"),
        extra_state: Optional[Any] = None,
    ) -> FunctionSpec:
        """Offline JIF preparation: layerwise layout, pre-warm + trace,
        access-order relocation, dedup vs base; also writes the baselines'
        formats for comparison."""
        import os

        os.makedirs(dirpath, exist_ok=True)
        state = layerwise_state(cfg, params)

        # pre-warm trace: run one tiny invocation under the recorder; the
        # recorder's lazy leaves record first touch when jit coerces them
        def run(view):
            self._generate(cfg, None, view, np.zeros((1, 4), np.int32), 2)

        order = trace_access_order(state, run, max_iters=2)
        jif_path = f"{dirpath}/{name}.jif"
        base = self.node_cache.get(base_name)
        if "jif" in formats:
            snapshot(
                state,
                jif_path,
                base=base,
                access_order=order,
                meta={"arch": cfg.name, "function": name},
            )
        if "criu" in formats:
            baselines.criu_star_snapshot(state, f"{dirpath}/{name}.criu")
        if "monolith" in formats:
            baselines.monolith_snapshot(
                state, f"{dirpath}/{name}.mono", extra_state=extra_state
            )
        spec = FunctionSpec(
            name=name, arch=cfg.name, jif_path=jif_path, base_image=base_name,
            warm_ttl_s=warm_ttl_s,
        )
        self.registry.register(spec)
        return spec

    # --------------------------------------------------------------- invoke
    def invoke(
        self,
        fname: str,
        prompt: np.ndarray,
        max_new_tokens: int = 8,
        mode: str = "spice",
        cfg: Optional[ModelConfig] = None,
        simulate_read_bw: Optional[float] = None,
    ) -> InvokeResult:
        from repro.configs import get_config

        spec = self.registry.get(fname)
        cfg = cfg or get_config(spec.arch)
        t0 = time.perf_counter()

        warm = self._warm.get(fname)
        if warm is not None:
            _, state, _ = warm
            toks, ttft = self._generate(cfg, None, state, prompt, max_new_tokens)
            dt = time.perf_counter() - t0
            return InvokeResult(toks, cold=False, mode="warm", ttft_s=ttft, total_s=dt)

        state, stats, getter = self._cold_restore(spec, mode, simulate_read_bw)
        restore_wait = time.perf_counter() - t0  # sync part of the restore
        toks, ttft = self._generate(cfg, getter, state, prompt, max_new_tokens)
        total = time.perf_counter() - t0
        if spec.warm_ttl_s > 0:
            self._warm[fname] = (cfg, _wait_tree(state), time.time() + spec.warm_ttl_s)
        return InvokeResult(
            toks, cold=True, mode=mode,
            restore_wait_s=restore_wait,
            ttft_s=restore_wait + ttft,  # time-to-first-token from request
            total_s=total,
            stats=stats.as_dict() if stats else None,
        )

    def evict(self, fname: Optional[str] = None):
        if fname is None:
            self._warm.clear()
        else:
            self._warm.pop(fname, None)

    # ----------------------------------------------------------- internals
    def _cold_restore(self, spec: FunctionSpec, mode: str, sim_bw=None):
        # eager install: numpy -> device array on the prefetcher thread (the
        # PTE-install analogue), so execution never pays conversion copies.
        # MUST copy: on CPU jnp.asarray can alias the staging buffer, which
        # the restorer recycles into the zero pool (on TPU device_put always
        # copies into HBM).
        install = lambda a: jnp.array(a, copy=True)
        if mode == "spice":
            restorer = SpiceRestorer(
                pool=self.pool, node_cache=self.node_cache,
                transform=install, simulate_read_bw=sim_bw,
            )
            state, meta, handles, stats = restorer.restore(spec.jif_path, wait=False)
            return state, stats, _wait_tree
        if mode == "spice_sync":
            restorer = SpiceRestorer(
                pool=self.pool, node_cache=self.node_cache, pipelined=False,
                transform=install, simulate_read_bw=sim_bw,
            )
            state, meta, handles, stats = restorer.restore(spec.jif_path, wait=True)
            return state, stats, None
        if mode == "criu_star":
            state, stats = baselines.criu_star_restore(
                spec.jif_path.replace(".jif", ".criu"), simulate_read_bw=sim_bw
            )
            return jax.tree.map(install, state), stats, None
        if mode == "reap_star":
            state, stats = baselines.reap_star_restore(
                spec.jif_path.replace(".jif", ".mono"), simulate_read_bw=sim_bw
            )
            return jax.tree.map(install, state), stats, None
        if mode == "faasnap_star":
            r = baselines.FaasnapAsyncRestorer(
                spec.jif_path.replace(".jif", ".mono"), simulate_read_bw=sim_bw
            )

            class _FaasnapView:
                """state view whose tensors fault in on demand."""

            # rebuild a handle-like tree backed by ensure()
            leaves = {
                t["name"]: _FaasnapLeaf(r, t["name"])
                for t in r.r.header["tensors"]
                if not t["name"].startswith("__extra__/")
            }
            from repro.core.treeutil import unflatten_state

            state = unflatten_state(r.r.header["tree"], leaves)
            return state, r.stats, _faasnap_wait
        raise ValueError(f"unknown restore mode {mode!r}")

    def _generate(self, cfg, getter, state, prompt: np.ndarray, max_new: int):
        """Layer-gated generation: each layer waits for exactly its params."""
        # default resolver materializes any lazy leaves (access-trace
        # proxies); a no-op for already-installed device arrays
        resolve = getter or (
            lambda t: jax.tree.map(lambda l: jnp.asarray(np.asarray(l)) if not isinstance(l, jax.Array) else l, t)
        )
        specs = layer_sequence(cfg)
        B, S = prompt.shape
        positions = np.broadcast_to(np.arange(S, dtype=np.int32), (B, S))

        t0 = time.perf_counter()
        p_embed = resolve(state["embed"])
        x = _embed_fn(cfg)(p_embed, prompt)
        caches = []
        for i, spec in enumerate(specs):
            p_i = resolve(state["layers"][i])
            x, c = _layer_fn(cfg, spec, "prefill")(p_i, x, positions, None, None)
            caches.append(c)
        p_norm = resolve(state["final_norm"])
        tok = _head_fn(cfg)(p_embed, p_norm, x)
        ttft = time.perf_counter() - t0
        out = [np.asarray(tok)]

        pos = S
        for _ in range(max_new - 1):
            x = _embed_fn(cfg)(p_embed, np.asarray(tok)[:, None])
            dpos = np.broadcast_to(np.int32(pos), (B, 1))
            for i, spec in enumerate(specs):
                x, caches[i] = _layer_fn(cfg, spec, "decode")(
                    state_layer(state, i, resolve), x, dpos, caches[i], jnp.int32(pos)
                )
            tok = _head_fn(cfg)(p_embed, p_norm, x)
            out.append(np.asarray(tok))
            pos += 1
        return np.stack(out, axis=1), ttft


def state_layer(state, i, resolve):
    return resolve(state["layers"][i])


class _FaasnapLeaf:
    def __init__(self, r, name):
        self._r = r
        self.name = name

    def fault(self):
        return self._r.ensure(self.name)


def _faasnap_wait(tree):
    return jax.tree.map(
        lambda l: jnp.asarray(l.fault()) if isinstance(l, _FaasnapLeaf) else l,
        tree,
        is_leaf=lambda l: isinstance(l, _FaasnapLeaf),
    )
