"""Serverless serving engine — compatibility facade.

The monolithic ``ServerlessNode`` was split into a layered runtime:

* :mod:`repro.core.iosched`   — node-wide prefetch I/O scheduler (per-stream
  queues, demand boost, bandwidth arbitration),
* :mod:`repro.serve.instance` — per-function lifecycle state machines
  (COLD → RESTORING → WARM → EVICTED) + layer-gated generation,
* :mod:`repro.serve.node`     — the per-node DATA PLANE: concurrent
  admission, keep-alive TTL, LRU eviction under a shared memory budget,
* :mod:`repro.serve.cluster`  — the CONTROL PLANE (`FunctionCatalog`:
  publish/relayout/registry ownership) and the N-node `ClusterRouter`
  with pluggable snapshot-locality-aware placement.

``ServerlessNode`` here is a thin facade composing a catalog with a
one-node router, so the existing examples, benchmarks, and tests keep
their `publish`/`invoke`/`evict` surface; new code should target the
layers directly.
"""
from __future__ import annotations

from typing import Optional

from repro.core import BufferPool, FunctionRegistry, NodeImageCache, PrefetchIOScheduler
from repro.serve.cluster import (  # re-exported: the cluster layer
    ClusterRouter,
    FunctionCatalog,
    LeastLoaded,
    LocalityFirst,
    PlacementPolicy,
    RoundRobin,
)
from repro.serve.instance import (  # re-exported: public serving helpers
    FunctionInstance,
    InstanceState,
    generate,
    layer_sequence,
    layerwise_state,
    wait_tree,
)
from repro.serve.instance import wait_tree as _wait_tree  # legacy alias
from repro.serve.invocation import (  # re-exported: the typed request surface
    AdmissionController,
    DeadlineExceeded,
    Invocation,
    InvocationCancelled,
    InvocationError,
    InvocationHandle,
    Overloaded,
    QosClass,
    deadline_in,
)
from repro.serve.node import (
    FixedTTLPolicy,
    InvokeResult,
    KeepAlivePolicy,
    NodeLoad,
    NodeScheduler,
    NoKeepAlive,
)
from repro.serve.prewarm import (  # re-exported: the warmth policy engine
    ArrivalTracker,
    PrewarmEngine,
    PrewarmPolicy,
)
from repro.serve.deploy import (  # re-exported: the deployment pipeline
    ColocatedTrainer,
    QualityGate,
    RolloutController,
    TokenHealthGate,
    VersionedFunction,
    VersionRecord,
)

__all__ = [
    "ServerlessNode",
    "NodeScheduler",
    "NodeLoad",
    "InvokeResult",
    "Invocation",
    "InvocationHandle",
    "QosClass",
    "AdmissionController",
    "InvocationError",
    "Overloaded",
    "DeadlineExceeded",
    "InvocationCancelled",
    "deadline_in",
    "KeepAlivePolicy",
    "FixedTTLPolicy",
    "NoKeepAlive",
    "ArrivalTracker",
    "PrewarmPolicy",
    "PrewarmEngine",
    "FunctionCatalog",
    "ClusterRouter",
    "PlacementPolicy",
    "LocalityFirst",
    "RoundRobin",
    "LeastLoaded",
    "FunctionInstance",
    "InstanceState",
    "layer_sequence",
    "layerwise_state",
    "generate",
    "wait_tree",
    "RolloutController",
    "VersionedFunction",
    "VersionRecord",
    "QualityGate",
    "TokenHealthGate",
    "ColocatedTrainer",
]


class ServerlessNode:
    """One node: catalog (control plane) + a single-node router over one
    `NodeScheduler` (data plane).

    Thin facade; construction signature and the ``publish`` / ``invoke`` /
    ``evict`` surface match the seed engine.  The catalog's authoring
    base-image cache IS the node's serving cache here (one machine), so
    ``node_cache.put(...)`` keeps feeding both publish-time dedup and
    restore-time base resolution."""

    def __init__(
        self,
        registry: Optional[FunctionRegistry] = None,
        node_cache: Optional[NodeImageCache] = None,
        pool: Optional[BufferPool] = None,
        scheduler: Optional[NodeScheduler] = None,
        catalog: Optional[FunctionCatalog] = None,
        prewarm: Optional[PrewarmEngine] = None,
        **scheduler_kwargs,
    ):
        if scheduler is None and catalog is not None and node_cache is None:
            # injected catalog, default scheduler: share the catalog's
            # authoring cache as the serving cache too, so base_name-
            # published functions restore (their base lives there)
            node_cache = catalog.base_images
        self._sched = scheduler or NodeScheduler(
            registry=registry, node_cache=node_cache, pool=pool,
            **scheduler_kwargs,
        )
        self._catalog = catalog or FunctionCatalog(
            registry=self._sched.registry, base_images=self._sched.node_cache
        )
        self._router = ClusterRouter(
            self._catalog, [self._sched], prewarm=prewarm
        )

    # shared-component accessors (benchmarks swap the pool between runs)
    @property
    def scheduler(self) -> NodeScheduler:
        return self._sched

    @property
    def catalog(self) -> FunctionCatalog:
        return self._catalog

    @property
    def router(self) -> ClusterRouter:
        return self._router

    @property
    def registry(self) -> FunctionRegistry:
        return self._catalog.registry

    @property
    def node_cache(self) -> NodeImageCache:
        return self._sched.node_cache

    @property
    def iosched(self) -> PrefetchIOScheduler:
        return self._sched.iosched

    @property
    def memory(self):
        """The node's memory ledger (:class:`NodeMemoryManager`)."""
        return self._sched.memory

    @property
    def pool(self) -> BufferPool:
        return self._sched.pool

    @pool.setter
    def pool(self, new_pool: BufferPool) -> None:
        self._sched.pool = new_pool
        # a zero-capacity pool means "no pooling", not "no memory": leave
        # the ledger unlimited rather than refusing every restore
        self._sched.memory_budget = new_pool.capacity or None

    def publish(self, *args, **kwargs):
        # the writer's state copy is node memory too: charge it as scratch
        kwargs.setdefault("memory", self._sched.memory)
        return self._catalog.publish(*args, **kwargs)

    def invoke(self, *args, **kwargs) -> InvokeResult:
        return self._router.invoke(*args, **kwargs)

    def submit(self, *args, **kwargs):
        return self._router.submit(*args, **kwargs)

    def submit_invocation(self, inv: Invocation) -> InvocationHandle:
        """The typed v2 surface (QoS class, deadline, cancellation)."""
        return self._router.submit_invocation(inv)

    def close(self) -> None:
        self._router.close()

    def evict(self, fname: Optional[str] = None) -> None:
        self._sched.evict(fname)

    def record_access(self, fname, *args, **kwargs):
        return self._catalog.record_access(fname, self._sched, *args, **kwargs)

    def relayout(self, fname, order=None):
        return self._catalog.relayout(fname, order=order, node=self._sched)
