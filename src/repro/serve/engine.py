"""Serverless serving engine — compatibility facade.

The monolithic ``ServerlessNode`` was split into a layered runtime:

* :mod:`repro.core.iosched`   — node-wide prefetch I/O scheduler (per-stream
  queues, demand boost, bandwidth arbitration),
* :mod:`repro.serve.instance` — per-function lifecycle state machines
  (COLD → RESTORING → WARM → EVICTED) + layer-gated generation,
* :mod:`repro.serve.node`     — concurrent admission, keep-alive TTL, LRU
  eviction under a shared memory budget.

``ServerlessNode`` here is a thin facade over :class:`NodeScheduler` so the
existing examples, benchmarks, and tests keep their `publish`/`invoke`/
`evict` surface; new code should target the layers directly.
"""
from __future__ import annotations

from typing import Optional

from repro.core import BufferPool, FunctionRegistry, NodeImageCache, PrefetchIOScheduler
from repro.serve.instance import (  # re-exported: public serving helpers
    FunctionInstance,
    InstanceState,
    generate,
    layer_sequence,
    layerwise_state,
    wait_tree,
)
from repro.serve.instance import wait_tree as _wait_tree  # legacy alias
from repro.serve.node import (
    FixedTTLPolicy,
    InvokeResult,
    KeepAlivePolicy,
    NodeScheduler,
    NoKeepAlive,
)

__all__ = [
    "ServerlessNode",
    "NodeScheduler",
    "InvokeResult",
    "KeepAlivePolicy",
    "FixedTTLPolicy",
    "NoKeepAlive",
    "FunctionInstance",
    "InstanceState",
    "layer_sequence",
    "layerwise_state",
    "generate",
    "wait_tree",
]


class ServerlessNode:
    """One node: registry + base-image cache + buffer pool + warm instances.

    Thin facade over :class:`NodeScheduler`; construction signature and the
    ``publish`` / ``invoke`` / ``evict`` surface match the seed engine."""

    def __init__(
        self,
        registry: Optional[FunctionRegistry] = None,
        node_cache: Optional[NodeImageCache] = None,
        pool: Optional[BufferPool] = None,
        scheduler: Optional[NodeScheduler] = None,
        **scheduler_kwargs,
    ):
        self._sched = scheduler or NodeScheduler(
            registry=registry, node_cache=node_cache, pool=pool,
            **scheduler_kwargs,
        )

    # shared-component accessors (benchmarks swap the pool between runs)
    @property
    def scheduler(self) -> NodeScheduler:
        return self._sched

    @property
    def registry(self) -> FunctionRegistry:
        return self._sched.registry

    @property
    def node_cache(self) -> NodeImageCache:
        return self._sched.node_cache

    @property
    def iosched(self) -> PrefetchIOScheduler:
        return self._sched.iosched

    @property
    def memory(self):
        """The node's memory ledger (:class:`NodeMemoryManager`)."""
        return self._sched.memory

    @property
    def pool(self) -> BufferPool:
        return self._sched.pool

    @pool.setter
    def pool(self, new_pool: BufferPool) -> None:
        self._sched.pool = new_pool
        # a zero-capacity pool means "no pooling", not "no memory": leave
        # the ledger unlimited rather than refusing every restore
        self._sched.memory_budget = new_pool.capacity or None

    def publish(self, *args, **kwargs):
        return self._sched.publish(*args, **kwargs)

    def invoke(self, *args, **kwargs) -> InvokeResult:
        return self._sched.invoke(*args, **kwargs)

    def submit(self, *args, **kwargs):
        return self._sched.submit(*args, **kwargs)

    def evict(self, fname: Optional[str] = None) -> None:
        self._sched.evict(fname)

    def record_access(self, *args, **kwargs):
        return self._sched.record_access(*args, **kwargs)

    def relayout(self, *args, **kwargs):
        return self._sched.relayout(*args, **kwargs)
