"""SLO-driven elastic autoscaling with warm-state handoff on scale-in.

The static ``scale_out_queue_depth`` knob sizes a fleet for its worst
minute: a queue-depth threshold neither knows what latency the user
actually experiences nor ever gives a node back.  This module closes the
loop against *declared service objectives* instead:

* :class:`ServiceSLO` — per-QoS-class targets (TTFT p99, queue-wait p95),
  the contract the operator writes down.
* :class:`SLOMonitor` — sliding-window per-class percentile tracker, fed
  by every node's ``on_result`` hook (speculative pre-warms and handoff
  restores are excluded: they are not requests).
* :class:`AutoScaler` — the control loop.  On *sustained* violation it
  joins a node to the fleet (hysteresis: one slow request never buys a
  machine); on sustained slack it DRAINS the least-loaded node:

  1. stop placement (``router.set_draining``) — queued work completes;
  2. quiesce, then hand off the node's warm instances to successors,
     most-valuable-first (:class:`~repro.serve.prewarm.PrewarmPolicy`'s
     cost-aware score, reversed), via
     :func:`repro.serve.handoff.handoff_warm` — scale-in converts ZERO
     warm instances into future cold starts;
  3. release the node's residual stream and ledger (audit-clean) and
     remove it from the fleet.

``tick()`` is a plain method: benchmarks call it from the replay loop for
determinism, deployments run :meth:`AutoScaler.start` for a daemon-thread
loop (weakref'd like the node reaper — a dropped fleet is GC-able).
"""
from __future__ import annotations

import collections
import dataclasses
import math
import threading
import time
import weakref
from typing import Callable, Deque, Dict, List, Optional, Tuple

from repro.serve.handoff import HandoffStats, handoff_warm
from repro.serve.invocation import QosClass
from repro.serve.node import InvokeResult, NodeScheduler

__all__ = ["ServiceSLO", "SLOMonitor", "AutoScaler"]


# ------------------------------------------------------------- the contract
@dataclasses.dataclass(frozen=True)
class ServiceSLO:
    """Targets for one QoS class; ``None`` leaves that metric unbounded."""

    qos: QosClass
    ttft_p99_s: Optional[float] = None
    queue_wait_p95_s: Optional[float] = None


def _percentile(values: List[float], q: float) -> float:
    """Nearest-rank percentile (q in (0, 1]) over a non-empty list."""
    ordered = sorted(values)
    rank = max(1, math.ceil(q * len(ordered)))
    return ordered[rank - 1]


# -------------------------------------------------------------- the monitor
class SLOMonitor:
    """Sliding-window per-class latency percentiles.

    ``observe`` is wired as every node's ``on_result`` hook — it runs on
    the node's drain thread, so it is O(1) append under one short lock.
    Pre-warm results (speculative restores, warm-state handoffs) are
    excluded: they are infrastructure, not requests."""

    def __init__(self, window_s: float = 10.0, min_samples: int = 8):
        self.window_s = window_s
        self.min_samples = min_samples
        self._lock = threading.Lock()
        # (monotonic ts, qos value, ttft_s, queue_wait_s)
        self._samples: Deque[Tuple[float, str, float, float]] = (
            collections.deque()
        )

    def observe(self, result: InvokeResult) -> None:
        if result.mode == "prewarm":
            return
        with self._lock:
            self._samples.append((
                time.monotonic(), result.qos,
                float(result.ttft_s), float(result.queue_wait_s),
            ))

    def _window(self, now: float) -> List[Tuple[float, str, float, float]]:
        cutoff = now - self.window_s
        with self._lock:
            while self._samples and self._samples[0][0] < cutoff:
                self._samples.popleft()
            return list(self._samples)

    def percentile(
        self, qos: QosClass, metric: str, q: float,
        now: Optional[float] = None,
    ) -> Optional[float]:
        """Windowed percentile of ``metric`` ("ttft" | "queue_wait") for
        one class; None when the window holds no samples of that class."""
        now = time.monotonic() if now is None else now
        idx = 2 if metric == "ttft" else 3
        values = [s[idx] for s in self._window(now) if s[1] == qos.value]
        if not values:
            return None
        return _percentile(values, q)

    # ------------------------------------------------------------ assessment
    def assess(
        self, slos: List[ServiceSLO], now: Optional[float] = None,
        slack_margin: float = 0.5,
    ) -> Tuple[List[str], bool]:
        """Evaluate the window against the declared SLOs.

        Returns ``(violations, slack)``: human-readable violation strings
        (empty = within SLO), and whether EVERY bounded metric sits under
        ``slack_margin`` × its target (an idle window — no samples — also
        counts as slack: nothing is arriving that a smaller fleet would
        hurt).  A class needs ``min_samples`` in-window samples before it
        can violate — one slow request is noise, not a trend."""
        now = time.monotonic() if now is None else now
        window = self._window(now)
        violations: List[str] = []
        slack = True
        for slo in slos:
            rows = [s for s in window if s[1] == slo.qos.value]
            checks: List[Tuple[str, int, float, float]] = []
            if slo.ttft_p99_s is not None:
                checks.append(("ttft", 2, 0.99, slo.ttft_p99_s))
            if slo.queue_wait_p95_s is not None:
                checks.append(("queue_wait", 3, 0.95, slo.queue_wait_p95_s))
            for name, idx, q, target in checks:
                if not rows:
                    continue  # idle class: no evidence either way -> slack
                value = _percentile([r[idx] for r in rows], q)
                if len(rows) >= self.min_samples and value > target:
                    violations.append(
                        f"{slo.qos.value}:{name} p{int(q * 100)}"
                        f"={value:.3f}s > {target:.3f}s"
                    )
                    slack = False
                elif value > slack_margin * target:
                    slack = False
        return violations, slack


# ----------------------------------------------------------- the control loop
class AutoScaler:
    """Elastic fleet controller: grow on sustained SLO violation, drain
    (with warm-state handoff) on sustained slack.

    ``node_factory(name) -> NodeScheduler`` provisions a node when the
    loop scales out (the benchmark builds one with the fleet's chunk
    cache/ledger shape; a deployment would boot a machine).  ``keepalive``
    (a :class:`~repro.serve.prewarm.PrewarmPolicy`) ranks a draining
    node's warm instances by re-restore cost / predicted demand; handoffs
    run most-valuable-first so, if the drain budget runs out, what is
    dropped is what was cheapest to lose.  ``handoff=False`` is the
    drain-and-evict ablation: scale-in simply evicts warm state, and the
    next request for each function pays a full cold restore.

    Node-seconds (the cost metric benchmarks compare) accrue per node from
    join (or :meth:`attach`) to removal."""

    def __init__(
        self,
        router,
        slos: List[ServiceSLO],
        *,
        handoff_dir: str,
        node_factory: Optional[Callable[[str], NodeScheduler]] = None,
        monitor: Optional[SLOMonitor] = None,
        keepalive=None,
        min_nodes: int = 1,
        max_nodes: Optional[int] = None,
        scale_out_after: int = 2,
        scale_in_after: int = 5,
        slack_margin: float = 0.5,
        handoff: bool = True,
        drain_timeout_s: float = 30.0,
        simulate_read_bw: Optional[float] = None,
    ):
        self.router = router
        self.slos = list(slos)
        self.handoff_dir = handoff_dir
        self.node_factory = node_factory
        self.monitor = monitor or SLOMonitor()
        self.keepalive = keepalive
        self.min_nodes = min_nodes
        self.max_nodes = max_nodes
        self.scale_out_after = scale_out_after
        self.scale_in_after = scale_in_after
        self.slack_margin = slack_margin
        self.handoff = handoff
        self.drain_timeout_s = drain_timeout_s
        self.simulate_read_bw = simulate_read_bw
        self._lock = threading.Lock()
        self._violating_ticks = 0
        self._slack_ticks = 0
        self._next_node_id = 0
        self._active_since: Dict[str, float] = {}
        self._node_seconds = 0.0
        self._thread: Optional[threading.Thread] = None
        self._stop: Optional[threading.Event] = None
        self.handoffs: List[HandoffStats] = []
        self.events: List[Dict] = []  # (t, action, node, detail) audit trail
        self.stats = {
            "ticks": 0,
            "scale_outs": 0,
            "scale_ins": 0,
            "handoffs_ok": 0,
            "handoffs_failed": 0,
            "drain_evictions": 0,
            "handoff_delta_bytes": 0,
            "handoff_restore_read_bytes": 0,
        }
        self.attach()

    # ------------------------------------------------------------- plumbing
    def attach(self) -> None:
        """Wire the monitor into every current node and start their
        node-seconds clocks (idempotent)."""
        now = time.monotonic()
        for node in list(self.router.nodes):
            node.on_result = self.monitor.observe
            self._active_since.setdefault(node.name, now)

    def node_seconds(self, now: Optional[float] = None) -> float:
        """Accumulated fleet cost: sum over nodes of active wall-clock."""
        now = time.monotonic() if now is None else now
        with self._lock:
            live = sum(now - t0 for t0 in self._active_since.values())
            return self._node_seconds + live

    def _event(self, action: str, node: str, detail: str = "") -> None:
        self.events.append({
            "t": time.monotonic(), "action": action,
            "node": node, "detail": detail,
        })

    # ----------------------------------------------------------- the loop
    def tick(self, now: Optional[float] = None) -> Optional[str]:
        """One control decision; returns "scale_out"/"scale_in"/None.
        Callable inline (deterministic benchmarks) or from the daemon
        thread (:meth:`start`)."""
        self.stats["ticks"] += 1
        now = time.monotonic() if now is None else now
        violations, slack = self.monitor.assess(
            self.slos, now=now, slack_margin=self.slack_margin
        )
        if violations:
            self._violating_ticks += 1
            self._slack_ticks = 0
            if (
                self._violating_ticks >= self.scale_out_after
                and (self.max_nodes is None
                     or len(self.router.nodes) < self.max_nodes)
                and self.node_factory is not None
            ):
                self._violating_ticks = 0
                return self._scale_out("; ".join(violations))
            return None
        self._violating_ticks = 0
        if slack:
            self._slack_ticks += 1
            if (
                self._slack_ticks >= self.scale_in_after
                and len(self.router.nodes) > self.min_nodes
            ):
                self._slack_ticks = 0
                return self._scale_in()
        else:
            self._slack_ticks = 0
        return None

    def _scale_out(self, reason: str) -> str:
        with self._lock:
            self._next_node_id += 1
            name = f"scale{self._next_node_id}"
        node = self.node_factory(name)
        if not node.name:
            node.name = name
        self.router.add_node(node)
        node.on_result = self.monitor.observe
        with self._lock:
            self._active_since[node.name] = time.monotonic()
        self.stats["scale_outs"] += 1
        self._event("scale_out", node.name, reason)
        return "scale_out"

    def _pick_drain_victim(self) -> Optional[NodeScheduler]:
        """Least-loaded non-draining node (fewest in-flight, then fewest
        warm instances — prefer giving back the node with least state to
        move)."""
        draining = set(self.router.draining())
        cands = [n for n in self.router.nodes if n.name not in draining]
        if len(cands) <= self.min_nodes:
            return None
        loads = {n.name: n.load() for n in cands}
        return min(
            cands,
            key=lambda n: (
                loads[n.name].queue_depth,
                len(loads[n.name].warm),
                loads[n.name].pressure,
            ),
        )

    def _scale_in(self) -> Optional[str]:
        victim = self._pick_drain_victim()
        if victim is None:
            return None
        self.drain_node(victim.name)
        self.stats["scale_ins"] += 1
        return "scale_in"

    # -------------------------------------------------------------- draining
    def drain_node(self, name: str) -> NodeScheduler:
        """Drain ``name`` out of the fleet: stop placement, let queued and
        in-flight work complete, hand off (or evict) its warm instances,
        release its residual stream and ledger, remove it.  Returns the
        closed node (its final ``memory.audit()`` ran clean or raised)."""
        node = self.router.node(name)
        self.router.set_draining(name)
        self._event("drain_start", name)
        node.quiesce(self.drain_timeout_s)
        warm = node.warm_instances()
        # most-valuable-first: PrewarmPolicy.victims ranks cheapest-to-lose
        # first, so the handoff order is its reverse — if the drain budget
        # runs out, what is dropped is what was cheapest to re-restore
        if self.keepalive is not None and len(warm) > 1:
            ranked = list(self.keepalive.victims(warm, need_evict=len(warm)))
            ranked.reverse()
            # WARMING instances are absent from a cost ranking (no final
            # restore stats yet); hand them off after the ranked ones
            warm = ranked + [i for i in warm if i not in ranked]
        for inst in warm:
            fname = inst.spec.name
            if self.handoff:
                dst = self._handoff_target(exclude=name)
                if dst is not None:
                    hs = handoff_warm(
                        self.router, fname, name, dst.name,
                        handoff_dir=self.handoff_dir,
                        timeout=self.drain_timeout_s,
                        simulate_read_bw=self.simulate_read_bw,
                    )
                    self.handoffs.append(hs)
                    if hs.ok:
                        self.stats["handoffs_ok"] += 1
                        self.stats["handoff_delta_bytes"] += hs.delta_bytes
                        self.stats["handoff_restore_read_bytes"] += (
                            hs.restore_read_bytes
                        )
                        self._event(
                            "handoff", name,
                            f"{fname} -> {dst.name} "
                            f"({hs.delta_bytes}B delta)",
                        )
                        continue
                    self.stats["handoffs_failed"] += 1
                    self._event("handoff_failed", name,
                                f"{fname}: {hs.reason}")
            # ablation path / handoff fallback: plain eviction — the next
            # request for fname pays a full cold restore somewhere else
            node.evict(fname)
            self.stats["drain_evictions"] += 1
            self._event("drain_evict", name, fname)
        # return the ledger to pre-restore residency: finish any residual
        # streams, drop every remaining instance, then audit
        node.drain_residual(self.drain_timeout_s)
        node.evict()
        self.router.remove_node(name)
        node.close()
        node.memory.audit()  # raises if the drain leaked a reservation
        with self._lock:
            started = self._active_since.pop(name, None)
            if started is not None:
                self._node_seconds += time.monotonic() - started
        self._event("drain_done", name)
        return node

    def _handoff_target(self, exclude: str) -> Optional[NodeScheduler]:
        """Successor for a drained instance: the least-loaded active node
        (locality does not help — the instance exists nowhere else — so
        load headroom decides)."""
        draining = set(self.router.draining())
        cands = [
            n for n in self.router.nodes
            if n.name != exclude and n.name not in draining
        ]
        if not cands:
            return None
        loads = {n.name: n.load() for n in cands}
        return min(
            cands,
            key=lambda n: (
                loads[n.name].queue_depth,
                loads[n.name].pressure,
                len(loads[n.name].warm),
            ),
        )

    # ------------------------------------------------------- daemon thread
    def start(self, interval_s: float = 0.25) -> None:
        if self._thread is not None:
            return
        self._stop = threading.Event()
        ref = weakref.ref(self)

        def loop(stop: threading.Event) -> None:
            while not stop.wait(interval_s):
                scaler = ref()
                if scaler is None:
                    return
                try:
                    scaler.tick()
                except Exception:
                    pass  # a failed decision must not kill the loop
                del scaler

        self._thread = threading.Thread(
            target=loop, args=(self._stop,),
            name="autoscaler", daemon=True,
        )
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=5.0)
        self._thread = None
        self._stop = None
