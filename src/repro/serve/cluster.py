"""Cluster serving layer: control plane / data plane split + N-node routing.

The paper's thesis — disk-resident snapshots make memory elasticity free —
only pays off when a fleet can place any function on any node and still get
near-warm restores.  This module supplies the two layers that make that
expressible:

* :class:`FunctionCatalog` — the CONTROL PLANE.  Owns the
  :class:`~repro.core.registry.FunctionRegistry` and the offline snapshot
  authoring path (``publish`` with pre-warm tracing, delta publishing
  against a parent JIF, ``record_access`` → ``relayout`` bookkeeping,
  registry persistence).  One catalog serves any number of nodes; it never
  touches live tenant state except through a node's explicit data-plane
  mechanisms (:meth:`~repro.serve.node.NodeScheduler.trace_warm`,
  :meth:`~repro.serve.node.NodeScheduler.warm_state`).

* :class:`ClusterRouter` — the DATA-PLANE FRONT DOOR.  Places invocations
  across N :class:`~repro.serve.node.NodeScheduler`\\ s through a pluggable
  :class:`PlacementPolicy`, reading each node's
  :class:`~repro.serve.node.NodeLoad` probe (queue depth, memory pressure,
  prefetcher backlog, warm/restoring sets, resident images).

Routing contract:

* **Sticky routing / single population per cluster** — a sticky policy
  (``LocalityFirst``, the default) pins each function to the node that
  first restored it; concurrent invocations of one function land on that
  node and *join* the in-flight restore there, so a single-replica
  function never pays two concurrent cold restores anywhere in the
  cluster.
* **Snapshot locality** — ``LocalityFirst`` ranks candidate nodes
  warm > joinable in-flight > base-image-cached > delta-parent-cached >
  least-loaded: a node that holds the function's base image (or the parent
  of its delta chain) restores it reading only private chunks, which is
  the whole point of disk-resident snapshots.
* **Scale-out knob** — with ``scale_out_queue_depth=K``, a function whose
  least-loaded replica has K or more invocations in flight gets a second
  replica placed by the same policy (opt-in; capped at the node count).

``RoundRobin`` and ``LeastLoaded`` are non-sticky baselines: they place
every request independently, which is exactly the placement regime the
cluster benchmark (``benchmarks/cluster.py``) compares against.
"""
from __future__ import annotations

import dataclasses
import os
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import (
    ChunkStore,
    FunctionRegistry,
    FunctionSpec,
    NodeImageCache,
    SpiceRestorer,
    snapshot,
)
from repro.core import baselines
from repro.core.memory import NodeMemoryManager
from repro.core.snapshot import SnapshotStats
from repro.core.trace import trace_access_order
from repro.serve.instance import generate, layerwise_state
from repro.serve.invocation import (
    Invocation,
    InvocationHandle,
    Overloaded,
    QosClass,
)
from repro.serve.node import InvokeResult, NodeLoad, NodeScheduler

__all__ = [
    "FunctionCatalog",
    "ClusterRouter",
    "PlacementPolicy",
    "LocalityFirst",
    "RoundRobin",
    "LeastLoaded",
]


# ------------------------------------------------------------ control plane
class FunctionCatalog:
    """The serving stack's control plane: registry ownership + snapshot
    authoring.  Shared by every node of a cluster (nodes hold a reference
    to ``catalog.registry`` and resolve invocations through it).

    ``base_images`` is the *authoring-side* image cache: ``publish``
    classifies against bases installed here.  A single-node deployment
    shares it with the node's serving cache (the facade wires that up); a
    multi-node cluster instead publishes deltas against a parent JIF on
    disk, which every node can bootstrap on demand
    (``BaseImage.from_jif``) — disk, not any one node's RAM, is the
    cluster-wide source of truth.
    """

    def __init__(
        self,
        registry: Optional[FunctionRegistry] = None,
        base_images: Optional[NodeImageCache] = None,
        chunk_store: Optional[ChunkStore] = None,
    ):
        self.registry = registry or FunctionRegistry()
        self.base_images = base_images or NodeImageCache()
        # cluster-wide content-addressed store: publish()/relayout() ingest
        # every image's chunks, so delta chains and sibling fine-tunes never
        # store an identical chunk twice; None = dedup off
        self.chunk_store = chunk_store
        # warmth-policy feed (repro.serve.prewarm.ArrivalTracker): wired by
        # a router with a PrewarmEngine; record_access (a warm generation on
        # live traffic) counts as demand evidence for the function too
        self.arrival_tracker = None
        self._lock = threading.Lock()
        # recorded first-touch orders from warm generations (relayout feed)
        self._recorded: Dict[str, List[str]] = {}
        # fname -> (jif identity, base-ref name) for placement locality
        self._locality: Dict[str, Tuple[Tuple[str, int, int], Optional[str]]] = {}
        # digest -> node names holding the chunk (peer-fetch routing), fed
        # by NodeChunkCache announce hooks the router wires up
        self._chunk_holders: Dict[bytes, set] = {}
        # fname -> published manifest (one store ref per chunk occurrence;
        # a republish/relayout returns the OLD manifest's refs)
        self._chunk_manifests: Dict[str, List[bytes]] = {}
        # fname -> SnapshotStats of its last publish (delta economics feed
        # for the deployment pipeline: private_bytes vs total_bytes)
        self._publish_stats: Dict[str, SnapshotStats] = {}
        self._handoff_seq = 0  # unique handoff image names (per catalog)
        self.stats = {
            "publishes": 0,
            "relayouts": 0,
            "handoffs": 0,
            "chunks_published": 0,
            "chunk_bytes_unique": 0,
            "chunk_bytes_deduped": 0,
        }

    def _bump(self, key: str) -> None:
        with self._lock:
            self.stats[key] += 1

    def install_base(self, img, evictable: bool = False) -> None:
        """Install an operator-provided base image into the authoring cache
        (pinned by default: there is no JIF behind it to recover from)."""
        self.base_images.put(img, evictable=evictable)

    # ------------------------------------------------- chunk store (dedup)
    def _ingest_chunks(self, fname: str, jif_path: str) -> None:
        """Write-time dedup: push the image's chunks into the CAS (one ref
        per occurrence) and swap the function's manifest.  A v1 image whose
        digests cannot be backfilled standalone (delta with BASE chunks)
        just skips dedup — never fails the publish."""
        if self.chunk_store is None:
            return
        try:
            manifest, unique, dup = self.chunk_store.ingest_jif(jif_path)
        except ValueError:
            return
        with self._lock:
            old = self._chunk_manifests.get(fname)
            self._chunk_manifests[fname] = manifest
            self.stats["chunks_published"] += len(manifest)
            self.stats["chunk_bytes_unique"] += unique
            self.stats["chunk_bytes_deduped"] += dup
        if old:
            self.chunk_store.release_many(old)

    def announce_chunk(self, node: str, digest: bytes, present: bool) -> None:
        """Node residency feed for the digest→holders index (wired to each
        NodeChunkCache by the router)."""
        with self._lock:
            holders = self._chunk_holders.setdefault(digest, set())
            if present:
                holders.add(node)
            else:
                holders.discard(node)
                if not holders:
                    del self._chunk_holders[digest]

    def chunk_holders(self, digest: bytes) -> Tuple[str, ...]:
        """Nodes currently holding ``digest`` (RAM or disk tier)."""
        with self._lock:
            return tuple(self._chunk_holders.get(digest, ()))

    # -------------------------------------------------------------- publish
    def publish(
        self,
        name: str,
        cfg: ModelConfig,
        params,
        dirpath: str,
        base_name: Optional[str] = None,
        parent: Optional[str] = None,
        warm_ttl_s: float = 0.0,
        formats: Tuple[str, ...] = ("jif", "criu", "monolith"),
        extra_state: Optional[Any] = None,
        memory: Optional[NodeMemoryManager] = None,
    ) -> FunctionSpec:
        """Offline JIF preparation: layerwise layout, pre-warm + trace,
        access-order relocation, dedup vs an in-memory base (``base_name``)
        or a parent JIF on disk (``parent`` — delta publishing: any node
        can bootstrap the parent from the snapshot store, no pre-installed
        base required); also writes the baselines' formats for comparison.
        ``memory`` (a node's ledger) charges the writer's state copy as
        scratch so publishing competes with live tenants honestly."""
        if base_name is not None and parent is not None:
            raise ValueError("pass either base_name= or parent=, not both")
        os.makedirs(dirpath, exist_ok=True)
        state = layerwise_state(cfg, params)

        # pre-warm trace: run one tiny invocation under the recorder; the
        # recorder's lazy leaves record first touch when jit coerces them.
        # ``touched`` is the traced working set; untouched stragglers (and
        # any extra_state below) land after the ws boundary as residual.
        def run(view):
            generate(cfg, None, view, np.zeros((1, 4), np.int32), 2)

        order, touched = trace_access_order(
            state, run, max_iters=2, return_touched=True
        )
        jif_path = f"{dirpath}/{name}.jif"
        base = self.base_images.get(base_name)
        if "jif" in formats:
            full_state = state
            if extra_state is not None:
                # VM-style snapshots capture scratch/optimizer memory too;
                # in the JIF it streams as residual behind the ws boundary
                full_state = dict(state)
                full_state["__extra__"] = extra_state
            stats = snapshot(
                full_state,
                jif_path,
                base=base,
                parent=parent,
                access_order=order,
                working_set=touched,
                meta={"arch": cfg.name, "function": name},
                memory=memory,
            )
            with self._lock:
                self._publish_stats[name] = stats
            self._ingest_chunks(name, jif_path)
        if "criu" in formats:
            baselines.criu_star_snapshot(state, f"{dirpath}/{name}.criu")
        if "monolith" in formats:
            baselines.monolith_snapshot(
                state, f"{dirpath}/{name}.mono", extra_state=extra_state
            )
        spec = FunctionSpec(
            name=name, arch=cfg.name, jif_path=jif_path, base_image=base_name,
            warm_ttl_s=warm_ttl_s,
        )
        self.registry.register(spec)
        self._bump("publishes")
        return spec

    def publish_stats(self, name: str) -> Optional[SnapshotStats]:
        """SnapshotStats of ``name``'s last JIF publish (None before any):
        ``private_bytes`` is what the publish actually cost in new storage —
        the per-version delta economics the rollout pipeline reports."""
        with self._lock:
            return self._publish_stats.get(name)

    def unpublish(self, name: str, unlink: bool = False) -> Optional[FunctionSpec]:
        """Retire a published function: release its CAS manifest refs
        (chunks no other image references are unlinked from the store),
        drop the catalog's bookkeeping, and unregister the spec.  With
        ``unlink=True`` the JIF file itself is deleted — the CALLER
        guarantees no live delta child still chains to it on disk (the
        rollout controller refuses to retire a version with live
        descendants for exactly this reason).  Returns the retired spec,
        or None if the name was never registered."""
        with self._lock:
            manifest = self._chunk_manifests.pop(name, None)
            self._publish_stats.pop(name, None)
            self._recorded.pop(name, None)
            self._locality.pop(name, None)
        if manifest and self.chunk_store is not None:
            self.chunk_store.release_many(manifest)
        spec = self.registry.unregister(name)
        if unlink and spec is not None:
            try:
                os.unlink(spec.jif_path)
            except OSError:
                pass
        return spec

    # ------------------------------------------------------------- locality
    def locality_key(self, fname: str) -> Optional[str]:
        """The node-cache key a restore of ``fname`` will look up (its
        in-memory base name, or its delta parent's cache key) — what
        placement means by "snapshot locality".  Read once from the JIF
        header and memoized against the file's identity (a relayout
        rewrites the file in place and may change the ref)."""
        spec = self.registry.get(fname)
        try:
            st = os.stat(spec.jif_path)
        except OSError:
            return spec.base_image
        ident = (spec.jif_path, st.st_mtime_ns, st.st_size)
        with self._lock:
            hit = self._locality.get(fname)
            if hit is not None and hit[0] == ident:
                return hit[1]
        from repro.core.jif import JifReader

        try:
            with JifReader(spec.jif_path) as r:
                ref = r.base_ref
        except Exception:
            return spec.base_image
        key = ref.get("name") if ref else None
        with self._lock:
            self._locality[fname] = (ident, key)
        return key

    # ---------------------------------------------------- record → relayout
    def record_access(
        self,
        fname: str,
        node: NodeScheduler,
        prompt: Optional[np.ndarray] = None,
        max_new_tokens: int = 4,
        cfg: Optional[ModelConfig] = None,
    ) -> List[str]:
        """Trace one warm generation on ``node`` (the instance must be WARM
        there) and keep the observed first-touch order for
        :meth:`relayout`.  Returns the touched order."""
        order = node.trace_warm(fname, prompt, max_new_tokens, cfg)
        with self._lock:
            self._recorded[fname] = order
        if self.arrival_tracker is not None:
            self.arrival_tracker.record(fname)
        return order

    def recorded_order(self, fname: str) -> Optional[List[str]]:
        with self._lock:
            return self._recorded.get(fname)

    def relayout(
        self,
        fname: str,
        order: Optional[List[str]] = None,
        node: Optional[NodeScheduler] = None,
    ) -> SnapshotStats:
        """Re-snapshot a function with the recorded first-touch order: the
        JIF data segment is rewritten so the observed working set sits in
        front of the boundary — closing the record → relayout → faster-TTFT
        loop.  Uses ``node``'s warm instance state when resident (zero
        storage reads), else restores the current image from disk once.
        A delta-published function is rewritten as a delta against the
        SAME parent JIF — dropping the chain would balloon the file to
        full size and erase its placement locality key."""
        from repro.core.jif import JifReader

        spec = self.registry.get(fname)
        if order is None:
            order = self.recorded_order(fname)
        if order is None:
            raise RuntimeError(
                f"{fname}: no recorded access order — call record_access first"
            )
        with JifReader(spec.jif_path) as r:
            ref = r.base_ref
        parent = ref.get("path") if ref else None
        state = node.warm_state(fname) if node is not None else None
        if state is None:
            restorer = SpiceRestorer(
                pool=node.pool if node is not None else None,
                node_cache=(
                    node.node_cache if node is not None else self.base_images
                ),
                pipelined=False,
                iosched=node.iosched if node is not None else None,
            )
            state, _, _, _ = restorer.restore(spec.jif_path)
        stats = snapshot(
            state,
            spec.jif_path,
            base=None if parent else self.base_images.get(spec.base_image),
            parent=parent,
            access_order=order,
            working_set=order,
            meta={"arch": spec.arch, "function": fname, "relayout": True},
            # rewrite copy charged as scratch against the tracing node
            memory=node.memory if node is not None else None,
        )
        # the rewrite changed the data segment: re-ingest under the new
        # identity (the old manifest's refs are returned — chunks no other
        # image or node references are unlinked from the CAS)
        self._ingest_chunks(fname, spec.jif_path)
        self._bump("relayouts")
        return stats

    # ------------------------------------------------- warm-state handoff
    def publish_handoff(
        self,
        fname: str,
        state: Dict[str, np.ndarray],
        dirpath: str,
        memory: Optional[NodeMemoryManager] = None,
    ) -> Tuple[str, SnapshotStats]:
        """Snapshot a node's LIVE warm state as a delta against the
        function's own published image (``repro.core.delta_snapshot``) and
        ingest it into the chunk CAS under a handoff-scoped manifest key.

        Because warm generation is read-only over the restored tree, the
        delta's private payload is the dirty warm state only — typically
        KBs against a multi-MB image — and every base chunk the successor
        node needs is already CAS-resident / peer-fetchable from the
        original publish.  Returns ``(handoff_jif_path, stats)``;
        ``stats.private_bytes`` is the handoff's wire cost.  ``memory``
        charges the snapshot writer's state copy as scratch against the
        source node so draining competes with live tenants honestly.

        The registry is never touched: the successor restores the handoff
        image via ``Invocation(jif_override=...)``, and
        :meth:`retire_handoff` drops the manifest (and the file) once the
        successor is WARM."""
        from repro.core import delta_snapshot

        spec = self.registry.get(fname)
        os.makedirs(dirpath, exist_ok=True)
        with self._lock:
            self._handoff_seq += 1
            seq = self._handoff_seq
        path = os.path.join(dirpath, f"{fname}.handoff{seq}.jif")
        stats = delta_snapshot(
            state,
            path,
            parent=spec.jif_path,
            meta={"arch": spec.arch, "function": fname, "handoff": True},
            node_cache=self.base_images,
            memory=memory,
        )
        self._ingest_chunks(self._handoff_key(fname, path), path)
        self._bump("handoffs")
        return path, stats

    @staticmethod
    def _handoff_key(fname: str, path: str) -> str:
        # manifest key disjoint from the function's own publish key, so a
        # handoff never swaps (and releases) the published image's manifest
        return f"{fname}#handoff:{path}"

    def retire_handoff(self, fname: str, path: str, unlink: bool = True) -> None:
        """Release a handoff image's CAS refs (chunks no other image or
        node references are unlinked) and optionally delete the file."""
        with self._lock:
            manifest = self._chunk_manifests.pop(self._handoff_key(fname, path), None)
        if manifest and self.chunk_store is not None:
            self.chunk_store.release_many(manifest)
        if unlink:
            try:
                os.unlink(path)
            except OSError:
                pass

    # ---------------------------------------------------------- persistence
    def save(self, path: str) -> None:
        """Persist the registry (the catalog's durable state — recorded
        orders are advisory and rebuilt from live traffic)."""
        self.registry.save(path)

    @classmethod
    def load(cls, path: str, base_images: Optional[NodeImageCache] = None,
             ) -> "FunctionCatalog":
        return cls(registry=FunctionRegistry.load(path), base_images=base_images)


# -------------------------------------------------------- placement policies
class PlacementPolicy:
    """Picks a node index for one invocation.  ``place`` sees the function's
    spec, its snapshot-locality key (:meth:`FunctionCatalog.locality_key`),
    and one :class:`NodeLoad` per candidate; it returns an index into that
    candidate list.  ``sticky`` policies place each function once and the
    router pins it (replicas only grow through the scale-out knob);
    non-sticky policies place every request independently."""

    name = "policy"
    sticky = False
    # policies that ignore the probes (RoundRobin) set this False and the
    # router skips the per-request O(N × locks) load collection; place()
    # then receives placeholder NodeLoad()s of the right length
    needs_loads = True

    def place(
        self, spec: FunctionSpec, key: Optional[str], loads: Sequence[NodeLoad]
    ) -> int:
        raise NotImplementedError

    def place_urgent(
        self, spec: FunctionSpec, key: Optional[str], loads: Sequence[NodeLoad]
    ) -> int:
        """Deadline/LATENCY-aware placement: where ``place`` optimizes for
        locality or fairness, ``place_urgent`` optimizes for time-to-first-
        token NOW — a warm-holding node with a shallow queue beats a
        locality match behind a deep one.  Default: warm first, then
        least-loaded; policies may override."""
        return min(
            range(len(loads)),
            key=lambda i: (
                spec.name not in loads[i].warm,
                loads[i].queue_depth,
                loads[i].pending_io_bytes,
                loads[i].pressure,
            ),
        )

    @staticmethod
    def _least_loaded(loads: Sequence[NodeLoad]) -> int:
        return min(
            range(len(loads)),
            key=lambda i: (
                loads[i].queue_depth,
                loads[i].pending_io_bytes,
                loads[i].pressure,
            ),
        )


class LocalityFirst(PlacementPolicy):
    """warm > joinable in-flight > base-image-cached > delta-parent-cached >
    least-loaded; ties inside a tier break toward the least-loaded node."""

    name = "locality_first"
    sticky = True

    def place(self, spec, key, loads):
        def tier(load: NodeLoad) -> int:
            if spec.name in load.warm:
                return 0
            if spec.name in load.restoring:
                return 1
            if spec.base_image is not None and spec.base_image in load.images:
                return 2
            if key is not None and key in load.images:
                return 3
            return 4

        return min(
            range(len(loads)),
            key=lambda i: (
                tier(loads[i]),
                loads[i].queue_depth,
                loads[i].pending_io_bytes,
                loads[i].pressure,
            ),
        )


class RoundRobin(PlacementPolicy):
    """Spread requests blindly — the no-locality baseline."""

    name = "round_robin"
    sticky = False
    needs_loads = False

    def __init__(self):
        self._lock = threading.Lock()
        self._next = 0

    def place(self, spec, key, loads):
        with self._lock:
            idx = self._next % len(loads)
            self._next += 1
        return idx

    def place_urgent(self, spec, key, loads):
        # the base-class default ranks loads — but round-robin never probes
        # (needs_loads=False), so every load is an identical placeholder
        # and min() would pin ALL urgent traffic to node 0; keep rotating
        return self.place(spec, key, loads)


class LeastLoaded(PlacementPolicy):
    """Pure load balancing: ignore snapshot locality entirely."""

    name = "least_loaded"
    sticky = False

    def place(self, spec, key, loads):
        return self._least_loaded(loads)


_EMPTY_LOAD = NodeLoad()  # placeholder for needs_loads=False policies


# ---------------------------------------------------------------- the router
class ClusterRouter:
    """Places invocations across N node data planes (see module docstring
    for the routing contract).  The router adopts registry ownership onto
    its nodes: every node resolves specs through ``catalog.registry``."""

    def __init__(
        self,
        catalog: FunctionCatalog,
        nodes: Sequence[NodeScheduler],
        placement: Optional[PlacementPolicy] = None,
        scale_out_queue_depth: Optional[int] = None,
        latency_spill_depth: int = 2,
        urgent_deadline_s: float = 1.0,
        interconnect_bw: Optional[float] = None,
        prewarm=None,
        load_cache_ttl_s: float = 0.005,
    ):
        """``latency_spill_depth``: an urgent invocation (LATENCY class, or
        a deadline within ``urgent_deadline_s``) whose sticky replica has
        this many invocations in flight steals a replica on the node
        ``place_urgent`` picks instead of queueing — BATCH work waits where
        LATENCY work scales out.

        ``scale_out_queue_depth`` is DEPRECATED as a scaling mechanism: it
        is a static per-function replica-growth threshold, kept as an alias
        for existing callers.  New deployments should drive replica and
        node count through :class:`repro.serve.autoscale.AutoScaler`, which
        reacts to declared SLOs instead of a fixed queue depth.

        ``load_cache_ttl_s`` bounds the cost of placement probes at fleet
        scale: the router sets it as every node's ``load_ttl_s``, so a
        placement decision over 50 nodes reads 50 cached snapshots instead
        of taking 50 × several locks per request.  Staleness is bounded by
        the TTL *and* by instance lifecycle edges (any state transition
        invalidates that node's cached probe immediately); 0 disables
        caching.

        ``interconnect_bw`` (bytes/s) paces peer chunk transfers between
        nodes with chunk caches, modeling the node-to-node fabric the same
        way ``simulate_read_bw``/``simulate_upload_bw`` model storage and
        PCIe (labeled benchmark runs only; None = instantaneous).

        ``prewarm`` (a :class:`repro.serve.prewarm.PrewarmEngine`) turns
        on predictive warmth management: every real ``submit_invocation``
        feeds its arrival tracker, and the engine speculates restores
        back through this router (BATCH class, ``prewarm=True``) so
        placement, admission, QoS ordering and restore joining all apply
        unchanged.  ``close()`` stops the engine with the fleet."""
        if not nodes:
            raise ValueError("a cluster needs at least one node")
        self.catalog = catalog
        self.nodes: List[NodeScheduler] = list(nodes)
        taken = {n.name for n in self.nodes if n.name}
        for i, node in enumerate(self.nodes):
            node.registry = catalog.registry  # control plane owns the registry
            if not node.name and len(self.nodes) > 1:
                # single-node paths keep node=""; skip caller-taken names
                name = f"node{i}"
                while name in taken:
                    name = f"{name}x"
                node.name = name
                taken.add(name)
        names = [n.name for n in self.nodes]
        if len(set(names)) != len(names):
            raise ValueError(f"node names must be unique, got {names}")
        self.placement = placement or LocalityFirst()
        self.scale_out_queue_depth = scale_out_queue_depth
        self.latency_spill_depth = latency_spill_depth
        self.urgent_deadline_s = urgent_deadline_s
        self.interconnect_bw = interconnect_bw
        self.load_cache_ttl_s = load_cache_ttl_s
        self._lock = threading.Lock()
        self._closed = False
        self._assign: Dict[str, List[str]] = {}  # sticky fname -> node names
        self._draining: set = set()  # node names excluded from placement
        # name -> live chunk cache (peer-fetch closures read this at call
        # time, so nodes added later serve peers immediately)
        self._chunk_caches: Dict[str, Any] = {}
        self.stats = {
            "routed": 0,
            "scale_outs": 0,
            "latency_steals": 0,
            "peer_fetches": 0,
            "peer_fetch_bytes": 0,
            "nodes_added": 0,
            "nodes_removed": 0,
        }
        for node in self.nodes:
            node.load_ttl_s = load_cache_ttl_s
            self._wire_node_chunks(node)
        self.prewarm = prewarm
        if prewarm is not None:
            prewarm.attach(self)
        # staged-rollout resolver (repro.serve.deploy.RolloutController):
        # rewrites a logical function name to the stable/canary version
        # name per invocation, BEFORE placement — set by its attach()
        self.deploy = None

    def _wire_node_chunks(self, node: NodeScheduler) -> None:
        """Connect one node's chunk cache to the cluster: residency
        announcements feed the catalog's digest→holders index, and the
        peer-fetch hook pulls a missing chunk from whichever peer holds it
        (paced by ``interconnect_bw``) instead of re-reading the image
        store.  The peer serves via ``peek`` — RAM first, else its local
        CAS file — so a transfer never perturbs the holder's LRU."""
        import time as _time

        if node.chunks is None:
            return
        cache = node.chunks
        self_name = node.name
        self._chunk_caches[self_name] = cache

        def fetch(digest: bytes) -> Optional[bytes]:
            for holder in self.catalog.chunk_holders(digest):
                if holder == self_name:
                    continue
                peer = self._chunk_caches.get(holder)
                if peer is None:
                    continue
                data = peer.peek(digest)
                if data is None:
                    continue  # stale index entry: try the next holder
                if self.interconnect_bw:
                    _time.sleep(len(data) / self.interconnect_bw)
                with self._lock:
                    self.stats["peer_fetches"] += 1
                    self.stats["peer_fetch_bytes"] += len(data)
                return data
            return None

        cache.node = self_name  # announce under the router-assigned name
        cache.announce = self.catalog.announce_chunk
        cache.peer_fetch = fetch

    # ------------------------------------------------------- fleet elasticity
    def add_node(self, node: NodeScheduler) -> NodeScheduler:
        """Join a node to the fleet: adopt the registry, assign a unique
        name if unnamed, apply the fleet's load-probe TTL, and wire its
        chunk cache into the peer-fetch mesh.  Placement sees it on the
        next request."""
        with self._lock:
            if self._closed:
                raise Overloaded("router is closed")
            node.registry = self.catalog.registry
            taken = {n.name for n in self.nodes}
            if not node.name:
                name = f"node{len(self.nodes)}"
                while name in taken:
                    name = f"{name}x"
                node.name = name
            if node.name in taken:
                raise ValueError(f"node name {node.name!r} already in fleet")
            node.load_ttl_s = self.load_cache_ttl_s
            self.nodes = self.nodes + [node]  # readers snapshot; never mutate
            self.stats["nodes_added"] += 1
        self._wire_node_chunks(node)
        return node

    def remove_node(self, name: str) -> NodeScheduler:
        """Detach a node from the fleet: placement stops immediately, the
        node's sticky assignments are dropped (a later request re-places
        the function), and its chunk cache leaves the peer mesh.  The
        caller still owns the node object — drain it first
        (:meth:`set_draining` + ``quiesce``) and ``close()`` it after; the
        close announces its chunks absent, cleaning the holders index."""
        with self._lock:
            node = next((n for n in self.nodes if n.name == name), None)
            if node is None:
                raise KeyError(name)
            if len(self.nodes) == 1:
                raise ValueError("cannot remove the last node")
            self.nodes = [n for n in self.nodes if n.name != name]
            for fname in list(self._assign):
                reps = [nm for nm in self._assign[fname] if nm != name]
                if reps:
                    self._assign[fname] = reps
                else:
                    del self._assign[fname]
            self._draining.discard(name)
            self._chunk_caches.pop(name, None)
            self.stats["nodes_removed"] += 1
        return node

    def set_draining(self, name: str, draining: bool = True) -> None:
        """Mark a node as draining: placement skips it (including sticky
        replicas already pinned there), but queued and in-flight work on it
        completes normally.  Reversible until :meth:`remove_node`."""
        self.node(name)  # raise KeyError for unknown names
        with self._lock:
            if draining:
                self._draining.add(name)
            else:
                self._draining.discard(name)

    def draining(self) -> Tuple[str, ...]:
        with self._lock:
            return tuple(self._draining)

    def active_nodes(self) -> List[NodeScheduler]:
        """Placement candidates: the fleet minus draining nodes (falling
        back to the whole fleet if everything is draining, so routing can
        never dead-end)."""
        with self._lock:
            nodes = self.nodes
            draining = set(self._draining)
        active = [n for n in nodes if n.name not in draining]
        return active or list(nodes)

    # ------------------------------------------------------------- routing
    def _probe(self, nodes: Sequence[NodeScheduler]) -> List[NodeLoad]:
        if self.placement.needs_loads:
            return [n.load() for n in nodes]
        return [_EMPTY_LOAD] * len(nodes)

    def _urgent(self, inv: Optional[Invocation]) -> bool:
        """LATENCY class, or a deadline tighter than ``urgent_deadline_s``:
        the invocations deadline-aware placement treats as urgent."""
        if inv is None:
            return False
        if inv.qos is QosClass.LATENCY:
            return True
        remaining = inv.remaining_s()
        return remaining is not None and remaining < self.urgent_deadline_s

    def _pick(self, fname: str, inv: Optional[Invocation] = None) -> NodeScheduler:
        """Load probes run OUTSIDE the router lock (each takes several node
        locks — though the fleet-wide ``load_ttl_s`` cache amortizes that
        to O(1) per node between lifecycle edges).  The lock only guards
        the sticky replica map and draining set — probes may be a beat
        stale, which placement tolerates (it ranks)."""
        spec = self.catalog.registry.get(fname)
        key = self.catalog.locality_key(fname)
        urgent = self._urgent(inv)
        with self._lock:
            self.stats["routed"] += 1
            draining = set(self._draining)
            assigned = (
                list(self._assign.get(fname, ())) if self.placement.sticky
                else None
            )
        cands = self.active_nodes()
        if assigned is None:  # non-sticky: place every request independently
            place = self.placement.place_urgent if urgent else self.placement.place
            return cands[place(spec, key, self._probe(cands))]
        by_name = {n.name: n for n in self.nodes}
        # draining replicas stop taking NEW placements; a removed node's
        # entries are pruned by remove_node but tolerate the race here
        live = [nm for nm in assigned if nm in by_name and nm not in draining]
        if not live:
            chosen = cands[self.placement.place(spec, key, self._probe(cands))]
            with self._lock:
                won = self._assign.setdefault(fname, [chosen.name])
                if won == [chosen.name]:
                    return chosen
                # lost the placement race: join the winner's replicas
                live = [nm for nm in won if nm in by_name] or [chosen.name]
        # sticky: route among this function's replicas (joins ride the
        # in-flight restore; warm hits stay warm)
        loads = {nm: by_name[nm].load() for nm in live}
        best = min(
            live,
            key=lambda nm: (loads[nm].queue_depth, loads[nm].pressure),
        )
        room = len(live) < len(cands)
        if urgent and room \
                and loads[best].urgent_depth >= self.latency_spill_depth:
            # deadline-aware steal: the least-loaded replica is backed up
            # with work the QoS queue cannot dispatch past (urgent_depth
            # discounts parked BATCH occupancy) and this invocation cannot
            # wait — grow a replica where place_urgent points (a BATCH
            # invocation queues instead)
            return self._grow_replica(
                fname, spec, key, live, by_name[best], urgent=True
            )
        if (
            self.scale_out_queue_depth is not None
            and (inv is None or inv.qos is not QosClass.BATCH)
            and room
            and loads[best].queue_depth >= self.scale_out_queue_depth
        ):
            # opt-in scale-out: the least-loaded replica is still backed
            # up — place one more replica by the same policy.  BATCH-class
            # invocations never trigger it: background work waits.
            return self._grow_replica(
                fname, spec, key, live, by_name[best], urgent=False
            )
        return by_name[best]

    def _grow_replica(
        self, fname, spec, key, live, best: NodeScheduler, urgent
    ) -> NodeScheduler:
        rest = [n for n in self.active_nodes() if n.name not in live]
        if not rest:
            return best
        place = self.placement.place_urgent if urgent else self.placement.place
        new = rest[place(spec, key, self._probe(rest))]
        with self._lock:
            current = self._assign.setdefault(fname, [best.name])
            if new.name not in current:
                current.append(new.name)
                self.stats["latency_steals" if urgent else "scale_outs"] += 1
                return new
        return best

    def submit_invocation(self, inv: Invocation) -> InvocationHandle:
        """Typed front door: place by QoS/deadline, admit on the chosen
        node (typed ``Overloaded`` / ``DeadlineExceeded`` raise here)."""
        if self._closed:
            raise Overloaded("router is closed")
        if self.prewarm is not None and not inv.prewarm and inv.payload is None:
            # feed the arrival histogram BEFORE placement (arrival time is
            # submit time); the engine's own speculations never count as
            # demand, or prediction would feed back on itself (colocated
            # compute payloads are not function demand either)
            self.prewarm.on_arrival(inv.function)
        if self.deploy is not None and inv.payload is None:
            # staged rollout: the caller addresses the LOGICAL function;
            # the controller's seeded A/B split picks the concrete version
            # (stable or canary) this invocation serves.  Resolution runs
            # AFTER the arrival feed (demand is per logical function) and
            # BEFORE placement, so sticky routing, restore joining and
            # warm hits all key on the version actually served.
            resolved = self.deploy.resolve(inv.function)
            if resolved != inv.function:
                inv = dataclasses.replace(inv, function=resolved)
        if inv.payload is not None:
            # spec-less colocated compute: nothing to place by locality —
            # run it where the queue is shallowest among active nodes
            cands = self.active_nodes()
            node = min(cands, key=lambda n: n.load().queue_depth)
            return node.submit_invocation(inv)
        return self._pick(inv.function, inv).submit_invocation(inv)

    def submit(
        self,
        fname: str,
        prompt: np.ndarray,
        max_new_tokens: int = 8,
        mode: str = "spice",
        cfg: Optional[ModelConfig] = None,
        simulate_read_bw: Optional[float] = None,
    ) -> InvocationHandle:
        """Legacy surface: a STANDARD-class :class:`Invocation` wrapper."""
        return self.submit_invocation(Invocation(
            function=fname, prompt=prompt, max_new_tokens=max_new_tokens,
            mode=mode, cfg=cfg, simulate_read_bw=simulate_read_bw,
        ))

    def invoke(self, *args, **kwargs) -> InvokeResult:
        return self.submit(*args, **kwargs).result()

    # ------------------------------------------------------------- queries
    def node(self, name: str) -> NodeScheduler:
        for n in self.nodes:
            if n.name == name:
                return n
        raise KeyError(name)

    def loads(self) -> List[NodeLoad]:
        return [n.load() for n in self.nodes]

    def replicas(self, fname: str) -> List[str]:
        """Node names a sticky function is currently placed on."""
        with self._lock:
            return list(self._assign.get(fname, []))

    def reassign(
        self, fname: str, to_name: str, from_name: Optional[str] = None
    ) -> None:
        """Rewrite the sticky replica map after a warm-state handoff:
        ``to_name`` joins ``fname``'s replicas, ``from_name`` (the drained
        source) leaves them.  No-op coverage for non-sticky policies (they
        never read the map)."""
        self.node(to_name)  # raise KeyError for unknown names
        with self._lock:
            reps = self._assign.setdefault(fname, [])
            if from_name is not None:
                reps[:] = [nm for nm in reps if nm != from_name]
            if to_name not in reps:
                reps.append(to_name)

    # ------------------------------------------------------ fleet operations
    def evict(self, fname: Optional[str] = None) -> None:
        for n in self.nodes:
            n.evict(fname)

    def reap_expired(self) -> int:
        return sum(n.reap_expired() for n in self.nodes)

    def drain_residual(self, timeout: float = 60.0) -> bool:
        return all(n.drain_residual(timeout) for n in self.nodes)

    def audit(self) -> Dict[str, Dict[str, int]]:
        """Run every node's ledger audit; returns per-node snapshots (and
        raises on the first node whose invariant is broken)."""
        return {n.name: n.memory.audit() for n in self.nodes}

    def close(self) -> None:
        """Idempotent fleet teardown: refuse new work, then close every
        node — each stops its reaper and drains its admission queue with
        typed :class:`Overloaded` rejections, so teardown can never hang on
        queued BATCH work.  In-flight invocations finish; their handles
        resolve normally.  Safe to call any number of times."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        if self.prewarm is not None:
            self.prewarm.stop()
        for n in self.nodes:
            n.close()

    # ---------------------------------------------- control-plane passthrough
    def _warm_node(self, fname: str) -> Optional[NodeScheduler]:
        """The node currently serving ``fname`` WARM, else any node that
        holds its (evicted) instance, else None."""
        from repro.serve.instance import InstanceState

        fallback = None
        for n in self.nodes:
            inst = n.instance(fname)
            if inst is None:
                continue
            if inst.state is InstanceState.WARM:
                return n
            fallback = fallback or n
        return fallback

    def record_access(self, fname: str, **kwargs) -> List[str]:
        """Trace ``fname`` on whichever node currently holds it WARM."""
        from repro.serve.instance import NotWarmError

        for n in self.nodes:
            if n.instance(fname) is not None:
                try:
                    return self.catalog.record_access(fname, n, **kwargs)
                except NotWarmError:
                    continue
        raise RuntimeError(f"{fname}: no node holds a WARM instance")

    def relayout(self, fname: str, order: Optional[List[str]] = None) -> SnapshotStats:
        # prefer a node with the WARM tree resident (zero-read re-snapshot);
        # any instance-holding node is only a ledger to charge the fallback
        # disk restore against
        return self.catalog.relayout(
            fname, order=order, node=self._warm_node(fname)
        )
