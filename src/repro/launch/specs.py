"""Per-cell (architecture x input-shape x mesh) lowering specs.

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input
(weak-type-correct, shardable, no device allocation); ``build_cell`` wires
step functions + sharding trees for jit lowering.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.base import InputShape, ModelConfig
from repro.launch.mesh import data_shards
from repro.models import lm
from repro.serve.steps import ServeStepConfig, make_decode_step, make_prefill_step
from repro.sharding.partition import axis_rules, map_specs, named_sharding
from repro.train.steps import TrainStepConfig, default_microbatches, make_train_step


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def make_rules(cfg: ModelConfig, shape: InputShape, multi_pod: bool) -> Dict:
    dp = ("pod", "data") if multi_pod else ("data",)
    train = shape.kind == "train"
    rules = {
        "batch": dp,
        "model": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "vocab": ("model",),
        "expert": ("model",),
        "capacity": dp,
        "fsdp": ("data",) if train else None,
        # KV caches shard on (replicated) heads — see kv_policy; kv_seq
        # sharding is kept as an experiment knob (default off: dynamic
        # update-slice on a sharded dim triggers full rematerialization).
        "kv_seq": None,
    }
    return rules


def kv_policy(cfg: ModelConfig, shape: InputShape, model_shards: int = 16) -> Dict:
    """KV-head replication factor + cache dtype for serve cells.

    Replicating KV heads r-fold makes the head dim divide the TP axis
    (qwen3: 8->16), keeping the cache sharded and update-slices local.
    Archs whose head counts can never divide (28H/4kv, 36H/4kv) fall back to
    replicated heads + int8 KV quantization for the 32k decode cell.
    """
    H, kvH = cfg.n_heads, cfg.n_kv_heads
    if kvH == 0:
        return {"kv_repeat": 1, "kv_dtype": "bfloat16"}
    r = 1
    if kvH % model_shards != 0:
        for cand in range(2, H // kvH + 1):
            eff = kvH * cand
            if H % eff == 0 and eff % model_shards == 0:
                r = cand
                break
    dtype = "bfloat16"
    if (kvH * r) % model_shards != 0 and shape.kind == "decode":
        dtype = "int8"  # unshardable heads: quantize the replicated cache
    return {"kv_repeat": r, "kv_dtype": dtype}


def input_specs(
    cfg: ModelConfig,
    shape: InputShape,
    compute_dtype=jnp.bfloat16,
    kv_dtype=jnp.bfloat16,
    kv_repeat: int = 1,
) -> Dict[str, Any]:
    """Abstract inputs for the step function of this cell (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model

    def lm_batch(seq, with_targets):
        if cfg.frontend == "audio":
            b = {"frame_embeds": sds((B, seq, d), compute_dtype)}
        else:
            b = {"tokens": sds((B, seq), jnp.int32)}
            if cfg.frontend == "vision" and seq > 1:
                b["patch_embeds"] = sds((B, cfg.frontend_tokens, d), compute_dtype)
                b["positions"] = sds((3, B, seq), jnp.int32)
        if with_targets:
            b["targets"] = sds((B, seq), jnp.int32)
        return b

    if shape.kind == "train":
        return {"batch": lm_batch(S, True)}
    if shape.kind == "prefill":
        return {"batch": lm_batch(S, False)}
    # decode: one new token against a cache of S
    return {
        "batch": lm_batch(1, False),
        "caches": lm.abstract_cache(cfg, B, S, kv_dtype, compute_dtype, kv_repeat),
        "pos": sds((), jnp.int32),
    }


def input_shardings(cfg: ModelConfig, shape: InputShape, kv_dtype=jnp.bfloat16,
                    kv_repeat: int = 1):
    """Sharding tree matching input_specs (must be called under axis_rules)."""

    def lm_batch_sh(seq, with_targets):
        if cfg.frontend == "audio":
            b = {"frame_embeds": named_sharding(("batch", None, None), (shape.global_batch, seq, cfg.d_model))}
        else:
            b = {"tokens": named_sharding(("batch", None), (shape.global_batch, seq))}
            if cfg.frontend == "vision" and seq > 1:
                b["patch_embeds"] = named_sharding(
                    ("batch", None, None), (shape.global_batch, cfg.frontend_tokens, cfg.d_model)
                )
                b["positions"] = named_sharding((None, "batch", None), (3, shape.global_batch, seq))
        if with_targets:
            b["targets"] = named_sharding(("batch", None), (shape.global_batch, seq))
        return b

    if shape.kind == "train":
        return {"batch": lm_batch_sh(shape.seq_len, True)}
    if shape.kind == "prefill":
        return {"batch": lm_batch_sh(shape.seq_len, False)}
    return {
        "batch": lm_batch_sh(1, False),
        "caches": lm.cache_shardings(
            cfg, shape.global_batch, shape.seq_len, kv_dtype, kv_repeat=kv_repeat
        ),
        "pos": named_sharding(()),
    }


@dataclasses.dataclass
class CellPlan:
    """Everything needed to lower one (arch x shape x mesh) cell."""

    fn: Any
    args: Tuple
    in_shardings: Tuple
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    meta: Dict


def build_cell(
    arch: str,
    shape_name: str,
    mesh,
    multi_pod: bool,
    overrides: Optional[Dict] = None,
    cfg: Optional[ModelConfig] = None,
) -> CellPlan:
    """Construct the jit plan for one cell. Must be called inside
    ``with mesh, axis_rules(mesh, rules)`` (see ``plan_context``)."""
    overrides = dict(overrides or {})
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    pad_heads = int(overrides.pop("pad_heads", 0))
    if pad_heads:
        # zero-padded extra attention heads: mathematically identical output,
        # makes the head dim divisible by the TP axis (see EXPERIMENTS §Perf)
        import dataclasses as _dc

        cfg = _dc.replace(cfg, name=cfg.name, n_heads=pad_heads, head_dim=cfg.hd)
    if overrides.pop("mamba_split_proj", 0):
        import dataclasses as _dc

        cfg = _dc.replace(cfg, mamba_split_proj=True)
    attn_stages = int(overrides.pop("attn_stages", 1))
    unroll_scans = bool(overrides.pop("unroll_scans", False))
    compute_dtype = jnp.dtype(overrides.pop("compute_dtype", "bfloat16"))
    pol = kv_policy(cfg, shape, mesh.shape.get("model", 1))
    kv_dtype = jnp.dtype(overrides.pop("kv_dtype", pol["kv_dtype"]))
    kv_repeat = int(overrides.pop("kv_repeat", pol["kv_repeat"]))

    specs = input_specs(cfg, shape, compute_dtype, kv_dtype, kv_repeat)
    shard = input_shardings(cfg, shape, kv_dtype, kv_repeat)
    meta: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "multi_pod": multi_pod,
        "kind": shape.kind,
        "compute_dtype": str(compute_dtype),
        "kv_dtype": str(kv_dtype),
        "kv_repeat": kv_repeat,
    }

    if shape.kind == "train":
        params_dtype = jnp.float32
        abstract_params = lm.abstract_params(cfg, params_dtype)
        param_sh = lm.param_shardings(cfg)
        opt_abs = {
            "m": abstract_params,
            "v": abstract_params,
            "count": sds((), jnp.int32),
        }
        opt_sh = {"m": param_sh, "v": param_sh, "count": named_sharding(())}
        n_mb = overrides.pop(
            "num_microbatches",
            default_microbatches(
                cfg, shape.global_batch, data_shards(mesh), shape.seq_len,
                mesh.shape.get("model", 1),
            ),
        )
        tcfg = TrainStepConfig(
            remat=overrides.pop("remat", "full"),
            compute_dtype=str(compute_dtype),
            num_microbatches=int(n_mb),
            q_chunk=int(overrides.pop("q_chunk", 2048)),
            kv_repeat=kv_repeat,
            attn_stages=attn_stages,
            unroll_scans=unroll_scans,
        )
        meta.update(remat=tcfg.remat, num_microbatches=tcfg.num_microbatches, q_chunk=tcfg.q_chunk)
        step = make_train_step(cfg, tcfg)
        return CellPlan(
            fn=step,
            args=(abstract_params, opt_abs, specs["batch"]),
            in_shardings=(param_sh, opt_sh, shard["batch"]),
            out_shardings=(param_sh, opt_sh, None),
            donate_argnums=(0, 1),
            meta=meta,
        )

    abstract_params = lm.abstract_params(cfg, jnp.bfloat16)
    param_sh = lm.param_shardings(cfg)
    ui = overrides.pop("unroll_inner", None)
    scfg = ServeStepConfig(
        compute_dtype=str(compute_dtype),
        kv_dtype=str(kv_dtype),
        kv_repeat=kv_repeat,
        kv_block=int(overrides.pop("kv_block", 2048)),
        attn_stages=attn_stages,
        q_chunk=int(overrides.pop("q_chunk", 512)),
        unroll_scans=unroll_scans,
        unroll_inner=None if ui is None else bool(ui),
    )
    meta.update(q_chunk=scfg.q_chunk)

    if shape.kind == "prefill":
        step = make_prefill_step(cfg, scfg)
        return CellPlan(
            fn=step,
            args=(abstract_params, specs["batch"]),
            in_shardings=(param_sh, shard["batch"]),
            out_shardings=None,
            donate_argnums=(),
            meta=meta,
        )

    step = make_decode_step(cfg, scfg)
    return CellPlan(
        fn=step,
        args=(abstract_params, specs["caches"], specs["batch"], specs["pos"]),
        in_shardings=(param_sh, shard["caches"], shard["batch"], shard["pos"]),
        out_shardings=(None, shard["caches"]),
        donate_argnums=(1,),
        meta=meta,
    )


def modeled_memory(cfg: ModelConfig, shape: InputShape, mesh, meta: Dict) -> Dict:
    """Analytic per-device HBM model for the TPU target.

    The CPU dry-run's ``memory_analysis()`` temps are inflated by XLA:CPU's
    bf16->f32 emulation (every bf16 weight/cache touched materializes an f32
    convert); TPUs execute bf16 natively.  We therefore judge v5e fit with
    this analytic model and record the raw CPU numbers alongside.
    """
    m = mesh.shape.get("model", 1)
    dp = data_shards(mesh)
    train = shape.kind == "train"
    B, S = shape.global_batch, shape.seq_len
    P = cfg.param_count()

    shards = dp * m if train else m  # fsdp x tp in train; tp only in serve
    param_bytes = P * (4 if train else 2) / shards
    opt_bytes = P * 8 / shards if train else 0.0  # adam m+v f32
    grad_bytes = P * 4 / shards if train else 0.0

    # KV / SSM caches (serve only)
    cache_bytes = 0.0
    if shape.kind != "train":
        kv_rep = meta.get("kv_repeat", 1)
        kv_dt = 1 if meta.get("kv_dtype") == "int8" else 2
        b_loc = max(B // dp, 1)
        specs_all = list(cfg.pattern) * cfg.pattern_reps + list(cfg.remainder)
        for s in specs_all:
            if s.kind == "attn":
                kvh = cfg.n_kv_heads * kv_rep
                kvh_loc = kvh / m if kvh % m == 0 else kvh
                Sc = min(s.window, S) if s.window else S
                cache_bytes += 2 * b_loc * kvh_loc * Sc * cfg.hd * kv_dt
                if kv_dt == 1:  # int8 scales
                    cache_bytes += 2 * b_loc * kvh_loc * Sc * 4
            else:
                h_loc = cfg.ssm_heads / m if cfg.ssm_heads % m == 0 else cfg.ssm_heads
                cache_bytes += b_loc * h_loc * cfg.ssm_head_dim * cfg.ssm_state * 4
                cache_bytes += b_loc * (cfg.conv_kernel - 1) * (
                    cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
                ) / m * 2

    # transient activations
    act = 0.0
    reps_total = cfg.pattern_reps + len(cfg.remainder)
    if train:
        mb = max(meta.get("num_microbatches", 1), 1)
        tok = (B // dp) * S / mb
        act += reps_total * tok * cfg.d_model * 2  # remat carries
        act += 3 * tok * (cfg.vocab_size / m if cfg.vocab_size % m == 0 else cfg.vocab_size) * 4
        if cfg.n_experts:
            from repro.models.moe import capacity

            t_dev = tok / m
            act += 3 * cfg.n_experts * capacity(cfg, int(max(t_dev, 1))) * cfg.d_model * 2
        q = min(meta.get("q_chunk", 2048), S)
        kvh = cfg.n_kv_heads * meta.get("kv_repeat", 1)
        kvh_loc = max(kvh / m, 1) if kvh and kvh % m == 0 else kvh
        g = cfg.n_heads / max(kvh, 1)
        act += 2 * (B // dp) / mb * kvh_loc * g * q * S * 4  # score block fwd+bwd
    elif shape.kind == "prefill":
        b_loc = max(B // dp, 1)
        act += 6 * b_loc * S * cfg.d_model * 2
        q = min(meta.get("q_chunk", 512), S)
        if cfg.n_heads:
            kvh = cfg.n_kv_heads * meta.get("kv_repeat", 1)
            kvh_loc = kvh / m if kvh % m == 0 else kvh
            g = cfg.n_heads / max(kvh, 1)
            act += b_loc * kvh_loc * g * q * S * 4
    else:  # decode: per-block transients + logits
        b_loc = max(B // dp, 1)
        act += 0.5e9  # block buffers, norms, residuals
        act += b_loc * cfg.vocab_size * 4

    total = param_bytes + opt_bytes + grad_bytes + cache_bytes + act
    return {
        "param_bytes": param_bytes,
        "opt_bytes": opt_bytes + grad_bytes,
        "cache_bytes": cache_bytes,
        "activation_bytes": act,
        "total_bytes": total,
        "fits_hbm": bool(total < 0.92 * hw_bytes()),
    }


def hw_bytes() -> int:
    from repro.launch import hw

    return hw.HBM_BYTES


def cell_skip_reason(arch: str, shape_name: str) -> Optional[str]:
    cfg = get_config(arch)
    if shape_name == "long_500k" and not cfg.long_context_ok:
        return (
            "long_500k requires sub-quadratic attention; "
            f"{arch} is pure full/GQA attention (see DESIGN.md §Arch-applicability)"
        )
    return None
