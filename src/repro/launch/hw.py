"""TPU v5e hardware constants used by the roofline model (targets, not the
runtime — this container is CPU-only)."""

PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
HBM_BYTES = 16 * 1024**3  # per chip
