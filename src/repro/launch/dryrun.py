import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell and extract roofline terms from the compiled artifact.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi --force
  ... --set remat=dots --set num_microbatches=4 --tag remat_dots

Results cached to results/dryrun/<cell>[.<tag>].json.
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCHS, SHAPES, get_config
from repro.launch import hw
from repro.launch.hlo_analysis import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell, cell_skip_reason, make_rules, modeled_memory
from repro.sharding.partition import axis_rules

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def model_flops(cfg, shape) -> float:
    """Analytic useful FLOPs: 6·N·D train, 2·N·D serve (N = active params)."""
    n = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    return (6.0 if shape.kind == "train" else 2.0) * n * tokens


def roofline(cost, coll, n_chips, cfg, shape) -> dict:
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    coll_dev = float(coll.get("total", 0.0))
    terms = {
        "compute_s": flops_dev / hw.PEAK_FLOPS_BF16,
        "memory_s": bytes_dev / hw.HBM_BW,
        "collective_s": coll_dev / hw.ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_global = flops_dev * n_chips
    return {
        **terms,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_flop_ratio": (mf / hlo_global) if hlo_global else 0.0,
        "roofline_fraction": (mf / hw.PEAK_FLOPS_BF16 / n_chips)
        / max(sum(terms.values()), 1e-30),
        "bound_time_s": max(terms.values()),
        "sum_time_s": sum(terms.values()),
    }


def _compile_plan(arch, shape_name, mesh, multi_pod, overrides, cfg=None):
    plan = build_cell(arch, shape_name, mesh, multi_pod, overrides, cfg=cfg)
    jf = jax.jit(
        plan.fn,
        in_shardings=plan.in_shardings,
        out_shardings=plan.out_shardings,
        donate_argnums=plan.donate_argnums,
    )
    t0 = time.time()
    lowered = jf.lower(*plan.args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    return plan, compiled, round(t1 - t0, 2), round(t2 - t1, 2)


def _measure(arch, shape_name, mesh, multi_pod, overrides, reps: int) -> dict:
    """Compile a reps-{1,2} fully-unrolled variant: XLA's cost_analysis counts
    while-loop bodies ONCE (not x trip-count), so true per-step costs are
    extrapolated as M1 + (reps-1)*(M2-M1) from two unrolled compiles."""
    cfg0 = get_config(arch)
    cfg_r = dataclasses.replace(
        cfg0,
        pattern_reps=reps,
        n_layers=len(cfg0.pattern) * reps + len(cfg0.remainder),
    )
    ov = dict(overrides or {})
    ov.update(unroll_scans=True, unroll_inner=True, num_microbatches=1)
    _, compiled, _, _ = _compile_plan(arch, shape_name, mesh, multi_pod, ov, cfg=cfg_r)
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": {k: float(v) for k, v in coll.items() if k != "counts"},
    }


def _extrapolate(m1: dict, m2: dict, reps: int) -> dict:
    out = {}
    for k in ("flops", "bytes"):
        out[k] = m1[k] + (reps - 1) * max(m2[k] - m1[k], 0.0)
    coll = {}
    keys = set(m1["coll"]) | set(m2["coll"])
    for k in keys:
        a, b = m1["coll"].get(k, 0.0), m2["coll"].get(k, 0.0)
        coll[k] = a + (reps - 1) * max(b - a, 0.0)
    out["coll"] = coll
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, overrides=None, tag="",
             save_hlo=False) -> dict:
    cell_id = f"{arch}__{shape_name}__{'multi' if multi_pod else 'single'}"
    if tag:
        cell_id += f".{tag}"
    out = {"cell": cell_id, "arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}

    skip = cell_skip_reason(arch, shape_name)
    if skip:
        out["skipped"] = skip
        return out

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    rules = make_rules(cfg, shape, multi_pod)

    # decode steps unroll the LAYER scan: scanning over per-layer caches
    # double-buffers them (xs + ys live simultaneously -> ~3x KV memory);
    # unrolled, the donated cache updates alias in place.  The inner
    # flash-decoding block loop stays rolled (bounded live converts).
    if shape.kind == "decode":
        overrides = {**(overrides or {}), "unroll_scans": True, "unroll_inner": False}

    with mesh, axis_rules(mesh, rules):
        # 1) the real step: proves lowering/compile, gives memory fit
        plan, compiled, lower_s, compile_s = _compile_plan(
            arch, shape_name, mesh, multi_pod, overrides
        )
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        coll_once = collective_bytes(hlo)
        if save_hlo:
            RESULTS.mkdir(parents=True, exist_ok=True)
            (RESULTS / f"{cell_id}.hlo.txt").write_text(hlo)
        del compiled, hlo

        # 2) cost measurement via two unrolled variants (see _measure)
        m1 = _measure(arch, shape_name, mesh, multi_pod, overrides, 1)
        m2 = _measure(arch, shape_name, mesh, multi_pod, overrides, 2)
        true = _extrapolate(m1, m2, cfg.pattern_reps)

        per_dev_bytes = (
            mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes
        )
        cost = {"flops": true["flops"], "bytes accessed": true["bytes"]}
        modeled = modeled_memory(cfg, shape, mesh, plan.meta)
        out.update(
            meta=plan.meta,
            lower_s=lower_s,
            compile_s=compile_s,
            n_chips=n_chips,
            memory={
                # raw XLA:CPU memory analysis (bf16 emulated in f32 -> temps
                # are inflated vs the TPU target; see EXPERIMENTS.md)
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "per_device_bytes": per_dev_bytes,
                "fits_hbm_cpu": bool(per_dev_bytes < hw.HBM_BYTES),
                # analytic v5e model (authoritative fit judgment)
                "modeled": modeled,
                "fits_hbm": modeled["fits_hbm"],
            },
            cost={
                "flops_per_device": true["flops"],
                "bytes_per_device": true["bytes"],
                "measure_points": {"m1": m1, "m2": m2} if m1 else "exact-unrolled",
            },
            collectives=true["coll"],
            collectives_hlo_loop_once={
                k: v for k, v in coll_once.items() if k != "counts"
            },
            roofline=roofline(cost, true["coll"], n_chips, cfg, shape),
        )
    return out


def iter_cells(args):
    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                yield arch, shape, mp


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--set", dest="overrides", action="append", default=[],
                    help="step overrides, e.g. --set remat=dots")
    args = ap.parse_args()

    overrides = {}
    for kv in args.overrides:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            pass
        overrides[k] = v

    RESULTS.mkdir(parents=True, exist_ok=True)
    failures = 0
    for arch, shape, mp in iter_cells(args):
        cell_id = f"{arch}__{shape}__{'multi' if mp else 'single'}"
        if args.tag:
            cell_id += f".{args.tag}"
        path = RESULTS / f"{cell_id}.json"
        if path.exists() and not args.force:
            print(f"[skip-cached] {cell_id}")
            continue
        print(f"[run] {cell_id} ...", flush=True)
        t0 = time.time()
        try:
            res = run_cell(arch, shape, mp, overrides or None, args.tag, args.save_hlo)
        except Exception as e:  # record failures: they are bugs in the system
            failures += 1
            res = {"cell": cell_id, "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()}
            print(f"[FAIL] {cell_id}: {e}")
        path.write_text(json.dumps(res, indent=2))
        status = "skipped" if "skipped" in res else ("FAILED" if "error" in res else "ok")
        if status == "ok":
            r = res["roofline"]
            print(
                f"[done {time.time()-t0:6.1f}s] {cell_id}: {status} "
                f"dominant={r['dominant']} fit={res['memory']['fits_hbm']} "
                f"useful={r['useful_flop_ratio']:.2f} roofline={r['roofline_fraction']:.2f}",
                flush=True,
            )
        else:
            print(f"[done {time.time()-t0:6.1f}s] {cell_id}: {status}", flush=True)
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
