"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh():
    """Degenerate 1x1 mesh over the local device (smoke/bench paths)."""
    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto, jax.sharding.AxisType.Auto),
    )


def data_shards(mesh) -> int:
    n = mesh.shape.get("data", 1)
    return n * mesh.shape.get("pod", 1)
