"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """jax.make_mesh with Auto axis types where the jax version has them
    (jax.sharding.AxisType arrived after 0.4.x; Auto is the default)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh over the local device (smoke/bench paths)."""
    return make_mesh_compat((1, 1), ("data", "model"))


def data_shards(mesh) -> int:
    n = mesh.shape.get("data", 1)
    return n * mesh.shape.get("pod", 1)
