"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --steps 50 \
      --ckpt-dir /tmp/ckpt [--reduced] [--resume] [--fail-at 20]

Uses the reduced config on CPU by default; the full configs are exercised
via the production-mesh dry-run (launch/dryrun.py).
"""
import argparse
import time

from repro.configs import get_config
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.ft.health import HealthMonitor
from repro.ft.manager import CheckpointManager
from repro.train.loop import LoopConfig, train_loop
from repro.train.steps import TrainStepConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--remat", default="dots")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full architecture (needs real accelerators)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.reduced()
    tcfg = TrainStepConfig(remat=args.remat, num_microbatches=args.microbatches)
    data = SyntheticLM(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                   global_batch=args.global_batch)
    )
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    mon = HealthMonitor(["host0"])

    def on_step(step, m):
        mon.heartbeat("host0", m["step_s"])
        if step % 10 == 0:
            print(f"step {step:5d}  loss {m['loss']:.4f}  {m['step_s']*1e3:.0f} ms")

    lcfg = LoopConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                      fail_at_step=args.fail_at)
    out = train_loop(cfg, tcfg, lcfg, data, mgr, on_step=on_step)
    print(f"done: {len(out['losses'])} steps, final loss {out['losses'][-1]:.4f}, "
          f"{out['wall_s']:.1f}s")


if __name__ == "__main__":
    main()
