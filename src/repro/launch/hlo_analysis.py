"""Parse post-SPMD HLO text for collective traffic.

``compiled.as_text()`` is the per-device module; shapes on collective ops are
per-device buffer shapes. We convert buffer sizes to *bytes moved per device*
with standard algorithm factors (ring all-reduce moves ~2x the buffer, etc.)
using the replica-group size when available.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_OP_RE = re.compile(
    r"=\s*((?:\([^=]*?\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+"
    r"(all-reduce-start|all-gather-start|collective-permute-start|"
    r"all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)\("
)
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [num_groups, group_size]
    return 2


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Bytes moved per device, by collective kind (+ 'total')."""
    out: Dict[str, float] = defaultdict(float)
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m is None:
            continue
        type_str, op = m.group(1), m.group(2)
        op = op.replace("-start", "")
        size = _shape_bytes(type_str)
        s = _group_size(line)
        if op == "all-reduce":
            moved = 2.0 * size * (s - 1) / s
        elif op == "all-gather":
            moved = size * (s - 1) / s  # output is the gathered buffer
        elif op == "reduce-scatter":
            moved = size * (s - 1)  # output is the scattered shard
        elif op == "all-to-all":
            moved = size * (s - 1) / s
        else:  # collective-permute
            moved = float(size)
        out[op] += moved
        counts[op] += 1
    out["total"] = sum(v for k, v in out.items() if k != "total")
    result = dict(out)
    result["counts"] = dict(counts)  # type: ignore[assignment]
    return result


def op_histogram(hlo_text: str, top: int = 20) -> Dict[str, int]:
    """Crude opcode histogram of the optimized module (debug aid)."""
    hist: Dict[str, int] = defaultdict(int)
    for m in re.finditer(r"=\s*[a-z0-9\[\],{}()\s]*?([a-z][a-z0-9-]*)\(", hlo_text):
        hist[m.group(1)] += 1
    return dict(sorted(hist.items(), key=lambda kv: -kv[1])[:top])
