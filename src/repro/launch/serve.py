"""End-to-end serving driver: publish a function and serve batched requests
with cold restores (the Spice serving loop).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
      --requests 8 --mode spice [--keep-warm]
"""
import argparse
import tempfile
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import ServerlessNode


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--mode", default="spice",
                    choices=["spice", "spice_sync", "criu_star", "reap_star",
                             "faasnap_star"])
    ap.add_argument("--keep-warm", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    node = ServerlessNode()
    with tempfile.TemporaryDirectory() as d:
        node.publish("fn", cfg, params, d,
                     warm_ttl_s=300.0 if args.keep_warm else 0.0)
        prompt = np.tile(np.arange(1, args.prompt_len + 1, dtype=np.int32),
                         (args.batch, 1))
        # compile-cache warmup
        node.invoke("fn", prompt, 2, mode="spice_sync", cfg=cfg)
        node.evict()

        print(f"{'req':>4} {'path':>6} {'ttft_ms':>9} {'total_ms':>9}")
        for i in range(args.requests):
            if not args.keep_warm:
                node.evict()
            r = node.invoke("fn", prompt, args.max_new, mode=args.mode, cfg=cfg)
            print(f"{i:>4} {('warm' if not r.cold else args.mode):>6} "
                  f"{r.ttft_s*1e3:9.2f} {r.total_s*1e3:9.2f}")
        print("pool:", node.pool.stats)


if __name__ == "__main__":
    main()
