"""End-to-end serving driver: publish a function and serve batched requests
with cold restores (the Spice serving loop).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
      --requests 8 --mode spice [--keep-warm | --prewarm]

Warmth modes:
  (none)       every request is a cold start (no keep-alive)
  --keep-warm  reactive: static 300 s keep-alive TTL (the pre-policy knob)
  --prewarm    predictive: adaptive per-function TTLs from the arrival
               histogram (PrewarmPolicy) + speculative restores ahead of
               the predicted next arrival (PrewarmEngine)
"""
import argparse
import tempfile
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import (
    ArrivalTracker,
    FixedTTLPolicy,
    PrewarmEngine,
    PrewarmPolicy,
    ServerlessNode,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--mode", default="spice",
                    choices=["spice", "spice_sync", "criu_star", "reap_star",
                             "faasnap_star"])
    ap.add_argument("--interval", type=float, default=0.0,
                    help="seconds between requests (gives --prewarm a "
                         "periodic arrival pattern to learn)")
    warmth = ap.add_mutually_exclusive_group()
    warmth.add_argument("--keep-warm", action="store_true",
                        help="reactive keep-alive: static 300 s TTL")
    warmth.add_argument("--prewarm", action="store_true",
                        help="predictive: adaptive TTLs + speculative "
                             "restores from the arrival histogram")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    if args.prewarm:
        tracker = ArrivalTracker()
        node = ServerlessNode(
            keepalive=PrewarmPolicy(
                tracker, default_ttl_s=0.0, max_ttl_s=300.0,
                min_observations=2,
            ),
            prewarm=PrewarmEngine(
                tracker, horizon_s=max(0.3, args.interval),
                interval_s=0.05, min_observations=2,
            ),
            reap_interval_s=0.25,
        )
    elif args.keep_warm:
        node = ServerlessNode(keepalive=FixedTTLPolicy(300.0))
    else:
        node = ServerlessNode()  # spec TTL 0: every request restores
    with tempfile.TemporaryDirectory() as d:
        node.publish("fn", cfg, params, d)
        prompt = np.tile(np.arange(1, args.prompt_len + 1, dtype=np.int32),
                         (args.batch, 1))
        # compile-cache warmup
        node.invoke("fn", prompt, 2, mode="spice_sync", cfg=cfg)
        node.evict()

        print(f"{'req':>4} {'path':>6} {'ttft_ms':>9} {'total_ms':>9}")
        for i in range(args.requests):
            if not (args.keep_warm or args.prewarm):
                node.evict()
            r = node.invoke("fn", prompt, args.max_new, mode=args.mode, cfg=cfg)
            path = "warm" if not r.cold else ("join" if r.joined else args.mode)
            print(f"{i:>4} {path:>6} "
                  f"{r.ttft_s*1e3:9.2f} {r.total_s*1e3:9.2f}")
            if args.interval:
                time.sleep(args.interval)
        print("pool:", node.pool.stats)
        if args.prewarm:
            eng = node.router.prewarm
            eng.drain(5.0)
            print("prewarm:", {k: v for k, v in eng.stats.items() if v})
        node.close()


if __name__ == "__main__":
    main()
