"""starcoder2-7b — GQA, RoPE [arXiv:2402.19173; hf]."""
from repro.configs.base import ModelConfig, dense_pattern

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    source="arXiv:2402.19173; hf",
    **dense_pattern(32),
)
