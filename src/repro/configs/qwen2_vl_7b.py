"""qwen2-vl-7b — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

The vision frontend is a stub per the assignment: ``input_specs()`` provides
precomputed patch embeddings that are overlaid on the sequence front.
"""
from repro.configs.base import ModelConfig, dense_pattern

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    mrope=True,
    frontend="vision",
    frontend_tokens=256,
    rope_theta=1_000_000.0,
    source="arXiv:2409.12191; hf",
    **dense_pattern(28),
)
