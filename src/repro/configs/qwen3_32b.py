"""qwen3-32b — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]."""
from repro.configs.base import ModelConfig, dense_pattern

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
    head_dim=128,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-8B; hf",
    **dense_pattern(64),
)
