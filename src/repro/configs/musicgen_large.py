"""musicgen-large — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

The EnCodec frontend is a stub per the assignment: ``input_specs()`` provides
precomputed frame embeddings in place of token embeddings.
"""
from repro.configs.base import ModelConfig, dense_pattern

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    frontend="audio",
    source="arXiv:2306.05284; hf",
    **dense_pattern(48),
)
