"""jamba-v0.1-52b — Mamba+attn 1:7 interleave, MoE 16e top-2 [arXiv:2403.19887; hf].

Jamba block: 8 layers, attention at in-block index 4 (1:7 attn:mamba),
MoE FFN on every other layer (odd indices) -> 16 MoE layers over 32.
"""
from repro.configs.base import LayerSpec, ModelConfig

_BLOCK = tuple(
    LayerSpec(kind="attn" if i == 4 else "mamba", moe=(i % 2 == 1))
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,  # per-expert FFN width
    vocab_size=65536,
    pattern=_BLOCK,
    pattern_reps=4,
    n_experts=16,
    top_k=2,
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
    long_context_ok=True,  # hybrid: only 4/32 layers carry a full KV cache
    source="arXiv:2403.19887; hf",
)
