"""Architecture registry: ``--arch <id>`` resolves through ``get_config``."""
from repro.configs.base import SHAPES, InputShape, LayerSpec, ModelConfig

from repro.configs.olmoe_1b_7b import CONFIG as _olmoe
from repro.configs.phi35_moe_42b import CONFIG as _phi35
from repro.configs.jamba_v01_52b import CONFIG as _jamba
from repro.configs.qwen2_vl_7b import CONFIG as _qwen2vl
from repro.configs.gemma3_27b import CONFIG as _gemma3
from repro.configs.qwen3_32b import CONFIG as _qwen3
from repro.configs.starcoder2_7b import CONFIG as _starcoder2
from repro.configs.qwen15_05b import CONFIG as _qwen15
from repro.configs.musicgen_large import CONFIG as _musicgen
from repro.configs.mamba2_780m import CONFIG as _mamba2

ARCHS = {
    c.name: c
    for c in [
        _olmoe,
        _phi35,
        _jamba,
        _qwen2vl,
        _gemma3,
        _qwen3,
        _starcoder2,
        _qwen15,
        _musicgen,
        _mamba2,
    ]
}

# Convenience aliases (ids as listed in the assignment).
ALIASES = {
    "olmoe-1b-7b": "olmoe-1b-7b",
    "phi3.5-moe-42b-a6.6b": "phi3.5-moe-42b-a6.6b",
    "phi3.5-moe": "phi3.5-moe-42b-a6.6b",
    "jamba-v0.1-52b": "jamba-v0.1-52b",
    "jamba": "jamba-v0.1-52b",
    "qwen2-vl-7b": "qwen2-vl-7b",
    "gemma3-27b": "gemma3-27b",
    "qwen3-32b": "qwen3-32b",
    "starcoder2-7b": "starcoder2-7b",
    "qwen1.5-0.5b": "qwen1.5-0.5b",
    "musicgen-large": "musicgen-large",
    "mamba2-780m": "mamba2-780m",
}


def get_config(arch: str) -> ModelConfig:
    key = ALIASES.get(arch, arch)
    if key not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(ARCHS)}")
    return ARCHS[key]


def get_shape(name: str) -> InputShape:
    return SHAPES[name]


__all__ = [
    "ARCHS",
    "SHAPES",
    "ModelConfig",
    "InputShape",
    "LayerSpec",
    "get_config",
    "get_shape",
]
