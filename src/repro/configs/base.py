"""Config dataclasses for architectures and input shapes.

Every assigned architecture is expressed as a ``ModelConfig`` whose layer
stack is a repeating ``pattern`` of ``LayerSpec``s (scanned) plus an optional
unrolled ``remainder``.  This keeps the lowered HLO size O(len(pattern))
instead of O(n_layers), which is what makes 256/512-device SPMD dry-run
compiles tractable.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class LayerSpec:
    """One layer position inside a pattern block."""

    kind: str = "attn"  # "attn" | "mamba"
    window: Optional[int] = None  # sliding-window size; None = global attention
    moe: bool = False  # MoE FFN instead of dense FFN
    ffn: bool = True  # mamba layers in some hybrids have no FFN


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # moe | hybrid | vlm | dense | audio | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    # layer pattern (repeated) + remainder (unrolled/stacked separately)
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    pattern_reps: int = 1
    remainder: Tuple[LayerSpec, ...] = ()
    head_dim: Optional[int] = None  # defaults to d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- attention details ---
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    mrope: bool = False  # multimodal rotary (3 sections: t/h/w)
    # --- mamba2 / SSD ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_groups: int = 1
    conv_kernel: int = 4
    # shard-aligned split of the fused zxbcdt projection + per-stream convs:
    # slicing a model-sharded fused dim at non-shard boundaries makes GSPMD
    # emit collective-permute realignments every layer (§Perf, mamba2 cell)
    mamba_split_proj: bool = False
    # --- modality frontend (stub: precomputed embeddings) ---
    frontend: Optional[str] = None  # None | "vision" | "audio"
    frontend_tokens: int = 256  # patches/frames overlaid at sequence front
    # --- misc ---
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    long_context_ok: bool = False  # eligible for the long_500k cell
    source: str = ""  # provenance tag from the assignment

    def __post_init__(self):
        n_pattern = len(self.pattern) * self.pattern_reps + len(self.remainder)
        if n_pattern != self.n_layers:
            raise ValueError(
                f"{self.name}: pattern covers {n_pattern} layers, "
                f"config says {self.n_layers}"
            )

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attn_layers(self) -> int:
        per = sum(1 for s in self.pattern if s.kind == "attn") * self.pattern_reps
        return per + sum(1 for s in self.remainder if s.kind == "attn")

    def param_count(self) -> int:
        """Analytic parameter count (for 6·N·D roofline terms)."""
        n = self.vocab_size * self.d_model  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model  # unembed
        specs = list(self.pattern) * self.pattern_reps + list(self.remainder)
        for s in specs:
            n += self._layer_params(s)
        n += self.d_model  # final norm
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k experts active)."""
        n = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        specs = list(self.pattern) * self.pattern_reps + list(self.remainder)
        for s in specs:
            n += self._layer_params(s, active_only=True)
        n += self.d_model
        return n

    def _layer_params(self, s: LayerSpec, active_only: bool = False) -> int:
        d, f = self.d_model, self.d_ff
        n = 0
        if s.kind == "attn":
            q = self.n_heads * self.hd
            kv = self.n_kv_heads * self.hd
            n += d * (q + 2 * kv) + q * d  # qkv + out
            if self.qkv_bias:
                n += q + 2 * kv
            n += 2 * d  # pre norms
        elif s.kind == "mamba":
            di, N, H, G = self.d_inner, self.ssm_state, self.ssm_heads, self.ssm_groups
            zx = 2 * di + 2 * G * N + H
            n += d * zx  # in_proj
            n += (di + 2 * G * N) * self.conv_kernel  # conv
            n += 3 * H  # A_log, D, dt_bias
            n += di * d  # out_proj
            n += d + di  # pre norm + gated norm
        if s.ffn:
            e = max(self.n_experts, 1) if s.moe else 1
            per_expert = 3 * d * f  # gated MLP
            if s.moe:
                n += d * self.n_experts  # router
                k = self.top_k if active_only else e
                n += k * per_expert
            else:
                n += per_expert
            n += d  # ffn pre-norm
        return n

    def reduced(self) -> "ModelConfig":
        """A tiny config of the same family for CPU smoke tests."""
        scale_pat = tuple(
            dataclasses.replace(s, window=min(s.window, 8) if s.window else None)
            for s in self.pattern
        )
        scale_rem = tuple(
            dataclasses.replace(s, window=min(s.window, 8) if s.window else None)
            for s in self.remainder
        )
        reps = min(self.pattern_reps, 2)
        n_layers = len(self.pattern) * reps + len(self.remainder)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=n_layers,
            pattern=scale_pat,
            remainder=scale_rem,
            pattern_reps=reps,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=8,
            frontend_tokens=4 if self.frontend else 256,
        )


@dataclass(frozen=True)
class InputShape:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": InputShape("train_4k", "train", 4_096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32_768, 128),
    "long_500k": InputShape("long_500k", "decode", 524_288, 1),
}


def dense_pattern(n_layers: int, window: Optional[int] = None) -> dict:
    return dict(pattern=(LayerSpec(kind="attn", window=window),), pattern_reps=n_layers)


def moe_pattern(n_layers: int) -> dict:
    return dict(pattern=(LayerSpec(kind="attn", moe=True),), pattern_reps=n_layers)
