"""olmoe-1b-7b — MoE 64e top-8 [arXiv:2409.02060; hf]."""
from repro.configs.base import ModelConfig, moe_pattern

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,  # per-expert FFN width
    vocab_size=50304,
    n_experts=64,
    top_k=8,
    source="arXiv:2409.02060; hf",
    **moe_pattern(16),
)
