"""gemma3-27b — 5:1 local:global attention, 128k context [hf:google/gemma-3-1b-pt; unverified].

62 layers = 10 x (5 local + 1 global) + 2 local remainder; local window 1024.
"""
from repro.configs.base import LayerSpec, ModelConfig

_LOCAL = LayerSpec(kind="attn", window=1024)
_GLOBAL = LayerSpec(kind="attn", window=None)

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    d_ff=21504,
    vocab_size=262144,
    pattern=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
    pattern_reps=10,
    remainder=(_LOCAL, _LOCAL),
    qk_norm=True,
    tie_embeddings=True,
    long_context_ok=True,  # 52/62 layers have a 1k-window KV cache
    rope_theta=1_000_000.0,
    source="hf:google/gemma-3-1b-pt; unverified",
)
