"""mamba2-780m — SSD (state-space duality), attention-free [arXiv:2405.21060; unverified]."""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,  # attention-free
    n_kv_heads=0,
    d_ff=0,  # no FFN: mamba2 blocks only
    vocab_size=50280,
    pattern=(LayerSpec(kind="mamba", ffn=False),),
    pattern_reps=48,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    long_context_ok=True,  # O(1) recurrent state
    source="arXiv:2405.21060; unverified",
)
