"""Elastic scaling: rebuild the mesh from the live device set and re-shard
the training state.

JIF checkpoints record *logical* axes, not device placements, so a restore
can materialize the same state under ANY mesh: scale-down after failures
and scale-up after recovery are both "restore under the new rules" — the
serverless cold-start machinery doing cluster-management work.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.sharding.partition import axis_rules, shardings_from_specs


@dataclasses.dataclass
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]


def plan_mesh(n_devices: int, model_parallel: int = 16, pods: int = 1) -> MeshPlan:
    """Largest (pod, data, model) grid that fits the live device count,
    holding TP fixed (weights layouts survive) and shrinking DP."""
    mp = model_parallel
    while mp > 1 and n_devices % mp:
        mp //= 2
    data = max(n_devices // (mp * pods), 1)
    if pods > 1:
        return MeshPlan((pods, data, mp), ("pod", "data", "model"))
    return MeshPlan((data, mp), ("data", "model"))


def make_mesh_from_plan(plan: MeshPlan, devices: Optional[List] = None):
    devices = devices if devices is not None else jax.devices()
    n = int(np.prod(plan.shape))
    dev = np.asarray(devices[:n]).reshape(plan.shape)
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:  # pre-AxisType jax: Auto is the only behaviour
        return jax.sharding.Mesh(dev, plan.axes)
    return jax.sharding.Mesh(
        dev, plan.axes,
        axis_types=(axis_type.Auto,) * len(plan.axes),
    )


def reshard_state(state_np, specs_tree, mesh, rules: Dict):
    """Place a host-resident (restored) state onto a new mesh."""
    with axis_rules(mesh, rules):
        sh = shardings_from_specs(specs_tree)

    def put(arr, s):
        if s is None:
            return jax.device_put(arr)
        return jax.device_put(arr, s)

    return jax.tree.map(put, state_np, sh)
