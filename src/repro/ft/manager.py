"""Checkpoint manager built on the JIF engine.

The paper's mechanism does double duty here: training checkpoints are JIF
snapshots written asynchronously with **incremental dedup** — each delta
checkpoint stores only chunks that changed vs the last *anchor* (full)
checkpoint, zero chunks elided, with atomic publish and keep-k GC.  Restore
is the same fast path the serving engine uses (restart-after-failure IS a
cold start — the paper's point).
"""
from __future__ import annotations

import json
import os
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import BaseImage, NodeImageCache, SpiceRestorer, snapshot
from repro.core.overlay import DEFAULT_PAGE


def _to_numpy(state):
    return jax.tree.map(lambda a: np.asarray(a), state)


class CheckpointManager:
    """``callbacks`` run after every completed save (on the save thread in
    async mode) with ``cb.on_checkpoint(manager, step, state_np, entry)``
    where ``entry`` is the just-appended :attr:`history` record — the hook
    the train→serve deployment pipeline publishes through
    (:class:`repro.ft.publish.DeltaPublishCallback`).  A callback exception
    fails the save exactly like a write error: captured and re-raised."""

    def __init__(
        self,
        directory: str,
        keep: int = 3,
        anchor_every: int = 4,  # every k-th checkpoint is a full anchor
        page_size: int = DEFAULT_PAGE,
        async_save: bool = True,
        callbacks: Sequence[Any] = (),
    ):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.anchor_every = anchor_every
        self.page_size = page_size
        self.async_save = async_save
        self.callbacks: List[Any] = list(callbacks)
        self.cache = NodeImageCache(capacity_bytes=32 << 30)
        self._anchor_name: Optional[str] = None
        self._n_saved = 0
        self._pending: Optional[threading.Thread] = None
        # a daemon-thread save that died must not die silently: the failure
        # is parked here and re-raised at the next wait()/save() on the
        # training thread, where the loop can actually react to it
        self._save_error: Optional[BaseException] = None
        self.history: List[Dict] = []

    # ----------------------------------------------------------------- save
    def save(self, step: int, state, blocking: bool = False) -> None:
        state_np = _to_numpy(state)  # device->host copy on the caller
        self.wait()  # one in-flight async save at a time; raises its error
        if self.async_save and not blocking:
            self._pending = threading.Thread(
                target=self._save_guarded, args=(step, state_np), daemon=True
            )
            self._pending.start()
        else:
            self._save_sync(step, state_np)

    def wait(self) -> None:
        """Join any in-flight async save and surface its failure: an
        exception raised on the save thread (snapshot write, GC, or a
        publish callback) re-raises HERE, on the caller's thread."""
        if self._pending is not None:
            self._pending.join()
            self._pending = None
        error, self._save_error = self._save_error, None
        if error is not None:
            raise error

    def _save_guarded(self, step: int, state_np) -> None:
        try:
            self._save_sync(step, state_np)
        except BaseException as exc:  # noqa: BLE001 — re-raised at wait()
            self._save_error = exc

    def _save_sync(self, step: int, state_np) -> None:
        t0 = time.perf_counter()
        anchor = self._n_saved % self.anchor_every == 0
        path = self.dir / f"ckpt_{step:08d}.jif"
        base = None if anchor else self.cache.get(self._anchor_name)
        stats = snapshot(
            state_np,
            str(path),  # jif writer publishes atomically (tmp+rename)
            base=base,
            page_size=self.page_size,
            meta={"step": step, "anchor": anchor},
        )
        if anchor:
            name = f"anchor:{path.name}"
            self.cache.put(BaseImage.from_state(name, state_np, self.page_size))
            self._anchor_name = name
        self._n_saved += 1
        self.history.append(
            {
                "step": step,
                "path": str(path),
                "anchor": anchor,
                "anchor_name": self._anchor_name,
                "bytes_written": stats.private_bytes,
                "total_bytes": stats.total_bytes,
                "save_s": time.perf_counter() - t0,
            }
        )
        (self.dir / "MANIFEST.json").write_text(json.dumps(self.history, indent=1))
        self._gc()
        entry = self.history[-1]
        for cb in self.callbacks:
            cb.on_checkpoint(self, step, state_np, entry)

    def _gc(self) -> None:
        """keep-k GC that never breaks a delta chain: a delta is only
        deletable together with everything older than its anchor."""
        if len(self.history) <= self.keep:
            return
        cut = len(self.history) - self.keep
        # move the cut back to the newest anchor at/before it so survivors
        # (anchor + its deltas) stay restorable
        while cut > 0 and not self.history[cut]["anchor"]:
            cut -= 1
        for h in self.history[:cut]:
            try:
                os.unlink(h["path"])
            except FileNotFoundError:
                pass
        self.history = self.history[cut:]

    # -------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        man = self.dir / "MANIFEST.json"
        if not man.exists():
            return None
        hist = json.loads(man.read_text())
        return hist[-1]["step"] if hist else None

    def restore(self, step: Optional[int] = None) -> Tuple[Any, int]:
        man = json.loads((self.dir / "MANIFEST.json").read_text())
        entry = man[-1] if step is None else next(h for h in man if h["step"] == step)
        # rebuild the anchor in the cache if this process just restarted
        if entry["anchor_name"] and self.cache.get(entry["anchor_name"]) is None:
            a = next(
                h for h in man if h["anchor"] and f"anchor:{Path(h['path']).name}" == entry["anchor_name"]
            )
            anchor_state, _, _, _ = SpiceRestorer().restore(a["path"])
            self.cache.put(
                BaseImage.from_state(entry["anchor_name"], anchor_state, self.page_size)
            )
        restorer = SpiceRestorer(node_cache=self.cache)
        state, meta, _, _ = restorer.restore(entry["path"])
        return state, int(meta["step"])
