"""Heartbeats + straggler detection.

At 1000+ nodes the control plane needs (a) liveness — miss N heartbeats ->
declare dead -> trigger elastic remesh + JIF restore on the survivors, and
(b) straggler mitigation — per-step duration outliers flag slow hosts so
the data pipeline can rebalance shards away from them.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque
from typing import Dict, List, Optional, Set


@dataclasses.dataclass
class HostHealth:
    last_beat: float
    step_times: deque


class HealthMonitor:
    def __init__(
        self,
        hosts: List[str],
        heartbeat_timeout_s: float = 30.0,
        straggler_factor: float = 1.5,
        window: int = 16,
        clock=time.monotonic,
    ):
        self._clock = clock
        self.timeout = heartbeat_timeout_s
        self.factor = straggler_factor
        self._h: Dict[str, HostHealth] = {
            h: HostHealth(self._clock(), deque(maxlen=window)) for h in hosts
        }

    def heartbeat(self, host: str, step_time_s: Optional[float] = None) -> None:
        hh = self._h[host]
        hh.last_beat = self._clock()
        if step_time_s is not None:
            hh.step_times.append(step_time_s)

    def dead_hosts(self) -> Set[str]:
        now = self._clock()
        return {h for h, hh in self._h.items() if now - hh.last_beat > self.timeout}

    def stragglers(self) -> Set[str]:
        meds = []
        per_host = {}
        for h, hh in self._h.items():
            if hh.step_times:
                t = sorted(hh.step_times)[len(hh.step_times) // 2]
                per_host[h] = t
                meds.append(t)
        if not meds:
            return set()
        global_med = sorted(meds)[len(meds) // 2]
        return {h for h, t in per_host.items() if t > self.factor * global_med}

    def remove(self, host: str) -> None:
        self._h.pop(host, None)

    def live_hosts(self) -> List[str]:
        dead = self.dead_hosts()
        return sorted(h for h in self._h if h not in dead)


def rebalance_shards(hosts: List[str], stragglers: Set[str], n_shards: int) -> Dict[str, List[int]]:
    """Weighted shard assignment: stragglers get half weight."""
    weights = {h: (0.5 if h in stragglers else 1.0) for h in hosts}
    total = sum(weights.values())
    out: Dict[str, List[int]] = {h: [] for h in hosts}
    acc = 0.0
    cursor = 0
    for h in hosts:
        share = int(round(n_shards * weights[h] / total))
        out[h] = list(range(cursor, min(cursor + share, n_shards)))
        cursor += len(out[h])
    # distribute remainder
    i = 0
    while cursor < n_shards:
        out[hosts[i % len(hosts)]].append(cursor)
        cursor += 1
        i += 1
    return out
